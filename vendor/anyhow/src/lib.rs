//! Minimal drop-in replacement for the `anyhow` crate covering exactly the
//! surface this workspace uses: [`Error`], [`Result`], and the `anyhow!`,
//! `bail!`, `ensure!` macros. The target container has no crates.io
//! access, so this shim is vendored as a path dependency; swapping in the
//! real crate is a one-line change in `rust/Cargo.toml`.
//!
//! Like the real crate, `Error` deliberately does *not* implement
//! `std::error::Error` — that keeps the blanket `From<E: std::error::Error>`
//! conversion (which powers `?`) coherent.

use std::fmt;

/// A string-backed error value. Carries the formatted message of whatever
/// produced it (the real crate also carries a cause chain and backtrace;
/// nothing here inspects those).
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (with inline captures) or
/// from any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/for/this/test")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_build_messages() {
        let who = "world";
        let e = anyhow!("hello {who}");
        assert_eq!(e.to_string(), "hello world");
        let e = anyhow!("{} + {}", 1, 2);
        assert_eq!(e.to_string(), "1 + 2");

        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big: {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).is_err());
        assert!(f(101).is_err());
    }
}
