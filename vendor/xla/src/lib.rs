//! API-compatible stub of the slice of `xla-rs` that `quip::runtime::pjrt`
//! uses. The container this repo builds in has no XLA/PJRT shared
//! libraries, so the real bindings cannot link; this stub keeps every call
//! site compiling and type-checking while failing *at runtime* with a
//! clear message the moment a PJRT client is actually requested.
//!
//! Swapping in the real backend is a one-line change in `rust/Cargo.toml`
//! (point the `xla` dependency at an xla-rs checkout); no call site
//! changes are needed — that is the point of keeping the stub's API
//! byte-for-byte identical to the slice used.
//!
//! Everything that merely *marshals host data* ([`Literal`] creation)
//! succeeds, so artifact-independent code paths (and tests) can hold
//! literals without touching a device.

use std::fmt;

const UNAVAILABLE: &str =
    "XLA/PJRT runtime not available in this build (vendored stub; see vendor/xla)";

/// Error type mirroring `xla::Error`. Implements `std::error::Error` so
/// `?` converts it into `anyhow::Error` at call sites.
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(UNAVAILABLE.to_string())
}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes used by the artifact inputs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U8,
}

/// Host-side literal. The stub records shape/dtype so marshalling code
/// works; device transfer and readback fail.
pub struct Literal {
    pub ty: ElementType,
    pub dims: Vec<usize>,
    bytes: usize,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        Ok(Literal {
            ty,
            dims: dims.to_vec(),
            bytes: data.len(),
        })
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    /// Size of the backing host buffer in bytes.
    pub fn size_bytes(&self) -> usize {
        self.bytes
    }
}

/// Parsed HLO module handle.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// A computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// The PJRT client. `cpu()` is the stub's hard failure point: nothing
/// downstream of a client can be reached without one.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_marshal_but_devices_fail() {
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 3], &[0u8; 24])
                .unwrap();
        assert_eq!(lit.dims, vec![2, 3]);
        assert_eq!(lit.size_bytes(), 24);
        assert!(lit.to_vec::<f32>().is_err());
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
