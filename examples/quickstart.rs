//! Quickstart: quantize one linear layer with every registered rounder ×
//! processing combination and watch incoherence processing rescue 2-bit
//! rounding.
//!
//!     cargo run --release --example quickstart
//!
//! No artifacts needed — weights and Hessian are synthetic. Rounders are
//! resolved by name through the `RounderRegistry`; add your own rounder
//! to a registry and this driver picks it up unchanged.

use quip::linalg::Mat;
use quip::quant::{quantize_layer_with, Processing, QuantConfig, RounderRegistry};
use quip::util::rng::Rng;
use quip::util::testkit::random_hessian;

fn main() {
    let mut rng = Rng::new(7);
    let (m, n) = (64, 128);

    // A weight matrix with outliers — the regime where plain rounding dies.
    let mut w = Mat::from_fn(m, n, |_, _| rng.uniform(-0.05, 0.05));
    for _ in 0..24 {
        let (i, j) = (rng.below(m), rng.below(n));
        w[(i, j)] = rng.uniform(-1.5, 1.5);
    }
    // A low-rank proxy Hessian, like real calibration Hessians (Fig 1).
    let h = random_hessian(&mut rng, n, n / 8, 1e-3);

    println!("quantizing a {m}x{n} layer, proxy loss tr((Ŵ-W)H(Ŵ-W)ᵀ):\n");
    println!(
        "{:<10} {:>6} {:>16} {:>16} {:>8}",
        "method", "bits", "baseline", "incoherence", "gain"
    );
    let registry = RounderRegistry::global();
    for name in ["near", "ldlq", "ldlq-rg", "greedy"] {
        let rounder = registry.resolve(name).expect("builtin rounder");
        for bits in [2u32, 3, 4] {
            let run = |processing: Processing| {
                let cfg = QuantConfig::builder()
                    .bits(bits)
                    .rounder(name)
                    .processing(processing)
                    .greedy_passes(5)
                    .build()
                    .expect("builtin rounder name");
                quantize_layer_with(rounder.as_ref(), &w, &h, &cfg, 42).proxy_loss
            };
            let base = run(Processing::baseline());
            let incp = run(Processing::incoherent());
            println!(
                "{:<10} {:>6} {:>16.5} {:>16.5} {:>7.1}x",
                rounder.name(),
                bits,
                base,
                incp,
                base / incp
            );
        }
    }

    println!("\nThe 2-bit rows are the paper's headline: LDLQ+IncP (QuIP) keeps the");
    println!("proxy loss orders of magnitude below baseline nearest rounding.");
}
