//! Quickstart: quantize one linear layer with every method × processing
//! combination and watch incoherence processing rescue 2-bit rounding.
//!
//!     cargo run --release --example quickstart
//!
//! No artifacts needed — weights and Hessian are synthetic.

use quip::linalg::Mat;
use quip::quant::{quantize_layer, Method, Processing, QuantConfig};
use quip::util::rng::Rng;
use quip::util::testkit::random_hessian;

fn main() {
    let mut rng = Rng::new(7);
    let (m, n) = (64, 128);

    // A weight matrix with outliers — the regime where plain rounding dies.
    let mut w = Mat::from_fn(m, n, |_, _| rng.uniform(-0.05, 0.05));
    for _ in 0..24 {
        let (i, j) = (rng.below(m), rng.below(n));
        w[(i, j)] = rng.uniform(-1.5, 1.5);
    }
    // A low-rank proxy Hessian, like real calibration Hessians (Fig 1).
    let h = random_hessian(&mut rng, n, n / 8, 1e-3);

    println!("quantizing a {m}x{n} layer, proxy loss tr((Ŵ-W)H(Ŵ-W)ᵀ):\n");
    println!(
        "{:<10} {:>6} {:>16} {:>16} {:>8}",
        "method", "bits", "baseline", "incoherence", "gain"
    );
    for method in [Method::Nearest, Method::Ldlq, Method::LdlqRg, Method::Greedy] {
        for bits in [2u32, 3, 4] {
            let run = |processing: Processing| {
                quantize_layer(
                    &w,
                    &h,
                    &QuantConfig {
                        bits,
                        method,
                        processing,
                        greedy_passes: 5,
                        ..Default::default()
                    },
                    42,
                )
                .proxy_loss
            };
            let base = run(Processing::baseline());
            let incp = run(Processing::incoherent());
            println!(
                "{:<10} {:>6} {:>16.5} {:>16.5} {:>7.1}x",
                method.name(),
                bits,
                base,
                incp,
                base / incp
            );
        }
    }

    println!("\nThe 2-bit rows are the paper's headline: LDLQ+IncP (QuIP) keeps the");
    println!("proxy loss orders of magnitude below baseline nearest rounding.");
}
