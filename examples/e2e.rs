//! End-to-end driver — proves all layers compose on a real workload:
//!
//!  1. verify the build-time training run (loss curve from train_log)
//!  2. quantize the model with the full coordinator pipeline (QuIP 2-bit
//!     and the OPTQ baseline)
//!  3. evaluate perplexity + zero-shot tasks for fp32 / OPTQ / QuIP
//!  4. execute the AOT JAX/Pallas artifact through PJRT and cross-check
//!     its logits against the native engine
//!  5. serve the quantized model over TCP under concurrent load and
//!     report latency/throughput
//!
//!     make artifacts && cargo run --release --example e2e -- [--model s1]
//!
//! The run is recorded in EXPERIMENTS.md.

use quip::coordinator::server::{Client, EngineKind, Server, ServerConfig};
use quip::engine::PjrtLm;
use quip::harness::env::{Env, SPLITS};
use quip::model::Transformer;
use quip::quant::{Processing, QuantConfig};
use quip::runtime::PjrtRuntime;
use quip::util::cli::Args;
use quip::util::json::Json;
use std::sync::Arc;

fn main() -> quip::Result<()> {
    let args = Args::from_env();
    let env = Env::load(&args)?;
    let model = args.opt_or("model", "s1");
    let bits = args.opt_usize("bits", 2) as u32;
    let mut record = Json::obj();

    // ---- 1. the training record --------------------------------------
    println!("=== 1. build-time training record ===");
    let log_path = env
        .registry
        .root
        .join("models")
        .join(format!("{model}_train_log.json"));
    let log = Json::parse(&std::fs::read_to_string(&log_path)?)?;
    let curve = log.get("curve").and_then(|c| c.as_arr()).unwrap_or(&[]);
    let first = curve.first().and_then(|p| p.req_f64("loss").ok()).unwrap_or(0.0);
    let last = curve.last().and_then(|p| p.req_f64("loss").ok()).unwrap_or(0.0);
    println!(
        "{model}: {} steps, train loss {first:.3} → {last:.3}, val ppl {:.2}",
        log.req_f64("steps")? as usize,
        log.req_f64("final_val_ppl")?
    );
    anyhow::ensure!(last < first, "training did not reduce the loss?");
    record.set("train_log", log.clone());

    // ---- 2+3. quantize + evaluate ------------------------------------
    println!("\n=== 2/3. quantize ({bits}-bit) + evaluate ===");
    let ck = env.checkpoint(&model)?;
    let fp_model = Transformer::from_checkpoint(&ck)?;
    let fp = env.evaluate(&fp_model);
    println!("fp32   : wiki {:.2}  ptb {:.2}  c4 {:.2}", fp.ppl["wiki"], fp.ppl["ptb"], fp.ppl["c4"]);
    record.set("fp32", fp.to_json());

    let mut quip_qm = None;
    for (label, processing) in [
        ("optq", Processing::baseline()),
        ("quip", Processing::incoherent()),
    ] {
        let t0 = std::time::Instant::now();
        let (qm, proxy) = env.quantize(
            &model,
            QuantConfig::builder()
                .bits(bits)
                .rounder("ldlq")
                .processing(processing)
                .build()?,
        )?;
        let mut m = Transformer::from_checkpoint(&ck)?;
        qm.apply_to(&mut m)?;
        let r = env.evaluate(&m);
        println!(
            "{label:<6} : wiki {:.2}  ptb {:.2}  c4 {:.2}  (quantized in {:.1}s, proxy {proxy:.3}, {:.2} bpw)",
            r.ppl["wiki"], r.ppl["ptb"], r.ppl["c4"],
            t0.elapsed().as_secs_f64(),
            qm.bits_per_weight()
        );
        record.set(label, r.to_json());
        if label == "quip" {
            quip_qm = Some(qm);
        }
    }
    let qm = quip_qm.unwrap();

    // ---- 4. PJRT artifact cross-check --------------------------------
    println!("\n=== 4. AOT artifact through PJRT (Pallas kernel inside) ===");
    match (
        env.registry.find_fp32(&model, 1),
        env.registry.find_quant(&model, bits),
    ) {
        (Some(fspec), Some(qspec)) => {
            let rt = PjrtRuntime::cpu()?;
            let lm_fp = PjrtLm::fp32(&rt, fspec, &ck)?;
            let lm_q = PjrtLm::quant(&rt, qspec, &ck, &qm)?;
            let seq = env.splits["wiki"].tokens[..fspec.seq].to_vec();

            let t0 = std::time::Instant::now();
            let pj_fp = lm_fp.logits(&[seq.clone()])?;
            let t_fp = t0.elapsed().as_secs_f64();
            let t1 = std::time::Instant::now();
            let pj_q = lm_q.logits(&[seq.clone()])?;
            let t_q = t1.elapsed().as_secs_f64();

            // Cross-check vs the native Rust forward.
            let native_fp = fp_model.forward(&seq, None);
            let mut mq = Transformer::from_checkpoint(&ck)?;
            qm.apply_to(&mut mq)?;
            let native_q = mq.forward(&seq, None);
            let max_d = |a: &[f32], b: &[f32]| {
                a.iter()
                    .zip(b)
                    .fold(0.0f64, |m, (x, y)| m.max((*x as f64 - *y as f64).abs()))
            };
            let d_fp = max_d(&native_fp, &pj_fp);
            let d_q = max_d(&native_q, &pj_q);
            println!("fp32 : PJRT {t_fp:.2}s, max|Δlogit| vs native = {d_fp:.4}");
            println!("quant: PJRT {t_q:.2}s, max|Δlogit| vs native = {d_q:.4}");
            anyhow::ensure!(d_fp < 0.05, "fp32 parity failed");
            anyhow::ensure!(d_q < 0.2, "quant parity failed");
            let mut pj = Json::obj();
            pj.set("fp_max_delta", Json::Num(d_fp));
            pj.set("quant_max_delta", Json::Num(d_q));
            record.set("pjrt", pj);
        }
        _ => println!("(skipping — no AOT artifacts for {model} @ {bits} bits)"),
    }

    // ---- 5. serve under load ------------------------------------------
    println!("\n=== 5. serving the quantized model ===");
    let m = Arc::new(Transformer::from_checkpoint(&ck)?);
    let mut server = Server::start(
        m,
        EngineKind::auto(Some(qm)),
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
    )?;
    let addr = server.addr;
    let clients = 6usize;
    let reqs = 5usize;
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || -> quip::Result<usize> {
                let mut cl = Client::connect(&addr)?;
                let mut toks = 0;
                for r in 0..reqs {
                    let prompt: Vec<u32> =
                        (0..5).map(|i| ((c * 13 + r * 5 + i) % 250 + 3) as u32).collect();
                    toks += cl.request(&prompt, 16)?.0.len();
                }
                Ok(toks)
            })
        })
        .collect();
    let mut tokens = 0;
    for h in handles {
        tokens += h.join().unwrap()?;
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{} requests / {tokens} tokens in {wall:.2}s → {:.1} tok/s; {}",
        clients * reqs,
        tokens as f64 / wall,
        server.metrics.summary()
    );
    let mut serve = Json::obj();
    serve.set("tokens_per_s", Json::Num(tokens as f64 / wall));
    serve.set("metrics", server.metrics.summary());
    record.set("serving", serve);
    server.shutdown();

    quip::util::fsx::atomic_write(
        std::path::Path::new("results/e2e.json"),
        record.pretty().as_bytes(),
    )?;
    println!("\nall stages green → results/e2e.json");
    let _ = SPLITS; // (quiet unused import on --fast paths)
    Ok(())
}
