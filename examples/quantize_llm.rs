//! Quantize a trained LM end to end with the coordinator pipeline and
//! evaluate perplexity + zero-shot accuracy before/after.
//!
//!     make artifacts          # trains the model series once
//!     cargo run --release --example quantize_llm -- [--model s1] [--bits 2]

use quip::harness::env::{Env, SPLITS, TASKS};
use quip::model::Transformer;
use quip::quant::{Processing, QuantConfig};
use quip::util::cli::Args;

fn main() -> quip::Result<()> {
    let args = Args::from_env();
    let env = Env::load(&args)?;
    let model = args.opt_or("model", "s1");
    let bits = args.opt_usize("bits", 2) as u32;
    let ck = env.checkpoint(&model)?;
    println!(
        "model {model}: {:.1}M params — quantizing to {bits} bits\n",
        ck.config.param_count() as f64 / 1e6
    );

    // fp32 reference
    let fp_model = Transformer::from_checkpoint(&ck)?;
    let fp = env.evaluate(&fp_model);

    let mut rows = vec![("fp32".to_string(), fp)];
    for (label, processing) in [
        ("optq(baseline)", Processing::baseline()),
        ("quip(incp)", Processing::incoherent()),
    ] {
        let t0 = std::time::Instant::now();
        let (qm, proxy) = env.quantize(
            &model,
            QuantConfig::builder()
                .bits(bits)
                .rounder("ldlq")
                .processing(processing)
                .build()?,
        )?;
        println!(
            "{label}: quantized in {:.1}s, proxy {proxy:.4}, {:.2} bits/weight",
            t0.elapsed().as_secs_f64(),
            qm.bits_per_weight()
        );
        let mut m = Transformer::from_checkpoint(&ck)?;
        qm.apply_to(&mut m)?;
        rows.push((label.to_string(), env.evaluate(&m)));
        // Persist the artifact for `quip serve --qz ...`.
        let out = format!("results/{model}_q{bits}_{}.qz", qm.recipe);
        std::fs::create_dir_all("results").ok();
        qm.save(std::path::Path::new(&out))?;
        println!("saved {out}");
    }

    println!("\n{:<16} {:>8} {:>8} {:>8} {:>7} {:>7} {:>7} {:>7}",
             "engine", "wiki↓", "ptb↓", "c4↓", "lamb↑", "arce↑", "piqa↑", "sc↑");
    for (label, r) in &rows {
        print!("{label:<16}");
        for s in SPLITS {
            print!(" {:>8.2}", r.ppl[s]);
        }
        for t in TASKS {
            print!(" {:>6.1}%", 100.0 * r.acc[t]);
        }
        println!();
    }
    println!("\nexpected shape (paper Fig 5/Table 1): at {bits} bits, quip ≈ fp while");
    println!("baseline degrades (catastrophically at 2 bits).");
    Ok(())
}
