//! Figure 4 standalone demo: the finite-grid counterexample where clamped
//! LDLQ with nearest rounding is *worse* than plain nearest rounding —
//! the motivation for Algorithm 5 (§5.2) — and Algorithm 5 fixing it.
//!
//!     cargo run --release --example counterexample

use quip::harness::figures::make_counterexample;
use quip::quant::alg5;
use quip::quant::ldlq::{ldlq, ldlq_with_feedback, round_matrix};
use quip::quant::proxy_loss;
use quip::quant::RoundMode;

fn main() {
    println!("finite-grid counterexample (paper Supplement C.3), 4-bit grid [0,15]:\n");
    println!(
        "{:>6} {:>14} {:>14} {:>14} {:>10}",
        "n", "ldlq(clamp)", "near", "alg5", "ldlq/near"
    );
    for n in [16usize, 32, 64, 128] {
        let (w, h) = make_counterexample(n, 16, 0.01);
        let l = ldlq(&w, &h, 4, RoundMode::Nearest, 0);
        let nr = round_matrix(&w, 4, RoundMode::Nearest, 0);
        // Algorithm 5: constrained feedback + stochastic rounding.
        let plan = alg5::solve(&h, 0.1, 300, 1e-10);
        let a5 = ldlq_with_feedback(&w, &plan.u_dot, 4, RoundMode::Stochastic, 0);
        let (pl, pn, pa) = (
            proxy_loss(&l, &w, &h),
            proxy_loss(&nr, &w, &h),
            proxy_loss(&a5, &w, &h),
        );
        println!(
            "{:>6} {:>14.2} {:>14.2} {:>14.2} {:>9.1}x",
            n,
            pl,
            pn,
            pa,
            pl / pn
        );
    }
    println!("\nclamping makes LDLQ's error-feedback explode on this adversarial (W, H);");
    println!("Algorithm 5's norm-capped feedback stays bounded (Theorem 7).");
}
