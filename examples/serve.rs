//! Serving demo: quantize a model, start the TCP server with the native
//! quantized engine, fire concurrent clients, report latency/throughput.
//!
//!     cargo run --release --example serve -- [--model s0] [--bits 2] [--clients 8]

use quip::coordinator::server::{Client, EngineKind, Server, ServerConfig};
use quip::harness::env::Env;
use quip::model::Transformer;
use quip::quant::{Processing, QuantConfig};
use quip::util::cli::Args;
use std::sync::Arc;

fn main() -> quip::Result<()> {
    let args = Args::from_env();
    let env = Env::load(&args)?;
    let model = args.opt_or("model", "s0");
    let bits = args.opt_usize("bits", 2) as u32;
    let clients = args.opt_usize("clients", 8);
    let reqs_per_client = args.opt_usize("requests", 8);
    let max_tokens = args.opt_usize("max-tokens", 24);

    let ck = env.checkpoint(&model)?;
    println!("quantizing {model} to {bits} bits (QuIP)…");
    let (qm, _) = env.quantize(
        &model,
        QuantConfig::builder()
            .bits(bits)
            .rounder("quip")
            .processing(Processing::incoherent())
            .build()?,
    )?;
    let m = Arc::new(Transformer::from_checkpoint(&ck)?);
    let mut server = Server::start(
        m,
        EngineKind::auto(Some(qm)),
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
    )?;
    println!("server up on {} — {clients} clients × {reqs_per_client} requests\n", server.addr);

    let addr = server.addr;
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || -> quip::Result<(usize, f64)> {
                let mut client = Client::connect(&addr)?;
                let mut tokens = 0usize;
                let mut lat = 0.0;
                for r in 0..reqs_per_client {
                    let prompt: Vec<u32> =
                        (0..6).map(|i| ((c * 31 + r * 7 + i) % 250 + 3) as u32).collect();
                    let (out, latency) = client.request(&prompt, max_tokens)?;
                    tokens += out.len();
                    lat += latency;
                }
                Ok((tokens, lat / reqs_per_client as f64))
            })
        })
        .collect();
    let mut total_tokens = 0usize;
    for h in handles {
        let (tokens, _) = h.join().unwrap()?;
        total_tokens += tokens;
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("total        : {} requests, {total_tokens} tokens in {wall:.2}s",
             clients * reqs_per_client);
    println!("throughput   : {:.1} tokens/s, {:.1} requests/s",
             total_tokens as f64 / wall,
             (clients * reqs_per_client) as f64 / wall);
    println!("server view  : {}", server.metrics.summary());
    server.shutdown();
    Ok(())
}
