"""Trainer plumbing: QCKP write/read round-trip and the function-preserving
channel-imbalance injection."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile import train as T

CFG = dict(d_model=32, n_layers=2, n_heads=4, d_ff=128, vocab=64, max_seq=32)


def test_ckpt_roundtrip(tmp_path):
    params = {k: np.asarray(v) for k, v in
              M.init_params(CFG, jax.random.PRNGKey(1)).items()}
    path = str(tmp_path / "t.ckpt")
    T.write_ckpt(path, "t", CFG, params)
    cfg2, back = T.read_ckpt(path)
    assert cfg2["d_model"] == 32 and cfg2["name"] == "t"
    assert set(back) == set(params)
    for k in params:
        np.testing.assert_array_equal(back[k], params[k].astype(np.float32))


def test_channel_imbalance_preserves_function():
    params = M.init_params(CFG, jax.random.PRNGKey(2))
    np_params = {k: np.asarray(v) for k, v in params.items()}
    out = T.inject_channel_imbalance(np_params, CFG, sigma=1.2, seed=3)
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, 64, size=(2, 16)), jnp.int32)
    a = M.forward(params, tokens, CFG)
    b = M.forward({k: jnp.asarray(v) for k, v in out.items()}, tokens, CFG)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)


def test_channel_imbalance_creates_outlier_columns():
    params = {k: np.asarray(v) for k, v in
              M.init_params(CFG, jax.random.PRNGKey(4)).items()}
    out = T.inject_channel_imbalance(params, CFG, sigma=1.2, seed=5)
    w = out["blk0.attn.wq"]
    col_norms = np.linalg.norm(w, axis=0)
    spread = col_norms.max() / np.median(col_norms)
    # LogNormal(0, 1.2) over 32 channels: max/median ≈ e^{2.2σ} ≫ Gaussian's ~1.3
    assert spread > 3.0, f"column-norm spread only {spread:.1f}"
    # untouched layers stay untouched
    np.testing.assert_array_equal(out["blk0.attn.wo"], params["blk0.attn.wo"])


def test_adam_reduces_loss():
    params = M.init_params(CFG, jax.random.PRNGKey(6))
    opt = T.adam_init(params)
    tokens = jnp.asarray(
        np.random.default_rng(1).integers(0, 64, size=(4, 17)), jnp.int32)
    loss0 = float(M.loss_fn(params, tokens, CFG))
    for _ in range(20):
        loss, grads = jax.value_and_grad(M.loss_fn)(params, tokens, CFG)
        params, opt = T.adam_step(params, grads, opt, 1e-2)
    assert float(loss) < loss0 * 0.9
