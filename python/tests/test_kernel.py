"""L1 correctness: the Pallas dequant-matmul kernel vs the pure-jnp oracle,
with hypothesis sweeping shapes/bit-widths, plus kron-transform inverses.
This is the CORE kernel correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quip_matmul as K
from compile.kernels import ref as R


def random_case(rng, m, n, t, bits):
    codes = rng.integers(0, 1 << bits, size=(m, n), dtype=np.uint8)
    x = rng.standard_normal((t, n)).astype(np.float32)
    return codes, x


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("m,n,t", [(128, 64, 4), (256, 96, 8), (128, 16, 1)])
def test_packed_kernel_matches_ref(bits, m, n, t):
    rng = np.random.default_rng(bits * 100 + m)
    codes, x = random_case(rng, m, n, t, bits)
    words = R.pack_codes(codes, bits)
    got = K.dequant_matmul_packed(jnp.asarray(words), bits, n, jnp.asarray(x))
    want = x @ codes.astype(np.float32).T
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,n,t", [(128, 48, 4), (384, 64, 2)])
def test_u8_kernel_matches_ref(m, n, t):
    rng = np.random.default_rng(7)
    codes, x = random_case(rng, m, n, t, 3)
    got = K.dequant_matmul_u8(jnp.asarray(codes), jnp.asarray(x))
    want = x @ codes.astype(np.float32).T
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-4)


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(1)
    for bits in (2, 4):
        codes = rng.integers(0, 1 << bits, size=(8, 50), dtype=np.uint8)
        words = R.pack_codes(codes, bits)
        back = np.asarray(R.unpack_codes_ref(jnp.asarray(words), bits, 50))
        np.testing.assert_array_equal(back, codes.astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from([2, 4]),
    mt=st.integers(1, 4),      # m = 128*mt (kernel tile multiple)
    n=st.integers(1, 96),
    t=st.integers(1, 8),
)
def test_hypothesis_packed_sweep(bits, mt, n, t):
    m = 128 * mt
    rng = np.random.default_rng(bits * 1000 + m + n + t)
    codes, x = random_case(rng, m, n, t, bits)
    words = R.pack_codes(codes, bits)
    got = K.dequant_matmul_packed(jnp.asarray(words), bits, n, jnp.asarray(x))
    want = x @ codes.astype(np.float32).T
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([12, 16, 24, 36, 64]), seed=st.integers(0, 2**31))
def test_kron_apply_inverse(n, seed):
    rng = np.random.default_rng(seed)
    from compile.model import balanced_factor
    p, q = balanced_factor(n)
    # random orthogonal factors via QR
    ql, _ = np.linalg.qr(rng.standard_normal((p, p)))
    qr_, _ = np.linalg.qr(rng.standard_normal((q, q)))
    perm = rng.permutation(n).astype(np.int32)
    v = rng.standard_normal((3, n)).astype(np.float32)
    y = R.kron_apply_ref(jnp.asarray(ql, jnp.float32), jnp.asarray(qr_, jnp.float32),
                         jnp.asarray(perm), jnp.asarray(v))
    back = R.kron_apply_t_ref(jnp.asarray(ql, jnp.float32), jnp.asarray(qr_, jnp.float32),
                              jnp.asarray(perm), y)
    np.testing.assert_allclose(np.asarray(back), v, rtol=1e-4, atol=1e-4)


def test_kron_apply_matches_dense():
    rng = np.random.default_rng(3)
    p, q = 3, 4
    n = p * q
    ql, _ = np.linalg.qr(rng.standard_normal((p, p)))
    qr_, _ = np.linalg.qr(rng.standard_normal((q, q)))
    perm = rng.permutation(n).astype(np.int32)
    pmat = np.zeros((n, n), np.float64)
    for i, pi in enumerate(perm):
        pmat[i, pi] = 1.0
    dense = np.kron(ql, qr_) @ pmat
    v = rng.standard_normal((n,)).astype(np.float32)
    got = R.kron_apply_ref(jnp.asarray(ql, jnp.float32), jnp.asarray(qr_, jnp.float32),
                           jnp.asarray(perm), jnp.asarray(v))
    np.testing.assert_allclose(np.asarray(got), dense @ v, rtol=1e-4, atol=1e-4)


def test_vmem_estimate_reasonable():
    # 2-bit, m=512, n=512, T=16 at BM=128 must fit comfortably in 16 MiB.
    b = K.vmem_bytes(512, 512, 16, 2)
    assert b < 16 * 1024 * 1024
    assert b > 0
