"""Tests for tools/preflight — the toolchain-independent static analyzer.

Each check has at least one firing fixture tree (bad_*) and the shared
`clean` tree that passes every check; the torture file pins the lexer's
handling of raw strings, lifetimes-vs-chars, and comments. The analyzer
is exercised both in-process (fast fixture matrix) and through the CLI
shim (exit codes, --json) exactly as CI invokes it.
"""

import json
import os
import subprocess
import sys

import pytest

REPO_ROOT = os.path.dirname(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
)
TOOLS = os.path.join(REPO_ROOT, "tools")
FIXTURES = os.path.join(TOOLS, "preflight", "fixtures")
SHIM = os.path.join(TOOLS, "preflight.py")

sys.path.insert(0, TOOLS)

from preflight.checks import ALL_CHECKS, by_name  # noqa: E402
from preflight.context import Context  # noqa: E402
from preflight.lexer import lex  # noqa: E402


def run_checks(root):
    """All findings for a fixture tree, in-process."""
    ctx = Context(root)
    findings = []
    for check in ALL_CHECKS:
        findings.extend(check.run(ctx))
    return findings


def fixture(name):
    return os.path.join(FIXTURES, name)


# --- fixture matrix: every check fires on its bad tree -----------------

BAD_TREES = {
    # tree -> (check name, expected finding count, substring of a message)
    "bad_delimiters": ("delimiters", 1, "mismatched delimiter"),
    "bad_modgraph": ("modgraph", 2, "orphan file"),
    "bad_items": ("use-resolution", 2, "unresolved import `a::Nope`"),
    "bad_traits": ("trait-impl", 3, "missing required method `round`"),
    "bad_structlit": ("struct-lit", 1, "has no field `betta`"),
    "bad_fmtargs": ("format-args", 1, "2 positional argument(s) but 1"),
    "bad_determinism": ("determinism", 2, "iterates a hash collection"),
    "bad_panicpolicy": ("panic-policy", 2, "serving-layer non-test code"),
    "bad_clippydrift": ("clippy-drift", 1, "clippy::unused_self"),
    "bad_metricnames": ("metric-names", 2, "metric name"),
    "bad_atomicwrites": ("atomic-writes", 2, "torn file"),
}


@pytest.mark.parametrize("tree", sorted(BAD_TREES))
def test_bad_fixture_fires_only_its_check(tree):
    check_name, count, needle = BAD_TREES[tree]
    findings = run_checks(fixture(tree))
    assert findings, f"{tree}: expected findings, got none"
    names = {f.check for f in findings}
    assert names == {check_name}, f"{tree}: unexpected checks fired: {names}"
    assert len(findings) == count
    assert any(needle in f.message for f in findings), [
        f.message for f in findings
    ]


def test_metricnames_flags_both_invalid_and_duplicate():
    """The two findings are distinct failure modes: a non-snake_case name
    and a re-registration of an already-seen name (even via a different
    metric kind)."""
    findings = run_checks(fixture("bad_metricnames"))
    msgs = [f.message for f in findings]
    assert any("not snake_case" in m for m in msgs), msgs
    assert any("already registered" in m for m in msgs), msgs


def test_atomicwrites_exempts_annotated_and_test_writes():
    """Only the two bare production writes fire; the allow()-annotated
    call and the write inside #[cfg(test)] are deliberate exemptions."""
    findings = run_checks(fixture("bad_atomicwrites"))
    assert sorted(f.line for f in findings) == [9, 13], [
        f.render() for f in findings
    ]


def test_every_check_has_a_firing_fixture():
    covered = {BAD_TREES[t][0] for t in BAD_TREES}
    assert covered == set(by_name().keys())


def test_clean_fixture_passes_every_check():
    findings = run_checks(fixture("clean"))
    assert findings == [], [f.render() for f in findings]


def test_annotations_suppress_inside_clean_tree():
    """The clean tree contains a hash-map reduction and an expect() that
    are only clean because of their allow() annotations — deleting the
    annotations must make both checks fire."""
    import shutil
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        shutil.copytree(fixture("clean"), tmp, dirs_exist_ok=True)
        for rel in ("rust/src/quant/mod.rs", "rust/src/coordinator/mod.rs"):
            path = os.path.join(tmp, rel)
            with open(path) as fh:
                text = fh.read()
            text = "\n".join(
                ln for ln in text.splitlines() if "preflight: allow" not in ln
            )
            with open(path, "w") as fh:
                fh.write(text)
        names = {f.check for f in run_checks(tmp)}
        assert "determinism" in names
        assert "panic-policy" in names


# --- aux-tree sweep: rust/tests and rust/benches are analyzed too ------


def _clean_copy(tmp):
    import shutil

    shutil.copytree(fixture("clean"), tmp, dirs_exist_ok=True)


def _write(root, rel, text):
    path = os.path.join(root, rel)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        fh.write(text)


def test_aux_crates_cover_tests_and_benches_trees():
    """Top-level files under rust/tests and rust/benches load as aux
    crates (Family-A sweep), and well-formed ones add zero findings."""
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        _clean_copy(tmp)
        _write(
            tmp,
            "rust/tests/smoke.rs",
            "use fixture::sum;\n\nfn check() -> f64 {\n    sum(&[1.0, 2.0])\n}\n",
        )
        _write(
            tmp,
            "rust/benches/perf.rs",
            "use fixture::sum;\n\nfn main() {\n    let _ = sum(&[3.0]);\n}\n",
        )
        ctx = Context(tmp)
        names = {c.name for c in ctx.aux_crates}
        assert {"smoke", "perf"} <= names, names
        swept = {rel for _, rel, _ in ctx.lexed_files()}
        assert "rust/tests/smoke.rs" in swept
        assert "rust/benches/perf.rs" in swept
        assert run_checks(tmp) == [], [f.render() for f in run_checks(tmp)]


def test_orphan_under_tests_tree_fires_modgraph():
    """A support module under rust/tests/ that no test root declares is
    an orphan — the widened glob catches it like an orphan under src."""
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        _clean_copy(tmp)
        _write(tmp, "rust/tests/smoke.rs", "use fixture::sum;\n\nfn f() -> f64 {\n    sum(&[])\n}\n")
        _write(tmp, "rust/tests/helpers/unused.rs", "pub fn lonely() {}\n")
        findings = run_checks(tmp)
        assert any(
            f.check == "modgraph" and f.path == "rust/tests/helpers/unused.rs"
            for f in findings
        ), [f.render() for f in findings]


def test_unresolved_import_in_tests_tree_fires_use_resolution():
    """A stale `use` in an integration test (the seed-test failure mode)
    is caught without a toolchain, same as in src."""
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        _clean_copy(tmp)
        _write(
            tmp,
            "rust/tests/stale.rs",
            "use fixture::no_such_module::Thing;\n\nfn f() -> Thing {\n    unimplemented!()\n}\n",
        )
        findings = run_checks(tmp)
        assert any(
            f.check == "use-resolution" and f.path == "rust/tests/stale.rs"
            for f in findings
        ), [f.render() for f in findings]


# --- lexer torture ------------------------------------------------------


def torture_lexed():
    path = os.path.join(FIXTURES, "torture.rs")
    with open(path, encoding="utf-8") as fh:
        return lex(fh.read(), path)


def test_torture_has_no_lex_errors():
    assert torture_lexed().errors == []


def test_torture_delimiters_balance():
    toks = torture_lexed().tokens
    opens = sum(1 for t in toks if t.kind == "punct" and t.value in "([{")
    closes = sum(1 for t in toks if t.kind == "punct" and t.value in ")]}")
    assert opens == closes


def test_torture_comments_swallow_raw_strings():
    # the r#"…"# inside a line comment must not become a string token
    strs = [t.value for t in torture_lexed().tokens if t.kind == "str"]
    assert not any("inside a line comment" in s for s in strs)
    # while real raw strings survive intact, fences and all
    assert any(s.startswith('r##"') and s.endswith('"##') for s in strs)


def test_torture_char_vs_lifetime():
    toks = torture_lexed().tokens
    chars = {t.value for t in toks if t.kind == "char"}
    lifetimes = {t.value for t in toks if t.kind == "lifetime"}
    assert "'a'" in chars  # quoted: char literal
    assert "'a" in lifetimes  # unquoted: lifetime
    assert r"'\u{1F600}'" in chars
    assert r"'\''" in chars
    assert "b'x'" in chars


def test_torture_allow_annotation_collected():
    lexed = torture_lexed()
    allows = [a for lst in lexed.allows.values() for a in lst]
    assert ("panic", "torture annotation collected from comments") in allows


# --- CLI shim (what CI runs) -------------------------------------------


def run_cli(*argv):
    return subprocess.run(
        [sys.executable, SHIM, *argv],
        capture_output=True,
        text=True,
        cwd=REPO_ROOT,
    )


def test_cli_clean_tree_exits_zero():
    proc = run_cli("--root", fixture("clean"))
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_bad_tree_exits_one_with_json():
    proc = run_cli("--root", fixture("bad_structlit"), "--json")
    assert proc.returncode == 1
    findings = json.loads(proc.stdout)
    assert findings and findings[0]["check"] == "struct-lit"
    assert findings[0]["path"] == "rust/src/lib.rs"


def test_cli_only_filters_checks():
    # bad_structlit is clean under every check except struct-lit
    proc = run_cli("--root", fixture("bad_structlit"), "--only", "delimiters")
    assert proc.returncode == 0


def test_cli_unknown_check_is_usage_error():
    proc = run_cli("--only", "no-such-check")
    assert proc.returncode == 2


def test_repo_tree_is_preflight_clean():
    """The real tree must stay at zero findings — the same gate CI runs."""
    proc = run_cli("--json")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert json.loads(proc.stdout) == []
