"""L2 correctness: JAX model shapes/causality, quantized-forward vs an
equivalent dense dequantized forward, and loss sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref as R

CFG = dict(d_model=32, n_layers=2, n_heads=4, d_ff=128, vocab=64, max_seq=32)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_forward_shapes(params):
    tokens = jnp.array([[1, 2, 3, 4, 5]], jnp.int32)
    logits = M.forward(params, tokens, CFG)
    assert logits.shape == (1, 5, 64)
    assert np.isfinite(np.asarray(logits)).all()


def test_causality(params):
    a = jnp.array([[1, 2, 3, 4]], jnp.int32)
    b = jnp.array([[1, 2, 3, 60]], jnp.int32)
    la = np.asarray(M.forward(params, a, CFG))
    lb = np.asarray(M.forward(params, b, CFG))
    np.testing.assert_allclose(la[0, :3], lb[0, :3], rtol=1e-5, atol=1e-5)
    assert not np.allclose(la[0, 3], lb[0, 3])


def test_loss_decreases_on_tiny_overfit(params):
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, 64, size=(4, 16)), jnp.int32)

    loss0 = M.loss_fn(params, tokens, CFG)

    @jax.jit
    def step(p):
        l, g = jax.value_and_grad(M.loss_fn)(p, tokens, CFG)
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g), l

    p = params
    for _ in range(15):
        p, l = step(p)
    assert float(l) < float(loss0) * 0.9


def _quantize_dense(w, bits, rng):
    """Trivial per-row min-max quantization (numpy) for parity testing."""
    lo = w.min(axis=1, keepdims=True)
    hi = w.max(axis=1, keepdims=True)
    q = (1 << bits) - 1
    codes = np.clip(np.round((w - lo) / (hi - lo) * q), 0, q).astype(np.uint8)
    rowscale = ((hi - lo) / q)[:, 0].astype(np.float32)
    rowoff = lo[:, 0].astype(np.float32)
    deq = codes.astype(np.float32) * rowscale[:, None] + rowoff[:, None]
    return codes, rowscale, rowoff, deq


@pytest.mark.parametrize("bits", [2, 4])
def test_quant_forward_matches_dense_dequant(params, bits):
    """quant_forward(baseline processing) must equal forward() run on the
    dequantized weights — the kernel+affine path is exact."""
    rng = np.random.default_rng(5)
    qlayers = {}
    dense_params = dict(params)
    for name in M.linear_names(CFG):
        w = np.asarray(params[name])
        codes, rowscale, rowoff, deq = _quantize_dense(w, bits, rng)
        words = R.pack_codes(codes, bits)
        qlayers[name] = {
            "words": jnp.asarray(words),
            "rowscale": jnp.asarray(rowscale),
            "rowoff": jnp.asarray(rowoff),
        }
        dense_params[name] = jnp.asarray(deq)
    tokens = jnp.array([[1, 5, 9, 13, 2]], jnp.int32)
    got = M.quant_forward(params, qlayers, tokens, CFG, incoherent=False,
                          bits=bits)
    want = M.forward(dense_params, tokens, CFG)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_param_names_cover_all_params(params):
    assert set(M.param_names(CFG)) == set(params.keys())
    for name in M.param_names(CFG):
        assert tuple(params[name].shape) == tuple(M.param_shape(CFG, name)), name


def test_balanced_factor_matches_rust_cases():
    assert M.balanced_factor(64) == (8, 8)
    assert M.balanced_factor(12) == (3, 4)
    assert M.balanced_factor(7) == (1, 7)
    assert M.balanced_factor(768) == (24, 32)
    assert M.balanced_factor(1024) == (32, 32)
