"""Build-time trainer: trains the model series (s0..s3 stand-ins for the
paper's OPT size series) on the synthlang corpus for a few hundred steps
each, logs the loss curves, and writes `QCKP` checkpoints the Rust side
loads. Runs once under `make artifacts`; never at request time.

Adam is implemented inline (no optax in the offline image).
"""

import argparse
import json
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M

# (name, steps, batch) — steps scale down as models grow to keep
# `make artifacts` within a CPU-minutes budget; loss curves are logged so
# EXPERIMENTS.md records exactly what each checkpoint saw.
SCHEDULE = [
    ("s0", 500, 24),
    ("s1", 450, 16),
    ("s2", 350, 12),
    ("s3", 220, 8),
]
SEQ = 128
LR = 3e-3
WARMUP = 40


def read_qtok(path):
    with open(path, "rb") as f:
        magic, version, vocab, n = struct.unpack("<IIIQ", f.read(20))
        assert magic == 0x4B4F5451 and version == 1
        data = np.frombuffer(f.read(n * 2), dtype="<u2").astype(np.int32)
    return vocab, data


def write_ckpt(path, cfg_name, cfg, params):
    """QCKP: magic, version, config json, n_tensors, tensors (sorted)."""
    cfg_json = json.dumps({
        "name": cfg_name, "d_model": cfg["d_model"], "n_layers": cfg["n_layers"],
        "n_heads": cfg["n_heads"], "d_ff": cfg["d_ff"], "vocab": cfg["vocab"],
        "max_seq": cfg["max_seq"],
    }, separators=(",", ":"))
    out = bytearray()
    out += struct.pack("<II", 0x504B4351, 1)
    b = cfg_json.encode()
    out += struct.pack("<I", len(b)) + b
    names = sorted(params.keys())
    out += struct.pack("<I", len(names))
    for name in names:
        arr = np.asarray(params[name], dtype=np.float32)
        nb = name.encode()
        out += struct.pack("<I", len(nb)) + nb
        out += struct.pack("<I", arr.ndim)
        for d in arr.shape:
            out += struct.pack("<Q", d)
        out += arr.astype("<f4").tobytes()
    with open(path, "wb") as f:
        f.write(bytes(out))


def inject_channel_imbalance(params, cfg, sigma=1.2, seed=77):
    """Function-preserving outlier-channel injection.

    Large trained LLMs exhibit per-channel outliers (the phenomenon
    SmoothQuant/LLM.int8 document and the *reason* QuIP's incoherence
    processing exists). Our briefly-trained tiny models keep near-Gaussian
    — already incoherent — weights, which hides the paper's 2-bit
    baseline collapse. This transform recreates the structure exactly,
    without changing the function: for each LayerNorm feeding linear
    layers, pick c ~ LogNormal(0, σ) per channel and rewrite

        g ← g·c,  b ← b·c,  W ← W·diag(1/c)   for every consumer W

    (wq/wk/wv share ln1's c; w1 uses ln2's). The model computes the same
    outputs; the *weights* now have the realistic coherent outlier
    columns. Documented in DESIGN.md §2.
    """
    rng = np.random.default_rng(seed)
    out = dict(params)
    for b in range(cfg["n_layers"]):
        for ln, consumers in [("ln1", ["attn.wq", "attn.wk", "attn.wv"]),
                              ("ln2", ["mlp.w1"])]:
            c = np.exp(rng.normal(0.0, sigma, size=cfg["d_model"])).astype(np.float32)
            out[f"blk{b}.{ln}.g"] = np.asarray(out[f"blk{b}.{ln}.g"]) * c
            out[f"blk{b}.{ln}.b"] = np.asarray(out[f"blk{b}.{ln}.b"]) * c
            for w in consumers:
                out[f"blk{b}.{w}"] = np.asarray(out[f"blk{b}.{w}"]) / c[None, :]
    return out


def read_ckpt(path):
    """Read a QCKP checkpoint back (transform-only mode + tests)."""
    with open(path, "rb") as f:
        raw = f.read()
    off = 0
    magic, version = struct.unpack_from("<II", raw, off); off += 8
    assert magic == 0x504B4351 and version == 1
    (ln,) = struct.unpack_from("<I", raw, off); off += 4
    cfg = json.loads(raw[off:off + ln].decode()); off += ln
    (nt,) = struct.unpack_from("<I", raw, off); off += 4
    params = {}
    for _ in range(nt):
        (sl,) = struct.unpack_from("<I", raw, off); off += 4
        name = raw[off:off + sl].decode(); off += sl
        (nd,) = struct.unpack_from("<I", raw, off); off += 4
        dims = struct.unpack_from(f"<{nd}Q", raw, off); off += 8 * nd
        cnt = int(np.prod(dims)) if nd else 1
        arr = np.frombuffer(raw, dtype="<f4", count=cnt, offset=off).reshape(dims)
        off += cnt * 4
        params[name] = arr.copy()
    return cfg, params


def adam_init(params):
    z = lambda: jax.tree.map(jnp.zeros_like, params)
    return {"m": z(), "v": z(), "t": jnp.zeros((), jnp.int32)}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    mhat_scale = 1.0 / (1 - b1 ** tf)
    vhat_scale = 1.0 / (1 - b2 ** tf)
    new = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v)
    return new, {"m": m, "v": v, "t": t}


def batches(tokens, batch, seq, rng):
    max_start = len(tokens) - seq - 1
    while True:
        starts = rng.integers(0, max_start, size=batch)
        yield np.stack([tokens[s:s + seq + 1] for s in starts])


def train_one(name, steps, batch, train_toks, val_toks, out_dir):
    cfg = M.CONFIGS[name]
    key = jax.random.PRNGKey(hash(name) & 0x7FFFFFFF)
    params = M.init_params(cfg, key)
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, toks, lr):
        loss, grads = jax.value_and_grad(M.loss_fn)(params, toks, cfg)
        params, opt = adam_step(params, grads, opt, lr)
        return params, opt, loss

    @jax.jit
    def eval_fn(params, toks):
        return M.loss_fn(params, toks, cfg)

    rng = np.random.default_rng(42)
    gen = batches(train_toks, batch, SEQ, rng)
    log = []
    t0 = time.time()
    for step in range(steps):
        lr = LR * min(1.0, (step + 1) / WARMUP) * (1.0 - 0.7 * step / steps)
        toks = jnp.asarray(next(gen))
        params, opt, loss = step_fn(params, opt, toks, lr)
        if step % 20 == 0 or step == steps - 1:
            log.append({"step": step, "loss": float(loss)})
            print(f"[{name}] step {step:4d} loss {float(loss):.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)

    # Validation loss on held-out windows.
    vrng = np.random.default_rng(7)
    vgen = batches(val_toks, 16, SEQ, vrng)
    vloss = float(np.mean([float(eval_fn(params, jnp.asarray(next(vgen))))
                           for _ in range(4)]))
    print(f"[{name}] val loss {vloss:.4f} ppl {np.exp(vloss):.2f}")

    # Outlier-channel injection (function-preserving; see docstring).
    np_params = inject_channel_imbalance(
        {k: np.asarray(v) for k, v in params.items()}, cfg)
    vloss2 = float(eval_fn({k: jnp.asarray(v) for k, v in np_params.items()},
                           jnp.asarray(next(vgen))))
    print(f"[{name}] val loss after channel-imbalance injection {vloss2:.4f} "
          f"(must match ≈{vloss:.4f})")
    assert abs(vloss2 - vloss) < 0.15, "imbalance injection changed the model!"

    models_dir = os.path.join(out_dir, "models")
    os.makedirs(models_dir, exist_ok=True)
    write_ckpt(os.path.join(models_dir, f"{name}.ckpt"), name, cfg, np_params)
    with open(os.path.join(models_dir, f"{name}_train_log.json"), "w") as f:
        json.dump({"name": name, "steps": steps, "batch": batch,
                   "seq": SEQ, "final_val_loss": vloss,
                   "final_val_ppl": float(np.exp(vloss)), "curve": log}, f,
                  indent=1)
    return vloss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="")   # comma list; default = all
    ap.add_argument("--steps-scale", type=float, default=1.0)
    ap.add_argument("--transform-only", action="store_true",
                    help="re-apply channel-imbalance injection to existing "
                         "checkpoints without retraining")
    args = ap.parse_args()

    if args.transform_only:
        models_dir = os.path.join(args.out, "models")
        for name, _, _ in SCHEDULE:
            path = os.path.join(models_dir, f"{name}.ckpt")
            if not os.path.exists(path):
                continue
            cfg_d, params = read_ckpt(path)
            cfg = M.CONFIGS[cfg_d["name"]]
            params = inject_channel_imbalance(params, cfg)
            write_ckpt(path, cfg_d["name"], cfg, params)
            print(f"transformed {name}.ckpt")
        return

    _, train_toks = read_qtok(os.path.join(args.out, "data", "train.bin"))
    _, val_toks = read_qtok(os.path.join(args.out, "data", "wiki.bin"))

    wanted = set(args.models.split(",")) if args.models else None
    for name, steps, batch in SCHEDULE:
        if wanted and name not in wanted:
            continue
        steps = max(20, int(steps * args.steps_scale))
        train_one(name, steps, batch, train_toks, val_toks, args.out)


if __name__ == "__main__":
    main()
