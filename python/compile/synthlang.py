"""synthlang — the build-time synthetic corpus + zero-shot task generator.

Stands in for the paper's C4/WikiText2/PTB + LAMBADA/ARC-E/PiQA/StoryCloze
(see DESIGN.md §2). A seeded probabilistic grammar over a 256-token vocab
with:

  * subject–verb number agreement (gives graded grammaticality for the
    multiple-choice tasks),
  * deterministic idiom pairs  a_i -> b_i  (gives exact cloze answers),
  * three eval splits with different mixture parameters (wiki/ptb/c4
    analogs: same grammar, different sentence-length/idiom/adjective rates).

Outputs (under artifacts/data/):
  vocab.json, train.bin, wiki.bin, ptb.bin, c4.bin   (QTOK binary)
  tasks_lamb.json  (cloze)        tasks_arce.json (4-way choice)
  tasks_piqa.json  (2-way choice) tasks_sc.json   (2-way idiom choice)

Everything is consumed by the Rust side (`quip::data`); Python never runs
at request time.
"""

import argparse
import json
import os
import random
import struct

PAD, BOS, EOS = 0, 1, 2

N_NOUN = 24          # singular/plural pairs
N_VERB = 18          # singular/plural pairs
N_ADJ = 16
N_ADV = 8
N_PREP = 6
N_NAME = 12
N_IDIOM = 16         # a_i -> b_i pairs
VOCAB = 256


def build_vocab():
    toks = ["<pad>", "<bos>", "<eos>"]
    det_sg = ["the", "a"]
    det_pl = ["these", "some"]
    toks += det_sg + det_pl
    noun_sg = [f"noun{i}" for i in range(N_NOUN)]
    noun_pl = [f"noun{i}s" for i in range(N_NOUN)]
    verb_sg = [f"verb{i}s" for i in range(N_VERB)]
    verb_pl = [f"verb{i}" for i in range(N_VERB)]
    adjs = [f"adj{i}" for i in range(N_ADJ)]
    advs = [f"adv{i}" for i in range(N_ADV)]
    preps = [f"prep{i}" for i in range(N_PREP)]
    names = [f"name{i}" for i in range(N_NAME)]
    idiom_a = [f"ida{i}" for i in range(N_IDIOM)]
    idiom_b = [f"idb{i}" for i in range(N_IDIOM)]
    toks += noun_sg + noun_pl + verb_sg + verb_pl + adjs + advs
    toks += preps + names + idiom_a + idiom_b + ["."]
    topics = [f"topic{i}" for i in range(VOCAB - len(toks))]
    toks += topics
    assert len(toks) == VOCAB, len(toks)
    ids = {t: i for i, t in enumerate(toks)}

    def rng_ids(words):
        return [ids[w] for w in words]

    groups = {
        "det_sg": rng_ids(det_sg),
        "det_pl": rng_ids(det_pl),
        "noun_sg": rng_ids(noun_sg),
        "noun_pl": rng_ids(noun_pl),
        "verb_sg": rng_ids(verb_sg),
        "verb_pl": rng_ids(verb_pl),
        "adj": rng_ids(adjs),
        "adv": rng_ids(advs),
        "prep": rng_ids(preps),
        "name": rng_ids(names),
        "idiom_a": rng_ids(idiom_a),
        "idiom_b": rng_ids(idiom_b),
        "period": ids["."],
        "topic": rng_ids(topics),
    }
    return toks, groups


class Grammar:
    """Seeded sentence sampler with tunable mixture parameters."""

    def __init__(self, groups, seed, p_adj=0.35, p_obj=0.6, p_pp=0.3,
                 p_adv=0.25, p_idiom=0.15, topic_lo=0.0, topic_hi=1.0):
        self.g = groups
        self.r = random.Random(seed)
        self.p_adj = p_adj
        self.p_obj = p_obj
        self.p_pp = p_pp
        self.p_adv = p_adv
        self.p_idiom = p_idiom
        # Each split draws topics from a sub-range (domain shift analog).
        t = groups["topic"]
        lo = int(topic_lo * len(t))
        hi = max(lo + 4, int(topic_hi * len(t)))
        self.topics = t[lo:hi]

    def np_(self, plural=None):
        """Noun phrase; returns (tokens, is_plural)."""
        r = self.r
        if plural is None:
            plural = r.random() < 0.5
        if r.random() < 0.2:
            return [r.choice(self.g["name"])], False
        det = r.choice(self.g["det_pl" if plural else "det_sg"])
        toks = [det]
        if r.random() < self.p_adj:
            toks.append(r.choice(self.g["adj"]))
        # Noun index correlates with the chosen idiom domain for structure.
        idx = r.randrange(N_NOUN)
        toks.append(self.g["noun_pl" if plural else "noun_sg"][idx])
        return toks, plural

    def sentence(self):
        r = self.r
        toks = []
        subj, plural = self.np_()
        toks += subj
        vi = r.randrange(N_VERB)
        toks.append(self.g["verb_pl" if plural else "verb_sg"][vi])
        if r.random() < self.p_obj:
            obj, _ = self.np_()
            toks += obj
        if r.random() < self.p_pp:
            toks.append(r.choice(self.g["prep"]))
            toks.append(r.choice(self.topics))
        if r.random() < self.p_adv:
            toks.append(r.choice(self.g["adv"]))
        if r.random() < self.p_idiom:
            i = r.randrange(N_IDIOM)
            toks.append(self.g["idiom_a"][i])
            toks.append(self.g["idiom_b"][i])
        toks.append(self.g["period"])
        return toks

    def stream(self, n_tokens):
        out = [BOS]
        while len(out) < n_tokens:
            out += self.sentence()
        return out[:n_tokens]


def write_qtok(path, tokens, vocab_size=VOCAB):
    with open(path, "wb") as f:
        f.write(struct.pack("<IIIQ", 0x4B4F5451, 1, vocab_size, len(tokens)))
        f.write(struct.pack(f"<{len(tokens)}H", *tokens))


def make_tasks(groups, seed):
    """Zero-shot task sets from the grammar's deterministic structure."""
    r = random.Random(seed)
    gram = Grammar(groups, seed + 1)

    def ctx_prefix():
        """A couple of sentences of context ending mid-discourse."""
        toks = [BOS]
        for _ in range(r.randrange(1, 3)):
            toks += gram.sentence()
        return toks

    lamb = []
    for _ in range(200):
        i = r.randrange(N_IDIOM)
        ctx = ctx_prefix()
        subj, plural = gram.np_()
        ctx += subj + [gram.g["verb_pl" if plural else "verb_sg"][r.randrange(N_VERB)]]
        ctx.append(groups["idiom_a"][i])
        lamb.append({"kind": "cloze", "context": ctx,
                     "options": [[groups["idiom_b"][i]]], "answer": 0})

    arce = []
    for _ in range(150):
        ctx = ctx_prefix()
        subj, plural = gram.np_(plural=r.random() < 0.5)
        ctx += subj
        vi = r.randrange(N_VERB)
        good = [groups["verb_pl" if plural else "verb_sg"][vi],
                r.choice(groups["det_pl" if plural else "det_sg"])]
        bads = []
        while len(bads) < 3:
            wrong_v = groups["verb_sg" if plural else "verb_pl"][r.randrange(N_VERB)]
            bad = [wrong_v, r.choice(groups["prep"])]
            if bad != good:
                bads.append(bad)
        options = bads[:]
        answer = r.randrange(4)
        options.insert(answer, good)
        arce.append({"kind": "choice", "context": ctx,
                     "options": options, "answer": answer})

    piqa = []
    for _ in range(150):
        ctx = ctx_prefix()
        subj, plural = gram.np_()
        ctx += subj
        vi = r.randrange(N_VERB)
        good = [groups["verb_pl" if plural else "verb_sg"][vi],
                r.choice(groups["det_pl" if plural else "det_sg"]),
                groups["noun_pl" if plural else "noun_sg"][r.randrange(N_NOUN)]]
        # Scrambled (ungrammatical order) continuation.
        bad = [good[2], good[0], good[1]]
        options = [good, bad] if r.random() < 0.5 else [bad, good]
        answer = options.index(good)
        piqa.append({"kind": "choice", "context": ctx,
                     "options": options, "answer": answer})

    sc = []
    for _ in range(150):
        i = r.randrange(N_IDIOM)
        j = (i + 1 + r.randrange(N_IDIOM - 1)) % N_IDIOM
        ctx = ctx_prefix()
        ctx.append(groups["idiom_a"][i])
        options = [[groups["idiom_b"][i]], [groups["idiom_b"][j]]]
        answer = 0
        if r.random() < 0.5:
            options.reverse()
            answer = 1
        sc.append({"kind": "choice", "context": ctx,
                   "options": options, "answer": answer})

    return {"lamb": lamb, "arce": arce, "piqa": piqa, "sc": sc}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--train-tokens", type=int, default=600_000)
    ap.add_argument("--eval-tokens", type=int, default=40_000)
    ap.add_argument("--seed", type=int, default=1234)
    args = ap.parse_args()

    data_dir = os.path.join(args.out, "data")
    os.makedirs(data_dir, exist_ok=True)

    toks, groups = build_vocab()
    with open(os.path.join(data_dir, "vocab.json"), "w") as f:
        json.dump({"tokens": toks}, f)

    # Train mixes the full topic range; eval splits are shifted mixtures.
    splits = {
        "train": Grammar(groups, args.seed, topic_lo=0.0, topic_hi=1.0),
        "wiki": Grammar(groups, args.seed + 1, p_adj=0.45, p_idiom=0.20,
                        topic_lo=0.0, topic_hi=0.5),
        "ptb": Grammar(groups, args.seed + 2, p_adj=0.20, p_obj=0.75,
                       p_idiom=0.10, topic_lo=0.25, topic_hi=0.75),
        "c4": Grammar(groups, args.seed + 3, p_pp=0.45, p_adv=0.35,
                      p_idiom=0.15, topic_lo=0.5, topic_hi=1.0),
    }
    for name, gram in splits.items():
        n = args.train_tokens if name == "train" else args.eval_tokens
        write_qtok(os.path.join(data_dir, f"{name}.bin"), gram.stream(n))
        print(f"wrote {name}.bin ({n} tokens)")

    tasks = make_tasks(groups, args.seed + 10)
    for name, instances in tasks.items():
        with open(os.path.join(data_dir, f"tasks_{name}.json"), "w") as f:
            json.dump({"name": name, "instances": instances}, f)
        print(f"wrote tasks_{name}.json ({len(instances)} instances)")


if __name__ == "__main__":
    main()
