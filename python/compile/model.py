"""Layer 2 — the JAX transformer (build-time only).

Mirrors the Rust `quip::model::transformer` op for op (pre-LN GPT, learned
positions, tanh-GELU, tied head, linear weights stored (out, in)); parity
is asserted by the cross-layer golden tests. Provides:

  * `forward`        — fp32 forward (training + fp AOT artifact)
  * `quant_forward`  — quantized forward whose every linear layer calls the
    Pallas dequant-matmul kernel and applies QuIP's incoherence transform
    (the serving artifact)
  * `init_params` / `param_names` — the canonical parameter ordering shared
    with `aot.py`'s manifest and the Rust runtime.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import quip_matmul
from .kernels.ref import kron_apply_ref, kron_apply_t_ref

# Mirrors rust ModelConfig::series().
CONFIGS = {
    "s0": dict(d_model=64, n_layers=2, n_heads=4, d_ff=256, vocab=256, max_seq=128),
    "s1": dict(d_model=128, n_layers=4, n_heads=4, d_ff=512, vocab=256, max_seq=128),
    "s2": dict(d_model=256, n_layers=6, n_heads=8, d_ff=1024, vocab=256, max_seq=128),
    "s3": dict(d_model=384, n_layers=8, n_heads=8, d_ff=1536, vocab=256, max_seq=128),
}

LN_EPS = 1e-5


def balanced_factor(n: int):
    """p·q = n with p ≤ q as balanced as possible. Mirrors rust
    `linalg::orthogonal::balanced_factor`."""
    best = (1, n)
    p = int(n ** 0.5) + 1
    while p >= 1:
        if n % p == 0:
            q = n // p
            lo, hi = (p, q) if p <= q else (q, p)
            if hi - lo < best[1] - best[0]:
                best = (lo, hi)
            if lo * lo <= n:
                return best
        p -= 1
    return best


def param_names(cfg):
    """Canonical parameter ordering (the AOT input order)."""
    names = ["embed", "pos_embed"]
    for b in range(cfg["n_layers"]):
        names += [
            f"blk{b}.ln1.g", f"blk{b}.ln1.b",
            f"blk{b}.attn.wq", f"blk{b}.attn.wk", f"blk{b}.attn.wv",
            f"blk{b}.attn.wo",
            f"blk{b}.ln2.g", f"blk{b}.ln2.b",
            f"blk{b}.mlp.w1", f"blk{b}.mlp.b1",
            f"blk{b}.mlp.w2", f"blk{b}.mlp.b2",
        ]
    names += ["lnf.g", "lnf.b"]
    return names


def linear_names(cfg):
    out = []
    for b in range(cfg["n_layers"]):
        out += [f"blk{b}.attn.wq", f"blk{b}.attn.wk", f"blk{b}.attn.wv",
                f"blk{b}.attn.wo", f"blk{b}.mlp.w1", f"blk{b}.mlp.w2"]
    return out


def param_shape(cfg, name):
    d, dff, v, ms = cfg["d_model"], cfg["d_ff"], cfg["vocab"], cfg["max_seq"]
    if name == "embed":
        return (v, d)
    if name == "pos_embed":
        return (ms, d)
    if name in ("lnf.g", "lnf.b"):
        return (d,)
    leaf = name.split(".", 1)[1]  # blk{i}.<leaf>
    return {
        "ln1.g": (d,), "ln1.b": (d,), "ln2.g": (d,), "ln2.b": (d,),
        "attn.wq": (d, d), "attn.wk": (d, d), "attn.wv": (d, d),
        "attn.wo": (d, d),
        "mlp.w1": (dff, d), "mlp.b1": (dff,),
        "mlp.w2": (d, dff), "mlp.b2": (d,),
    }[leaf]


def init_params(cfg, key):
    params = {}
    keys = jax.random.split(key, len(param_names(cfg)))
    resid_scale = 0.02 / np.sqrt(2.0 * cfg["n_layers"])
    for k, name in zip(keys, param_names(cfg)):
        shape = param_shape(cfg, name)
        if name.endswith(".g") or name == "lnf.g":
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith((".b", ".b1", ".b2")) or name.endswith("b1") or name.endswith("b2"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            scale = resid_scale if name.endswith(("wo", "w2")) else 0.02
            params[name] = (jax.random.normal(k, shape) * scale).astype(jnp.float32)
    return params


def layernorm(x, g, b):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + LN_EPS) * g + b


def attention(q, k, v, n_heads):
    """q,k,v: (B, T, D) → causal MHA output (B, T, D)."""
    b_, t, d = q.shape
    hd = d // n_heads

    def split(x):
        return x.reshape(b_, t, n_heads, hd).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    scores = jnp.einsum("bhid,bhjd->bhij", qh, kh) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhij,bhjd->bhid", probs, vh)
    return out.transpose(0, 2, 1, 3).reshape(b_, t, d)


def forward(params, tokens, cfg):
    """tokens (B, T) int32 → logits (B, T, V)."""
    b_, t = tokens.shape
    x = params["embed"][tokens] + params["pos_embed"][:t][None, :, :]
    for i in range(cfg["n_layers"]):
        p = lambda s: params[f"blk{i}.{s}"]
        ln1 = layernorm(x, p("ln1.g"), p("ln1.b"))
        q = ln1 @ p("attn.wq").T
        k = ln1 @ p("attn.wk").T
        v = ln1 @ p("attn.wv").T
        a = attention(q, k, v, cfg["n_heads"])
        x = x + a @ p("attn.wo").T
        ln2 = layernorm(x, p("ln2.g"), p("ln2.b"))
        h = jax.nn.gelu(ln2 @ p("mlp.w1").T + p("mlp.b1"), approximate=True)
        x = x + h @ p("mlp.w2").T + p("mlp.b2")
    x = layernorm(x, params["lnf.g"], params["lnf.b"])
    return x @ params["embed"].T


def loss_fn(params, tokens, cfg):
    """Mean next-token cross-entropy over (B, T) int32 tokens."""
    logits = forward(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------
# Quantized forward (the serving artifact).
# ---------------------------------------------------------------------

def qlinear(x, qp, incoherent, bits):
    """Apply one quantized linear layer to x (..., n) → (..., m).

    qp fields (all jnp arrays, see aot.py's manifest):
      words/codes, rowscale (m,), rowoff (m,), dinv (n,),
      [uL, uR, uperm, vL, vR, vperm] when incoherent. `bits` is static.
    """
    lead = x.shape[:-1]
    n = x.shape[-1]
    xf = x.reshape(-1, n)
    if incoherent:
        xf = xf * qp["dinv"][None, :]
        xf = kron_apply_ref(qp["vL"], qp["vR"], qp["vperm"], xf)
    if "words" in qp:
        raw = quip_matmul.dequant_matmul_packed(qp["words"], bits, n, xf)
    else:
        raw = quip_matmul.dequant_matmul_u8(qp["codes"], xf)
    xsum = jnp.sum(xf, axis=-1, keepdims=True)
    y = raw * qp["rowscale"][None, :] + xsum * qp["rowoff"][None, :]
    if incoherent:
        y = kron_apply_t_ref(qp["uL"], qp["uR"], qp["uperm"], y)
    m = y.shape[-1]
    return y.reshape(lead + (m,))


def quant_forward(params, qlayers, tokens, cfg, incoherent, bits):
    """Forward with every linear layer quantized. `params` holds the
    non-linear leftovers (embeddings, LNs, biases); `qlayers` maps linear
    names to qparam dicts. `incoherent`/`bits` are static (baked into the
    lowered HLO — one artifact per recipe)."""
    b_, t = tokens.shape
    x = params["embed"][tokens] + params["pos_embed"][:t][None, :, :]
    for i in range(cfg["n_layers"]):
        p = lambda s: params[f"blk{i}.{s}"]
        ql = lambda s: qlayers[f"blk{i}.{s}"]
        ln1 = layernorm(x, p("ln1.g"), p("ln1.b"))
        q = qlinear(ln1, ql("attn.wq"), incoherent, bits)
        k = qlinear(ln1, ql("attn.wk"), incoherent, bits)
        v = qlinear(ln1, ql("attn.wv"), incoherent, bits)
        a = attention(q, k, v, cfg["n_heads"])
        x = x + qlinear(a, ql("attn.wo"), incoherent, bits)
        ln2 = layernorm(x, p("ln2.g"), p("ln2.b"))
        h = jax.nn.gelu(qlinear(ln2, ql("mlp.w1"), incoherent, bits) + p("mlp.b1"),
                        approximate=True)
        x = x + qlinear(h, ql("mlp.w2"), incoherent, bits) + p("mlp.b2")
    x = layernorm(x, params["lnf.g"], params["lnf.b"])
    return x @ params["embed"].T
