"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth
pytest checks the kernels against, and the reference used by hypothesis
sweeps.

Code packing convention (shared with rust `quant::packed` at the semantic
level; the PJRT wire format packs codes LSB-first into int32 words):
  2-bit: 16 codes / word, 4-bit: 8 codes / word, 3-bit: uint8 codes
  (3 does not divide 32; rust stores a cross-byte bitstream on disk and
  unpacks to u8 before feeding PJRT).
"""

import jax.numpy as jnp
import numpy as np


def pack_codes(codes: np.ndarray, bits: int) -> np.ndarray:
    """Pack uint8 codes (m, n) into int32 words (m, ceil(n*bits/32)),
    LSB-first within each word."""
    assert bits in (2, 4), "packed path supports 2/4 bits"
    per = 32 // bits
    m, n = codes.shape
    nw = -(-n // per)
    padded = np.zeros((m, nw * per), dtype=np.uint32)
    padded[:, :n] = codes.astype(np.uint32)
    words = np.zeros((m, nw), dtype=np.uint32)
    for k in range(per):
        words |= padded[:, k::per] << np.uint32(k * bits)
    return words.astype(np.int32)


def unpack_codes_ref(words: jnp.ndarray, bits: int, n: int) -> jnp.ndarray:
    """Unpack int32 words back to float codes (m, n). jnp, so it can run
    inside jitted reference code."""
    per = 32 // bits
    mask = (1 << bits) - 1
    w = words.astype(jnp.uint32)
    parts = [((w >> (k * bits)) & mask) for k in range(per)]
    # interleave: codes[:, word*per + k]
    stacked = jnp.stack(parts, axis=-1)  # (m, nw, per)
    flat = stacked.reshape(w.shape[0], -1)
    return flat[:, :n].astype(jnp.float32)


def dequant_matmul_ref(codes_f32: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """y[t, m] = x[t, n] · codes[m, n]ᵀ — the raw integer-code matmul.
    Affine dequantization (scales/offsets) is applied by the caller."""
    return x @ codes_f32.T


def dequant_matmul_packed_ref(words: jnp.ndarray, bits: int, n: int,
                              x: jnp.ndarray) -> jnp.ndarray:
    return dequant_matmul_ref(unpack_codes_ref(words, bits, n), x)


def kron_apply_ref(xl: jnp.ndarray, xr: jnp.ndarray, perm: jnp.ndarray,
                   v: jnp.ndarray) -> jnp.ndarray:
    """y = (L ⊗ R) P v over the last axis of v (v: ..., n). Matches rust
    `KronOrtho::apply_vec`: (P v)_i = v[perm[i]], reshape p×q, L·Z·Rᵀ."""
    p, q = xl.shape[0], xr.shape[0]
    vp = jnp.take(v, perm, axis=-1)
    z = vp.reshape(v.shape[:-1] + (p, q))
    y = jnp.einsum("ab,...bc,dc->...ad", xl, z, xr)
    return y.reshape(v.shape)


def kron_apply_t_ref(xl: jnp.ndarray, xr: jnp.ndarray, perm: jnp.ndarray,
                     v: jnp.ndarray) -> jnp.ndarray:
    """y = Pᵀ (Lᵀ ⊗ Rᵀ) v — the inverse of kron_apply_ref."""
    p, q = xl.shape[0], xr.shape[0]
    z = v.reshape(v.shape[:-1] + (p, q))
    y = jnp.einsum("ba,...bc,cd->...ad", xl, z, xr).reshape(v.shape)
    inv = jnp.zeros_like(perm).at[perm].set(jnp.arange(perm.shape[0]))
    return jnp.take(y, inv, axis=-1)
