"""Layer 1 — the Pallas QuIP inference kernel: packed-code dequantize +
matmul. This is the hot spot of quantized inference; it lowers (under
interpret=True — CPU PJRT cannot run Mosaic custom-calls) into the same
HLO as the surrounding JAX model, which `aot.py` exports for the Rust
runtime.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the output
rows (BM per step); each step pulls a (BM × n/16) int32 code tile into
VMEM (~4 KiB at 2 bits for BM=128, n=512), unpacks on the VPU with
shift/mask, and feeds an (BM × n)·(n × T) MXU matmul. The Kronecker
incoherence transform stays *outside* the kernel as two small dense
matmuls (MXU-friendly), exactly mirroring the rust native engine.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output-row tile. 128 aligns with the MXU systolic dimension.
BM = 128


def _kernel(words_ref, x_ref, o_ref, *, bits: int, n: int):
    """One grid step: o[bm, T] = unpack(words[bm, nw]) @ x[n, T]."""
    per = 32 // bits
    mask = (1 << bits) - 1
    words = words_ref[...].astype(jnp.uint32)            # (bm, nw)
    parts = [((words >> (k * bits)) & mask) for k in range(per)]
    codes = jnp.stack(parts, axis=-1).reshape(words.shape[0], -1)
    codes = codes[:, :n].astype(jnp.float32)             # (bm, n)
    o_ref[...] = codes @ x_ref[...]                      # MXU matmul


def dequant_matmul_packed(words: jnp.ndarray, bits: int, n: int,
                          x: jnp.ndarray) -> jnp.ndarray:
    """y[T, m] = x[T, n] · W_codesᵀ with W codes packed in int32 words.

    words: (m, nw) int32, nw = ceil(n*bits/32); x: (T, n) f32.
    Returns raw integer-code products; affine dequant is applied by the
    caller (XLA fuses it).
    """
    assert bits in (2, 4)
    m = words.shape[0]
    t = x.shape[0]
    xt = x.T  # (n, T)
    bm = min(BM, m)
    assert m % bm == 0, f"m={m} not divisible by tile {bm}"
    grid = (m // bm,)
    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, words.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((n, t), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, t), jnp.float32),
        interpret=True,
    )(words, xt)
    return out.T  # (T, m)


def _kernel_u8(codes_ref, x_ref, o_ref):
    o_ref[...] = codes_ref[...].astype(jnp.float32) @ x_ref[...]


def dequant_matmul_u8(codes: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """3-bit (or any ≤8-bit) path: codes held as uint8 (m, n)."""
    m, n = codes.shape
    t = x.shape[0]
    bm = min(BM, m)
    assert m % bm == 0
    out = pl.pallas_call(
        _kernel_u8,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n, t), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, t), jnp.float32),
        interpret=True,
    )(codes, x.T)
    return out.T


def vmem_bytes(m: int, n: int, t: int, bits: int, bm: int = BM) -> int:
    """Analytic VMEM footprint of one grid step (EXPERIMENTS.md §Perf):
    code tile + activation panel + output tile, all resident."""
    bm = min(bm, m)
    words = bm * (-(-n * bits // 32)) * 4
    xpanel = n * t * 4
    otile = bm * t * 4
    unpacked = bm * n * 4  # the dequantized tile before the matmul
    return words + xpanel + otile + unpacked
