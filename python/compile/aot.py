"""AOT lowering: JAX/Pallas → HLO *text* artifacts + manifest.json.

HLO text (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(behind the rust `xla` crate) rejects; the text parser reassigns ids (see
/opt/xla-example/README.md). All functions are lowered with
return_tuple=True; the rust side unwraps with `to_tuple1()`.

Artifacts (under artifacts/hlo/):
  {model}_fp32_b{B}_t{T}.hlo.txt          fp32 prefill/scoring
  {model}_q{bits}{suffix}_b{B}_t{T}.hlo.txt  quantized forward via the
                                          Pallas dequant-matmul kernel
  kernel_q{bits}_m{M}_n{N}_t{T}.hlo.txt   kernel microbench artifact

manifest.json describes every artifact's ordered input list so the rust
runtime can marshal literals without guessing.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import quip_matmul


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def fp32_input_spec(cfg, b, t):
    """Ordered inputs: tokens, then params in canonical order."""
    spec = [("tokens", (b, t), "i32")]
    for name in M.param_names(cfg):
        spec.append((name, M.param_shape(cfg, name), "f32"))
    return spec


def qparam_fields(cfg, name, bits, incoherent):
    """The ordered qparam fields replacing one linear weight."""
    m, n = M.param_shape(cfg, name)
    fields = []
    if bits in (2, 4):
        nw = -(-n * bits // 32)
        fields.append(("words", (m, nw), "i32"))
    else:
        fields.append(("codes", (m, n), "u8"))
    fields += [("rowscale", (m,), "f32"), ("rowoff", (m,), "f32")]
    if incoherent:
        pu, qu = M.balanced_factor(m)
        pv, qv = M.balanced_factor(n)
        fields += [
            ("dinv", (n,), "f32"),
            ("vL", (pv, pv), "f32"), ("vR", (qv, qv), "f32"),
            ("vperm", (n,), "i32"),
            ("uL", (pu, pu), "f32"), ("uR", (qu, qu), "f32"),
            ("uperm", (m,), "i32"),
        ]
    return fields


def quant_input_spec(cfg, bits, incoherent, b, t):
    """Ordered inputs for the quantized forward + a rebuilder."""
    linear = set(M.linear_names(cfg))
    spec = [("tokens", "", (b, t), "i32")]
    for name in M.param_names(cfg):
        if name in linear:
            for field, shape, dtype in qparam_fields(cfg, name, bits, incoherent):
                spec.append((name, field, shape, dtype))
        else:
            spec.append((name, "", M.param_shape(cfg, name), "f32"))

    def build(flat):
        params, qlayers = {}, {}
        for (name, field, _, _), arr in zip(spec[1:], flat):
            if field:
                qlayers.setdefault(name, {})[field] = arr
            else:
                params[name] = arr
        return params, qlayers

    return spec, build


DTYPES = {"f32": jnp.float32, "i32": jnp.int32, "u8": jnp.uint8}


def lower_fp32(cfg, b, t):
    spec = fp32_input_spec(cfg, b, t)

    def fn(*flat):
        tokens, rest = flat[0], flat[1:]
        params = {name: arr for (name, _, _), arr in zip(spec[1:], rest)}
        return (M.forward(params, tokens, cfg),)

    args = [sds(shape, DTYPES[d]) for (_, shape, d) in spec]
    return to_hlo_text(jax.jit(fn).lower(*args)), [
        {"name": n, "field": "", "shape": list(s), "dtype": d}
        for (n, s, d) in spec
    ]


def lower_quant(cfg, bits, incoherent, b, t):
    spec, build = quant_input_spec(cfg, bits, incoherent, b, t)

    def fn(*flat):
        tokens = flat[0]
        params, qlayers = build(flat[1:])
        return (M.quant_forward(params, qlayers, tokens, cfg, incoherent, bits),)

    args = [sds(shape, DTYPES[d]) for (_, _, shape, d) in spec]
    return to_hlo_text(jax.jit(fn).lower(*args)), [
        {"name": n, "field": f, "shape": list(s), "dtype": d}
        for (n, f, s, d) in spec
    ]


def lower_kernel(bits, m, n, t):
    """Standalone dequant-matmul kernel (throughput microbench)."""
    if bits in (2, 4):
        nw = -(-n * bits // 32)

        def fn(words, x):
            return (quip_matmul.dequant_matmul_packed(words, bits, n, x),)

        args = [sds((m, nw), jnp.int32), sds((t, n), jnp.float32)]
        spec = [{"name": "words", "field": "", "shape": [m, nw], "dtype": "i32"},
                {"name": "x", "field": "", "shape": [t, n], "dtype": "f32"}]
    else:
        def fn(codes, x):
            return (quip_matmul.dequant_matmul_u8(codes, x),)

        args = [sds((m, n), jnp.uint8), sds((t, n), jnp.float32)]
        spec = [{"name": "codes", "field": "", "shape": [m, n], "dtype": "u8"},
                {"name": "x", "field": "", "shape": [t, n], "dtype": "f32"}]
    return to_hlo_text(jax.jit(fn).lower(*args)), spec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="s0,s1")
    ap.add_argument("--quick", action="store_true",
                    help="skip the larger artifacts (CI smoke)")
    args = ap.parse_args()

    hlo_dir = os.path.join(args.out, "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    manifest = {"artifacts": []}

    def emit(fname, text, entry):
        path = os.path.join(hlo_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        entry["file"] = f"hlo/{fname}"
        manifest["artifacts"].append(entry)
        print(f"wrote {fname} ({len(text)//1024} KiB)")

    models = args.models.split(",")
    for name in models:
        cfg = M.CONFIGS[name]
        t = 128
        for b in ([1] if args.quick else [1, 4]):
            text, spec = lower_fp32(cfg, b, t)
            emit(f"{name}_fp32_b{b}_t{t}.hlo.txt", text, {
                "kind": "fp32", "model": name, "batch": b, "seq": t,
                "inputs": spec,
            })
        for bits in ([2] if args.quick else [2, 3, 4]):
            text, spec = lower_quant(cfg, bits, True, 1, t)
            emit(f"{name}_q{bits}_incp_b1_t{t}.hlo.txt", text, {
                "kind": "quant", "model": name, "bits": bits,
                "incoherent": True, "batch": 1, "seq": t, "inputs": spec,
            })

    # Kernel microbench artifacts (Table 4 companion).
    for bits, m, n in ([(2, 512, 512)] if args.quick
                       else [(2, 512, 512), (4, 512, 512), (3, 512, 512)]):
        text, spec = lower_kernel(bits, m, n, 16)
        emit(f"kernel_q{bits}_m{m}_n{n}_t16.hlo.txt", text, {
            "kind": "kernel", "bits": bits, "m": m, "n": n, "batch": 16,
            "inputs": spec,
        })

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
