#!/usr/bin/env python3
"""Entry shim: `python3 tools/preflight.py [--json] [--only …]`.

The analyzer lives in tools/preflight/ (a package); this shim makes the
documented invocation work from the repo root with no installation.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from preflight.main import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
