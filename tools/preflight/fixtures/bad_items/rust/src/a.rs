pub struct Widget;
