pub mod a;

pub use a::Nope;

pub fn thing() {}

pub fn thing(x: u32) -> u32 {
    x
}
