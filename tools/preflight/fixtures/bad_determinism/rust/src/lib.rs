pub mod quant;
