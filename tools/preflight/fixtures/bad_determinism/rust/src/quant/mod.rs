use std::collections::HashMap;

pub fn spread() -> f64 {
    let mut m = HashMap::new();
    m.insert(1u32, 0.5f64);
    let mut s = 0.0;
    for v in m.values() {
        s += v;
    }
    for (_k, v) in &m {
        s += v;
    }
    s
}
