pub fn last(xs: &[u32]) -> u32 {
    *xs.last().unwrap()
}

pub fn never() {
    panic!("boom");
}
