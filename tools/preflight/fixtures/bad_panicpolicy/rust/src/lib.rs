pub mod coordinator;
