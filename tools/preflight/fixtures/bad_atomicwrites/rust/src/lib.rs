//! Fixture: bare `fs::write` of durable artifacts. Fires atomic-writes
//! twice (a fully-qualified `std::fs::write` and an imported
//! `fs::write`); the annotated call and the test-only call are exempt.
//! Clean under every other check.

use std::fs;

pub fn save_report(path: &str, body: &[u8]) -> std::io::Result<()> {
    std::fs::write(path, body)
}

pub fn save_index(path: &str, body: &[u8]) -> std::io::Result<()> {
    fs::write(path, body)
}

pub fn save_scratch(path: &str, body: &[u8]) -> std::io::Result<()> {
    // preflight: allow(atomic-writes, "scratch file, rebuilt on startup")
    fs::write(path, body)
}

#[cfg(test)]
mod tests {
    #[test]
    fn scratch_roundtrip() {
        std::fs::write("/tmp/quip_fixture_scratch", b"fixture").unwrap();
    }
}
