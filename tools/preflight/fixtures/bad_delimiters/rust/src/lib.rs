pub fn broken() {
    let _v = vec![1, 2;
}
