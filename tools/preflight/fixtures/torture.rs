//! Lexer torture: constructs that break naive regex scanners.
// r#"this raw string is inside a line comment"# and must not lex
/* nested /* block */ comments */
// preflight: allow(panic, "torture annotation collected from comments")
pub fn torture<'a>(x: &'a str) -> usize {
    let _c: char = 'a';
    let _nl = '\n';
    let _uni = '\u{1F600}';
    let _quote = '\'';
    let _byte = b'x';
    let _raw = r#"outer "quoted {" inner"#;
    let _fenced = r##"keeps r#"inner"# intact"##;
    let _braw = br"raw bytes \ no escape";
    let _esc = "escaped \" quote and {brace}";
    let _lt: &'a str = x;
    let _range = 0..x.len();
    x.len()
}
