pub fn unreachable_from_any_root() {}
