pub mod missing;
