pub struct Config {
    pub alpha: f64,
    pub beta: f64,
}

pub fn make() -> Config {
    Config {
        alpha: 1.0,
        betta: 2.0,
    }
}
