pub fn log(x: u32) {
    println!("{} and {}", x);
}
