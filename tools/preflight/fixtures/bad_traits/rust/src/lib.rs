pub trait Rounder {
    fn round(&self, x: f64) -> f64;
    fn label(&self) -> &'static str {
        "r"
    }
}

pub struct Nearest;

impl Rounder for Nearest {
    fn round(&self, x: f64, y: f64) -> f64 {
        x + y
    }

    fn quantize(&self) -> f64 {
        0.0
    }
}

pub struct Floor;

impl Rounder for Floor {
    fn label(&self) -> &'static str {
        "floor"
    }
}
