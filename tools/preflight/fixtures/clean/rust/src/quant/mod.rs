use std::collections::HashMap;

/// Keyed lookups into a hash map are fine; only iteration is ordered
/// nondeterministically.
pub struct Table {
    pub cells: HashMap<String, f64>,
}

impl Table {
    pub fn get(&self, k: &str) -> Option<f64> {
        self.cells.get(k).copied()
    }

    /// Order-insensitive reduction over the map, annotated as such.
    pub fn total(&self) -> f64 {
        // preflight: allow(nondeterministic-iteration, "sum is order-insensitive")
        self.cells.values().sum()
    }
}
