/// Annotated deliberate backstop: allowed by the panic policy.
pub fn head(xs: &[u32]) -> u32 {
    // preflight: allow(panic, "caller guarantees non-empty input")
    *xs.first().expect("non-empty")
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_test_code_is_exempt() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
    }
}
