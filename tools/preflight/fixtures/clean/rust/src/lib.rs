//! Clean fixture: exercises every preflight check without firing one.

pub mod coordinator;
pub mod quant;

pub use quant::Table;

pub struct Point {
    pub x: f64,
    pub y: f64,
}

pub trait Shape {
    fn area(&self) -> f64;
    fn name(&self) -> &'static str {
        "shape"
    }
}

pub struct Circle {
    pub r: f64,
}

impl Shape for Circle {
    fn area(&self) -> f64 {
        let p = Point { x: self.r, y: 0.0 };
        let _raw = r#"braces {in raw strings} are not placeholders"#;
        let _c = 'a';
        let _msg = format!("{} at {w}", p.x, w = p.y);
        std::f64::consts::PI * self.r * self.r
    }
}

#[allow(clippy::needless_range_loop)]
pub fn sum(xs: &[f64]) -> f64 {
    let mut s = 0.0;
    for i in 0..xs.len() {
        s += xs[i];
    }
    s
}

pub struct Metrics;

impl Metrics {
    pub fn counter(&self, name: &str, help: &str) -> usize {
        name.len() + help.len()
    }
}

pub fn register(m: &Metrics) -> usize {
    m.counter("clean_requests_total", "snake_case and unique")
}
