//! Fixture: metric registrations with a non-snake_case name and a
//! duplicate. Fires metric-names twice; clean under every other check.

pub struct Registry;

impl Registry {
    pub fn counter(&self, name: &str, help: &str) -> usize {
        name.len() + help.len()
    }

    pub fn gauge(&self, name: &str, help: &str) -> usize {
        name.len() + help.len()
    }
}

pub fn register(r: &Registry) -> usize {
    let a = r.counter("requests_total", "requests observed");
    let b = r.counter("BadCamel", "name is not snake_case");
    let c = r.gauge("requests_total", "re-registers the counter's name");
    a + b + c
}
