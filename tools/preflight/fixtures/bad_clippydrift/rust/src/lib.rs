#[allow(clippy::unused_self)]
pub fn noop() {}
