"""Format-macro placeholder/argument arity.

Counts `{}` placeholders in the literal format string of `format!`-family
macros and compares against the supplied arguments. Skips anything it
cannot be certain about: non-literal format strings, `$`-parameterised
macro bodies, and width/precision `$` references.
"""

from ..crate import OPEN
from ..findings import Finding

NAME = "format-args"
DESCRIPTION = "format!-family placeholder count vs argument count"

# macro name -> index of the format-string argument
MACROS = {
    "format": 0,
    "format_args": 0,
    "print": 0,
    "println": 0,
    "eprint": 0,
    "eprintln": 0,
    "panic": 0,
    "unreachable": 0,
    "todo": 0,
    "unimplemented": 0,
    "anyhow": 0,
    "bail": 0,
    "write": 1,
    "writeln": 1,
    "assert": 1,
    "debug_assert": 1,
    "ensure": 1,
    "assert_eq": 2,
    "assert_ne": 2,
    "debug_assert_eq": 2,
    "debug_assert_ne": 2,
}

# macros whose message (and thus format string) is optional
OPTIONAL_FMT = {
    "panic", "unreachable", "todo", "unimplemented", "assert", "debug_assert",
    "ensure", "assert_eq", "assert_ne", "debug_assert_eq", "debug_assert_ne",
    "write", "writeln", "print", "println", "eprint", "eprintln", "anyhow",
    "bail", "format", "format_args",
}


def run(ctx):
    findings = []
    for _crate, rel, lexed in ctx.lexed_files():
        findings.extend(_scan_file(rel, lexed))
    return findings


def _scan_file(rel, lexed):
    findings = []
    toks = lexed.tokens
    n = len(toks)
    i = 0
    while i < n:
        t = toks[i]
        if (
            t.kind == "ident"
            and t.value in MACROS
            and i + 2 < n
            and toks[i + 1].kind == "punct"
            and toks[i + 1].value == "!"
            and toks[i + 2].kind == "punct"
            and toks[i + 2].value in ("(", "[")
        ):
            # not our macro if it's a path tail like `std::panic!` — still
            # the same macro semantics, so no exclusion needed
            args, end = _split_args(toks, i + 2)
            msg = _check_call(t.value, args)
            if msg is not None:
                findings.append(Finding(NAME, rel, t.line, msg))
            i = end
            continue
        i += 1
    return findings


def _split_args(toks, i):
    """toks[i] is the opening delimiter. Split top-level comma-separated
    argument token lists. Returns (args, index_after_close)."""
    open_v = toks[i].value
    close_v = OPEN[open_v]
    n = len(toks)
    depth = {"(": 0, "[": 0, "{": 0}
    args = [[]]
    j = i + 1
    while j < n:
        t = toks[j]
        if t.kind == "punct":
            v = t.value
            if v in OPEN:
                depth[v] += 1
            elif v in (")", "]", "}"):
                inner = {")": "(", "]": "[", "}": "{"}[v]
                if depth[inner] == 0 and v == close_v:
                    break
                depth[inner] -= 1
            elif v == "," and not any(depth.values()):
                args.append([])
                j += 1
                continue
        args[-1].append(t)
        j += 1
    if args and not args[-1]:
        args.pop()  # trailing comma
    return args, j + 1


def _is_named_arg(arg):
    return (
        len(arg) >= 3
        and arg[0].kind == "ident"
        and arg[1].kind == "punct"
        and arg[1].value == "="
        and not (arg[2].kind == "punct" and arg[2].value in ("=",))
    )


def _check_call(name, args):
    fmt_idx = MACROS[name]
    if len(args) <= fmt_idx:
        return None  # no message — fine for the optional-fmt macros
    fmt = args[fmt_idx]
    if len(fmt) != 1 or fmt[0].kind != "str":
        return None  # not a plain literal — can't reason about it
    for arg in args[fmt_idx + 1 :]:
        if any(t.kind == "punct" and t.value == "$" for t in arg):
            return None  # macro-definition body
    parsed = _parse_placeholders(_literal_text(fmt[0].value))
    if parsed is None:
        return None
    implicit, positions, named = parsed
    required = implicit
    if positions:
        required = max(required, max(positions) + 1)
    rest = args[fmt_idx + 1 :]
    provided_pos = [a for a in rest if not _is_named_arg(a)]
    provided_named = {a[0].value for a in rest if _is_named_arg(a)}
    if len(provided_pos) != required:
        return (
            f"{name}! format string consumes {required} positional argument(s) "
            f"but {len(provided_pos)} provided"
        )
    unused = provided_named - named
    if unused:
        return (
            f"{name}! named argument(s) never used by the format string: "
            f"{', '.join(sorted(unused))}"
        )
    return None


def _literal_text(raw):
    """Strip the quotes/prefix off a string-literal token's raw text."""
    body = raw
    if body.startswith(("r", "b")):
        first = body.find('"')
        # fence length = chars between prefix letters and the quote
        hashes = body[:first].count("#")
        return body[first + 1 : len(body) - 1 - hashes]
    return body[1:-1]


def _parse_placeholders(text):
    """Return (implicit_count, positional_indices, named_set) or None when
    the string uses constructs we don't model ($ width/precision refs,
    malformed braces)."""
    implicit = 0
    positions = []
    named = set()
    i = 0
    n = len(text)
    while i < n:
        c = text[i]
        if c == "{":
            if i + 1 < n and text[i + 1] == "{":
                i += 2
                continue
            close = text.find("}", i + 1)
            if close == -1:
                return None
            spec = text[i + 1 : close]
            arg, _, fmtspec = spec.partition(":")
            if "$" in fmtspec or "*" in fmtspec:
                return None  # width/precision taken from the arg list
            if arg == "":
                implicit += 1
            elif arg.isdigit():
                positions.append(int(arg))
            elif arg.replace("_", "a").isalnum() and not arg[0].isdigit():
                named.add(arg)
            else:
                return None  # something exotic
            i = close + 1
            continue
        if c == "}":
            if i + 1 < n and text[i + 1] == "}":
                i += 2
                continue
            return None  # stray closing brace — malformed
        i += 1
    return implicit, positions, named
