"""Durable artifacts must not be written with bare `fs::write`.

A process killed mid-`std::fs::write` leaves a torn file at the final
path: the next reader sees a truncated quantized model, manifest, or
sweep result and fails in a confusing place (or worse, silently loads
garbage). `crate::util::fsx::atomic_write` stages the bytes in a
sibling temp file, fsyncs, and renames into place so every observer
sees either the old contents or the complete new ones (DESIGN.md §10).

Test code gets a free pass (tests write scratch files whose torn state
nobody ever reloads), as does `util/fsx.rs` itself — the rename trick
has to bottom out in a real write somewhere. Deliberate non-durable
writes are annotated in place:

    // preflight: allow(atomic-writes, "scratch file, rebuilt on startup")
"""

from ..findings import Finding
from ..spans import in_spans, test_spans

NAME = "atomic-writes"
DESCRIPTION = "no bare fs::write outside util/fsx.rs, test code, or annotated sites"

# The one module allowed to call fs::write — it implements atomic_write.
IMPL_FILE = "rust/src/util/fsx.rs"


def run(ctx):
    findings = []
    for _crate, rel, lexed in ctx.lexed_files():
        if rel == IMPL_FILE:
            continue
        findings.extend(_scan_file(rel, lexed))
    return findings


def _scan_file(rel, lexed):
    findings = []
    toks = lexed.tokens
    n = len(toks)
    spans = test_spans(toks)

    for i, t in enumerate(toks):
        # matches the tail of both `std::fs::write(` and `fs::write(`
        if t.kind != "ident" or t.value != "write":
            continue
        if not (
            i >= 2
            and toks[i - 1].kind == "punct"
            and toks[i - 1].value == "::"
            and toks[i - 2].kind == "ident"
            and toks[i - 2].value == "fs"
        ):
            continue
        if not (i + 1 < n and toks[i + 1].kind == "punct" and toks[i + 1].value == "("):
            continue
        if in_spans(spans, t.line):
            continue
        if lexed.allowed(NAME, t.line):
            continue
        findings.append(
            Finding(
                NAME,
                rel,
                t.line,
                "bare `fs::write` — a crash mid-write leaves a torn file "
                "at the final path; use `crate::util::fsx::atomic_write` "
                "(temp + fsync + rename), or annotate a deliberate "
                'non-durable write: // preflight: allow(atomic-writes, "reason")',
            )
        )
    return findings
