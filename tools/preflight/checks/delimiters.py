"""Per-file delimiter balance ((), [], {}) plus lexer-level errors
(unterminated strings / block comments)."""

from ..crate import CLOSE, OPEN
from ..findings import Finding

NAME = "delimiters"
DESCRIPTION = "per-file (), [], {} balance and unterminated literals"


def run(ctx):
    findings = []
    for _crate, rel, lexed in ctx.lexed_files(include_vendor=True):
        for line, msg in lexed.errors:
            findings.append(Finding(NAME, rel, line, msg))
        stack = []
        for tok in lexed.tokens:
            if tok.kind != "punct":
                continue
            if tok.value in OPEN:
                stack.append(tok)
            elif tok.value in CLOSE:
                if not stack:
                    findings.append(
                        Finding(NAME, rel, tok.line, f"unmatched closing `{tok.value}`")
                    )
                    break
                top = stack.pop()
                if OPEN[top.value] != tok.value:
                    findings.append(
                        Finding(
                            NAME,
                            rel,
                            tok.line,
                            f"mismatched delimiter: `{top.value}` opened on "
                            f"line {top.line} closed by `{tok.value}`",
                        )
                    )
                    break
        else:
            for top in stack:
                findings.append(
                    Finding(NAME, rel, top.line, f"unclosed `{top.value}`")
                )
    return findings
