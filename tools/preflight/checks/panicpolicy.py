"""Panic policy for the serving layers (coordinator/, engine/).

A stray `unwrap()` on a request path is an availability bug: one poisoned
lock or malformed frame takes down the whole continuous-batching server.
Non-test code in these directories must not call the panic family —
convert to `anyhow` errors (the crate-wide `quip::Result`) or shed the
request. Deliberate backstops (e.g. pool-exhaustion after admission
control already guaranteed capacity) are annotated in place:

    // preflight: allow(panic, "admission control guarantees capacity")

Indexing (`[idx]`) deliberately gets a free pass — the numeric kernels are
index-heavy by design (see the ci.yml clippy allow rationale).
"""

from ..findings import Finding
from ..spans import in_spans, test_spans
from ..context import PANIC_DIRS

NAME = "panic-policy"
DESCRIPTION = "no unannotated unwrap/expect/panic family in coordinator/ and engine/ non-test code"

METHOD_CALLS = {"unwrap", "expect"}
PANIC_MACROS = {"panic", "todo", "unimplemented", "unreachable"}


def run(ctx):
    findings = []
    for _crate, rel, lexed in ctx.lexed_files():
        if not rel.startswith(PANIC_DIRS):
            continue
        findings.extend(_scan_file(rel, lexed))
    return findings


def _scan_file(rel, lexed):
    findings = []
    toks = lexed.tokens
    n = len(toks)
    spans = test_spans(toks)

    def flag(line, what):
        if in_spans(spans, line):
            return
        if lexed.allowed("panic", line):
            return
        findings.append(
            Finding(
                NAME,
                rel,
                line,
                f"{what} in serving-layer non-test code — return an error / "
                "shed instead, or annotate a deliberate backstop: "
                '// preflight: allow(panic, "reason")',
            )
        )

    for i, t in enumerate(toks):
        if t.kind != "ident":
            continue
        if (
            t.value in METHOD_CALLS
            and i >= 1
            and toks[i - 1].kind == "punct"
            and toks[i - 1].value == "."
            and i + 1 < n
            and toks[i + 1].kind == "punct"
            and toks[i + 1].value == "("
        ):
            flag(t.line, f"`.{t.value}()`")
            continue
        if (
            t.value in PANIC_MACROS
            and i + 1 < n
            and toks[i + 1].kind == "punct"
            and toks[i + 1].value == "!"
        ):
            flag(t.line, f"`{t.value}!`")
    return findings
