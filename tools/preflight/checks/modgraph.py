"""Module-graph resolution: `mod foo;` must have a matching file, and every
file under rust/src must be reachable from a crate root (orphan detection)."""

from ..findings import Finding

NAME = "modgraph"
DESCRIPTION = "mod decl <-> file mapping and orphan-file detection"


def run(ctx):
    findings = []
    for crate in list(ctx.crates.values()) + ctx.aux_crates:
        for path, line, msg in crate.graph_findings:
            findings.append(Finding(NAME, path, line, msg))
    for rel in ctx.orphans:
        findings.append(
            Finding(
                NAME,
                rel,
                1,
                "orphan file: not reachable from any crate root via `mod` declarations",
            )
        )
    return findings
