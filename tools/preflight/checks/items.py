"""Crate-wide item index checks: resolve every `use crate::…` / `use quip::…`
path (and `pub use` re-exports) against the indexed item tree; flag
duplicate definitions in one module."""

from ..findings import Finding

NAME = "use-resolution"
DESCRIPTION = "use-path / pub-use resolution against the crate item index and duplicate defs"


def run(ctx):
    findings = []
    for crate in ctx.checked_crates():
        for module in crate.modules:
            for name, kind, first, dup in module.duplicates:
                findings.append(
                    Finding(
                        NAME,
                        module.file,
                        dup,
                        f"duplicate definition of `{name}` ({kind}) — first "
                        f"defined on line {first}",
                    )
                )
            for use in module.uses:
                res = ctx.resolver.resolve_use(crate, module, use.segments, use.is_glob)
                if res[0] == "err":
                    path_str = "::".join(use.segments) + ("::*" if use.is_glob else "")
                    findings.append(
                        Finding(
                            NAME,
                            module.file,
                            use.line,
                            f"unresolved import `{path_str}`: {res[1]}",
                        )
                    )
    return findings
