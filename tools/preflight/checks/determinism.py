"""Determinism lint: no HashMap/HashSet *iteration* in bit-deterministic
kernel directories (linalg/, hessian/, quant/).

QuIP's LDLQ proxy objective and the seeded codebook/Hadamard layers are
only reproducible when reduction and traversal order are fixed; iterating
a std HashMap visits entries in RandomState order. Keyed lookups are fine.
Use BTreeMap/BTreeSet (or sort the keys first) — or annotate a deliberate
order-insensitive traversal with
`// preflight: allow(nondeterministic-iteration, "why order can't leak")`.
"""

from ..findings import Finding
from ..spans import in_spans, test_spans
from ..context import DETERMINISM_DIRS

NAME = "determinism"
DESCRIPTION = "no HashMap/HashSet iteration inside bit-deterministic kernel dirs"

HASH_TYPES = ("HashMap", "HashSet")
ITER_METHODS = {
    "iter", "iter_mut", "keys", "values", "values_mut", "drain",
    "into_iter", "into_keys", "into_values", "retain",
}


def run(ctx):
    findings = []
    for _crate, rel, lexed in ctx.lexed_files():
        if not rel.startswith(DETERMINISM_DIRS):
            continue
        findings.extend(_scan_file(rel, lexed))
    return findings


def _scan_file(rel, lexed):
    toks = lexed.tokens
    n = len(toks)
    hash_names = set(HASH_TYPES)
    tracked = set()

    # pass 1: aliases (`use …::HashMap as Lookup`) and hash-typed bindings
    for i, t in enumerate(toks):
        if t.kind != "ident":
            continue
        if t.value in HASH_TYPES:
            # `use std::collections::HashMap as H;`
            if i + 2 < n and toks[i + 1].kind == "ident" and toks[i + 1].value == "as":
                if toks[i + 2].kind == "ident":
                    hash_names.add(toks[i + 2].value)
    for i, t in enumerate(toks):
        if t.kind != "ident" or t.value not in hash_names:
            continue
        # `name : HashMap<…>` — struct field, let-annotation, or fn param
        if i >= 2 and toks[i - 1].kind == "punct" and toks[i - 1].value == ":":
            if toks[i - 2].kind == "ident":
                tracked.add(toks[i - 2].value)
        # `let [mut] name = HashMap::new()` / `HashMap::with_capacity` / `HashMap::from`
        if (
            i + 2 < n
            and toks[i + 1].kind == "punct"
            and toks[i + 1].value == "::"
            and i >= 2
            and toks[i - 1].kind == "punct"
            and toks[i - 1].value == "="
            and toks[i - 2].kind == "ident"
        ):
            tracked.add(toks[i - 2].value)

    findings = []
    spans = test_spans(toks)

    def flag(line, what):
        if in_spans(spans, line):
            return
        if lexed.allowed("nondeterministic-iteration", line):
            return
        findings.append(
            Finding(
                NAME,
                rel,
                line,
                f"{what} iterates a hash collection in a bit-deterministic "
                "kernel dir — use BTreeMap/BTreeSet or sorted keys "
                "(or annotate: // preflight: allow(nondeterministic-iteration, \"…\"))",
            )
        )

    for i, t in enumerate(toks):
        if t.kind != "ident":
            continue
        # `x.iter()` / `self.field.keys()` on a tracked binding
        if (
            t.value in ITER_METHODS
            and i >= 2
            and toks[i - 1].kind == "punct"
            and toks[i - 1].value == "."
            and toks[i - 2].kind == "ident"
            and toks[i - 2].value in tracked
            and i + 1 < n
            and toks[i + 1].kind == "punct"
            and toks[i + 1].value == "("
        ):
            flag(t.line, f"`{toks[i - 2].value}.{t.value}()`")
            continue
        # `for pat in [&[mut]] x {` / `for (k, v) in &map {`
        if t.value == "for":
            j = i + 1
            hops = 0
            while j < n and hops < 24:
                tj = toks[j]
                if tj.kind == "punct" and tj.value == "{":
                    break
                if tj.kind == "ident" and tj.value == "in":
                    k = j + 1
                    while k < n and (
                        (toks[k].kind == "punct" and toks[k].value == "&")
                        or (toks[k].kind == "ident" and toks[k].value == "mut")
                    ):
                        k += 1
                    # direct loop over the binding itself (`for x in map {`)
                    if (
                        k < n
                        and toks[k].kind == "ident"
                        and toks[k].value in tracked
                        and k + 1 < n
                        and toks[k + 1].kind == "punct"
                        and toks[k + 1].value == "{"
                    ):
                        flag(toks[k].line, f"`for … in {toks[k].value}`")
                    # loop over `self.field` (`for x in &self.accums {`)
                    elif (
                        k + 3 < n
                        and toks[k].kind == "ident"
                        and toks[k].value == "self"
                        and toks[k + 1].kind == "punct"
                        and toks[k + 1].value == "."
                        and toks[k + 2].kind == "ident"
                        and toks[k + 2].value in tracked
                        and toks[k + 3].kind == "punct"
                        and toks[k + 3].value == "{"
                    ):
                        flag(toks[k].line, f"`for … in self.{toks[k + 2].value}`")
                    break
                j += 1
                hops += 1
    return findings
