"""`#[allow(clippy::…)]` in source must appear in ci.yml's `-A clippy::…`
allow-list — one source of truth for style exemptions."""

from ..findings import Finding

NAME = "clippy-drift"
DESCRIPTION = "in-source #[allow(clippy::…)] must match the ci.yml allow-list"


def run(ctx):
    allowed = ctx.ci_clippy_allows()
    if allowed is None:
        return []  # no CI config (e.g. fixture trees) — nothing to drift from
    findings = []
    for _crate, rel, lexed in ctx.lexed_files():
        toks = lexed.tokens
        for i, t in enumerate(toks):
            if t.kind != "ident" or t.value != "clippy":
                continue
            if not (
                i + 2 < len(toks)
                and toks[i + 1].kind == "punct"
                and toks[i + 1].value == "::"
                and toks[i + 2].kind == "ident"
            ):
                continue
            # confirm we're inside an allow(...) attribute
            if i < 2 or toks[i - 1].value != "(" or toks[i - 2].value != "allow":
                # also handle `clippy::a, clippy::b` lists: scan back over
                # `name , clippy :: name` repetitions
                j = i
                ok = False
                while j >= 2:
                    if toks[j - 1].value == "(" and toks[j - 2].value == "allow":
                        ok = True
                        break
                    if toks[j - 1].value == "," and j >= 4 and toks[j - 2].kind == "ident":
                        j -= 4  # skip back over `clippy :: name ,`
                        continue
                    break
                if not ok:
                    continue
            lint = toks[i + 2].value
            if lint not in allowed:
                findings.append(
                    Finding(
                        NAME,
                        rel,
                        t.line,
                        f"#[allow(clippy::{lint})] is not in the ci.yml clippy "
                        "allow-list — add it there or drop the attribute",
                    )
                )
    return findings
