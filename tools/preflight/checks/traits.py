"""In-crate trait-impl conformance: for `impl Trait for X` where Trait is
defined in this repo, method names and arities must match the trait
declaration, required (default-less) items must be present, and the impl
must not invent methods the trait doesn't declare."""

from ..findings import Finding

NAME = "trait-impl"
DESCRIPTION = "impl blocks match in-crate trait declarations (names, arity, required items)"


def run(ctx):
    findings = []
    for crate in ctx.checked_crates():
        for module in crate.modules:
            for imp in module.impls:
                if imp.trait_path is None:
                    continue
                tdef = _resolve_trait(ctx, crate, module, imp)
                if tdef is None:
                    continue
                findings.extend(_check_impl(module, imp, tdef))
    return findings


def _resolve_trait(ctx, crate, module, imp):
    segs = [s for s in imp.trait_path if s]
    if not segs:
        return None
    # a trait path whose head is one of the impl's generic params
    # (`impl<R: Rounder> …`) can't be resolved lexically — skip
    if segs[0] in imp.generics:
        return None
    res = ctx.resolver.resolve_path(crate, module, segs)
    if res is None or res[0] != "ok" or res[1] != "trait" or res[2] is None:
        return None
    return res[2]


def _check_impl(module, imp, tdef):
    findings = []
    where = f"impl {tdef.name} for {'::'.join(imp.self_path)}"
    for name, (arity, line) in sorted(imp.methods.items()):
        if name not in tdef.methods:
            findings.append(
                Finding(
                    NAME,
                    module.file,
                    line,
                    f"{where}: method `{name}` is not a member of trait "
                    f"`{tdef.name}` (declared: {', '.join(sorted(tdef.methods)) or 'none'})",
                )
            )
            continue
        want_arity = tdef.methods[name][0]
        if arity != want_arity:
            findings.append(
                Finding(
                    NAME,
                    module.file,
                    line,
                    f"{where}: method `{name}` takes {arity} parameter(s) but "
                    f"the trait declares {want_arity}",
                )
            )
    for name, (arity, has_default, _line) in sorted(tdef.methods.items()):
        if not has_default and name not in imp.methods:
            findings.append(
                Finding(
                    NAME,
                    module.file,
                    imp.line,
                    f"{where}: missing required method `{name}`",
                )
            )
    for name, has_default in sorted(tdef.assoc_types.items()):
        if not has_default and name not in imp.assoc_types:
            findings.append(
                Finding(
                    NAME,
                    module.file,
                    imp.line,
                    f"{where}: missing required associated type `{name}`",
                )
            )
    for name in sorted(imp.assoc_types):
        if name not in tdef.assoc_types:
            findings.append(
                Finding(
                    NAME,
                    module.file,
                    imp.line,
                    f"{where}: associated type `{name}` is not declared by the trait",
                )
            )
    for name, has_default in sorted(tdef.assoc_consts.items()):
        if not has_default and name not in imp.assoc_consts:
            findings.append(
                Finding(
                    NAME,
                    module.file,
                    imp.line,
                    f"{where}: missing required associated const `{name}`",
                )
            )
    for name in sorted(imp.assoc_consts):
        if name not in tdef.assoc_consts:
            findings.append(
                Finding(
                    NAME,
                    module.file,
                    imp.line,
                    f"{where}: associated const `{name}` is not declared by the trait",
                )
            )
    return findings
