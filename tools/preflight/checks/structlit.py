"""Struct-literal / struct-pattern field names vs in-crate struct defs.

Scans expression positions for `Path { field: …, .. }` where `Path`
resolves to an in-crate struct (or enum variant) with named fields, and
flags listed field names the definition doesn't have. Conservative by
construction: unresolvable paths, tuple/unit types, and macro-definition
bodies (anything containing `$`) are skipped, so a finding is near-certain
to be a real compile error at first toolchain contact.
"""

from ..crate import OPEN
from ..findings import Finding

NAME = "struct-lit"
DESCRIPTION = "struct literal / pattern field names match in-crate struct definitions"

# a path followed by `{` in these contexts is a type position or block
# header, not a literal
_BAD_PREV = {
    "impl", "for", "dyn", "as", "where", "trait", "struct", "enum", "union",
    "mod", "fn", "use", "type",
}
_BAD_PREV_PUNCT = {"->", "<", "&", "#"}


def run(ctx):
    findings = []
    for crate, rel, lexed in ctx.lexed_files():
        module = ctx.primary_module(crate, rel)
        if module is None:
            continue
        findings.extend(_scan_file(ctx, crate, module, rel, lexed))
    return findings


def _scan_file(ctx, crate, module, rel, lexed):
    findings = []
    toks = lexed.tokens
    n = len(toks)
    i = 0
    while i < n:
        t = toks[i]
        prev = toks[i - 1] if i > 0 else None
        # only consider path *heads*: not a path tail (`::x`) or method (`  .x`)
        if t.kind != "ident" or (
            prev is not None and prev.kind == "punct" and prev.value in ("::", ".")
        ):
            i += 1
            continue
        segs, j = _read_path(toks, i)
        if (
            segs
            and segs[-1][:1].isupper()
            and segs[-1] != "Self"
            and j < n
            and toks[j].kind == "punct"
            and toks[j].value == "{"
            and not _type_position(prev)
        ):
            target = _resolve_fields(ctx, crate, module, segs)
            if target is not None:
                fields, type_name = target
                listed, clean, _j_end = _literal_fields(toks, j)
                if clean:
                    for fname, fline in listed:
                        if fname not in fields:
                            findings.append(
                                Finding(
                                    NAME,
                                    rel,
                                    fline,
                                    f"`{type_name}` has no field `{fname}` "
                                    f"(fields: {', '.join(fields)})",
                                )
                            )
                i = j + 1  # rescan inside the body for nested literals
                continue
        i = j if j > i else i + 1
    return findings


def _type_position(prev):
    if prev is None:
        return False
    if prev.kind == "ident" and prev.value in _BAD_PREV:
        return True
    if prev.kind == "punct" and prev.value in _BAD_PREV_PUNCT:
        return True
    return False


def _read_path(toks, i):
    """Read `A::b::C` starting at ident toks[i]; skip one turbofish.
    Returns (segments, index_after_path)."""
    n = len(toks)
    segs = [toks[i].value]
    j = i + 1
    while j + 1 < n and toks[j].kind == "punct" and toks[j].value == "::":
        nxt = toks[j + 1]
        if nxt.kind == "ident":
            segs.append(nxt.value)
            j += 2
        elif nxt.kind == "punct" and nxt.value == "<":
            # turbofish: skip to matching `>`
            depth = 1
            k = j + 2
            while k < n and depth:
                if toks[k].kind == "punct":
                    if toks[k].value == "<":
                        depth += 1
                    elif toks[k].value == ">":
                        depth -= 1
                k += 1
            j = k
            break
        else:
            break
    return segs, j


def _resolve_fields(ctx, crate, module, segs):
    """Return (field_list, display_name) if segs names an in-crate struct
    or enum variant with named fields; else None."""
    res = ctx.resolver.resolve_path(crate, module, segs)
    if res is None or res[0] != "ok":
        return None
    if res[1] == "struct" and res[2] is not None and res[2].fields is not None:
        return res[2].fields, res[2].name
    if res[1] == "variant":
        edef, vname = res[2]
        vfields = edef.variants.get(vname)
        if vfields is not None:
            return vfields, f"{edef.name}::{vname}"
    return None


def _literal_fields(toks, j):
    """Parse the literal body starting at `{` toks[j].

    Returns (fields [(name, line)], clean, index_of_closing_brace).
    `clean` is False when the body contains macro fragments (`$`) or a
    rest-pattern/update (`..`) — we still return fields seen before the
    point of uncertainty ... except for `$`, which aborts entirely.
    """
    n = len(toks)
    fields = []
    k = j + 1
    while k < n:
        t = toks[k]
        if t.kind == "punct" and t.value == "}":
            return fields, True, k
        if t.kind == "punct" and t.value == "$":
            return [], False, k
        if t.kind == "punct" and t.value in ("..", "..="):
            # `..base` / rest pattern: everything after is an expression;
            # skip to the closing brace at this depth
            depth = 0
            while k < n:
                t2 = toks[k]
                if t2.kind == "punct":
                    if t2.value in OPEN:
                        depth += 1
                    elif t2.value in ("}", ")", "]"):
                        if t2.value == "}" and depth == 0:
                            return fields, True, k
                        depth -= 1
                k += 1
            return fields, True, k
        if t.kind == "ident":
            # `ref`/`mut` prefixes appear in patterns
            if t.value in ("ref", "mut"):
                k += 1
                continue
            name = t.value
            line = t.line
            k += 1
            if k < n and toks[k].kind == "punct" and toks[k].value == ":":
                fields.append((name, line))
                # skip the value expression to `,` or `}` at depth 0
                k += 1
                depth = 0
                while k < n:
                    t2 = toks[k]
                    if t2.kind == "punct":
                        if t2.value in OPEN:
                            depth += 1
                        elif t2.value in (")", "]"):
                            depth -= 1
                        elif t2.value == "}":
                            if depth == 0:
                                return fields, True, k
                            depth -= 1
                        elif t2.value == "," and depth == 0:
                            k += 1
                            break
                        elif t2.value == "$":
                            return [], False, k
                    k += 1
                continue
            if k < n and toks[k].kind == "punct" and toks[k].value in (",", "}"):
                # shorthand `Foo { x }` / pattern binding
                fields.append((name, line))
                if toks[k].value == "}":
                    return fields, True, k
                k += 1
                continue
            # something else (e.g. a path expression misread) — bail
            return [], False, k
        # unexpected token at field position
        return [], False, k
    return fields, False, n - 1
