"""Metric-name lint: registry registrations use valid, unique names.

The observability layer (DESIGN.md §9) renders every registered metric
into Prometheus text exposition, whose grammar only admits
`[a-z_][a-z0-9_]*` for the names we emit (we deliberately forbid the
uppercase/colon forms Prometheus tolerates — one casing style keeps
dashboards greppable). A duplicate registration is almost always a
copy-paste slip: the registry hands back the existing handle, so both
call sites silently share one counter and the second help string is
dropped. This check scans non-test registration call sites —
`.counter("name", …)` / `.gauge(…)` / `.histogram(…)` — and flags
malformed names, `__` (reserved by Prometheus for internal names), and
repeat registrations anywhere in the crate. A deliberate re-registration
(two subsystems sharing one handle by name) can be annotated with
`// preflight: allow(metric-name, "why the share is intended")`.
"""

import re

from ..findings import Finding
from ..spans import in_spans, test_spans

NAME = "metric-names"
DESCRIPTION = "registered metric names are snake_case, Prometheus-safe, and unique"

REGISTER_METHODS = ("counter", "gauge", "histogram")
NAME_RE = re.compile(r"^[a-z_][a-z0-9_]*$")


def run(ctx):
    findings = []
    # name -> (rel, line) of the first registration, across the whole crate
    seen = {}
    for _crate, rel, lexed in ctx.lexed_files():
        findings.extend(_scan_file(rel, lexed, seen))
    return findings


def _scan_file(rel, lexed, seen):
    toks = lexed.tokens
    n = len(toks)
    spans = test_spans(toks)
    findings = []

    def flag(line, msg):
        if in_spans(spans, line):
            return
        if lexed.allowed("metric-name", line):
            return
        findings.append(Finding(NAME, rel, line, msg))

    for i, t in enumerate(toks):
        # `<recv>.counter("name", …)` — method call with a literal name.
        if (
            t.kind != "ident"
            or t.value not in REGISTER_METHODS
            or i == 0
            or toks[i - 1].kind != "punct"
            or toks[i - 1].value != "."
            or i + 2 >= n
            or toks[i + 1].kind != "punct"
            or toks[i + 1].value != "("
            or toks[i + 2].kind != "str"
        ):
            continue
        if in_spans(spans, t.line) or lexed.allowed("metric-name", t.line):
            continue
        raw = toks[i + 2].value
        name = raw[1:-1] if raw.startswith('"') and raw.endswith('"') else raw
        if not NAME_RE.fullmatch(name) or "__" in name:
            flag(
                t.line,
                f'metric name "{name}" is not snake_case — exposition names '
                "must match [a-z_][a-z0-9_]* with no '__'",
            )
            continue
        if name in seen:
            first_rel, first_line = seen[name]
            flag(
                t.line,
                f'metric name "{name}" already registered at '
                f"{first_rel}:{first_line} — duplicates silently share one "
                "handle (or annotate: "
                '// preflight: allow(metric-name, "…"))',
            )
        else:
            seen[name] = (rel, t.line)
    return findings
