"""Check registry. Each check module exports NAME, DESCRIPTION, run(ctx).

`run` returns a list of Finding. Adding a lint = adding a module here and
listing it in ALL_CHECKS (keep the order stable — output is sorted anyway,
but --only parsing and docs follow this list).
"""

from . import (
    atomicwrites,
    clippydrift,
    delimiters,
    determinism,
    fmtargs,
    items,
    metricnames,
    modgraph,
    panicpolicy,
    structlit,
    traits,
)

ALL_CHECKS = [
    delimiters,
    modgraph,
    items,
    traits,
    structlit,
    fmtargs,
    determinism,
    panicpolicy,
    clippydrift,
    metricnames,
    atomicwrites,
]


def by_name():
    return {c.NAME: c for c in ALL_CHECKS}
