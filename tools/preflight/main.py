"""CLI driver: load the repo, run all (or selected) checks, report.

Exit status: 0 when clean, 1 when findings, 2 on usage errors. `--json`
emits machine-readable findings for CI annotation.
"""

import argparse
import json
import os
import sys

from .checks import ALL_CHECKS, by_name
from .context import Context


def main(argv=None):
    parser = argparse.ArgumentParser(
        prog="preflight",
        description="Toolchain-independent static analysis for the quip Rust tree.",
    )
    parser.add_argument(
        "--root",
        default=None,
        help="repo root (default: auto-detected from this script's location)",
    )
    parser.add_argument("--json", action="store_true", help="emit findings as JSON")
    parser.add_argument(
        "--only",
        default=None,
        help="comma-separated check names to run (default: all)",
    )
    parser.add_argument(
        "--list", action="store_true", help="list available checks and exit"
    )
    args = parser.parse_args(argv)

    checks = ALL_CHECKS
    if args.list:
        for c in checks:
            print(f"{c.NAME:16s} {c.DESCRIPTION}")
        return 0
    if args.only:
        table = by_name()
        try:
            checks = [table[name.strip()] for name in args.only.split(",") if name.strip()]
        except KeyError as exc:
            print(f"unknown check {exc}; --list shows the inventory", file=sys.stderr)
            return 2

    root = args.root
    if root is None:
        # tools/preflight/main.py -> repo root is two levels up from tools/
        root = os.path.dirname(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        )

    ctx = Context(root)
    findings = []
    for check in checks:
        findings.extend(check.run(ctx))
    findings.sort(key=lambda f: f.key())

    if args.json:
        print(json.dumps([f.to_dict() for f in findings], indent=2))
    else:
        for f in findings:
            print(f.render())
        ran = ", ".join(c.NAME for c in checks)
        n_files = sum(1 for _ in ctx.lexed_files(include_vendor=True))
        print(
            f"preflight: {len(findings)} finding(s) across {n_files} file(s) "
            f"[checks: {ran}]",
            file=sys.stderr,
        )
    return 1 if findings else 0
