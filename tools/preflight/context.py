"""Analysis context: discovers crate roots, loads them, exposes shared state."""

import glob
import os
import re

from .crate import Resolver, load_crate

# Directories whose kernels must stay bit-deterministic (ROADMAP / DESIGN:
# seeded Hessian accumulation, blocked factorization, codebook rounding).
DETERMINISM_DIRS = ("rust/src/linalg/", "rust/src/hessian/", "rust/src/quant/")
# Serving/decode layers where a stray panic is an availability bug.
PANIC_DIRS = ("rust/src/coordinator/", "rust/src/engine/")

CI_YML = ".github/workflows/ci.yml"


def _crate_name_from_manifest(repo_root, manifest_rel, default):
    path = os.path.join(repo_root, manifest_rel)
    try:
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
    except OSError:
        return default
    m = re.search(r'^\s*name\s*=\s*"([^"]+)"', text, re.M)
    return m.group(1).replace("-", "_") if m else default


class Context:
    """Everything a check needs: loaded crates, resolver, policy config."""

    def __init__(self, repo_root):
        self.repo_root = os.path.abspath(repo_root)
        self.crates = {}  # extern-name -> Crate (lib + vendored)
        self.lib_crate = None
        self.aux_crates = []  # bin / bench / test / example Crates
        self.resolver = None
        self.orphans = []  # .rs files under rust/src reachable from no root
        self._load()

    # -- loading -----------------------------------------------------------

    def _exists(self, rel):
        return os.path.isfile(os.path.join(self.repo_root, rel))

    def _load(self):
        lib_name = _crate_name_from_manifest(self.repo_root, "rust/Cargo.toml", "quip")
        if self._exists("rust/src/lib.rs"):
            self.lib_crate = load_crate(self.repo_root, "rust/src/lib.rs", lib_name)
            self.crates[lib_name] = self.lib_crate
        for vendor_lib in sorted(
            glob.glob(os.path.join(self.repo_root, "vendor", "*", "src", "lib.rs"))
        ):
            rel = os.path.relpath(vendor_lib, self.repo_root).replace(os.sep, "/")
            name = rel.split("/")[1].replace("-", "_")
            self.crates[name] = load_crate(self.repo_root, rel, name)

        aux_roots = []
        if self._exists("rust/src/main.rs"):
            aux_roots.append(("rust/src/main.rs", lib_name + "_bin"))
        for pattern in ("rust/benches/*.rs", "rust/tests/*.rs", "examples/*.rs"):
            for path in sorted(glob.glob(os.path.join(self.repo_root, pattern))):
                rel = os.path.relpath(path, self.repo_root).replace(os.sep, "/")
                stem = os.path.splitext(os.path.basename(rel))[0]
                aux_roots.append((rel, stem))
        for root_file, name in aux_roots:
            self.aux_crates.append(load_crate(self.repo_root, root_file, name))

        self.resolver = Resolver(self.crates)

        # orphan detection: every .rs under rust/src must be reachable from
        # the lib or bin root, and every .rs under rust/tests / rust/benches
        # from some aux root (top-level files there are roots themselves;
        # support modules in subdirectories must be declared by one).
        reachable = set()
        for crate in list(self.crates.values()) + self.aux_crates:
            reachable.update(crate.files)
        for tree in (("rust", "src"), ("rust", "tests"), ("rust", "benches")):
            for path in sorted(
                glob.glob(
                    os.path.join(self.repo_root, *tree, "**", "*.rs"), recursive=True
                )
            ):
                rel = os.path.relpath(path, self.repo_root).replace(os.sep, "/")
                if rel not in reachable:
                    self.orphans.append(rel)

    # -- iteration helpers -------------------------------------------------

    def checked_crates(self):
        """Crates whose source we lint (vendored stand-ins are exempt)."""
        out = []
        if self.lib_crate is not None:
            out.append(self.lib_crate)
        out.extend(self.aux_crates)
        return out

    def lexed_files(self, include_vendor=False):
        """Yield (crate, rel_path, LexedFile), deduped across crates."""
        seen = set()
        crates = list(self.crates.values()) + self.aux_crates
        for crate in crates:
            if not include_vendor and crate.root_file.startswith("vendor/"):
                continue
            for rel, lexed in sorted(crate.files.items()):
                if rel in seen:
                    continue
                seen.add(rel)
                yield crate, rel, lexed

    def primary_module(self, crate, rel_path):
        """The out-of-line module whose body is `rel_path` (shortest path
        wins when inline mods share the file)."""
        best = None
        for mod in crate.modules:
            if mod.file == rel_path:
                if best is None or len(mod.path) < len(best.path):
                    best = mod
        return best

    def module_of(self, crate, path_tuple):
        node = crate.root
        for seg in path_tuple:
            node = node.submods.get(seg)
            if node is None:
                return None
        return node

    def ci_clippy_allows(self):
        """Parse the clippy allow-list out of ci.yml; None if absent."""
        path = os.path.join(self.repo_root, CI_YML)
        try:
            with open(path, encoding="utf-8") as fh:
                text = fh.read()
        except OSError:
            return None
        return set(re.findall(r"-A\s+clippy::([A-Za-z0-9_]+)", text))
