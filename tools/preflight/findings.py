"""Finding record shared by all preflight checks."""


class Finding:
    __slots__ = ("check", "path", "line", "message", "severity")

    def __init__(self, check, path, line, message, severity="error"):
        self.check = check
        self.path = path  # repo-relative string
        self.line = line
        self.message = message
        self.severity = severity  # error | warning

    def key(self):
        return (self.path, self.line, self.check, self.message)

    def to_dict(self):
        return {
            "check": self.check,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "severity": self.severity,
        }

    def render(self):
        return f"{self.path}:{self.line}: [{self.check}] {self.message}"
