"""Crate model: item scanner, module graph, and path resolution.

Builds, per crate root (lib, bin, each bench/test/example, vendored
crates), a tree of :class:`Module` objects holding the item index that
Family-A checks resolve against. The scanner is token-driven and
deliberately shallow: it recognises item heads (``fn``/``struct``/…) at
module-body depth, records names/signatures, and skips bodies. It never
needs to understand expressions.
"""

import os

from .lexer import lex

EXTERNAL_CRATES = {"std", "core", "alloc", "proc_macro"}


class StructDef:
    def __init__(self, name, fields, line):
        self.name = name
        # list of field-name strings for named-field structs; None for
        # tuple/unit structs.
        self.fields = fields
        self.line = line


class EnumDef:
    def __init__(self, name, line):
        self.name = name
        self.variants = {}  # name -> list[str] | None (named fields or not)
        self.line = line


class TraitDef:
    def __init__(self, name, line):
        self.name = name
        self.methods = {}  # name -> (arity, has_default, line)
        self.assoc_types = {}  # name -> has_default
        self.assoc_consts = {}  # name -> has_default
        self.line = line


class ImplBlock:
    def __init__(self, module, trait_path, self_path, line):
        self.module = module  # tuple module path
        self.trait_path = trait_path  # list[str] | None for inherent impls
        self.self_path = self_path  # list[str]
        self.generics = set()  # generic parameter names, e.g. {"T"}
        self.methods = {}  # name -> (arity, line)
        self.assoc_types = set()
        self.assoc_consts = set()
        self.line = line


class UseEntry:
    def __init__(self, segments, alias, is_glob, is_pub, line):
        self.segments = segments  # list[str]
        self.alias = alias  # binding name (last segment unless `as`)
        self.is_glob = is_glob
        self.is_pub = is_pub
        self.line = line


class ModDecl:
    """`mod name;` — an out-of-line module declaration awaiting a file."""

    def __init__(self, name, line, path_attr, cfg_test):
        self.name = name
        self.line = line
        self.path_attr = path_attr  # value of #[path = "…"] if present
        self.cfg_test = cfg_test


class Module:
    def __init__(self, path, file, cfg_test=False):
        self.path = path  # tuple of segment strings; () is the crate root
        self.file = file  # repo-relative file this module's body lives in
        self.cfg_test = cfg_test
        self.types = {}  # name -> (kind, line); kind: struct/enum/trait/type/union
        self.values = {}  # name -> (kind, line); kind: fn/const/static
        self.macros = {}  # name -> line
        self.submods = {}  # name -> Module
        self.mod_decls = []  # ModDecl list (out-of-line)
        self.uses = []  # UseEntry list
        self.structs = {}
        self.enums = {}
        self.traits = {}
        self.impls = []
        self.duplicates = []  # (name, kind, first_line, dup_line)

    def record_type(self, name, kind, line):
        if name in self.types:
            self.duplicates.append((name, kind, self.types[name][1], line))
        else:
            self.types[name] = (kind, line)

    def record_value(self, name, kind, line):
        if name in self.values:
            self.duplicates.append((name, kind, self.values[name][1], line))
        else:
            self.values[name] = (kind, line)


class Crate:
    def __init__(self, name, root_file):
        self.name = name
        self.root_file = root_file  # repo-relative path
        self.root = None  # Module
        self.modules = []  # flat list of all Modules
        self.files = {}  # repo-relative path -> LexedFile
        self.graph_findings = []  # (path, line, message) from mod resolution


# ---------------------------------------------------------------------------
# token cursor
# ---------------------------------------------------------------------------

OPEN = {"(": ")", "[": "]", "{": "}"}
CLOSE = {")": "(", "]": "[", "}": "{"}


class Cursor:
    def __init__(self, tokens):
        self.toks = tokens
        self.i = 0

    def at_end(self):
        return self.i >= len(self.toks)

    def peek(self, k=0):
        j = self.i + k
        return self.toks[j] if 0 <= j < len(self.toks) else None

    def advance(self):
        t = self.peek()
        self.i += 1
        return t

    def eat_punct(self, value):
        t = self.peek()
        if t and t.kind == "punct" and t.value == value:
            self.i += 1
            return True
        return False

    def eat_ident(self, value=None):
        t = self.peek()
        if t and t.kind == "ident" and (value is None or t.value == value):
            self.i += 1
            return t
        return None

    def is_punct(self, value, k=0):
        t = self.peek(k)
        return t is not None and t.kind == "punct" and t.value == value

    def is_ident(self, value=None, k=0):
        t = self.peek(k)
        return (
            t is not None
            and t.kind == "ident"
            and (value is None or t.value == value)
        )

    def skip_balanced(self):
        """Current token must be an opener; skip to just past its match.

        Counts only the one delimiter kind — all three kinds nest properly
        in lexed Rust, so a flat per-kind count is sufficient.
        """
        opener = self.advance()
        want_close = OPEN[opener.value]
        depth = 1
        while not self.at_end() and depth:
            t = self.advance()
            if t.kind == "punct":
                if t.value == opener.value:
                    depth += 1
                elif t.value == want_close:
                    depth -= 1

    def skip_generics(self):
        """Skip a `<…>` group if present (type/def position only)."""
        if not self.is_punct("<"):
            return
        self.advance()
        depth = 1
        while not self.at_end() and depth:
            t = self.peek()
            if t.kind == "punct":
                if t.value == "<":
                    depth += 1
                elif t.value == ">":
                    depth -= 1
                elif t.value in OPEN:
                    self.skip_balanced()
                    continue
            self.advance()

    def skip_to_semi_or_body(self):
        """Skip until `;` (consumed) or `{` (NOT consumed) at delim depth 0.

        Used to pass over return types, where clauses, supertrait bounds.
        Returns "semi", "body", or "eof".
        """
        while not self.at_end():
            t = self.peek()
            if t.kind == "punct":
                if t.value == ";":
                    self.advance()
                    return "semi"
                if t.value == "{":
                    return "body"
                if t.value in ("(", "["):
                    self.skip_balanced()
                    continue
                if t.value == "<":
                    self.skip_generics()
                    continue
            self.advance()
        return "eof"


def parse_path(cur):
    """Parse `a::b::c`, return list of segments.

    Stops before any token that is not part of a plain path. Turbofish
    (`::<…>`) is skipped. `crate`/`self`/`super`/`Self` count as segments.
    """
    segs = []
    cur.eat_punct("::")
    while True:
        t = cur.peek()
        if t is None or t.kind != "ident":
            break
        segs.append(t.value)
        cur.advance()
        if not cur.is_punct("::"):
            break
        if cur.is_punct("<", 1):
            cur.advance()  # ::
            cur.skip_generics()
            if not cur.is_punct("::"):
                break
            cur.advance()
        elif cur.is_ident(None, 1):
            cur.advance()
        else:
            break
    return segs


def count_params(cur):
    """Current token must be `(`. Count comma-separated params; consume
    through the closing `)`. Nested delimiters and generics don't split."""
    cur.advance()  # (
    depth_paren = 1
    depth_other = 0
    count = 0
    saw_any = False
    while not cur.at_end() and depth_paren:
        t = cur.advance()
        if t.kind != "punct":
            saw_any = True
            continue
        v = t.value
        if v == "(":
            depth_paren += 1
        elif v == ")":
            depth_paren -= 1
        elif v in "[{":
            depth_other += 1
        elif v in "]}":
            depth_other -= 1
        elif v == "<":
            depth_other += 1
        elif v == ">":
            depth_other = max(0, depth_other - 1)
        elif v == "," and depth_paren == 1 and depth_other == 0:
            count += 1
        else:
            saw_any = True
    if saw_any:
        count += 1  # final param had no trailing comma
    return count


# ---------------------------------------------------------------------------
# item scanner
# ---------------------------------------------------------------------------

MODIFIERS = {"pub", "unsafe", "async", "default", "extern"}


class _Scanner:
    def __init__(self, crate, lexed):
        self.crate = crate
        self.lexed = lexed
        # set when a `pub` modifier was consumed before the current item —
        # `pub use` re-exports participate in cross-module resolution
        self._pending_pub = False

    def scan(self, module, cur, stop_at_close):
        """Scan one module body. If stop_at_close, return after consuming
        the matching `}`."""
        while not cur.at_end():
            t = cur.peek()
            if t.kind == "punct" and t.value == "}" and stop_at_close:
                cur.advance()
                return
            attrs = self._collect_attrs(cur)
            t = cur.peek()
            if t is None:
                return
            if t.kind != "ident":
                if t.kind == "punct" and t.value == "}" and stop_at_close:
                    cur.advance()
                    return
                if t.kind == "punct" and t.value in OPEN:
                    cur.skip_balanced()
                else:
                    cur.advance()
                continue

            kw = t.value
            if kw in MODIFIERS:
                cur.advance()
                if kw == "pub":
                    self._pending_pub = True
                    if cur.is_punct("("):
                        cur.skip_balanced()
                if kw == "extern":
                    if cur.peek() and cur.peek().kind == "str":
                        cur.advance()
                    if cur.is_ident("crate"):
                        cur.advance()
                        cur.eat_ident()
                        if cur.is_ident("as"):
                            cur.advance()
                            cur.eat_ident()
                        cur.eat_punct(";")
                continue

            if kw == "const" and (cur.is_ident("fn", 1) or cur.is_ident("unsafe", 1)):
                cur.advance()  # `const fn` — next loop handles `fn`
                continue

            handler = getattr(self, "_item_" + kw, None)
            if handler is not None:
                cur.advance()
                handler(module, cur, attrs, t.line)
                self._pending_pub = False
                continue

            if cur.is_punct("!", 1):
                # macro invocation at item position: `name! { … }` etc.
                cur.advance()
                cur.advance()
                if cur.peek() and cur.peek().kind == "punct" and cur.peek().value in OPEN:
                    cur.skip_balanced()
                cur.eat_punct(";")
                continue

            cur.advance()

    # -- attribute helpers -------------------------------------------------

    def _collect_attrs(self, cur):
        attrs = []
        while cur.is_punct("#"):
            j = cur.i
            cur.advance()
            cur.eat_punct("!")
            if not cur.is_punct("["):
                cur.i = j
                break
            start = cur.i
            cur.skip_balanced()
            attrs.append(cur.toks[start + 1 : cur.i - 1])
        return attrs

    @staticmethod
    def _attr_text(attr):
        return " ".join(t.value for t in attr)

    def _attrs_have(self, attrs, needle):
        return any(needle in self._attr_text(a) for a in attrs)

    def _path_attr(self, attrs):
        for a in attrs:
            if a and a[0].kind == "ident" and a[0].value == "path":
                for t in a:
                    if t.kind == "str":
                        return t.value.strip('"')
        return None

    # -- item handlers -----------------------------------------------------

    def _item_fn(self, module, cur, attrs, line):
        name_t = cur.eat_ident()
        if name_t is None:
            return
        module.record_value(name_t.value, "fn", line)
        cur.skip_generics()
        if cur.is_punct("("):
            cur.skip_balanced()
        if cur.skip_to_semi_or_body() == "body":
            cur.skip_balanced()

    def _item_struct(self, module, cur, attrs, line):
        name_t = cur.eat_ident()
        if name_t is None:
            return
        name = name_t.value
        module.record_type(name, "struct", line)
        cur.skip_generics()
        if cur.is_punct("("):  # tuple struct
            cur.skip_balanced()
            cur.skip_to_semi_or_body()
            module.structs[name] = StructDef(name, None, line)
            return
        if cur.eat_punct(";"):  # unit struct
            module.structs[name] = StructDef(name, None, line)
            return
        if cur.skip_to_semi_or_body() != "body":
            module.structs[name] = StructDef(name, None, line)
            return
        fields = self._parse_named_fields(cur)
        module.structs[name] = StructDef(name, fields, line)

    def _parse_named_fields(self, cur):
        """Current token is `{`. Parse `[pub] name: Type,`* through `}`."""
        cur.advance()
        fields = []
        while not cur.at_end():
            self._collect_attrs(cur)
            if cur.eat_punct("}"):
                break
            if cur.is_ident("pub"):
                cur.advance()
                if cur.is_punct("("):
                    cur.skip_balanced()
            name_t = cur.eat_ident()
            if name_t is None:
                if cur.eat_punct("}"):
                    break
                cur.advance()
                continue
            fields.append(name_t.value)
            if cur.eat_punct(":"):
                self._skip_type_until(cur, (",", "}"))
            if cur.eat_punct(","):
                continue
            if cur.eat_punct("}"):
                break
        return fields

    @staticmethod
    def _skip_type_until(cur, stops):
        depth = 0
        while not cur.at_end():
            t = cur.peek()
            if t.kind == "punct":
                if depth == 0 and t.value in stops:
                    return
                if t.value in OPEN:
                    cur.skip_balanced()
                    continue
                if t.value == "<":
                    depth += 1
                elif t.value == ">":
                    depth = max(0, depth - 1)
            cur.advance()

    def _item_enum(self, module, cur, attrs, line):
        name_t = cur.eat_ident()
        if name_t is None:
            return
        name = name_t.value
        module.record_type(name, "enum", line)
        cur.skip_generics()
        if cur.skip_to_semi_or_body() != "body":
            return
        cur.advance()  # {
        edef = EnumDef(name, line)
        while not cur.at_end():
            self._collect_attrs(cur)
            if cur.eat_punct("}"):
                break
            var_t = cur.eat_ident()
            if var_t is None:
                if cur.eat_punct("}"):
                    break
                cur.advance()
                continue
            vfields = None
            if cur.is_punct("("):
                cur.skip_balanced()
            elif cur.is_punct("{"):
                vfields = self._parse_named_fields(cur)
            if cur.eat_punct("="):
                self._skip_type_until(cur, (",", "}"))  # discriminant
            edef.variants[var_t.value] = vfields
            if cur.eat_punct(","):
                continue
            if cur.eat_punct("}"):
                break
        module.enums[name] = edef

    def _item_trait(self, module, cur, attrs, line):
        name_t = cur.eat_ident()
        if name_t is None:
            return
        name = name_t.value
        module.record_type(name, "trait", line)
        cur.skip_generics()
        if cur.skip_to_semi_or_body() != "body":
            return
        cur.advance()  # {
        tdef = TraitDef(name, line)
        self._scan_assoc_items(cur, tdef=tdef)
        module.traits[name] = tdef

    def _item_impl(self, module, cur, attrs, line):
        generics = set()
        if cur.is_punct("<"):
            generics = self._generic_param_names(cur)
        cur.eat_punct("!")  # negative impl
        first = parse_path(cur)
        cur.skip_generics()
        trait_path, self_path = None, first
        if cur.is_ident("for"):
            cur.advance()
            trait_path = first
            while cur.is_punct("&") or cur.is_ident("mut") or cur.is_ident("dyn"):
                cur.advance()
                if cur.peek() and cur.peek().kind == "lifetime":
                    cur.advance()
            self_path = parse_path(cur)
            cur.skip_generics()
        if cur.skip_to_semi_or_body() != "body":
            return
        cur.advance()  # {
        imp = ImplBlock(module.path, trait_path, self_path, line)
        imp.generics = generics
        self._scan_assoc_items(cur, imp=imp)
        module.impls.append(imp)

    def _generic_param_names(self, cur):
        """Current token is `<`. Collect top-level generic parameter names."""
        names = set()
        cur.advance()
        depth = 1
        expect_name = True
        while not cur.at_end() and depth:
            t = cur.peek()
            if t.kind == "punct":
                if t.value == "<":
                    depth += 1
                elif t.value == ">":
                    depth -= 1
                elif t.value == "," and depth == 1:
                    expect_name = True
                elif t.value == ":" and depth == 1:
                    expect_name = False
                elif t.value in OPEN:
                    cur.skip_balanced()
                    continue
            elif t.kind == "ident" and depth == 1 and expect_name and t.value != "const":
                names.add(t.value)
                expect_name = False
            cur.advance()
        return names

    def _scan_assoc_items(self, cur, tdef=None, imp=None):
        """Scan a trait or impl body (position just past `{`)."""
        while not cur.at_end():
            self._collect_attrs(cur)
            if cur.eat_punct("}"):
                return
            t = cur.peek()
            if t is None:
                return
            if t.kind != "ident":
                if t.kind == "punct" and t.value in OPEN:
                    cur.skip_balanced()
                else:
                    cur.advance()
                continue
            kw = t.value
            if kw in MODIFIERS:
                cur.advance()
                if kw == "pub" and cur.is_punct("("):
                    cur.skip_balanced()
                if kw == "extern" and cur.peek() and cur.peek().kind == "str":
                    cur.advance()
                continue
            if kw == "const" and (cur.is_ident("fn", 1) or cur.is_ident("unsafe", 1)):
                cur.advance()
                continue
            if kw == "fn":
                cur.advance()
                name_t = cur.eat_ident()
                if name_t is None:
                    continue
                cur.skip_generics()
                arity = count_params(cur) if cur.is_punct("(") else 0
                has_default = cur.skip_to_semi_or_body() == "body"
                if has_default:
                    cur.skip_balanced()
                if tdef is not None:
                    tdef.methods[name_t.value] = (arity, has_default, name_t.line)
                if imp is not None:
                    imp.methods[name_t.value] = (arity, name_t.line)
                continue
            if kw == "type":
                cur.advance()
                name_t = cur.eat_ident()
                saw_eq = self._skip_assoc_tail(cur)
                if name_t is not None:
                    if tdef is not None:
                        tdef.assoc_types[name_t.value] = saw_eq
                    if imp is not None:
                        imp.assoc_types.add(name_t.value)
                continue
            if kw == "const":
                cur.advance()
                name_t = cur.eat_ident()
                saw_eq = self._skip_assoc_tail(cur)
                if name_t is not None:
                    if tdef is not None:
                        tdef.assoc_consts[name_t.value] = saw_eq
                    if imp is not None:
                        imp.assoc_consts.add(name_t.value)
                continue
            if cur.is_punct("!", 1):
                cur.advance()
                cur.advance()
                if cur.peek() and cur.peek().kind == "punct" and cur.peek().value in OPEN:
                    cur.skip_balanced()
                cur.eat_punct(";")
                continue
            cur.advance()

    @staticmethod
    def _skip_assoc_tail(cur):
        """Skip to `;` at depth 0, reporting whether an `=` was seen
        (i.e. the item has a default/definition)."""
        saw_eq = False
        while not cur.at_end():
            t = cur.peek()
            if t.kind == "punct":
                if t.value == ";":
                    cur.advance()
                    return saw_eq
                if t.value == "=":
                    saw_eq = True
                if t.value in OPEN:
                    cur.skip_balanced()
                    continue
                if t.value == "<":
                    cur.skip_generics()
                    continue
            cur.advance()
        return saw_eq

    def _item_const(self, module, cur, attrs, line):
        name_t = cur.eat_ident()
        if name_t is not None and name_t.value != "_":
            module.record_value(name_t.value, "const", line)
        self._skip_const_tail(cur)

    def _item_static(self, module, cur, attrs, line):
        cur.eat_ident("mut")
        name_t = cur.eat_ident()
        if name_t is not None:
            module.record_value(name_t.value, "static", line)
        self._skip_const_tail(cur)

    @staticmethod
    def _skip_const_tail(cur):
        while not cur.at_end():
            t = cur.peek()
            if t.kind == "punct":
                if t.value == ";":
                    cur.advance()
                    return
                if t.value in OPEN:
                    cur.skip_balanced()
                    continue
            cur.advance()

    def _item_type(self, module, cur, attrs, line):
        name_t = cur.eat_ident()
        if name_t is not None:
            module.record_type(name_t.value, "type", line)
        self._skip_const_tail(cur)

    def _item_union(self, module, cur, attrs, line):
        # treat like a named-field struct; rare enough that the distinction
        # doesn't matter for resolution
        self._item_struct(module, cur, attrs, line)

    def _item_mod(self, module, cur, attrs, line):
        name_t = cur.eat_ident()
        if name_t is None:
            return
        name = name_t.value
        cfg_test = self._attrs_have(attrs, "cfg ( test )")
        if cur.eat_punct(";"):
            module.mod_decls.append(ModDecl(name, line, self._path_attr(attrs), cfg_test))
            return
        if cur.is_punct("{"):
            cur.advance()
            sub = Module(
                module.path + (name,), self.lexed.path, cfg_test or module.cfg_test
            )
            module.submods[name] = sub
            self.crate.modules.append(sub)
            self.scan(sub, cur, stop_at_close=True)

    def _item_use(self, module, cur, attrs, line):
        self._parse_use(module, cur, is_pub=self._pending_pub, line=line)

    def _parse_use(self, module, cur, is_pub, line=0):
        entries = []
        self._parse_use_tree(cur, [], entries)
        cur.eat_punct(";")
        for segs, alias, is_glob in entries:
            module.uses.append(UseEntry(segs, alias, is_glob, is_pub, line))

    def _parse_use_tree(self, cur, prefix, out):
        while True:
            if cur.is_punct("{"):
                cur.advance()
                while not cur.at_end() and not cur.is_punct("}"):
                    self._parse_use_tree(cur, list(prefix), out)
                    if not cur.eat_punct(","):
                        break
                cur.eat_punct("}")
                return
            if cur.is_punct("*"):
                cur.advance()
                out.append((list(prefix), None, True))
                return
            t = cur.peek()
            if t is None or t.kind != "ident":
                return
            seg = t.value
            cur.advance()
            if seg == "self" and prefix:
                out.append((list(prefix), prefix[-1], False))  # binds `b` in a::b::{self}
                return
            prefix = prefix + [seg]
            if cur.eat_punct("::"):
                continue
            alias = seg
            if cur.is_ident("as"):
                cur.advance()
                alias_t = cur.eat_ident()
                if alias_t is not None:
                    alias = alias_t.value
            out.append((prefix, alias, False))
            return

    def _item_macro_rules(self, module, cur, attrs, line):
        """Cursor sits just past `macro_rules` (dispatched like any item)."""
        if not cur.eat_punct("!"):
            return
        name_t = cur.eat_ident()
        if name_t is not None:
            module.macros[name_t.value] = line
            # #[macro_export] hoists the macro to the crate root path
            if self._attrs_have(attrs, "macro_export"):
                self.crate.root.macros.setdefault(name_t.value, line)
        if cur.peek() and cur.peek().kind == "punct" and cur.peek().value in OPEN:
            cur.skip_balanced()


# ---------------------------------------------------------------------------
# crate loading
# ---------------------------------------------------------------------------


def load_crate(repo_root, root_file, name):
    """Load a crate from its root file; follows `mod x;` declarations."""
    crate = Crate(name, root_file)
    root = Module((), root_file)
    crate.root = root
    crate.modules.append(root)
    _load_module_file(crate, repo_root, root_file, root)
    return crate


def _load_module_file(crate, repo_root, rel_path, module):
    abs_path = os.path.join(repo_root, rel_path)
    try:
        with open(abs_path, encoding="utf-8") as fh:
            text = fh.read()
    except OSError as exc:
        crate.graph_findings.append((rel_path, 0, f"cannot read module file: {exc}"))
        return
    lexed = lex(text, rel_path)
    crate.files[rel_path] = lexed
    module.file = rel_path
    scanner = _Scanner(crate, lexed)
    scanner.scan(module, Cursor(lexed.tokens), stop_at_close=False)

    base_dir = os.path.dirname(rel_path)
    fname = os.path.basename(rel_path)
    is_root_like = fname in ("lib.rs", "main.rs", "mod.rs") or not module.path
    if not is_root_like:
        base_dir = os.path.join(base_dir, os.path.splitext(fname)[0])
    for decl in module.mod_decls:
        if decl.path_attr is not None:
            candidates = [os.path.join(os.path.dirname(rel_path), decl.path_attr)]
        else:
            candidates = [
                os.path.join(base_dir, decl.name + ".rs"),
                os.path.join(base_dir, decl.name, "mod.rs"),
            ]
        chosen = None
        for cand in candidates:
            if os.path.isfile(os.path.join(repo_root, cand)):
                chosen = cand
                break
        if chosen is None:
            crate.graph_findings.append(
                (
                    rel_path,
                    decl.line,
                    f"`mod {decl.name};` has no matching file "
                    f"({' or '.join(os.path.normpath(c) for c in candidates)})",
                )
            )
            continue
        chosen = os.path.normpath(chosen).replace(os.sep, "/")
        sub = Module(module.path + (decl.name,), chosen, decl.cfg_test or module.cfg_test)
        module.submods[decl.name] = sub
        crate.modules.append(sub)
        _load_module_file(crate, repo_root, chosen, sub)


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------


class Resolver:
    """Resolves paths against the loaded crate graph.

    ``crates`` maps extern-crate names (e.g. "quip", "anyhow", "xla") to
    their Crate objects. Unknown first segments (std, …) resolve as
    ("external",).
    """

    def __init__(self, crates):
        self.crates = crates

    def resolve_use(self, crate, module, segments, is_glob):
        """Resolve a use-declaration path. Returns one of:
        ("ok", kind, obj) | ("external",) | ("err", message)
        """
        return self._resolve(crate, module, segments, is_glob, set())

    def resolve_name(self, crate, module, name):
        """Resolve a bare name in module scope (items, then use-aliases,
        then glob imports). Returns ("ok", kind, obj) | ("external",) | None.
        """
        hit = self._lookup_in_module(crate, module, name, set())
        if hit is not None:
            return hit
        for use in module.uses:
            if not use.is_glob and use.alias == name:
                res = self._resolve(crate, module, use.segments, False, set())
                return res if res[0] == "ok" else ("external",)
        for use in module.uses:
            if not use.is_glob:
                continue
            res = self._resolve(crate, module, use.segments, True, set())
            if res[0] == "ok" and res[1] == "mod":
                tcrate, tmod = res[2]
                hit = self._lookup_in_module(tcrate, tmod, name, set())
                if hit is not None:
                    return hit
        return None

    def resolve_path(self, crate, module, segments):
        """Resolve a multi-segment expression-position path (e.g. a struct
        literal's `a::B`). The first segment may be a use-alias."""
        if not segments:
            return None
        if len(segments) == 1:
            return self.resolve_name(crate, module, segments[0])
        head = segments[0]
        if head in ("crate", "self", "super") or head in self.crates:
            res = self._resolve(crate, module, segments, False, set())
            return res if res[0] != "err" else None
        base = self.resolve_name(crate, module, head)
        if base is None:
            return None
        if base[0] == "external":
            return ("external",)
        kind, obj = base[1], base[2]
        if kind == "mod":
            tcrate, tmod = obj
            res = self._resolve(tcrate, tmod, ["self"] + segments[1:], False, set())
            return res if res[0] != "err" else None
        if kind == "enum" and len(segments) == 2:
            if segments[1] in obj.variants:
                return ("ok", "variant", (obj, segments[1]))
            return None
        return None

    # -- internals ---------------------------------------------------------

    def _resolve(self, crate, module, segments, is_glob, seen):
        segs = list(segments)
        if not segs:
            return ("err", "empty path")
        head = segs[0]
        if head == "crate":
            cur_crate, cur_mod = crate, crate.root
            segs = segs[1:]
        elif head == "self":
            cur_crate, cur_mod = crate, module
            segs = segs[1:]
        elif head == "super":
            cur_crate = crate
            cur_mod = self._parent_of(crate, module)
            segs = segs[1:]
            while segs and segs[0] == "super" and cur_mod is not None:
                cur_mod = self._parent_of(crate, cur_mod)
                segs = segs[1:]
            if cur_mod is None:
                return ("err", "`super` escapes the crate root")
        elif head in self.crates:
            target = self.crates[head]
            cur_crate, cur_mod = target, target.root
            segs = segs[1:]
        elif head in EXTERNAL_CRATES:
            return ("external",)
        elif head in module.submods:
            cur_crate, cur_mod = crate, module
        else:
            # 2018 idiom: a bare head can also be a use-alias for a module
            # (e.g. `use std::fmt;` then `fmt::Display`)
            for use in module.uses:
                if not use.is_glob and use.alias == head:
                    res = self._resolve(crate, module, use.segments, False, seen)
                    if res[0] == "ok" and res[1] == "mod" and len(segs) > 1:
                        tcrate, tmod = res[2]
                        return self._resolve(
                            tcrate, tmod, ["self"] + segs[1:], is_glob, seen
                        )
                    return ("external",)
            return ("external",)

        for idx, seg in enumerate(segs):
            last = idx == len(segs) - 1
            hit = self._lookup_in_module(cur_crate, cur_mod, seg, seen)
            if hit is None:
                return (
                    "err",
                    f"`{seg}` not found in `{self._mod_name(cur_crate, cur_mod)}`",
                )
            if hit[0] == "external":
                return ("external",)
            kind, obj = hit[1], hit[2]
            if last:
                if is_glob and kind not in ("mod", "enum"):
                    return ("err", f"glob import target `{seg}` is not a module")
                return hit
            if kind == "mod":
                cur_crate, cur_mod = obj
                continue
            if kind == "enum" and idx == len(segs) - 2:
                variant = segs[idx + 1]
                if variant in obj.variants:
                    return ("ok", "variant", (obj, variant))
                return ("err", f"enum `{seg}` has no variant `{variant}`")
            return ("err", f"`{seg}` is a {kind}, not a module")
        return ("ok", "mod", (cur_crate, cur_mod))

    def _parent_of(self, crate, module):
        if not module.path:
            return None
        node = crate.root
        for seg in module.path[:-1]:
            node = node.submods.get(seg)
            if node is None:
                return None
        return node

    @staticmethod
    def _mod_name(crate, module):
        return crate.name + ("::" + "::".join(module.path) if module.path else "")

    def _lookup_in_module(self, crate, module, name, seen):
        if name in module.submods:
            return ("ok", "mod", (crate, module.submods[name]))
        if name in module.structs:
            return ("ok", "struct", module.structs[name])
        if name in module.enums:
            return ("ok", "enum", module.enums[name])
        if name in module.traits:
            return ("ok", "trait", module.traits[name])
        if name in module.types:
            return ("ok", module.types[name][0], None)
        if name in module.values:
            return ("ok", module.values[name][0], None)
        if name in module.macros:
            return ("ok", "macro", None)
        key = (id(module), name)
        if key in seen:
            return None
        seen.add(key)
        for use in module.uses:
            if use.is_pub and not use.is_glob and use.alias == name:
                res = self._resolve(crate, module, use.segments, False, seen)
                if res[0] == "err":
                    return None
                if res[0] == "external":
                    return ("external",)
                return res
        for use in module.uses:
            if not (use.is_pub and use.is_glob):
                continue
            res = self._resolve(crate, module, use.segments, True, seen)
            if res[0] == "ok" and res[1] == "mod":
                tcrate, tmod = res[2]
                hit = self._lookup_in_module(tcrate, tmod, name, seen)
                if hit is not None:
                    return hit
            elif res[0] == "external":
                return ("external",)
        return None
