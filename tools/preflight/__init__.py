"""Toolchain-independent preflight static analyzer for the quip Rust tree.

Run via `python3 tools/preflight.py`. See DESIGN.md §8 for the check
inventory and the annotation grammar.
"""
