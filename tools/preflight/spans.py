"""Locate `#[cfg(test)]` modules and `#[test]` functions in a token stream.

Policy lints (panic-policy, determinism) exempt test code; this module
computes the exempt line ranges once per file.
"""

MODIFIER_IDENTS = {"pub", "unsafe", "async", "const", "extern", "default"}


def _skip_attr(tokens, i):
    """tokens[i] is `#`. Return (attr_token_list, next_index) or (None, i)."""
    n = len(tokens)
    j = i + 1
    if j < n and tokens[j].kind == "punct" and tokens[j].value == "!":
        j += 1
    if not (j < n and tokens[j].kind == "punct" and tokens[j].value == "["):
        return None, i
    depth = 1
    j += 1
    body = []
    while j < n and depth:
        t = tokens[j]
        if t.kind == "punct":
            if t.value == "[":
                depth += 1
            elif t.value == "]":
                depth -= 1
        if depth:
            body.append(t)
        j += 1
    return body, j


def _is_test_attr(body):
    text = " ".join(t.value for t in body)
    if text == "test" or text == "bench":
        return True
    if text.startswith("cfg") and "test" in text.split():
        return True
    return False


def test_spans(tokens):
    """Return [(start_line, end_line)] spans of test-only items."""
    spans = []
    n = len(tokens)
    i = 0
    while i < n:
        t = tokens[i]
        if not (t.kind == "punct" and t.value == "#"):
            i += 1
            continue
        body, j = _skip_attr(tokens, i)
        if body is None:
            i += 1
            continue
        if not _is_test_attr(body):
            i = j
            continue
        start_line = t.line
        # skip any further attributes
        while j < n and tokens[j].kind == "punct" and tokens[j].value == "#":
            more, j2 = _skip_attr(tokens, j)
            if more is None:
                break
            j = j2
        # skip modifiers (pub(crate), unsafe, …)
        while j < n and tokens[j].kind == "ident" and tokens[j].value in MODIFIER_IDENTS:
            j += 1
            if j < n and tokens[j].kind == "punct" and tokens[j].value == "(":
                depth = 1
                j += 1
                while j < n and depth:
                    if tokens[j].kind == "punct":
                        if tokens[j].value == "(":
                            depth += 1
                        elif tokens[j].value == ")":
                            depth -= 1
                    j += 1
        if j < n and tokens[j].kind == "ident" and tokens[j].value in ("mod", "fn"):
            # find the body `{` then its matching `}` — signatures can
            # contain (), <> and [] but not stray braces
            while j < n and not (tokens[j].kind == "punct" and tokens[j].value in ("{", ";")):
                j += 1
            if j < n and tokens[j].value == "{":
                depth = 1
                j += 1
                while j < n and depth:
                    if tokens[j].kind == "punct":
                        if tokens[j].value == "{":
                            depth += 1
                        elif tokens[j].value == "}":
                            depth -= 1
                    j += 1
                end_line = tokens[j - 1].line if j - 1 < n else tokens[-1].line
                spans.append((start_line, end_line))
        i = j
    return spans


def in_spans(spans, line):
    return any(a <= line <= b for a, b in spans)
