r"""Comment/string/raw-string/char/lifetime-aware Rust lexer.

Produces a flat token stream good enough for structural analysis — not a
full grammar. Handles the constructs that break naive regex scanners:

* nested block comments (``/* /* */ */`` — Rust block comments nest)
* raw strings with arbitrary hash fences (``r#"…"#``, ``br##"…"##``)
* raw identifiers (``r#type``)
* char literals vs lifetimes (``'a'`` vs ``'a``, ``'\u{41}'``, ``'\''``)
* byte strings / byte chars (``b"…"``, ``b'x'``)

Line comments are not emitted as tokens, but ``// preflight: allow(...)``
annotations inside them are collected into ``LexedFile.allows`` so policy
checks can honour suppressions.
"""

import re

IDENT_START = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ_")
IDENT_CONT = IDENT_START | set("0123456789")

# // preflight: allow(lint-name, "reason")  — reason optional.
ALLOW_RE = re.compile(
    r"preflight:\s*allow\(\s*([A-Za-z0-9_-]+)\s*(?:,\s*\"([^\"]*)\")?\s*\)"
)

KEYWORDS = frozenset(
    """as async await break const continue crate dyn else enum extern false fn
    for if impl in let loop match mod move mut pub ref return self Self static
    struct super trait true type union unsafe use where while""".split()
)


class Token:
    __slots__ = ("kind", "value", "line", "col")

    def __init__(self, kind, value, line, col):
        self.kind = kind  # ident | lifetime | char | str | num | punct
        self.value = value
        self.line = line
        self.col = col

    def __repr__(self):
        return f"Token({self.kind!r}, {self.value!r}, L{self.line})"


class LexedFile:
    """Token stream plus side tables for one source file."""

    def __init__(self, path, tokens, allows, errors):
        self.path = path
        self.tokens = tokens
        # line -> [(lint, reason)]: preflight allow() annotations by line.
        self.allows = allows
        self.errors = errors  # [(line, message)] — unterminated constructs

    def allowed(self, lint, line):
        """True if `lint` is suppressed on `line` or the line above it."""
        for ln in (line, line - 1):
            for name, _reason in self.allows.get(ln, ()):
                if name == lint:
                    return True
        return False


# Multi-char puncts worth keeping whole; longest match first.
_COMPOUND = ("::", "->", "=>", "..=", "...", "..")


def lex(text, path="<memory>"):
    toks = []
    allows = {}
    errors = []
    i, n = 0, len(text)
    line = 1

    def bump_lines(segment):
        nonlocal line
        line += segment.count("\n")

    while i < n:
        c = text[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue

        # ---- comments -------------------------------------------------
        if c == "/" and i + 1 < n:
            nxt = text[i + 1]
            if nxt == "/":
                end = text.find("\n", i)
                if end == -1:
                    end = n
                body = text[i:end]
                m = ALLOW_RE.search(body)
                if m:
                    allows.setdefault(line, []).append((m.group(1), m.group(2) or ""))
                i = end
                continue
            if nxt == "*":
                depth = 1
                j = i + 2
                while j < n and depth:
                    if text.startswith("/*", j):
                        depth += 1
                        j += 2
                    elif text.startswith("*/", j):
                        depth -= 1
                        j += 2
                    else:
                        j += 1
                if depth:
                    errors.append((line, "unterminated block comment"))
                bump_lines(text[i:j])
                i = j
                continue

        # ---- raw strings / raw idents / byte literals -----------------
        if c in "rb":
            m = _match_raw_or_byte(text, i)
            if m is not None:
                kind, j, err = m
                if err:
                    errors.append((line, err))
                start_line = line
                bump_lines(text[i:j])
                toks.append(Token(kind, text[i:j], start_line, 0))
                i = j
                continue

        # ---- identifiers ----------------------------------------------
        if c in IDENT_START:
            j = i + 1
            while j < n and text[j] in IDENT_CONT:
                j += 1
            toks.append(Token("ident", text[i:j], line, i))
            i = j
            continue

        # ---- numbers --------------------------------------------------
        if c.isdigit():
            j = _scan_number(text, i)
            toks.append(Token("num", text[i:j], line, i))
            i = j
            continue

        # ---- strings --------------------------------------------------
        if c == '"':
            j, err = _scan_string(text, i + 1)
            if err:
                errors.append((line, err))
            start_line = line
            bump_lines(text[i:j])
            toks.append(Token("str", text[i:j], start_line, 0))
            i = j
            continue

        # ---- char literal vs lifetime ---------------------------------
        if c == "'":
            tok, j, err = _scan_quote(text, i, line)
            if err:
                errors.append((line, err))
            if tok is not None:
                toks.append(tok)
            bump_lines(text[i:j])
            i = j
            continue

        # ---- punctuation ----------------------------------------------
        for comp in _COMPOUND:
            if text.startswith(comp, i):
                toks.append(Token("punct", comp, line, i))
                i += len(comp)
                break
        else:
            toks.append(Token("punct", c, line, i))
            i += 1

    return LexedFile(path, toks, allows, errors)


def _match_raw_or_byte(text, i):
    """Match r"…", r#"…"#, br…, b"…", b'…', r#ident at position i.

    Returns (kind, end_index, error | None) or None if this is a plain
    identifier starting with r/b.
    """
    n = len(text)
    j = i
    if text[j] == "b":
        j += 1
        if j < n and text[j] == "r":
            j += 1
        elif j < n and text[j] == '"':
            end, err = _scan_string(text, j + 1)
            return ("str", end, err)
        elif j < n and text[j] == "'":
            # byte char b'x' / b'\n'
            tok, end, err = _scan_quote(text, j, 0)
            if tok is not None and tok.kind == "char":
                return ("char", end, err)
            return None
        else:
            return None
    else:  # 'r'
        j += 1

    hashes = 0
    while j < n and text[j] == "#":
        hashes += 1
        j += 1
    if j < n and text[j] == '"':
        fence = '"' + "#" * hashes
        end = text.find(fence, j + 1)
        if end == -1:
            return ("str", n, "unterminated raw string")
        return ("str", end + len(fence), None)
    if hashes == 1 and j < n and text[j] in IDENT_START:
        # raw identifier r#type
        k = j
        while k < n and text[k] in IDENT_CONT:
            k += 1
        return ("ident", k, None)
    return None


def _scan_number(text, i):
    n = len(text)
    j = i
    if text.startswith(("0x", "0o", "0b"), i):
        j = i + 2
        while j < n and (text[j] in IDENT_CONT):
            j += 1
        return j
    while j < n and (text[j].isdigit() or text[j] == "_"):
        j += 1
    # fractional part — but not the start of a `..` range
    if j + 1 < n and text[j] == "." and text[j + 1].isdigit():
        j += 1
        while j < n and (text[j].isdigit() or text[j] == "_"):
            j += 1
    # exponent
    if j < n and text[j] in "eE" and j + 1 < n and (text[j + 1].isdigit() or text[j + 1] in "+-"):
        j += 2
        while j < n and text[j].isdigit():
            j += 1
    # type suffix (f32, u64, usize, …)
    while j < n and text[j] in IDENT_CONT:
        j += 1
    return j


def _scan_string(text, j):
    """Scan a double-quoted string body starting after the opening quote."""
    n = len(text)
    while j < n:
        c = text[j]
        if c == "\\":
            j += 2
            continue
        if c == '"':
            return j + 1, None
        j += 1
    return n, "unterminated string literal"


def _scan_quote(text, i, line):
    """Disambiguate char literal from lifetime at a `'`.

    Returns (token | None, end_index, error | None).
    """
    n = len(text)
    j = i + 1
    if j >= n:
        return None, n, "dangling quote"
    c = text[j]
    if c == "\\":
        # escape: '\n', '\'', '\u{1F600}', '\x7f'
        k = j + 1
        if k < n and text[k] == "u":
            close = text.find("}", k)
            k = close + 1 if close != -1 else k + 1
        else:
            k += 1
        if k < n and text[k] == "'":
            return Token("char", text[i : k + 1], line, i), k + 1, None
        return None, k, "malformed char escape"
    if c in IDENT_START:
        k = j
        while k < n and text[k] in IDENT_CONT:
            k += 1
        if k < n and text[k] == "'":
            # '<ident>' closed by a quote is a char literal ('a'); anything
            # longer would be invalid Rust — still consume it as char-ish so
            # the stream stays aligned.
            return Token("char", text[i : k + 1], line, i), k + 1, None
        return Token("lifetime", text[i:k], line, i), k, None
    # punctuation char literal: '(' , ' ' , unicode
    k = j + 1
    if k < n and text[k] == "'":
        return Token("char", text[i : k + 1], line, i), k + 1, None
    # a lone quote we can't make sense of — emit as punct so balance checks
    # don't silently desync
    return Token("punct", "'", line, i), j, None
