//! Numerical verification of the paper's theory (Theorems 1 & 4, Lemmas
//! 2, 3 and 5) on random instances — the claims that make QuIP
//! "quantization with guarantees".

use quip::linalg::eigen::eigen_sym;
use quip::linalg::ldl::udu;
use quip::linalg::{KronOrtho, Mat};
use quip::quant::ldlq::{ldlq, round_matrix};
use quip::quant::proxy_loss;
use quip::quant::RoundMode;
use quip::util::rng::Rng;
use quip::util::testkit::{random_hessian, random_spd};

/// Lemma 2: tr(D) ≤ (μ²/n)·tr(H^{1/2})² with μ the eigenvector
/// incoherence of H.
#[test]
fn lemma2_trace_d_spectral_bound() {
    for seed in 0..8 {
        let mut rng = Rng::new(1000 + seed);
        let n = 24;
        let h = if seed % 2 == 0 {
            random_spd(&mut rng, n, 1e-3)
        } else {
            random_hessian(&mut rng, n, 6, 1e-3)
        };
        let e = eigen_sym(&h, 1e-12, 60);
        let mu = e.incoherence_mu();
        let bound = mu * mu / n as f64 * e.trace_sqrt().powi(2);
        let trd = udu(&h, 1e-12).trace_d();
        assert!(
            trd <= bound * (1.0 + 1e-8),
            "seed {seed}: tr(D)={trd} > bound {bound} (μ={mu})"
        );
    }
}

/// Lemma 3 (average case): nearest rounding achieves (m/12)·tr(H) for
/// W ~ Unif over the grid interior.
#[test]
fn lemma3_nearest_average_rate() {
    let mut rng = Rng::new(7);
    let n = 20;
    let m = 400;
    let h = random_spd(&mut rng, n, 1e-2);
    let wg = Mat::from_fn(m, n, |_, _| rng.uniform(64.0, 192.0));
    let codes = round_matrix(&wg, 8, RoundMode::Nearest, 1);
    let loss = proxy_loss(&codes, &wg, &h);
    let expected = m as f64 / 12.0 * h.trace();
    let ratio = loss / expected;
    assert!((0.85..1.18).contains(&ratio), "ratio {ratio}");
}

/// Lemma 3 (average case): stochastic rounding achieves (m/6)·tr(H).
#[test]
fn lemma3_stochastic_average_rate() {
    let mut rng = Rng::new(8);
    let n = 20;
    let m = 400;
    let h = random_spd(&mut rng, n, 1e-2);
    let wg = Mat::from_fn(m, n, |_, _| rng.uniform(64.0, 192.0));
    let codes = round_matrix(&wg, 8, RoundMode::Stochastic, 2);
    let loss = proxy_loss(&codes, &wg, &h);
    let expected = m as f64 / 6.0 * h.trace();
    let ratio = loss / expected;
    assert!((0.85..1.18).contains(&ratio), "ratio {ratio}");
}

/// Theorem 1 corollary: the LDLQ-vs-nearest average-case advantage is
/// exactly tr(D)/tr(H) (both at rate m/12 of their trace).
#[test]
fn theorem1_advantage_is_trd_over_trh() {
    let mut rng = Rng::new(9);
    let n = 16;
    let m = 600;
    let h = random_hessian(&mut rng, n, 4, 5e-3);
    let f = udu(&h, 1e-12);
    let predicted = f.trace_d() / h.trace();
    let wg = Mat::from_fn(m, n, |_, _| rng.uniform(64.0, 192.0));
    let l_ldlq = proxy_loss(&ldlq(&wg, &h, 8, RoundMode::Nearest, 3), &wg, &h);
    let l_near = proxy_loss(&round_matrix(&wg, 8, RoundMode::Nearest, 3), &wg, &h);
    let measured = l_ldlq / l_near;
    assert!(
        (measured - predicted).abs() < 0.25 * predicted.max(0.05),
        "measured {measured:.4} vs predicted tr(D)/tr(H) {predicted:.4}"
    );
}

/// Theorem 4 flavor: for *diagonal* H (the worst case for LDLQ's
/// advantage) LDLQ's feedback vanishes and it equals nearest exactly.
#[test]
fn theorem4_diagonal_h_no_advantage() {
    let mut rng = Rng::new(10);
    let n = 12;
    let d: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 3.0)).collect();
    let h = Mat::diag(&d);
    let wg = Mat::from_fn(30, n, |_, _| rng.uniform(0.0, 15.0));
    let a = ldlq(&wg, &h, 4, RoundMode::Nearest, 5);
    let b = round_matrix(&wg, 4, RoundMode::Nearest, 5);
    assert_eq!(a.data, b.data);
}

/// Lemma 5: conjugating by a two-factor Kronecker orthogonal (with
/// permutation) makes H μ-incoherent with μ = Õ(1) — operationally,
/// μ stays bounded by a small polylog constant while adversarially
/// *coherent* H (diagonal: μ = √n) gets fixed.
#[test]
fn lemma5_kron_conjugation_restores_incoherence() {
    let mut rng = Rng::new(11);
    for n in [16usize, 36, 64] {
        // Diagonal H with spread eigenvalues: eigenvectors are e_i, the
        // most coherent possible (μ = √n).
        let d: Vec<f64> = (0..n).map(|i| 1.0 + i as f64).collect();
        let h = Mat::diag(&d);
        let mu_before = eigen_sym(&h, 1e-12, 50).incoherence_mu();
        assert!((mu_before - (n as f64).sqrt()).abs() < 0.3);
        let v = KronOrtho::from_seed(rng.next_u64(), n);
        let hc = v.conj_sym(&h);
        let mu_after = eigen_sym(&hc, 1e-12, 60).incoherence_mu();
        // Õ(1): μ ≤ A·log(n)-ish; generous constant, but ≪ √n.
        assert!(
            mu_after < 2.5 * (n as f64).ln().max(2.0),
            "n={n}: μ after = {mu_after}"
        );
        assert!(mu_after < 0.8 * mu_before, "n={n}: no improvement");
    }
}

/// §4: the conjugation preserves the proxy quadratic form exactly —
/// tr(W H Wᵀ) = tr((UWVᵀ)(VHVᵀ)(UWVᵀ)ᵀ).
#[test]
fn conjugation_preserves_quadratic_form() {
    let mut rng = Rng::new(12);
    let (m, n) = (12, 18);
    let w = Mat::from_fn(m, n, |_, _| rng.uniform(-1.0, 1.0));
    let h = random_spd(&mut rng, n, 1e-3);
    let u = KronOrtho::from_seed(3, m);
    let v = KronOrtho::from_seed(4, n);
    let before = proxy_loss(&w, &Mat::zeros(m, n), &h);
    let wt = v.apply_mat_right_t(&u.apply_mat_left(&w));
    let ht = v.conj_sym(&h);
    let after = proxy_loss(&wt, &Mat::zeros(m, n), &ht);
    assert!(
        (before - after).abs() < 1e-8 * before,
        "{before} vs {after}"
    );
}

/// Theorem 1 worst case: the adversarial W̃ from the proof places every
/// feedback-adjusted argument at a half-integer (±ε with random signs),
/// forcing |η| = 1/2 at every step; LDLQ's loss is then (m/4)·tr(D).
/// The adversary is *adaptive* (w_k depends on the correction from
/// previous columns), so we construct it by running the recurrence.
#[test]
fn theorem1_worst_case_rate() {
    let mut rng = Rng::new(13);
    let n = 14;
    let m = 64;
    let h = random_spd(&mut rng, n, 1e-2);
    let f = udu(&h, 1e-12);
    let u_dot = f.strictly_upper();
    let trd = f.trace_d();
    let mut wg = Mat::zeros(m, n);
    for r in 0..m {
        let mut err = vec![0.0f64; n];
        for k in 0..n {
            let mut fb = 0.0;
            for j in 0..k {
                fb += err[j] * u_dot[(j, k)];
            }
            let eps = if rng.coin(0.5) { 1e-6 } else { -1e-6 };
            let w = 100.5 - fb + eps; // argument v = w + fb lands at 100.5 ± ε
            wg[(r, k)] = w;
            let v = w + fb;
            let q = v.round();
            let eta = v - q; // the Q-subroutine error the theorem bounds
            assert!((eta.abs() - 0.5).abs() < 1e-5);
            err[k] = w - q; // the linear-feedback state (W − Ŵ)
        }
    }
    let codes = ldlq(&wg, &h, 8, RoundMode::Nearest, 6);
    let loss = proxy_loss(&codes, &wg, &h);
    let expected = m as f64 / 4.0 * trd;
    assert!(
        (loss - expected).abs() < 0.35 * expected,
        "loss {loss} vs (m/4)tr(D) {expected}"
    );
}
