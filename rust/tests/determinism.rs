//! Differential determinism suite (DESIGN.md §11): the sharded streaming
//! Hessian path — budget-bounded accumulation with spill files plus the
//! across-layer worker pool — must produce artifacts **byte-identical**
//! to the in-memory path. These tests pin the tentpole invariant from
//! outside the crate, across the full grid the issue names: calibration
//! splits {1 row, ragged, all-at-once} × worker counts {1, 3, 8} × spill
//! forced on/off, at 2 and 4 bits for both the scalar `ldlq` and the
//! vector `vq` rounders, plus a kill-during-spill crash-resume drill
//! composing with the `--inject-fault` machinery (fault point
//! `hessian.spill`).

use quip::coordinator::pipeline::{quantize_model, PipelineConfig};
use quip::coordinator::QuantSession;
use quip::data::gen::markov_stream;
use quip::hessian::sharded::ShardedHessianStore;
use quip::hessian::{HessianAccum, PANEL};
use quip::model::quantized::QZ_VERSION;
use quip::model::weights::Checkpoint;
use quip::model::ModelConfig;
use quip::quant::{Method, Processing, QuantConfig};
use quip::util::fault::{FaultInjector, FaultSpec};
use std::sync::Arc;

fn tiny_cfg() -> ModelConfig {
    ModelConfig::sized("dt", 32, 2, 4, 64)
}

fn base_cfg(bits: u32, method: Method) -> PipelineConfig {
    PipelineConfig {
        quant: QuantConfig {
            bits,
            method,
            processing: Processing::incoherent(),
            greedy_passes: 2,
            ..Default::default()
        },
        calib_seqs: 4,
        calib_seq_len: 24,
        seed: 11,
        ..Default::default()
    }
}

/// Budget holding ~1.5 of the tiny model's d×d accumulators: each block
/// has four Hessian-sharing keys, so collection under this budget must
/// spill.
fn spill_budget(d: usize) -> usize {
    d * d * 8 * 3 / 2
}

fn quantize_bytes(ck: &Checkpoint, calib: &[Vec<u32>], pcfg: &PipelineConfig) -> Vec<u8> {
    let (qm, report) = quantize_model(ck, calib, pcfg).unwrap();
    assert!(
        report.failed_blocks.is_empty(),
        "failed blocks: {:?}",
        report.failed_blocks
    );
    qm.to_bytes(QZ_VERSION)
}

#[test]
fn qz_bytes_identical_across_worker_counts_budgets_bits_and_rounders() {
    // The e2e half of the grid: for each (bits, rounder) cell, the
    // default in-memory single-threaded run is the reference; every
    // (worker count × budget) combination must reproduce its `.qz`
    // bytes exactly — spills, reloads and pool scheduling included.
    let cfg = tiny_cfg();
    let ck = Checkpoint::random(&cfg, 42);
    let stream = markov_stream(cfg.vocab as u32, 5_000, 3);
    let calib = stream.calibration(24, 4, 9);
    let d = cfg.d_model;
    for (bits, method) in [
        (2, Method::Ldlq),
        (4, Method::Ldlq),
        (2, Method::Vq),
        (4, Method::Vq),
    ] {
        let reference = quantize_bytes(&ck, &calib, &base_cfg(bits, method));
        for workers in [1usize, 3, 8] {
            for budget in [0usize, spill_budget(d)] {
                let mut pcfg = base_cfg(bits, method);
                pcfg.layer_workers = workers;
                pcfg.hessian_mem_budget = budget;
                let bytes = quantize_bytes(&ck, &calib, &pcfg);
                assert!(
                    bytes == reference,
                    "artifact bytes changed: bits={bits} method={method:?} \
                     workers={workers} budget={budget}"
                );
            }
        }
    }
}

#[test]
fn sharded_store_matches_in_memory_across_calib_splits_and_budgets() {
    // The calib-split half of the grid, through the public store API:
    // the same per-key row streams delivered {1 row at a time, in a
    // ragged repeating pattern, all at once}, interleaved round-robin
    // across keys so spills land mid-stream, under {unlimited,
    // spill-forcing} budgets — every finished Hessian must match a plain
    // in-memory accumulator bit for bit.
    let n = 24;
    let keys: Vec<(String, usize)> =
        ["q", "r", "s"].iter().map(|k| (k.to_string(), n)).collect();
    let mut rng = quip::util::rng::Rng::new(0xD7);
    let streams: Vec<(String, Vec<f32>)> = keys
        .iter()
        .enumerate()
        .map(|(i, (k, _))| {
            let rows = PANEL + 17 * (i + 1);
            let data: Vec<f32> =
                (0..rows * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            (k.clone(), data)
        })
        .collect();
    let reference: Vec<Vec<f64>> = streams
        .iter()
        .map(|(_, data)| {
            let mut acc = HessianAccum::new(n);
            acc.add_rows(data, n);
            acc.finish().data
        })
        .collect();
    let splits: &[&[usize]] = &[&[1], &[5, 19, 64, 2], &[usize::MAX]];
    for (si, split) in splits.iter().enumerate() {
        for &budget in &[0usize, n * n * 8 * 3 / 2] {
            let dir = std::env::temp_dir().join(format!(
                "quip_dt_store_{}_{si}_{budget}",
                std::process::id()
            ));
            let _ = std::fs::remove_dir_all(&dir);
            let mut store = ShardedHessianStore::new(&keys, budget, &dir);
            let mut offsets = vec![0usize; streams.len()];
            let mut pat = vec![0usize; streams.len()];
            loop {
                let mut progressed = false;
                for (i, (key, data)) in streams.iter().enumerate() {
                    let total = data.len() / n;
                    if offsets[i] >= total {
                        continue;
                    }
                    let want = split[pat[i] % split.len()];
                    pat[i] += 1;
                    let take = want.min(total - offsets[i]);
                    let lo = offsets[i] * n;
                    store.add_rows(key, &data[lo..lo + take * n], n);
                    offsets[i] += take;
                    progressed = true;
                }
                if !progressed {
                    break;
                }
            }
            store.check().unwrap();
            if budget > 0 {
                assert!(store.spill_count() > 0, "split {si}: tiny budget never spilled");
                assert!(
                    store.peak_bytes() <= budget.max(n * n * 8 + PANEL * n * 4),
                    "split {si}: peak {} over bound",
                    store.peak_bytes()
                );
            } else {
                assert_eq!(store.spill_count(), 0, "split {si}: unlimited budget spilled");
            }
            for ((key, _), want) in streams.iter().zip(&reference) {
                assert!(
                    store.finish(key).unwrap().data == *want,
                    "split {si} budget {budget} key {key}: Hessian bits changed"
                );
            }
        }
    }
}

#[test]
fn kill_during_spill_resumes_byte_identical() {
    // Crash-resume composition: a soft `hessian.spill` kill aborts the
    // session mid-collection (stale spill files left on disk, zero or
    // more blocks journaled); resuming with the same config must finish
    // byte-identical to an uninterrupted budget-capped run — which the
    // grid test above already pinned to the in-memory bytes.
    let cfg = tiny_cfg();
    let ck = Checkpoint::random(&cfg, 42);
    let stream = markov_stream(cfg.vocab as u32, 5_000, 3);
    let calib = stream.calibration(24, 4, 9);
    let mut pcfg = base_cfg(2, Method::Ldlq);
    pcfg.hessian_mem_budget = spill_budget(cfg.d_model);
    pcfg.layer_workers = 3;
    let cold = quantize_bytes(&ck, &calib, &pcfg);

    let dir = std::env::temp_dir().join(format!("quip_dt_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut kill_cfg = pcfg.clone();
    kill_cfg.faults = Some(Arc::new(FaultInjector::new(
        vec![FaultSpec::parse("hessian.spill@2").unwrap()],
        true,
        0xD1E,
    )));
    let killed = QuantSession::new(&ck, kill_cfg)
        .unwrap()
        .with_checkpoint_dir(&dir)
        .unwrap()
        .run(&calib);
    let err = killed.err().expect("kill during spill must abort the session");
    assert!(
        err.to_string().contains("hessian.spill"),
        "unexpected abort: {err}"
    );

    let (qm, report) = QuantSession::resume(&ck, pcfg.clone(), &dir)
        .unwrap()
        .run(&calib)
        .unwrap();
    assert!(
        report.failed_blocks.is_empty(),
        "failed blocks: {:?}",
        report.failed_blocks
    );
    assert!(
        qm.to_bytes(QZ_VERSION) == cold,
        "resume after kill-during-spill changed artifact bytes"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_refuses_a_different_shard_layout() {
    // The Fingerprint now covers the memory budget and worker count:
    // "resume means the same run", so a journal written under one shard
    // layout refuses a resume under another instead of silently mixing
    // configurations.
    let cfg = tiny_cfg();
    let ck = Checkpoint::random(&cfg, 42);
    let stream = markov_stream(cfg.vocab as u32, 5_000, 3);
    let calib = stream.calibration(24, 4, 9);
    let mut pcfg = base_cfg(2, Method::Ldlq);
    pcfg.hessian_mem_budget = spill_budget(cfg.d_model);
    let dir = std::env::temp_dir().join(format!("quip_dt_refuse_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    QuantSession::new(&ck, pcfg.clone())
        .unwrap()
        .with_checkpoint_dir(&dir)
        .unwrap()
        .run(&calib)
        .unwrap();
    let mut other = pcfg.clone();
    other.hessian_mem_budget = 0;
    let err = QuantSession::resume(&ck, other, &dir)
        .err()
        .expect("resume under a different budget must refuse");
    assert!(err.to_string().contains("hessian_mem_budget"), "{err}");
    let mut other = pcfg;
    other.layer_workers = 7;
    let err = QuantSession::resume(&ck, other, &dir)
        .err()
        .expect("resume under a different worker count must refuse");
    assert!(err.to_string().contains("layer_workers"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
