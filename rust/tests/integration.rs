//! Cross-module integration tests (artifact-free: everything synthetic).

use quip::coordinator::pipeline::{quantize_model, PipelineConfig};
use quip::data::gen::markov_stream;
use quip::engine::native::{decode_step_with, FpLinears, QuantLinears};
use quip::model::lm;
use quip::model::quantized::QuantizedModel;
use quip::model::weights::Checkpoint;
use quip::model::{ModelConfig, Transformer};
use quip::quant::{Method, Processing, QuantConfig};

fn tiny_cfg() -> ModelConfig {
    ModelConfig::sized("it", 32, 2, 4, 64)
}

fn pipeline(bits: u32, method: Method, processing: Processing) -> (Checkpoint, QuantizedModel) {
    let cfg = tiny_cfg();
    let ck = Checkpoint::random(&cfg, 42);
    let stream = markov_stream(cfg.vocab as u32, 6_000, 7);
    let calib = stream.calibration(32, 6, 1);
    let pcfg = PipelineConfig {
        quant: QuantConfig {
            bits,
            method,
            processing,
            greedy_passes: 2,
            ..Default::default()
        },
        calib_seqs: 6,
        calib_seq_len: 32,
        seed: 5,
        ..Default::default()
    };
    let (qm, _) = quantize_model(&ck, &calib, &pcfg).unwrap();
    (ck, qm)
}

#[test]
fn full_pipeline_then_eval_preserves_function_at_4_bits() {
    let (ck, qm) = pipeline(4, Method::Ldlq, Processing::incoherent());
    let stream = markov_stream(ck.config.vocab as u32, 6_000, 9);
    let fp = Transformer::from_checkpoint(&ck).unwrap();
    let mut q = Transformer::from_checkpoint(&ck).unwrap();
    qm.apply_to(&mut q).unwrap();
    let p_fp = lm::perplexity(&fp, &stream, 32, 8);
    let p_q = lm::perplexity(&q, &stream, 32, 8);
    // 4-bit QuIP on a random model: perplexity within ~20% of fp.
    assert!(
        (p_q - p_fp).abs() / p_fp < 0.2,
        "fp {p_fp:.2} vs 4-bit {p_q:.2}"
    );
}

#[test]
fn qz_roundtrip_through_disk_and_native_engine() {
    let (ck, qm) = pipeline(2, Method::Ldlq, Processing::incoherent());
    let dir = std::env::temp_dir().join("quip_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m.qz");
    qm.save(&path).unwrap();
    let loaded = QuantizedModel::load(&path).unwrap();

    // Native on-the-fly engine from the loaded artifact ≈ dequantized fwd.
    let model = Transformer::from_checkpoint(&ck).unwrap();
    let qlin = QuantLinears::from_model(&loaded).unwrap();
    let mut deq = Transformer::from_checkpoint(&ck).unwrap();
    loaded.apply_to(&mut deq).unwrap();
    let fp = FpLinears { model: &deq };
    let mut c1 = model.new_cache();
    let mut c2 = deq.new_cache();
    for &t in &[1u32, 30, 12, 55] {
        let a = decode_step_with(&model, &qlin, &mut c1, t);
        let b = decode_step_with(&deq, &fp, &mut c2, t);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 2e-2, "{x} vs {y}");
        }
    }
}

#[test]
fn vq_qz_roundtrip_through_disk_and_native_engine() {
    // The vector-codebook path end to end: pipeline with the vq rounder
    // → v3 `.qz` on disk → load → LUT-expansion decode ≈ dequantized fwd,
    // at the same storage footprint as the scalar 2-bit artifact.
    let (ck, qm) = pipeline(2, Method::Vq, Processing::incoherent());
    for l in &qm.layers {
        assert!(matches!(l.layout, quip::quant::CodeLayout::Vq { .. }));
        assert_eq!(l.packed.len(), l.m * l.n.div_ceil(8) * 2);
    }
    let dir = std::env::temp_dir().join("quip_it_vq");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m.qz");
    qm.save(&path).unwrap();
    let loaded = QuantizedModel::load(&path).unwrap();

    let model = Transformer::from_checkpoint(&ck).unwrap();
    let qlin = QuantLinears::from_model(&loaded).unwrap();
    let mut deq = Transformer::from_checkpoint(&ck).unwrap();
    loaded.apply_to(&mut deq).unwrap();
    let fp = FpLinears { model: &deq };
    let mut c1 = model.new_cache();
    let mut c2 = deq.new_cache();
    for &t in &[1u32, 30, 12, 55] {
        let a = decode_step_with(&model, &qlin, &mut c1, t);
        let b = decode_step_with(&deq, &fp, &mut c2, t);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 2e-2, "{x} vs {y}");
        }
    }
}

#[test]
fn storage_is_actually_two_bit() {
    // On this deliberately tiny model (32×32 layers) the per-layer
    // metadata (grid + D̃ vector) is a visible constant; it amortizes to
    // ≈bits at real layer sizes (see quant::packed tests at 64×64 and the
    // quantize_llm example at s1+). Assert the code payload is exactly
    // 2-bit and total stays bounded.
    let (_, qm) = pipeline(2, Method::Nearest, Processing::incoherent());
    for l in &qm.layers {
        assert_eq!(l.packed.len(), (l.m * l.n * 2).div_ceil(8));
    }
    let bpw = qm.bits_per_weight();
    assert!(bpw < 4.8, "bits/weight {bpw} too high for 2-bit artifact");
}

#[test]
fn incp_beats_baseline_on_trained_like_weights_at_2_bits() {
    // The headline comparison through the *whole pipeline* (not just one
    // layer): proxy sums.
    let cfg = tiny_cfg();
    let mut ck = Checkpoint::random(&cfg, 11);
    // Random Gaussian weights are already incoherent; trained LLM weights
    // have per-channel outliers (the paper's Fig 2; also what
    // train.py's channel-imbalance injection recreates). Scale weight
    // columns lognormally, compensating in the feeding LayerNorm gain so
    // the function is preserved — same transform as the build pipeline.
    {
        let mut rng = quip::util::rng::Rng::new(99);
        let d = cfg.d_model;
        for b in 0..cfg.n_layers {
            for (ln, consumers) in [
                ("ln1", vec!["attn.wq", "attn.wk", "attn.wv"]),
                ("ln2", vec!["mlp.w1"]),
            ] {
                let c: Vec<f32> = (0..d).map(|_| (rng.normal() * 1.2).exp() as f32).collect();
                for suffix in ["g", "b"] {
                    let t = ck.tensors.get_mut(&format!("blk{b}.{ln}.{suffix}")).unwrap();
                    for (x, ci) in t.data.iter_mut().zip(&c) {
                        *x *= ci;
                    }
                }
                for w in consumers {
                    let t = ck.tensors.get_mut(&format!("blk{b}.{w}")).unwrap();
                    let cols = d;
                    for r in 0..t.dims[0] {
                        for (j, ci) in c.iter().enumerate() {
                            t.data[r * cols + j] /= ci;
                        }
                    }
                }
            }
        }
    }
    let stream = markov_stream(cfg.vocab as u32, 6_000, 13);
    let calib = stream.calibration(24, 4, 2);
    let run = |processing: Processing, method: Method| {
        let pcfg = PipelineConfig {
            quant: QuantConfig {
                bits: 2,
                method,
                processing,
                greedy_passes: 2,
                ..Default::default()
            },
            calib_seqs: 4,
            calib_seq_len: 24,
            seed: 5,
            ..Default::default()
        };
        let (_, report) = quantize_model(&ck, &calib, &pcfg).unwrap();
        report.total_proxy()
    };
    let quip = run(Processing::incoherent(), Method::Ldlq);
    let base_near = run(Processing::baseline(), Method::Nearest);
    assert!(quip < base_near, "quip {quip} vs baseline-near {base_near}");
}

#[test]
fn generation_with_quantized_engine_is_deterministic_and_bounded() {
    let (ck, qm) = pipeline(3, Method::Ldlq, Processing::incoherent());
    let model = Transformer::from_checkpoint(&ck).unwrap();
    let qlin = QuantLinears::from_model(&qm).unwrap();
    let params = quip::coordinator::generate::GenParams {
        max_tokens: 10,
        ..Default::default()
    };
    let a = quip::coordinator::generate::generate(&model, &qlin, &[1, 2, 3], &params);
    let b = quip::coordinator::generate::generate(&model, &qlin, &[1, 2, 3], &params);
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.tokens.len(), 10);
    assert!(a.tokens.iter().all(|&t| (t as usize) < ck.config.vocab));
}
