//! Experiment harness — regenerates every table and figure of the paper's
//! evaluation on this repo's substrate (see DESIGN.md §5 for the mapping).
//! Results print as aligned tables and land in `results/*.json`.

pub mod env;
pub mod tables;
pub mod figures;
pub mod sweeps;

pub use env::Env;

/// Dispatch `quip table <id>`.
pub fn run_table(id: &str, args: &crate::util::cli::Args) -> crate::Result<()> {
    match id {
        "1" => tables::table1(args),
        "2" => tables::table2(args),
        "3" => tables::table3(args),
        "4" => tables::table4(args),
        "5" => tables::table5(args),
        "6" => tables::table6(args),
        "14" => tables::table14(args),
        "15" => tables::table15(args),
        "16" => tables::table16(args),
        "optq" => tables::table_optq(args),
        "all" => {
            for t in ["optq", "6", "14", "3", "5", "15", "16", "4", "2", "1"] {
                println!("\n================ table {t} ================");
                run_table(t, args)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown table '{other}' (1,2,3,4,5,6,14,15,16,optq,all)"),
    }
}

/// Dispatch `quip figure <id>`.
pub fn run_figure(id: &str, args: &crate::util::cli::Args) -> crate::Result<()> {
    match id {
        "1" => figures::figure1(args),
        "2" => figures::figure2_3(args, false),
        "3" => figures::figure2_3(args, true),
        "4" => figures::figure4(args),
        "5" | "6" => figures::figure5(args),
        "all" => {
            for f in ["1", "2", "3", "4", "5"] {
                println!("\n================ figure {f} ================");
                run_figure(f, args)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown figure '{other}' (1,2,3,4,5,all)"),
    }
}
