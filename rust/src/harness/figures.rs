//! Figure regenerators: numeric series printed as ASCII + written to
//! results/*.json (plots are data series; no plotting deps offline).

use super::env::{f2, pct, write_result, Env, TablePrinter};
use super::tables::collect_hessians;
use crate::linalg::Mat;
use crate::quant::incoherence::{preprocess, Processing};
use crate::util::cli::Args;
use crate::util::json::{arr_f64, Json};

/// Figure 1 — eig(H) spectra decay rapidly (approximately low-rank H).
pub fn figure1(args: &Args) -> crate::Result<()> {
    let env = Env::load(args)?;
    let model = args.opt_or("model", "s1");
    let ck = env.checkpoint(&model)?;
    let (hessians, _) = collect_hessians(&env, &ck)?;
    println!("Figure 1 analog — {model}: normalized eig(H) spectra (3 random layers)\n");
    let mut out = Json::obj();
    let picks = [0usize, hessians.len() / 2, hessians.len() - 1];
    for (pi, &li) in picks.iter().enumerate() {
        let h = &hessians[li];
        let e = crate::linalg::eigen::eigen_sym(h, 1e-11, 40);
        let lmax = e.values.last().copied().unwrap_or(1.0).max(1e-30);
        let spectrum: Vec<f64> = e
            .values
            .iter()
            .rev()
            .map(|&l| l.max(0.0) / lmax)
            .collect();
        // ASCII decay sketch: eigenvalue index where λ/λmax crosses thresholds.
        print!("layer {li:2}  ");
        for &thr in &[0.5, 0.1, 0.01, 0.001] {
            let k = spectrum.iter().take_while(|&&x| x > thr).count();
            print!("λ/λmax>{thr:<5} for {k:4}/{} | ", spectrum.len());
        }
        println!();
        out.set(&format!("layer{pi}"), arr_f64(&spectrum));
    }
    println!("\npaper shape: most mass in the first few % of eigenvalues.");
    write_result("figure1", &out)?;
    Ok(())
}

/// Figures 2 & 3 — max |W_ij| (weights) or max |Q_ij| (H eigenvectors)
/// before vs after incoherence processing, per layer.
pub fn figure2_3(args: &Args, eigvecs: bool) -> crate::Result<()> {
    let env = Env::load(args)?;
    let model = args.opt_or("model", "s1");
    let ck = env.checkpoint(&model)?;
    let (hessians, weights) = collect_hessians(&env, &ck)?;
    let what = if eigvecs { "max|Q_ij| (H eigvecs)" } else { "max|W_ij|" };
    println!(
        "Figure {} analog — {model}: {what} before vs after incoherence\n",
        if eigvecs { 3 } else { 2 }
    );
    let mut tp = TablePrinter::new(&["layer", "before", "after", "after/before"]);
    let mut before_v = Vec::new();
    let mut after_v = Vec::new();
    let mut p = Processing::incoherent();
    p.rescale = false;
    p.frob_range = false;
    for (li, (h, w)) in hessians.iter().zip(&weights).enumerate() {
        let pre = preprocess(w, h, 8, &p, 1234 + li as u64);
        let (before, after) = if eigvecs {
            let eb = crate::linalg::eigen::eigen_sym(h, 1e-10, 30);
            let ea = crate::linalg::eigen::eigen_sym(&pre.h, 1e-10, 30);
            (eb.vectors.max_abs(), ea.vectors.max_abs())
        } else {
            // processed W recovered from its grid coords
            let wp = pre.post.grid.from_grid(&pre.wg);
            // normalize by ‖W‖_F/√(mn) so the comparison is the paper's
            // incoherence parameter μ
            let norm = |m_: &Mat| m_.frob_norm() / ((m_.rows * m_.cols) as f64).sqrt();
            (w.max_abs() / norm(w), wp.max_abs() / norm(&wp))
        };
        before_v.push(before);
        after_v.push(after);
        if li % 3 == 0 {
            tp.row(vec![
                li.to_string(),
                format!("{before:.3}"),
                format!("{after:.3}"),
                format!("{:.3}", after / before),
            ]);
        }
    }
    tp.print();
    let frac_reduced = before_v
        .iter()
        .zip(&after_v)
        .filter(|(b, a)| a < b)
        .count() as f64
        / before_v.len() as f64;
    println!(
        "\nlayers with reduced max-entry: {:.0}% (paper: nearly all below the slope-1 line)",
        100.0 * frac_reduced
    );
    let mut out = Json::obj();
    out.set("before", arr_f64(&before_v));
    out.set("after", arr_f64(&after_v));
    write_result(if eigvecs { "figure3" } else { "figure2" }, &out)?;
    Ok(())
}

/// Figure 4 — the finite-grid counterexample: clamped LDLQ (nearest) is
/// asymptotically worse than plain nearest on the adversarial (W, H).
pub fn figure4(args: &Args) -> crate::Result<()> {
    let d = args.opt_usize("d", 16);
    println!("Figure 4 analog — finite-grid counterexample, 4-bit grid [0,15], m={d}\n");
    let mut tp = TablePrinter::new(&["n", "ldlq(clamped)", "near", "ldlq/near"]);
    let mut ns = Vec::new();
    let mut ratio = Vec::new();
    for n in [16usize, 32, 64, 128, 256] {
        let (w, h) = make_counterexample(n, d, 0.01);
        // W ≈ 0.5 quantized directly on the integer grid [0,15] (as in the
        // paper's snippet): the clamp at 0 binds for LDLQ's feedback.
        let wg = w;
        let ldlq = crate::quant::ldlq::ldlq(&wg, &h, 4, crate::quant::RoundMode::Nearest, 0);
        let near = crate::quant::ldlq::round_matrix(&wg, 4, crate::quant::RoundMode::Nearest, 0);
        let l_ldlq = crate::quant::proxy_loss(&ldlq, &wg, &h);
        let l_near = crate::quant::proxy_loss(&near, &wg, &h);
        tp.row(vec![
            n.to_string(),
            f2(l_ldlq),
            f2(l_near),
            f2(l_ldlq / l_near),
        ]);
        ns.push(n as f64);
        ratio.push(l_ldlq / l_near);
    }
    tp.print();
    println!("\npaper shape: the ratio grows with n (clamped LDLQ asymptotically worse).");
    anyhow::ensure!(
        ratio.last().unwrap() > ratio.first().unwrap(),
        "counterexample did not reproduce"
    );
    let mut out = Json::obj();
    out.set("n", arr_f64(&ns));
    out.set("ldlq_over_near", arr_f64(&ratio));
    write_result("figure4", &out)?;
    Ok(())
}

/// The paper's Supplement C.3 construction (verbatim port of the PyTorch
/// snippet): H = ones + I with tweaks, W ≈ 1/2 · 1_{m×n} + alternating
/// 0.002 perturbation — here scaled into 4-bit grid units.
pub fn make_counterexample(n: usize, d: usize, c: f64) -> (Mat, Mat) {
    let mut h = Mat::from_fn(n, n, |i, j| 1.0 + if i == j { 1.0 } else { 0.0 });
    h[(n - 1, n - 1)] = 1.0;
    for j in 1..(n - 1) {
        h[(0, j)] += 2.0 * c;
        h[(j, 0)] += 2.0 * c;
    }
    h[(0, n - 1)] += c;
    h[(n - 1, 0)] += c;
    h[(0, 0)] += 4.0 * c + n as f64 * c * c;
    // W = 0.499/0.501 alternating — quantized *directly* against the
    // integer grid [0, 15], exactly as the paper's snippet does. The values
    // sit at the grid's bottom edge, so LDLQ's accumulated error
    // corrections hit the clamp at 0 (that asymmetry is the whole
    // counterexample; re-scaling W to mid-grid destroys it).
    let w = Mat::from_fn(d, n, |_, j| 0.499 + 0.002 * ((j % 2) as f64));
    (w, h)
}

/// Figure 5/6 — perplexity and zero-shot accuracy vs model size, QuIP vs
/// OPTQ at 2/3 bits (+ fp16 reference).
pub fn figure5(args: &Args) -> crate::Result<()> {
    let env = Env::load(args)?;
    let models: Vec<String> = args
        .opt_or("models", "s0,s1,s2")
        .split(',')
        .map(|s| s.to_string())
        .collect();
    println!("Figure 5/6 analog — ppl + task acc vs model size, QuIP vs OPTQ\n");
    let mut tp = TablePrinter::new(&[
        "model", "params", "wbits", "method", "wiki↓", "c4↓", "arce↑", "lamb↑",
    ]);
    let mut out = Json::obj();
    for model in &models {
        let ck = env.checkpoint(model)?;
        let params = ck.config.param_count();
        let fp = env.run_recipe(model, 16, "ldlq", Processing::baseline())?;
        tp.row(vec![
            model.clone(),
            format!("{:.1}M", params as f64 / 1e6),
            "16".into(),
            "fp".into(),
            f2(fp.ppl["wiki"]),
            f2(fp.ppl["c4"]),
            pct(fp.acc["arce"]),
            pct(fp.acc["lamb"]),
        ]);
        out.set(&format!("{model}_fp"), fp.to_json());
        for bits in [3u32, 2] {
            for (label, processing) in [
                ("optq", Processing::baseline()),
                ("quip", Processing::incoherent()),
            ] {
                let r = env.run_recipe(model, bits, "ldlq", processing)?;
                tp.row(vec![
                    model.clone(),
                    format!("{:.1}M", params as f64 / 1e6),
                    bits.to_string(),
                    label.into(),
                    f2(r.ppl["wiki"]),
                    f2(r.ppl["c4"]),
                    pct(r.acc["arce"]),
                    pct(r.acc["lamb"]),
                ]);
                out.set(&format!("{model}_{label}_w{bits}"), r.to_json());
            }
        }
    }
    tp.print();
    println!("\npaper shape: QuIP ≈ fp at 3 bits; at 2 bits QuIP viable while OPTQ collapses,\nwith the gap shrinking as model size grows.");
    write_result("figure5", &out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counterexample_matches_paper_construction() {
        let (w, h) = make_counterexample(8, 4, 0.01);
        assert_eq!((w.rows, w.cols), (4, 8));
        assert_eq!(h.rows, 8);
        // H is symmetric and positive definite (Cholesky succeeds).
        for i in 0..8 {
            for j in 0..8 {
                assert!((h[(i, j)] - h[(j, i)]).abs() < 1e-12);
            }
        }
        assert!(crate::linalg::chol::cholesky(&h).is_ok());
        // W sits at the paper's 0.499/0.501 values.
        for &x in &w.data {
            assert!((x - 0.5).abs() < 0.01);
        }
    }

    #[test]
    fn counterexample_ldlq_underperforms_nearest() {
        // The §5.2 phenomenon itself, as a regression test.
        let (w, h) = make_counterexample(64, 8, 0.01);
        let l = crate::quant::ldlq::ldlq(&w, &h, 4, crate::quant::RoundMode::Nearest, 0);
        let n = crate::quant::ldlq::round_matrix(&w, 4, crate::quant::RoundMode::Nearest, 0);
        let pl = crate::quant::proxy_loss(&l, &w, &h);
        let pn = crate::quant::proxy_loss(&n, &w, &h);
        assert!(pl > 2.0 * pn, "clamped LDLQ {pl} vs nearest {pn}");
    }

    #[test]
    fn alg5_fixes_the_counterexample() {
        let (w, h) = make_counterexample(64, 8, 0.01);
        let plan = crate::quant::alg5::solve(&h, 0.1, 200, 1e-9);
        let a5 = crate::quant::ldlq::ldlq_with_feedback(
            &w, &plan.u_dot, 4, crate::quant::RoundMode::Stochastic, 1);
        let l = crate::quant::ldlq::ldlq(&w, &h, 4, crate::quant::RoundMode::Nearest, 0);
        let pa = crate::quant::proxy_loss(&a5, &w, &h);
        let pl = crate::quant::proxy_loss(&l, &w, &h);
        assert!(pa < pl, "alg5 {pa} should beat clamped ldlq {pl}");
    }
}
