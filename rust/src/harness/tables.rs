//! Table regenerators. Each prints the paper-shaped rows and writes
//! results/tableN.json. Paper → substrate mapping in DESIGN.md §5.

use super::env::{f2, pct, write_result, Env, TablePrinter};
use crate::engine::native::{decode_step_with, FpLinears, QuantLinears};
use crate::linalg::ldl::udu;
use crate::linalg::Mat;
use crate::model::Transformer;
use crate::quant::{quantize_layer_with, Processing, QuantConfig, RounderRegistry};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Paper Table 1 — largest model, QuIP vs OPTQ at 16/4/3/2 bits.
pub fn table1(args: &Args) -> crate::Result<()> {
    let env = Env::load(args)?;
    let model = args.opt_or("model", "s2");
    println!("Table 1 analog — {model}: QuIP (LDLQ+IncP) vs OPTQ (LDLQ+baseline)\n");
    let mut tp = TablePrinter::new(&[
        "wbits", "method", "wiki↓", "ptb↓", "c4↓", "arce↑", "piqa↑", "sc↑",
    ]);
    let mut out = Json::obj();
    for bits in [16u32, 4, 3, 2] {
        for (label, rounder, processing) in [
            ("optq", "ldlq", Processing::baseline()),
            ("quip", "ldlq", Processing::incoherent()),
        ] {
            let r = env.run_recipe(&model, bits, rounder, processing)?;
            tp.row(vec![
                bits.to_string(),
                label.into(),
                f2(r.ppl["wiki"]),
                f2(r.ppl["ptb"]),
                f2(r.ppl["c4"]),
                pct(r.acc["arce"]),
                pct(r.acc["piqa"]),
                pct(r.acc["sc"]),
            ]);
            out.set(&format!("{label}_w{bits}"), r.to_json());
            if bits == 16 {
                break; // fp row identical for both methods
            }
        }
    }
    tp.print();
    write_result("table1", &out)?;
    Ok(())
}

/// Paper Table 2 (and 7–13) — all rounding methods × processing.
pub fn table2(args: &Args) -> crate::Result<()> {
    let env = Env::load(args)?;
    let models: Vec<String> = if args.flag("all-sizes") {
        vec!["s0".into(), "s1".into(), "s2".into()]
    } else {
        vec![args.opt_or("model", "s1")]
    };
    let methods = ["ldlq", "ldlq-rg", "greedy", "near"];
    let mut out = Json::obj();
    for model in &models {
        println!("\nTable 2 analog — {model}: methods × processing\n");
        let mut tp = TablePrinter::new(&[
            "processing", "method", "wbits", "wiki↓", "ptb↓", "c4↓", "arce↑", "lamb↑",
        ]);
        let fp = env.run_recipe(model, 16, "ldlq", Processing::baseline())?;
        tp.row(vec![
            "-".into(),
            "fp32".into(),
            "16".into(),
            f2(fp.ppl["wiki"]),
            f2(fp.ppl["ptb"]),
            f2(fp.ppl["c4"]),
            pct(fp.acc["arce"]),
            pct(fp.acc["lamb"]),
        ]);
        out.set(&format!("{model}_fp"), fp.to_json());
        for (pname, processing) in [
            ("baseline", Processing::baseline()),
            ("incp", Processing::incoherent()),
        ] {
            for mname in methods {
                for bits in [4u32, 3, 2] {
                    let r = env.run_recipe(model, bits, mname, processing.clone())?;
                    tp.row(vec![
                        pname.into(),
                        mname.into(),
                        bits.to_string(),
                        f2(r.ppl["wiki"]),
                        f2(r.ppl["ptb"]),
                        f2(r.ppl["c4"]),
                        pct(r.acc["arce"]),
                        pct(r.acc["lamb"]),
                    ]);
                    out.set(&format!("{model}_{pname}_{mname}_w{bits}"), r.to_json());
                }
            }
        }
        tp.print();
    }
    write_result("table2", &out)?;
    Ok(())
}

/// Paper Table 3 — ablating the incoherence-processing sub-steps.
pub fn table3(args: &Args) -> crate::Result<()> {
    let env = Env::load(args)?;
    let model = args.opt_or("model", "s0");
    println!("Table 3 analog — {model}: IncP sub-step ablation (mean ppl over splits)\n");
    let variants: Vec<(&str, Processing)> = vec![
        ("rescale", {
            let mut p = Processing::baseline();
            p.rescale = true;
            p
        }),
        ("incoherence", {
            let mut p = Processing::baseline();
            p.incoherent = true;
            p.permute = true;
            p
        }),
        ("rescale+incoherence", {
            let mut p = Processing::incoherent();
            p.frob_range = false;
            p
        }),
        ("rescale+incoherence+quantrange", Processing::incoherent()),
    ];
    let mut tp = TablePrinter::new(&["wbits", "rescale", "incoh", "resc+incoh", "resc+incoh+range"]);
    let mut out = Json::obj();
    for bits in [4u32, 3, 2] {
        let mut cells = vec![bits.to_string()];
        for (name, p) in &variants {
            let r = env.run_recipe(&model, bits, "ldlq", p.clone())?;
            cells.push(f2(r.mean_ppl()));
            out.set(&format!("{name}_w{bits}"), Json::Num(r.mean_ppl()));
        }
        tp.row(cells);
    }
    tp.print();
    write_result("table3", &out)?;
    Ok(())
}

/// Paper Table 4 — per-token generation throughput: QuIP's incoherence
/// overhead vs the OPTQ-style kernel (plus the fp32 reference and the
/// PJRT kernel artifact when present).
pub fn table4(args: &Args) -> crate::Result<()> {
    let env = Env::load(args)?;
    let model = args.opt_or("model", "s1");
    let ck = env.checkpoint(&model)?;
    let m = Transformer::from_checkpoint(&ck)?;
    let bits = args.opt_usize("bits", 2) as u32;

    let (q_base, _) = env.quantize(
        &model,
        QuantConfig::builder()
            .bits(bits)
            .rounder("ldlq")
            .processing(Processing::baseline())
            .build()?,
    )?;
    let (q_incp, _) = env.quantize(
        &model,
        QuantConfig::builder()
            .bits(bits)
            .rounder("ldlq")
            .processing(Processing::incoherent())
            .build()?,
    )?;
    let lin_base = QuantLinears::from_model(&q_base)?;
    let lin_incp = QuantLinears::from_model(&q_incp)?;
    let fp = FpLinears { model: &m };

    let tokens = args.opt_usize("tokens", 128);
    let bench = |lin: &dyn crate::engine::native::LinearOps| {
        let mut cache = m.new_cache();
        // warmup a few tokens
        for t in 0..4u32 {
            decode_step_with(&m, lin, &mut cache, t + 1);
        }
        let t0 = std::time::Instant::now();
        let mut tok = 1u32;
        let mut n = 0usize;
        while n < tokens {
            if cache.len() >= m.cfg.max_seq {
                cache.reset();
            }
            let logits = decode_step_with(&m, lin, &mut cache, tok);
            tok = (logits[0].abs() as u32 % 250) + 1;
            n += 1;
        }
        t0.elapsed().as_secs_f64() / tokens as f64
    };

    let t_fp = bench(&fp);
    let t_base = bench(&lin_base);
    let t_incp = bench(&lin_incp);

    println!(
        "Table 4 analog — {model}, {bits}-bit, {tokens} tokens, seq {}\n",
        m.cfg.max_seq
    );
    let mut tp = TablePrinter::new(&["engine", "ms/token", "vs optq"]);
    tp.row(vec!["fp32 (reference)".into(), f2(t_fp * 1e3), f2(t_fp / t_base)]);
    tp.row(vec!["optq-style (no IncP)".into(), f2(t_base * 1e3), "1.00".into()]);
    tp.row(vec!["quip (IncP)".into(), f2(t_incp * 1e3), f2(t_incp / t_base)]);
    tp.print();
    println!(
        "\npaper: QuIP 81ms vs OPTQ 53ms (1.53×) on OPT-66B/A6000 — the\n\
         reproduction target is the *ratio*, here {:.2}×",
        t_incp / t_base
    );

    let mut out = Json::obj();
    out.set("fp32_ms", Json::Num(t_fp * 1e3));
    out.set("optq_ms", Json::Num(t_base * 1e3));
    out.set("quip_ms", Json::Num(t_incp * 1e3));
    out.set("ratio", Json::Num(t_incp / t_base));
    write_result("table4", &out)?;
    Ok(())
}

/// Paper Table 5 — random-permutation ablation inside the fast orthogonal
/// multiply: Δ mean perplexity (with − without permutation).
pub fn table5(args: &Args) -> crate::Result<()> {
    let env = Env::load(args)?;
    let model = args.opt_or("model", "s0");
    println!("Table 5 analog — {model}: Δppl from random permutation (negative = helps)\n");
    let mut tp = TablePrinter::new(&["wbits", "with perm", "without perm", "Δ(with-without)"]);
    let mut out = Json::obj();
    for bits in [4u32, 3, 2] {
        let with = env.run_recipe(&model, bits, "ldlq", Processing::incoherent())?;
        let mut p = Processing::incoherent();
        p.permute = false;
        let without = env.run_recipe(&model, bits, "ldlq", p)?;
        let d = with.mean_ppl() - without.mean_ppl();
        tp.row(vec![
            bits.to_string(),
            f2(with.mean_ppl()),
            f2(without.mean_ppl()),
            format!("{d:+.2}"),
        ]);
        out.set(&format!("w{bits}"), Json::Num(d));
    }
    tp.print();
    write_result("table5", &out)?;
    Ok(())
}

/// Paper Table 6 — Hessian rank statistics + tr(D)/tr(H) across layers,
/// baseline vs incoherent processing.
pub fn table6(args: &Args) -> crate::Result<()> {
    let env = Env::load(args)?;
    let models: Vec<&str> = vec!["s0", "s1"];
    println!("Table 6 analog — H stats across layers (mean ± std)\n");
    let mut tp = TablePrinter::new(&[
        "model", "processing", "abs-frac-rank", "approx-frac-rank", "tr(D)/tr(H)",
    ]);
    let mut out = Json::obj();
    for model in models {
        let ck = env.checkpoint(model)?;
        let (hessians, weights) = collect_hessians(&env, &ck)?;
        for incoherent in [false, true] {
            let mut ranks_abs = Vec::new();
            let mut ranks_apx = Vec::new();
            let mut ratios = Vec::new();
            for (h, w) in hessians.iter().zip(&weights) {
                let (h_used, _w_used) = if incoherent {
                    let p = Processing::incoherent();
                    let pre = crate::quant::incoherence::preprocess(w, h, 8, &p, 33);
                    (pre.h, ())
                } else {
                    (h.clone(), ())
                };
                let e = crate::linalg::eigen::eigen_sym(&h_used, 1e-11, 40);
                ranks_abs.push(e.abs_frac_rank());
                ranks_apx.push(e.approx_frac_rank(0.01));
                let f = udu(&h_used, 1e-12);
                ratios.push(f.trace_d() / h_used.trace().max(1e-30));
            }
            let stats = |v: &[f64]| {
                let m = v.iter().sum::<f64>() / v.len() as f64;
                let s = (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64).sqrt();
                format!("{m:.3} (±{s:.3})")
            };
            tp.row(vec![
                model.into(),
                if incoherent { "incoherent" } else { "baseline" }.into(),
                stats(&ranks_abs),
                stats(&ranks_apx),
                stats(&ratios),
            ]);
            let mut o = Json::obj();
            o.set("trd_trh", crate::util::json::arr_f64(&ratios));
            o.set("approx_rank", crate::util::json::arr_f64(&ranks_apx));
            out.set(&format!("{model}_{incoherent}"), o);
        }
    }
    tp.print();
    println!("\npaper: tr(D)/tr(H) ≤ 0.65 across OPT models, falling with size.");
    write_result("table6", &out)?;
    Ok(())
}

/// Collect per-hkey Hessians (and the matching weights) of a model from
/// calibration data — shared by tables 6/14/15 and figures 1–3.
pub fn collect_hessians(
    env: &Env,
    ck: &crate::model::weights::Checkpoint,
) -> crate::Result<(Vec<Mat>, Vec<Mat>)> {
    let model = Transformer::from_checkpoint(ck)?;
    let calib = env.calibration(ck.config.max_seq.min(128))?;
    let mut hset = crate::hessian::HessianSet::for_model(&ck.config);
    {
        let mut sink = hset.sink();
        for seq in &calib {
            model.forward(seq, Some(&mut sink));
        }
    }
    let mut hs = Vec::new();
    let mut ws = Vec::new();
    for spec in ck.config.linear_specs() {
        // One H per layer; qkv share, but the paper reports per-layer.
        if !spec.name.ends_with("wq") && spec.hkey.ends_with("attn.in") {
            continue; // skip duplicated qkv Hessians (keep wq's)
        }
        hs.push(hset.finish(&spec.hkey)?);
        let wdata = model.get_weight(&spec.name)?;
        ws.push(Mat {
            rows: spec.out_dim,
            cols: spec.in_dim,
            data: wdata.iter().map(|&x| x as f64).collect(),
        });
    }
    Ok((hs, ws))
}

/// Paper Table 14 — proxy loss by rounding method (no processing).
pub fn table14(args: &Args) -> crate::Result<()> {
    let env = Env::load(args)?;
    let model = args.opt_or("model", "s0");
    let ck = env.checkpoint(&model)?;
    let (hessians, weights) = collect_hessians(&env, &ck)?;
    println!("Table 14 analog — {model}: proxy loss by method (normalized by d_model)\n");
    let methods = [
        ("ldlq/optq", "ldlq"),
        ("ldlq-rg", "ldlq-rg"),
        ("greedy", "greedy"),
        ("near", "near"),
    ];
    let mut tp = TablePrinter::new(&["wbits", "ldlq/optq", "ldlq-rg", "greedy", "near"]);
    let mut out = Json::obj();
    for bits in [4u32, 3, 2] {
        let mut cells = vec![bits.to_string()];
        for (name, rname) in methods {
            let rounder = RounderRegistry::global().resolve(rname)?;
            // Proxy evaluation is about the *rounding* methods: per-row
            // grid, no incoherence (paper: "We do not conduct any
            // processing in the proxy evaluation").
            let cfg = QuantConfig::builder()
                .bits(bits)
                .rounder(rname)
                .processing(Processing::baseline())
                .greedy_passes(3)
                .build()?;
            let mut total = 0.0;
            for (h, w) in hessians.iter().zip(&weights) {
                let r = quantize_layer_with(rounder.as_ref(), w, h, &cfg, 5);
                total += r.proxy_loss;
            }
            let norm = total / ck.config.d_model as f64;
            cells.push(format!("{norm:.4}"));
            out.set(&format!("{name}_w{bits}"), Json::Num(norm));
        }
        tp.row(cells);
    }
    tp.print();
    println!("\npaper shape: LDLQ ≈ LDLQ-RG ≈ Greedy ≪ Near at 2 bits.");
    write_result("table14", &out)?;
    Ok(())
}

/// Paper Table 15 — unbiased (stochastic) vs biased (nearest) LDLQ.
pub fn table15(args: &Args) -> crate::Result<()> {
    let env = Env::load(args)?;
    let model = args.opt_or("model", "s0");
    println!("Table 15 analog — {model}: mean ppl(unbiased) − ppl(biased), LDLQ\n");
    let mut tp = TablePrinter::new(&["wbits", "incp Δ", "baseline Δ"]);
    let mut out = Json::obj();
    for bits in [4u32, 3, 2] {
        let mut cells = vec![bits.to_string()];
        for processing in [Processing::incoherent(), Processing::baseline()] {
            let pname = if processing.incoherent { "incp" } else { "base" };
            let biased = env.run_recipe(&model, bits, "ldlq", processing.clone())?;
            // Unbiased: force the stochastic Q subroutine inside LDLQ.
            let ck = env.checkpoint(&model)?;
            let mut m = Transformer::from_checkpoint(&ck)?;
            let (qm, _) = {
                let cfg = QuantConfig::builder()
                    .bits(bits)
                    .rounder("ldlq")
                    .processing(processing.clone())
                    .force_stochastic(true)
                    .build()?;
                env.quantize(&model, cfg)?
            };
            qm.apply_to(&mut m)?;
            let unbiased = env.evaluate(&m);
            let d = unbiased.mean_ppl() - biased.mean_ppl();
            cells.push(format!("{d:+.2}"));
            out.set(&format!("{pname}_w{bits}"), Json::Num(d));
        }
        tp.row(cells);
    }
    tp.print();
    println!("\npaper: differences are positive (unbiased worse), growing at low bits.");
    write_result("table15", &out)?;
    Ok(())
}

/// Paper Table 16 — Algorithm 5 (clamp-aware convex program) vs QuIP.
pub fn table16(args: &Args) -> crate::Result<()> {
    let env = Env::load(args)?;
    let model = args.opt_or("model", "s0");
    println!("Table 16 analog — {model}: Algorithm 5 vs QuIP (LDLQ)\n");
    let mut tp = TablePrinter::new(&["wbits", "processing", "alg5 wiki↓", "quip wiki↓"]);
    let mut out = Json::obj();
    for bits in [4u32, 3, 2] {
        for processing in [Processing::incoherent(), Processing::baseline()] {
            let pname = if processing.incoherent { "incp" } else { "base" };
            let alg5 = env.run_recipe(&model, bits, "alg5", processing.clone())?;
            let quip = env.run_recipe(&model, bits, "ldlq", processing.clone())?;
            tp.row(vec![
                bits.to_string(),
                pname.into(),
                f2(alg5.ppl["wiki"]),
                f2(quip.ppl["wiki"]),
            ]);
            out.set(&format!("alg5_{pname}_w{bits}"), Json::Num(alg5.ppl["wiki"]));
            out.set(&format!("quip_{pname}_w{bits}"), Json::Num(quip.ppl["wiki"]));
        }
    }
    tp.print();
    write_result("table16", &out)?;
    Ok(())
}

/// Supplement C.2 — the OPTQ ≡ LDLQ empirical verification at the paper's
/// scale (W ~ Unif[0,1]^{1000×1000}).
pub fn table_optq(args: &Args) -> crate::Result<()> {
    let n = args.opt_usize("n", 1000);
    let m = args.opt_usize("m", 1000);
    println!("OPTQ ≡ LDLQ equivalence check (W ~ Unif[0,1]^{{{m}×{n}}})\n");
    let mut rng = Rng::new(2023);
    let h = crate::util::testkit::random_spd(&mut rng, n, 1e-2);
    let wg = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 15.0));
    let t0 = std::time::Instant::now();
    let a = crate::quant::optq::optq(&wg, &h, 4)?;
    let t_optq = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let b = crate::quant::ldlq::ldlq(&wg, &h, 4, crate::quant::RoundMode::Nearest, 0);
    let t_ldlq = t1.elapsed().as_secs_f64();
    let mismatches = a.data.iter().zip(&b.data).filter(|(x, y)| x != y).count();
    println!("identical outputs: {}", mismatches == 0);
    println!("mismatched codes : {mismatches}/{}", a.data.len());
    println!("OPTQ time        : {t_optq:.2}s (matrix inversion + 2 Cholesky-ish)");
    println!("LDLQ time        : {t_ldlq:.2}s (1 LDL, no inversion)");
    anyhow::ensure!(mismatches == 0, "Theorem 6 violated!");
    let mut out = Json::obj();
    out.set("m", Json::Num(m as f64));
    out.set("n", Json::Num(n as f64));
    out.set("mismatches", Json::Num(mismatches as f64));
    out.set("optq_seconds", Json::Num(t_optq));
    out.set("ldlq_seconds", Json::Num(t_ldlq));
    write_result("table_optq", &out)?;
    Ok(())
}
