//! Shared experiment environment: artifacts, checkpoints, eval splits,
//! task sets, recipe runners and result output.

use crate::coordinator::pipeline::{quantize_model, PipelineConfig};
use crate::data::{TaskSet, TokenStream};
use crate::model::lm;
use crate::model::quantized::QuantizedModel;
use crate::model::weights::Checkpoint;
use crate::model::Transformer;
use crate::quant::{Processing, QuantConfig};
use crate::runtime::registry::{default_root, Registry};
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::PathBuf;

/// Evaluation splits (wiki/ptb/c4 analogs) and task sets (lamb/arce/piqa/sc).
pub const SPLITS: [&str; 3] = ["wiki", "ptb", "c4"];
pub const TASKS: [&str; 4] = ["lamb", "arce", "piqa", "sc"];

pub struct Env {
    pub registry: Registry,
    pub splits: HashMap<String, TokenStream>,
    pub tasks: HashMap<String, TaskSet>,
    /// Eval budget: sequences per split (–fast lowers it).
    pub eval_seqs: usize,
    pub task_limit: usize,
    pub calib_seqs: usize,
    checkpoints: std::cell::RefCell<HashMap<String, std::rc::Rc<Checkpoint>>>,
}

impl Env {
    /// Load the experiment environment; requires `make artifacts`.
    pub fn load(args: &crate::util::cli::Args) -> crate::Result<Env> {
        let root = args
            .opt("artifacts")
            .map(PathBuf::from)
            .unwrap_or_else(default_root);
        let registry = Registry::load(&root)?;
        let mut splits = HashMap::new();
        for s in SPLITS {
            splits.insert(s.to_string(), TokenStream::load(&registry.split(s))?);
        }
        let mut tasks = HashMap::new();
        for t in TASKS {
            tasks.insert(t.to_string(), TaskSet::load(&registry.tasks(t))?);
        }
        let fast = args.flag("fast");
        Ok(Env {
            registry,
            splits,
            tasks,
            eval_seqs: if fast { 6 } else { args.opt_usize("eval-seqs", 16) },
            task_limit: if fast { 40 } else { args.opt_usize("task-limit", 120) },
            calib_seqs: if fast { 8 } else { args.opt_usize("calib", 24) },
            checkpoints: Default::default(),
        })
    }

    pub fn checkpoint(&self, model: &str) -> crate::Result<std::rc::Rc<Checkpoint>> {
        if let Some(ck) = self.checkpoints.borrow().get(model) {
            return Ok(std::rc::Rc::clone(ck));
        }
        let ck = std::rc::Rc::new(Checkpoint::load(&self.registry.checkpoint(model))?);
        self.checkpoints
            .borrow_mut()
            .insert(model.to_string(), std::rc::Rc::clone(&ck));
        Ok(ck)
    }

    /// Calibration windows from the *train* distribution (the paper: no
    /// task data seen at quantization time). Uses the wiki split's sibling
    /// train.bin.
    pub fn calibration(&self, seq_len: usize) -> crate::Result<Vec<Vec<u32>>> {
        let train = TokenStream::load(&self.registry.split("train"))?;
        Ok(train.calibration(seq_len, self.calib_seqs, 0xCA11B))
    }

    /// Quantize `model` with the given recipe and return the artifact.
    pub fn quantize(
        &self,
        model: &str,
        quant: QuantConfig,
    ) -> crate::Result<(QuantizedModel, f64)> {
        let ck = self.checkpoint(model)?;
        let calib = self.calibration(ck.config.max_seq.min(128))?;
        let pcfg = PipelineConfig {
            quant,
            calib_seqs: self.calib_seqs,
            calib_seq_len: 128,
            seed: 0x5155_4950,
            ..Default::default()
        };
        let (qm, report) = quantize_model(&ck, &calib, &pcfg)?;
        Ok((qm, report.total_proxy()))
    }

    /// Full evaluation of an fp32 model: per-split perplexity + task acc.
    pub fn evaluate(&self, model: &Transformer) -> EvalResult {
        let mut ppl = HashMap::new();
        for s in SPLITS {
            let stream = &self.splits[s];
            ppl.insert(
                s.to_string(),
                lm::perplexity(model, stream, model.cfg.max_seq.min(128), self.eval_seqs),
            );
        }
        let mut acc = HashMap::new();
        for t in TASKS {
            let full = &self.tasks[t];
            let limited = TaskSet {
                name: full.name.clone(),
                instances: full
                    .instances
                    .iter()
                    .take(self.task_limit)
                    .cloned()
                    .collect(),
            };
            acc.insert(t.to_string(), lm::score_tasks(model, &limited).accuracy);
        }
        EvalResult { ppl, acc }
    }

    /// Quantize + evaluate one recipe. The rounding algorithm is named
    /// (any [`crate::quant::RounderRegistry`] alias, e.g. `"ldlq"`,
    /// `"quip"`, `"gptq"`, `"allbal"`). `bits == 16` means "no
    /// quantization" (the fp baseline row).
    pub fn run_recipe(
        &self,
        model: &str,
        bits: u32,
        rounder: &str,
        processing: Processing,
    ) -> crate::Result<EvalResult> {
        let ck = self.checkpoint(model)?;
        let mut m = Transformer::from_checkpoint(&ck)?;
        if bits < 16 {
            let cfg = QuantConfig::builder()
                .bits(bits)
                .rounder(rounder)
                .processing(processing)
                .greedy_passes(5)
                .build()?;
            let (qm, _) = self.quantize(model, cfg)?;
            qm.apply_to(&mut m)?;
        }
        Ok(self.evaluate(&m))
    }
}

#[derive(Clone, Debug)]
pub struct EvalResult {
    pub ppl: HashMap<String, f64>,
    pub acc: HashMap<String, f64>,
}

impl EvalResult {
    pub fn mean_ppl(&self) -> f64 {
        self.ppl.values().sum::<f64>() / self.ppl.len().max(1) as f64
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        let mut p = Json::obj();
        for (k, v) in &self.ppl {
            p.set(k, Json::Num(*v));
        }
        let mut a = Json::obj();
        for (k, v) in &self.acc {
            a.set(k, Json::Num(*v));
        }
        j.set("ppl", p);
        j.set("acc", a);
        j
    }
}

/// Write a result JSON under results/.
pub fn write_result(name: &str, j: &Json) -> crate::Result<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results");
    let path = dir.join(format!("{name}.json"));
    crate::util::fsx::atomic_write(&path, j.pretty().as_bytes())?;
    println!("→ results/{name}.json");
    Ok(path)
}

/// Aligned table printer.
pub struct TablePrinter {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TablePrinter {
    pub fn new(headers: &[&str]) -> TablePrinter {
        TablePrinter {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate().take(ncol) {
                s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Format helpers for table cells.
pub fn f2(x: f64) -> String {
    if x >= 10_000.0 {
        format!("{:.3e}", x)
    } else {
        format!("{:.2}", x)
    }
}

pub fn pct(x: f64) -> String {
    format!("{:.1}", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_printer_aligns_and_prints() {
        let mut tp = TablePrinter::new(&["name", "value"]);
        tp.row(vec!["a".into(), "1.00".into()]);
        tp.row(vec!["long-name".into(), "2".into()]);
        tp.print(); // visual; must not panic on ragged widths
        assert_eq!(tp.rows.len(), 2);
    }

    #[test]
    fn formatters() {
        assert_eq!(f2(1.234), "1.23");
        assert!(f2(123456.0).contains('e'));
        assert_eq!(pct(0.515), "51.5");
    }

    #[test]
    fn eval_result_mean_and_json() {
        let mut ppl = std::collections::HashMap::new();
        ppl.insert("wiki".to_string(), 10.0);
        ppl.insert("ptb".to_string(), 20.0);
        let r = EvalResult {
            ppl,
            acc: std::collections::HashMap::new(),
        };
        assert_eq!(r.mean_ppl(), 15.0);
        assert!(r.to_json().get("ppl").is_some());
    }
}
