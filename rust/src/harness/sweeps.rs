//! Hyperparameter sweeps — the knobs the paper tunes but does not table:
//!
//! * `rho`    — the quantization-range multiplier (Supplement B.1: "we
//!   tune it and find that a value of 2.4 works well across all our
//!   experiments")
//! * `calib`  — calibration-set size (paper fixes 128 segments)
//! * `greedy` — greedy polish passes (paper: 10, or 5 on the largest)
//!
//! `quip sweep <rho|calib|greedy> [--model s0] [--bits 2]`.

use super::env::{f2, write_result, Env, TablePrinter};
use crate::coordinator::pipeline::{quantize_model, PipelineConfig};
use crate::model::Transformer;
use crate::quant::{Processing, QuantConfig};
use crate::util::cli::Args;
use crate::util::json::{arr_f64, Json};

pub fn run_sweep(which: &str, args: &Args) -> crate::Result<()> {
    match which {
        "rho" => sweep_rho(args),
        "calib" => sweep_calib(args),
        "greedy" => sweep_greedy(args),
        other => anyhow::bail!("unknown sweep '{other}' (rho, calib, greedy)"),
    }
}

/// ρ sweep: too small clips the distribution tails hard, too large wastes
/// grid levels; the paper lands on 2.4.
fn sweep_rho(args: &Args) -> crate::Result<()> {
    let env = Env::load(args)?;
    let model = args.opt_or("model", "s0");
    let bits = args.opt_usize("bits", 2) as u32;
    println!("ρ sweep — {model} @ {bits} bits (paper tunes ρ = 2.4)\n");
    let mut tp = TablePrinter::new(&["rho", "mean ppl↓", "proxy loss↓"]);
    let mut rhos = Vec::new();
    let mut ppls = Vec::new();
    for rho in [1.2, 1.8, 2.4, 3.2, 4.5] {
        let mut processing = Processing::incoherent();
        processing.rho = rho;
        let ck = env.checkpoint(&model)?;
        let (qm, proxy) = env.quantize(
            &model,
            QuantConfig::builder()
                .bits(bits)
                .rounder("ldlq")
                .processing(processing)
                .build()?,
        )?;
        let mut m = Transformer::from_checkpoint(&ck)?;
        qm.apply_to(&mut m)?;
        let r = env.evaluate(&m);
        tp.row(vec![format!("{rho:.1}"), f2(r.mean_ppl()), format!("{proxy:.3}")]);
        rhos.push(rho);
        ppls.push(r.mean_ppl());
    }
    tp.print();
    let best = rhos[argmin(&ppls)];
    println!("\nbest ρ here: {best:.1} (paper: 2.4 across all their experiments)");
    let mut out = Json::obj();
    out.set("rho", arr_f64(&rhos));
    out.set("mean_ppl", arr_f64(&ppls));
    write_result("sweep_rho", &out)?;
    Ok(())
}

/// Calibration-size sweep: H quality vs cost.
fn sweep_calib(args: &Args) -> crate::Result<()> {
    let env = Env::load(args)?;
    let model = args.opt_or("model", "s0");
    let bits = args.opt_usize("bits", 2) as u32;
    println!("calibration-size sweep — {model} @ {bits} bits (paper: 128 segments)\n");
    let ck = env.checkpoint(&model)?;
    let train = crate::data::TokenStream::load(&env.registry.split("train"))?;
    let mut tp = TablePrinter::new(&["segments", "mean ppl↓"]);
    let mut sizes = Vec::new();
    let mut ppls = Vec::new();
    for segs in [2usize, 8, 24, 64] {
        let calib = train.calibration(128, segs, 0xCA11B);
        let pcfg = PipelineConfig {
            quant: QuantConfig::builder()
                .bits(bits)
                .rounder("ldlq")
                .processing(Processing::incoherent())
                .build()?,
            calib_seqs: segs,
            calib_seq_len: 128,
            seed: 0x5155_4950,
        };
        let (qm, _) = quantize_model(&ck, &calib, &pcfg)?;
        let mut m = Transformer::from_checkpoint(&ck)?;
        qm.apply_to(&mut m)?;
        let r = env.evaluate(&m);
        tp.row(vec![segs.to_string(), f2(r.mean_ppl())]);
        sizes.push(segs as f64);
        ppls.push(r.mean_ppl());
    }
    tp.print();
    println!("\nexpected shape: diminishing returns once H is well estimated.");
    let mut out = Json::obj();
    out.set("segments", arr_f64(&sizes));
    out.set("mean_ppl", arr_f64(&ppls));
    write_result("sweep_calib", &out)?;
    Ok(())
}

/// Greedy polish passes (used by LDLQ-RG / QuIP-RG).
fn sweep_greedy(args: &Args) -> crate::Result<()> {
    let env = Env::load(args)?;
    let model = args.opt_or("model", "s0");
    let bits = args.opt_usize("bits", 2) as u32;
    println!("greedy-passes sweep — {model} @ {bits} bits (paper: 10 passes, 5 on 30b/66b)\n");
    let mut tp = TablePrinter::new(&["passes", "proxy loss↓", "mean ppl↓"]);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for passes in [0usize, 1, 3, 10] {
        let ck = env.checkpoint(&model)?;
        let (qm, proxy) = env.quantize(
            &model,
            QuantConfig::builder()
                .bits(bits)
                .rounder("ldlq-rg")
                .processing(Processing::incoherent())
                .greedy_passes(passes)
                .build()?,
        )?;
        let mut m = Transformer::from_checkpoint(&ck)?;
        qm.apply_to(&mut m)?;
        let r = env.evaluate(&m);
        tp.row(vec![passes.to_string(), format!("{proxy:.4}"), f2(r.mean_ppl())]);
        xs.push(passes as f64);
        ys.push(proxy);
    }
    tp.print();
    // Greedy is a descent method on the proxy: more passes never hurt it.
    for w in ys.windows(2) {
        anyhow::ensure!(w[1] <= w[0] * 1.001, "greedy passes increased proxy");
    }
    let mut out = Json::obj();
    out.set("passes", arr_f64(&xs));
    out.set("proxy", arr_f64(&ys));
    write_result("sweep_greedy", &out)?;
    Ok(())
}

fn argmin(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn argmin_works() {
        assert_eq!(super::argmin(&[3.0, 1.0, 2.0]), 1);
        assert_eq!(super::argmin(&[5.0]), 0);
    }
}
