//! Hyperparameter sweeps — the knobs the paper tunes but does not table:
//!
//! * `rho`    — the quantization-range multiplier (Supplement B.1: "we
//!   tune it and find that a value of 2.4 works well across all our
//!   experiments")
//! * `calib`  — calibration-set size (paper fixes 128 segments)
//! * `greedy` — greedy polish passes (paper: 10, or 5 on the largest)
//!
//! plus the serving-side `batch` sweep: tokens/sec of the batched fused
//! packed-weight engine vs batch size {1, 4, 16, 64} at 2/3/4 bits,
//! against the repeated single-vector `QuantLinear::apply` baseline
//! (EXPERIMENTS.md §Perf records the results),
//!
//! plus the `transform` sweep: the incoherence-transform backends (kron
//! vs hadamard) compared end-to-end — quantize → save a v2 `.qz` → load →
//! decode — on proxy loss and per-token transform cost at 2/3/4 bits
//! (EXPERIMENTS.md §Perf 3),
//!
//! plus the `quant` sweep: quantization-throughput stages — Hessian
//! accumulation (scalar rank-1 vs blocked SYRK), LDL/Cholesky
//! factorization (scalar vs blocked), and LDLQ rounding — timed per stage
//! across n ∈ {256, 512, 1024} × bits ∈ {2, 4}, with end-to-end
//! seconds/layer for both kernel sets (EXPERIMENTS.md §Perf 4),
//!
//! plus the `codebook` sweep: scalar-LDLQ vs the E8-style vector
//! codebook (`vq`) at equal bitrate — proxy loss, bits/weight and decode
//! ms/token through quantize → save v3 `.qz` → load → decode
//! (EXPERIMENTS.md §Quality),
//!
//! plus the `serve` sweep: contiguous vs paged KV caches through the
//! continuous-batching loop — KV bytes per active token, tokens/s, the
//! prefix-sharing hit numbers, and the shed rate of a real server under
//! synthetic overload of a deliberately tiny pool (EXPERIMENTS.md
//! §Perf 6),
//!
//! plus the `session` sweep: the crash-resume drill (DESIGN.md §10) —
//! quantize with a `.qzp` journal, kill at a seeded block boundary,
//! resume, verify the artifact is byte-identical to an uninterrupted
//! run, and report the crash-path cost vs a cold start (EXPERIMENTS.md
//! §Robustness) — followed by the sharded-memory phase (DESIGN.md §11):
//! the same quantization under a Hessian budget small enough to force
//! spills plus a 3-worker layer pool, reporting peak resident bytes and
//! spill count and requiring the artifact byte-identical to the
//! unlimited run (EXPERIMENTS.md §Perf 7).
//!
//! `quip sweep <rho|calib|greedy|batch|transform|quant|codebook|serve|session>
//! [--model s0] [--bits 2]`. `batch`, `transform`, `quant`, `codebook`,
//! `serve` and `session` are artifact-free (synthetic inputs) so they
//! run anywhere, including CI (`--fast`).

use super::env::{f2, write_result, Env, TablePrinter};
use crate::coordinator::pipeline::{quantize_model, PipelineConfig};
use crate::model::Transformer;
use crate::quant::{Processing, QuantConfig};
use crate::util::cli::Args;
use crate::util::json::{arr_f64, Json};

pub fn run_sweep(which: &str, args: &Args) -> crate::Result<()> {
    match which {
        "rho" => sweep_rho(args),
        "calib" => sweep_calib(args),
        "greedy" => sweep_greedy(args),
        "batch" => sweep_batch(args),
        "transform" => sweep_transform(args),
        "quant" => sweep_quant(args),
        "codebook" => sweep_codebook(args),
        "serve" => sweep_serve(args),
        "session" => sweep_session(args),
        other => {
            anyhow::bail!(
                "unknown sweep '{other}' (rho, calib, greedy, batch, transform, quant, codebook, \
                 serve, session)"
            )
        }
    }
}

/// Crash-resume drill (DESIGN.md §10): quantize a synthetic checkpoint
/// with a `.qzp` journal, kill the session at a seeded block boundary
/// (soft fault — the journal on disk is exactly what a process kill
/// would leave), resume, and require the final artifact byte-identical
/// to an uninterrupted run. Reports the crash-path cost (interrupted +
/// resume wall-clock) against the cold run. A second phase reruns the
/// quantization budget-capped (spilling Hessians, 3 layer workers) and
/// pins peak resident bytes, spill count, and byte-identity (DESIGN.md
/// §11). Artifact-free; CI runs it with `--fast`.
fn sweep_session(args: &Args) -> crate::Result<()> {
    use crate::coordinator::QuantSession;
    use crate::data::gen::markov_stream;
    use crate::model::quantized::QZ_VERSION;
    use crate::model::weights::Checkpoint;
    use crate::model::ModelConfig;
    use crate::util::fault::{FaultInjector, FaultSpec};
    use std::sync::Arc;
    use std::time::Instant;

    let fast = args.flag("fast");
    let cfg = if fast {
        ModelConfig::sized("t", 32, 2, 4, 64)
    } else {
        ModelConfig::sized("t", 64, 4, 4, 256)
    };
    let seed = args.opt_u64("seed", 0x5EED);
    let bits = args.opt_usize("bits", 2) as u32;
    let ck = Checkpoint::random(&cfg, 1);
    let stream = markov_stream(cfg.vocab as u32, 4_000, 2);
    let calib = stream.calibration(24, 4, 3);
    let pcfg = PipelineConfig {
        quant: QuantConfig {
            bits,
            greedy_passes: 2,
            ..Default::default()
        },
        calib_seqs: 4,
        calib_seq_len: 24,
        seed: 7,
        ..Default::default()
    };
    let n_blocks = cfg.n_layers;
    println!(
        "crash-resume session sweep — {} blocks @ {bits} bits: quantize, kill at a \
         seeded block boundary, resume, verify byte-identity\n",
        n_blocks
    );

    // Cold (uninterrupted, journal-free) reference run.
    let t0 = Instant::now();
    let (cold, _) = quantize_model(&ck, &calib, &pcfg)?;
    let cold_s = t0.elapsed().as_secs_f64();
    let cold_bytes = cold.to_bytes(QZ_VERSION);

    // Kill at a seeded block boundary. Soft mode surfaces the injected
    // kill as an Err *after* the journal append is durable, so the
    // on-disk state is exactly what a real `kill -9` at that boundary
    // leaves behind.
    let kill_at = 1 + (seed as usize % n_blocks);
    let dir = std::env::temp_dir().join(format!(
        "quip_sweep_session_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let mut kill_cfg = pcfg.clone();
    kill_cfg.faults = Some(Arc::new(FaultInjector::new(
        vec![FaultSpec::parse(&format!("pipeline.block_done@{kill_at}"))?],
        true,
        seed,
    )));
    let t1 = Instant::now();
    let killed = QuantSession::new(&ck, kill_cfg)?
        .with_checkpoint_dir(&dir)?
        .run(&calib);
    anyhow::ensure!(
        killed.is_err(),
        "injected fault at block boundary {kill_at} must abort the run"
    );
    let interrupted_s = t1.elapsed().as_secs_f64();

    // Resume the wreck and run it to completion.
    let t2 = Instant::now();
    let (qm, report) = QuantSession::resume(&ck, pcfg.clone(), &dir)?.run(&calib)?;
    let resume_s = t2.elapsed().as_secs_f64();
    anyhow::ensure!(
        report.failed_blocks.is_empty(),
        "resumed session reported failed blocks: {:?}",
        report.failed_blocks
    );
    let identical = qm.to_bytes(QZ_VERSION) == cold_bytes;
    anyhow::ensure!(
        identical,
        "resumed artifact differs from the uninterrupted run (kill at {kill_at})"
    );
    let _ = std::fs::remove_dir_all(&dir);

    let crash_path_x = (interrupted_s + resume_s) / cold_s.max(1e-9);
    let mut tp = TablePrinter::new(&[
        "blocks",
        "kill@",
        "cold s",
        "interrupted s",
        "resume s",
        "crash-path x",
        "identical",
    ]);
    tp.row(vec![
        n_blocks.to_string(),
        kill_at.to_string(),
        f2(cold_s),
        f2(interrupted_s),
        f2(resume_s),
        f2(crash_path_x),
        "yes".to_string(),
    ]);
    tp.print();
    println!(
        "\nresume re-quantized {} of {n_blocks} blocks; the {kill_at} journaled \
         blocks replay as dequantize-only. Crash path (interrupted + resume) cost \
         {:.2}x the cold run.",
        n_blocks - kill_at,
        crash_path_x
    );

    let mut out = Json::obj();
    out.set("blocks", Json::Num(n_blocks as f64));
    out.set("bits", Json::Num(bits as f64));
    out.set("kill_at", Json::Num(kill_at as f64));
    out.set("cold_s", Json::Num(cold_s));
    out.set("interrupted_s", Json::Num(interrupted_s));
    out.set("resume_s", Json::Num(resume_s));
    out.set("crash_path_x", Json::Num(crash_path_x));
    out.set("byte_identical", Json::Num(1.0));

    // Sharded phase (DESIGN.md §11): rerun the same quantization with a
    // Hessian budget too small to hold one block's accumulators resident
    // (forcing spills) and a 3-worker layer pool, and require the artifact
    // byte-identical to the unlimited in-memory run above. Reports the
    // measured peak resident bytes (gauge `quip_hessian_peak_bytes`) and
    // spill count scraped from a fresh metric registry.
    let d = cfg.d_model;
    let budget = d * d * 8 + d * d * 4; // 1.5 accumulators: spills guaranteed
    let mut shard_cfg = pcfg.clone();
    shard_cfg.hessian_mem_budget = budget;
    shard_cfg.layer_workers = 3;
    let registry = Arc::new(crate::obs::registry::MetricRegistry::new());
    let t3 = Instant::now();
    let (sharded, sreport) = QuantSession::new(&ck, shard_cfg)?
        .with_metrics(Arc::clone(&registry))
        .run(&calib)?;
    let sharded_s = t3.elapsed().as_secs_f64();
    anyhow::ensure!(
        sreport.failed_blocks.is_empty(),
        "sharded session reported failed blocks: {:?}",
        sreport.failed_blocks
    );
    anyhow::ensure!(
        sharded.to_bytes(QZ_VERSION) == cold_bytes,
        "budget-capped sharded artifact differs from the in-memory run"
    );
    let scrape = registry.render_prometheus();
    let metric = |name: &str| -> f64 {
        scrape
            .lines()
            .find(|l| l.split_whitespace().next() == Some(name))
            .and_then(|l| l.split_whitespace().last())
            .and_then(|v| v.parse().ok())
            .unwrap_or(0.0)
    };
    let peak = metric("quip_hessian_peak_bytes");
    let spills = metric("quip_hessian_spill_total");
    let ceiling = budget.max(d * d * 8 + crate::hessian::PANEL * d * 4);
    anyhow::ensure!(
        peak > 0.0 && peak <= ceiling as f64,
        "peak Hessian bytes {peak} outside (0, {ceiling}] — budget not enforced"
    );
    anyhow::ensure!(spills >= 1.0, "tiny budget produced no spills");
    let s_per_layer = sharded_s / sharded.layers.len().max(1) as f64;
    let mut st = TablePrinter::new(&[
        "budget B", "workers", "peak Hessian B", "spills", "s/layer", "identical",
    ]);
    st.row(vec![
        budget.to_string(),
        "3".to_string(),
        format!("{peak:.0}"),
        format!("{spills:.0}"),
        format!("{s_per_layer:.3}"),
        "yes".to_string(),
    ]);
    println!();
    st.print();
    println!(
        "\nsharded phase: {:.0} peak resident Hessian bytes under a {budget}-byte \
         budget ({spills:.0} spills), artifact byte-identical to the in-memory run.",
        peak
    );
    let mut so = Json::obj();
    so.set("budget_bytes", Json::Num(budget as f64));
    so.set("layer_workers", Json::Num(3.0));
    so.set("peak_hessian_bytes", Json::Num(peak));
    so.set("spills", Json::Num(spills));
    so.set("sharded_s", Json::Num(sharded_s));
    so.set("s_per_layer", Json::Num(s_per_layer));
    so.set("byte_identical", Json::Num(1.0));
    out.set("sharded", so);

    write_result("sweep_session", &out)?;
    Ok(())
}

/// ρ sweep: too small clips the distribution tails hard, too large wastes
/// grid levels; the paper lands on 2.4.
fn sweep_rho(args: &Args) -> crate::Result<()> {
    let env = Env::load(args)?;
    let model = args.opt_or("model", "s0");
    let bits = args.opt_usize("bits", 2) as u32;
    println!("ρ sweep — {model} @ {bits} bits (paper tunes ρ = 2.4)\n");
    let mut tp = TablePrinter::new(&["rho", "mean ppl↓", "proxy loss↓"]);
    let mut rhos = Vec::new();
    let mut ppls = Vec::new();
    for rho in [1.2, 1.8, 2.4, 3.2, 4.5] {
        let mut processing = Processing::incoherent();
        processing.rho = rho;
        let ck = env.checkpoint(&model)?;
        let (qm, proxy) = env.quantize(
            &model,
            QuantConfig::builder()
                .bits(bits)
                .rounder("ldlq")
                .processing(processing)
                .build()?,
        )?;
        let mut m = Transformer::from_checkpoint(&ck)?;
        qm.apply_to(&mut m)?;
        let r = env.evaluate(&m);
        tp.row(vec![format!("{rho:.1}"), f2(r.mean_ppl()), format!("{proxy:.3}")]);
        rhos.push(rho);
        ppls.push(r.mean_ppl());
    }
    tp.print();
    let best = rhos[argmin(&ppls)];
    println!("\nbest ρ here: {best:.1} (paper: 2.4 across all their experiments)");
    let mut out = Json::obj();
    out.set("rho", arr_f64(&rhos));
    out.set("mean_ppl", arr_f64(&ppls));
    write_result("sweep_rho", &out)?;
    Ok(())
}

/// Calibration-size sweep: H quality vs cost.
fn sweep_calib(args: &Args) -> crate::Result<()> {
    let env = Env::load(args)?;
    let model = args.opt_or("model", "s0");
    let bits = args.opt_usize("bits", 2) as u32;
    println!("calibration-size sweep — {model} @ {bits} bits (paper: 128 segments)\n");
    let ck = env.checkpoint(&model)?;
    let train = crate::data::TokenStream::load(&env.registry.split("train"))?;
    let mut tp = TablePrinter::new(&["segments", "mean ppl↓"]);
    let mut sizes = Vec::new();
    let mut ppls = Vec::new();
    for segs in [2usize, 8, 24, 64] {
        let calib = train.calibration(128, segs, 0xCA11B);
        let pcfg = PipelineConfig {
            quant: QuantConfig::builder()
                .bits(bits)
                .rounder("ldlq")
                .processing(Processing::incoherent())
                .build()?,
            calib_seqs: segs,
            calib_seq_len: 128,
            seed: 0x5155_4950,
            ..Default::default()
        };
        let (qm, _) = quantize_model(&ck, &calib, &pcfg)?;
        let mut m = Transformer::from_checkpoint(&ck)?;
        qm.apply_to(&mut m)?;
        let r = env.evaluate(&m);
        tp.row(vec![segs.to_string(), f2(r.mean_ppl())]);
        sizes.push(segs as f64);
        ppls.push(r.mean_ppl());
    }
    tp.print();
    println!("\nexpected shape: diminishing returns once H is well estimated.");
    let mut out = Json::obj();
    out.set("segments", arr_f64(&sizes));
    out.set("mean_ppl", arr_f64(&ppls));
    write_result("sweep_calib", &out)?;
    Ok(())
}

/// Greedy polish passes (used by LDLQ-RG / QuIP-RG).
fn sweep_greedy(args: &Args) -> crate::Result<()> {
    let env = Env::load(args)?;
    let model = args.opt_or("model", "s0");
    let bits = args.opt_usize("bits", 2) as u32;
    println!("greedy-passes sweep — {model} @ {bits} bits (paper: 10 passes, 5 on 30b/66b)\n");
    let mut tp = TablePrinter::new(&["passes", "proxy loss↓", "mean ppl↓"]);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for passes in [0usize, 1, 3, 10] {
        let ck = env.checkpoint(&model)?;
        let (qm, proxy) = env.quantize(
            &model,
            QuantConfig::builder()
                .bits(bits)
                .rounder("ldlq-rg")
                .processing(Processing::incoherent())
                .greedy_passes(passes)
                .build()?,
        )?;
        let mut m = Transformer::from_checkpoint(&ck)?;
        qm.apply_to(&mut m)?;
        let r = env.evaluate(&m);
        tp.row(vec![passes.to_string(), format!("{proxy:.4}"), f2(r.mean_ppl())]);
        xs.push(passes as f64);
        ys.push(proxy);
    }
    tp.print();
    // Greedy is a descent method on the proxy: more passes never hurt it.
    for w in ys.windows(2) {
        anyhow::ensure!(w[1] <= w[0] * 1.001, "greedy passes increased proxy");
    }
    let mut out = Json::obj();
    out.set("passes", arr_f64(&xs));
    out.set("proxy", arr_f64(&ys));
    write_result("sweep_greedy", &out)?;
    Ok(())
}

/// Tokens/sec vs batch size for the batched fused packed-weight engine,
/// at 2/3/4 bits, with the repeated single-vector `QuantLinear::apply`
/// path as the baseline at each batch size. Runs on a synthetic
/// checkpoint — no artifacts needed — so it doubles as the CI smoke run.
fn sweep_batch(args: &Args) -> crate::Result<()> {
    use crate::coordinator::generate::{generate, generate_batch, GenParams};
    use crate::engine::native::QuantLinears;
    use crate::linalg::Mat;
    use crate::model::quantized::QuantizedModel;
    use crate::model::weights::Checkpoint;
    use crate::model::ModelConfig;
    use crate::quant::packed::QuantizedLayer;
    use crate::quant::{quantize_layer, Method};
    use crate::util::testkit::random_hessian;

    let fast = args.flag("fast");
    let cfg = crate::model::ModelConfig::by_name(&args.opt_or("model", "s0"))
        .unwrap_or_else(|_| ModelConfig::sized("s0", 64, 2, 4, 256));
    let ck = Checkpoint::random(&cfg, 7);
    let model = Transformer::from_checkpoint(&ck)?;
    let max_tokens = if fast { 6 } else { 24 };
    let prompt_len = 4usize;
    let batches: &[usize] = if fast { &[1, 4, 16] } else { &[1, 4, 16, 64] };
    let params = GenParams {
        max_tokens,
        ..Default::default()
    };
    println!(
        "batch sweep — {} (d={} L={}), {} new tokens/request, fused batched engine vs \
         repeated single-vector apply\n",
        cfg.name, cfg.d_model, cfg.n_layers, max_tokens
    );

    // Quantize once per bit width (rounding method is irrelevant for
    // serving throughput; nearest keeps the sweep fast).
    let quantize = |bits: u32| -> crate::Result<QuantizedModel> {
        let mut rng = crate::util::rng::Rng::new(3);
        let mut layers = Vec::new();
        for spec in cfg.linear_specs() {
            let wdata = model.get_weight(&spec.name)?;
            let w = Mat {
                rows: spec.out_dim,
                cols: spec.in_dim,
                data: wdata.iter().map(|&x| x as f64).collect(),
            };
            let h = random_hessian(&mut rng, spec.in_dim, 8, 1e-2);
            let out = quantize_layer(
                &w,
                &h,
                &QuantConfig {
                    bits,
                    method: Method::Nearest,
                    processing: Processing::incoherent(),
                    ..Default::default()
                },
                5,
            );
            layers.push(QuantizedLayer::from_codes(&spec.name, &out.codes, bits, out.post));
        }
        Ok(QuantizedModel {
            config: cfg.clone(),
            bits,
            recipe: "sweep".into(),
            layers,
        })
    };

    let prompts = |count: usize| -> Vec<Vec<u32>> {
        (0..count)
            .map(|c| {
                (0..prompt_len)
                    .map(|i| ((c * 31 + i * 7) % (cfg.vocab - 1) + 1) as u32)
                    .collect()
            })
            .collect()
    };

    let mut tp = TablePrinter::new(&[
        "bits", "batch", "batched tok/s", "matvec tok/s", "speedup",
    ]);
    let mut out = Json::obj();
    let mut speedup_at_16 = Vec::new();
    for bits in [2u32, 3, 4] {
        let qm = quantize(bits)?;
        let qlin = QuantLinears::from_model(&qm)?;
        for &b in batches {
            let reqs = prompts(b);
            // Warmup (allocations, scratch growth).
            generate_batch(&model, &qlin, &reqs[..1.min(reqs.len())], &params);
            let t0 = std::time::Instant::now();
            let gens = generate_batch(&model, &qlin, &reqs, &params);
            let batched_secs = t0.elapsed().as_secs_f64();
            let toks: usize = gens.iter().map(|g| g.tokens.len()).sum();
            let batched_tps = toks as f64 / batched_secs.max(1e-9);
            // Baseline: the same requests served one vector at a time
            // through the pre-tentpole QuantLinear::apply path.
            let t1 = std::time::Instant::now();
            let mut base_toks = 0usize;
            for r in &reqs {
                base_toks += generate(&model, &qlin, r, &params).tokens.len();
            }
            let matvec_secs = t1.elapsed().as_secs_f64();
            let matvec_tps = base_toks as f64 / matvec_secs.max(1e-9);
            let speedup = batched_tps / matvec_tps.max(1e-9);
            if b == 16 {
                speedup_at_16.push(speedup);
            }
            tp.row(vec![
                bits.to_string(),
                b.to_string(),
                f2(batched_tps),
                f2(matvec_tps),
                format!("{speedup:.2}x"),
            ]);
            let mut o = Json::obj();
            o.set("batched_tokens_per_s", Json::Num(batched_tps));
            o.set("matvec_tokens_per_s", Json::Num(matvec_tps));
            o.set("speedup", Json::Num(speedup));
            out.set(&format!("q{bits}_b{b}"), o);
        }
    }
    tp.print();
    if !speedup_at_16.is_empty() {
        let mean16 = speedup_at_16.iter().sum::<f64>() / speedup_at_16.len() as f64;
        println!(
            "\nbatch-16 speedup over repeated single-vector apply: {mean16:.2}x mean \
             (acceptance floor: 2.0x; record in EXPERIMENTS.md §Perf)"
        );
        out.set("speedup_at_16_mean", Json::Num(mean16));
    }
    write_result("sweep_batch", &out)?;
    Ok(())
}

/// Incoherence-transform backend sweep: kron vs hadamard, end-to-end.
/// For each (bits, transform) cell the model is quantized (LDLQ + IncP),
/// written to a v2 `.qz`, loaded back, and decoded through the native
/// engine — so the cell numbers cover the whole artifact lifecycle. Two
/// metrics per cell: total proxy loss (quantization quality; QuIP#'s
/// claim is hadamard ≤ kron) and the per-token cost of the forward +
/// inverse transform applies on the decode hot path (the RHT's O(n log n)
/// butterfly vs the Kronecker's O(n(p+q)) multiplies). Artifact-free.
fn sweep_transform(args: &Args) -> crate::Result<()> {
    use crate::coordinator::generate::{generate, GenParams};
    use crate::engine::native::QuantLinears;
    use crate::linalg::{make_transform, Mat, TransformKind};
    use crate::model::quantized::QuantizedModel;
    use crate::model::weights::Checkpoint;
    use crate::model::ModelConfig;
    use crate::quant::packed::QuantizedLayer;
    use crate::quant::{quantize_layer, Method};
    use crate::util::testkit::random_hessian;
    use std::hint::black_box;

    let fast = args.flag("fast");
    let cfg = crate::model::ModelConfig::by_name(&args.opt_or("model", "s0"))
        .unwrap_or_else(|_| ModelConfig::sized("s0", 64, 2, 4, 256));
    let ck = Checkpoint::random(&cfg, 7);
    let model = Transformer::from_checkpoint(&ck)?;
    let bits_list: &[u32] = if fast { &[2] } else { &[2, 3, 4] };
    let reps = if fast { 50usize } else { 300 };
    let max_tokens = if fast { 4 } else { 16 };
    println!(
        "transform sweep — {} (d={} L={}), LDLQ + IncP, quantize → save v2 .qz → \
         load → decode per cell\n",
        cfg.name, cfg.d_model, cfg.n_layers
    );

    let dir = std::env::temp_dir().join("quip_sweep_transform");
    std::fs::create_dir_all(&dir)?;
    let mut tp = TablePrinter::new(&[
        "bits",
        "transform",
        "proxy loss↓",
        "transform µs/tok↓",
        "decode ms/tok↓",
    ]);
    let mut out = Json::obj();
    let mut proxy_at_2 = std::collections::HashMap::new();
    for &bits in bits_list {
        for kind in [TransformKind::Kron, TransformKind::Hadamard] {
            // Quantize every linear with this backend.
            let mut rng = crate::util::rng::Rng::new(3);
            let mut layers = Vec::new();
            let mut proxy_total = 0.0f64;
            for spec in cfg.linear_specs() {
                let wdata = model.get_weight(&spec.name)?;
                let w = Mat {
                    rows: spec.out_dim,
                    cols: spec.in_dim,
                    data: wdata.iter().map(|&x| x as f64).collect(),
                };
                let h = random_hessian(&mut rng, spec.in_dim, 8, 1e-2);
                let lq = quantize_layer(
                    &w,
                    &h,
                    &QuantConfig {
                        bits,
                        method: Method::Ldlq,
                        processing: Processing::incoherent_with(kind),
                        ..Default::default()
                    },
                    5,
                );
                proxy_total += lq.proxy_loss;
                layers.push(QuantizedLayer::from_codes(&spec.name, &lq.codes, bits, lq.post));
            }
            let qm = QuantizedModel {
                config: cfg.clone(),
                bits,
                recipe: format!("ldlq+incp-{kind}"),
                layers,
            };
            // Full artifact lifecycle: save v2 → load → decode.
            let path = dir.join(format!("{}_q{bits}_{kind}.qz", cfg.name));
            qm.save(&path)?;
            let loaded = QuantizedModel::load(&path)?;
            anyhow::ensure!(
                loaded.layers.iter().all(|l| l.post.transform == kind),
                "loaded artifact lost the transform kind"
            );
            let qlin = QuantLinears::from_model(&loaded)?;
            let params = GenParams {
                max_tokens,
                ..Default::default()
            };
            let gen = generate(&model, &qlin, &[1, 5, 9], &params);
            anyhow::ensure!(
                !gen.tokens.is_empty(),
                "decode produced no tokens ({kind} @ {bits} bits)"
            );
            let decode_ms_tok = gen.decode_seconds * 1e3 / gen.tokens.len().max(1) as f64;

            // Per-token transform cost: one decode token applies each
            // linear's forward V (n) and inverse U (m) exactly once.
            let mut pairs = Vec::new();
            for l in &loaded.layers {
                if l.post.incoherent {
                    pairs.push((
                        make_transform(l.post.transform, l.post.v_seed, l.n, l.post.permute),
                        make_transform(l.post.transform, l.post.u_seed, l.m, l.post.permute),
                        l.n,
                        l.m,
                    ));
                }
            }
            let maxd = pairs.iter().map(|&(_, _, n, m)| n.max(m)).max().unwrap_or(1);
            let mut xbuf: Vec<f32> = (0..maxd).map(|i| (i as f32 * 0.37).sin()).collect();
            let mut ybuf = vec![0.0f32; maxd];
            let mut scratch = vec![0.0f32; maxd];
            let t0 = std::time::Instant::now();
            for _ in 0..reps {
                for (v, u, n, m) in &pairs {
                    // One decode token: forward V on the input side,
                    // inverse U on the output side.
                    v.forward_f32(&xbuf[..*n], &mut ybuf[..*n], &mut scratch[..*n]);
                    u.inverse_f32(&ybuf[..*m], &mut xbuf[..*m], &mut scratch[..*m]);
                }
            }
            black_box(&xbuf);
            let us_per_tok = t0.elapsed().as_secs_f64() * 1e6 / reps as f64;

            if bits == 2 {
                proxy_at_2.insert(kind.name(), proxy_total);
            }
            tp.row(vec![
                bits.to_string(),
                kind.to_string(),
                format!("{proxy_total:.4}"),
                f2(us_per_tok),
                format!("{decode_ms_tok:.3}"),
            ]);
            let mut o = Json::obj();
            o.set("proxy_loss", Json::Num(proxy_total));
            o.set("transform_us_per_token", Json::Num(us_per_tok));
            o.set("decode_ms_per_token", Json::Num(decode_ms_tok));
            out.set(&format!("q{bits}_{kind}"), o);
        }
    }
    tp.print();
    if let (Some(&had), Some(&kr)) = (proxy_at_2.get("hadamard"), proxy_at_2.get("kron")) {
        println!(
            "\n2-bit proxy loss: hadamard {had:.4} vs kron {kr:.4} ({})",
            if had <= kr {
                "hadamard ≤ kron, matching QuIP#'s incoherence bound"
            } else {
                "kron ahead on this draw — rerun with another seed/model"
            }
        );
    }
    write_result("sweep_transform", &out)?;
    Ok(())
}

/// Quantization-throughput sweep: per-stage wall-clock of the quantize
/// hot path — Hessian accumulation (scalar rank-1 baseline vs the blocked
/// SYRK panel kernel), UDUᵀ/Cholesky factorization (scalar vs blocked),
/// and LDLQ rounding — plus end-to-end seconds/layer for both kernel
/// sets, on synthetic activations/weights (artifact-free; `--fast` is the
/// CI smoke shape). Each cell self-checks blocked-vs-scalar numerical
/// equivalence before reporting. Results feed EXPERIMENTS.md §Perf 4.
fn sweep_quant(args: &Args) -> crate::Result<()> {
    use crate::hessian::{accumulate_reference, HessianAccum};
    use crate::linalg::chol::{cholesky, cholesky_scalar};
    use crate::linalg::ldl::{udu, udu_scalar};
    use crate::linalg::matrix::max_abs_diff;
    use crate::linalg::Mat;
    use crate::quant::ldlq::ldlq_with_feedback;
    use crate::quant::RoundMode;
    use crate::util::rng::Rng;
    use crate::util::threadpool::default_threads;
    use crate::util::timer::time_once;

    let fast = args.flag("fast");
    let sizes: &[usize] = if fast { &[96, 160] } else { &[256, 512, 1024] };
    let bits_list: &[u32] = if fast { &[2] } else { &[2, 4] };
    let threads = default_threads();
    println!(
        "quant-throughput sweep — {} worker threads, scalar vs blocked kernels \
         (accumulate / factorize / round per layer)\n",
        threads
    );

    let mut kt = TablePrinter::new(&[
        "n",
        "accum scalar ms",
        "accum syrk ms",
        "GB/s",
        "speedup",
        "udu scalar ms",
        "udu blocked ms",
        "chol scalar ms",
        "chol blocked ms",
    ]);
    let mut et = TablePrinter::new(&[
        "n", "bits", "round ms", "s/layer blocked", "s/layer scalar", "speedup",
    ]);
    let mut out = Json::obj();
    out.set("threads", Json::Num(threads as f64));
    out.set("fast", Json::Num(fast as u8 as f64));

    for &n in sizes {
        // Synthetic calibration stream: enough rows that the accumulate
        // stage dominates cache effects (2n rows ⇒ rank-deficient is fine,
        // damping restores PD below).
        let rows = if fast { n } else { 2 * n };
        let mut rng = Rng::new(0x9E37 ^ n as u64);
        let x: Vec<f32> = (0..rows * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();

        // --- Stage 1: Hessian accumulation, scalar vs blocked SYRK. ---
        let (scalar_s, h_ref) = time_once(|| accumulate_reference(&x, n));
        let (blocked_s, h) = time_once(|| {
            let mut acc = HessianAccum::new(n);
            acc.add_rows(&x, n);
            acc.finish()
        });
        let h_scale = h_ref.max_abs().max(1.0);
        anyhow::ensure!(
            max_abs_diff(&h, &h_ref) < 1e-9 * h_scale,
            "blocked Hessian diverged from scalar at n={n}"
        );
        let bytes = rows as f64 * (n * n) as f64 * 8.0;
        let gbps_blocked = bytes / blocked_s.max(1e-9) / 1e9;

        // --- Stage 2: factorization, scalar vs blocked. ---
        let hd = crate::quant::incoherence::damp(&h, 0.01);
        let (udu_scalar_s, f_scalar) = time_once(|| udu_scalar(&hd, 1e-12));
        let (udu_blocked_s, f_blocked) = time_once(|| udu(&hd, 1e-12));
        anyhow::ensure!(
            max_abs_diff(&f_blocked.u, &f_scalar.u) < 1e-6,
            "blocked UDU diverged from scalar at n={n}"
        );
        let (chol_scalar_s, cs) = time_once(|| cholesky_scalar(&hd));
        let (chol_blocked_s, cb) = time_once(|| cholesky(&hd));
        anyhow::ensure!(
            max_abs_diff(&cs?, &cb?) < 1e-6,
            "blocked Cholesky diverged from scalar at n={n}"
        );

        kt.row(vec![
            n.to_string(),
            f2(scalar_s * 1e3),
            f2(blocked_s * 1e3),
            f2(gbps_blocked),
            format!("{:.2}x", scalar_s / blocked_s.max(1e-9)),
            f2(udu_scalar_s * 1e3),
            f2(udu_blocked_s * 1e3),
            f2(chol_scalar_s * 1e3),
            f2(chol_blocked_s * 1e3),
        ]);
        let mut o = Json::obj();
        o.set("rows", Json::Num(rows as f64));
        o.set("accum_scalar_ms", Json::Num(scalar_s * 1e3));
        o.set("accum_blocked_ms", Json::Num(blocked_s * 1e3));
        o.set("accum_gbps_blocked", Json::Num(gbps_blocked));
        o.set(
            "accum_gbps_scalar",
            Json::Num(bytes / scalar_s.max(1e-9) / 1e9),
        );
        o.set("udu_scalar_ms", Json::Num(udu_scalar_s * 1e3));
        o.set("udu_blocked_ms", Json::Num(udu_blocked_s * 1e3));
        o.set("chol_scalar_ms", Json::Num(chol_scalar_s * 1e3));
        o.set("chol_blocked_ms", Json::Num(chol_blocked_s * 1e3));
        out.set(&format!("n{n}"), o);

        // --- Stage 3: LDLQ rounding (same kernel either way — it was
        // already row-parallel) + end-to-end seconds/layer. ---
        let u_dot = f_blocked.strictly_upper();
        for &bits in bits_list {
            let qmax = crate::quant::grid::levels(bits) as f64;
            let wg = Mat::from_fn(n, n, |_, _| rng.uniform(0.0, qmax));
            let (round_s, codes) =
                time_once(|| ldlq_with_feedback(&wg, &u_dot, bits, RoundMode::Nearest, 7));
            anyhow::ensure!(
                codes.data.iter().all(|&c| c >= 0.0 && c <= qmax),
                "LDLQ codes out of range at n={n} bits={bits}"
            );
            let e2e_blocked = blocked_s + udu_blocked_s + round_s;
            let e2e_scalar = scalar_s + udu_scalar_s + round_s;
            et.row(vec![
                n.to_string(),
                bits.to_string(),
                f2(round_s * 1e3),
                format!("{:.3}", e2e_blocked),
                format!("{:.3}", e2e_scalar),
                format!("{:.2}x", e2e_scalar / e2e_blocked.max(1e-9)),
            ]);
            let mut o = Json::obj();
            o.set("round_ms", Json::Num(round_s * 1e3));
            o.set("seconds_per_layer_blocked", Json::Num(e2e_blocked));
            o.set("seconds_per_layer_scalar", Json::Num(e2e_scalar));
            o.set("speedup", Json::Num(e2e_scalar / e2e_blocked.max(1e-9)));
            out.set(&format!("n{n}_q{bits}"), o);
        }
    }
    kt.print();
    println!();
    et.print();
    println!(
        "\nper-stage kernels: accumulate = hessian::HessianAccum (SYRK panels) vs \
         hessian::accumulate_reference; factorize = linalg::{{ldl,chol}} blocked vs \
         scalar; record the n=1024 numbers in EXPERIMENTS.md §Perf 4."
    );
    write_result("sweep_quant", &out)?;
    Ok(())
}

/// Rounding-target sweep: scalar-LDLQ vs the E8-style vector codebook
/// (`vq`) at equal bitrate, end-to-end. For each (bits, rounder) cell the
/// model is quantized (IncP on both), written to a v3 `.qz`, loaded back,
/// and decoded through the native engine — proxy loss measures
/// quantization quality (QuIP#'s claim: the lattice codebook closes the
/// 2-bit gap, so vq ≤ scalar at 2 bits), decode ms/token measures the
/// LUT-expansion path against the bit-unpack path, and bits/weight pins
/// the equal-bitrate comparison. Artifact-free; `--fast` is the CI smoke
/// shape (EXPERIMENTS.md §Quality).
fn sweep_codebook(args: &Args) -> crate::Result<()> {
    use crate::coordinator::generate::{generate, GenParams};
    use crate::engine::native::QuantLinears;
    use crate::linalg::Mat;
    use crate::model::quantized::QuantizedModel;
    use crate::model::weights::Checkpoint;
    use crate::model::ModelConfig;
    use crate::quant::quantize_layer;

    let fast = args.flag("fast");
    let cfg = crate::model::ModelConfig::by_name(&args.opt_or("model", "s0"))
        .unwrap_or_else(|_| ModelConfig::sized("s0", 64, 2, 4, 256));
    let ck = Checkpoint::random(&cfg, 7);
    let model = Transformer::from_checkpoint(&ck)?;
    let bits_list: &[u32] = if fast { &[2] } else { &[2, 4] };
    let max_tokens = if fast { 4 } else { 16 };
    println!(
        "codebook sweep — {} (d={} L={}), LDLQ feedback + IncP, scalar grid vs \
         E8-style vq at equal bitrate; quantize → save v3 .qz → load → decode per cell\n",
        cfg.name, cfg.d_model, cfg.n_layers
    );

    let dir = std::env::temp_dir().join("quip_sweep_codebook");
    std::fs::create_dir_all(&dir)?;
    let mut tp = TablePrinter::new(&[
        "bits",
        "rounder",
        "proxy loss↓",
        "bits/weight",
        "decode ms/tok↓",
    ]);
    let mut out = Json::obj();
    let mut proxy_at_2 = std::collections::HashMap::new();
    for &bits in bits_list {
        for rounder in ["ldlq", "vq"] {
            let qcfg = QuantConfig::builder()
                .bits(bits)
                .rounder(rounder)
                .processing(Processing::incoherent())
                .build()?;
            let mut rng = crate::util::rng::Rng::new(3);
            let mut layers = Vec::new();
            let mut proxy_total = 0.0f64;
            for spec in cfg.linear_specs() {
                let wdata = model.get_weight(&spec.name)?;
                let w = Mat {
                    rows: spec.out_dim,
                    cols: spec.in_dim,
                    data: wdata.iter().map(|&x| x as f64).collect(),
                };
                let h = crate::util::testkit::random_hessian(&mut rng, spec.in_dim, 8, 1e-2);
                let lq = quantize_layer(&w, &h, &qcfg, 5);
                proxy_total += lq.proxy_loss;
                layers.push(lq.into_layer(&spec.name));
            }
            let qm = QuantizedModel {
                config: cfg.clone(),
                bits,
                recipe: format!("{rounder}+incp"),
                layers,
            };
            let bpw = qm.bits_per_weight();
            // Full artifact lifecycle: save v3 → load → decode.
            let path = dir.join(format!("{}_q{bits}_{rounder}.qz", cfg.name));
            qm.save(&path)?;
            let loaded = QuantizedModel::load(&path)?;
            anyhow::ensure!(
                loaded
                    .layers
                    .iter()
                    .all(|l| matches!(l.layout, crate::quant::CodeLayout::Vq { .. })
                        == (rounder == "vq")),
                "loaded artifact lost the code layout"
            );
            let qlin = QuantLinears::from_model(&loaded)?;
            let params = GenParams {
                max_tokens,
                ..Default::default()
            };
            let gen = generate(&model, &qlin, &[1, 5, 9], &params);
            anyhow::ensure!(
                !gen.tokens.is_empty(),
                "decode produced no tokens ({rounder} @ {bits} bits)"
            );
            let decode_ms_tok = gen.decode_seconds * 1e3 / gen.tokens.len().max(1) as f64;

            if bits == 2 {
                proxy_at_2.insert(rounder, proxy_total);
            }
            tp.row(vec![
                bits.to_string(),
                rounder.to_string(),
                format!("{proxy_total:.4}"),
                format!("{bpw:.3}"),
                format!("{decode_ms_tok:.3}"),
            ]);
            let mut o = Json::obj();
            o.set("proxy_loss", Json::Num(proxy_total));
            o.set("bits_per_weight", Json::Num(bpw));
            o.set("decode_ms_per_token", Json::Num(decode_ms_tok));
            out.set(&format!("q{bits}_{rounder}"), o);
        }
    }
    tp.print();
    if let (Some(&vq), Some(&sc)) = (proxy_at_2.get("vq"), proxy_at_2.get("ldlq")) {
        println!(
            "\n2-bit proxy loss at equal bitrate: vq {vq:.4} vs scalar-LDLQ {sc:.4} ({})",
            if vq <= sc {
                "vq ≤ scalar — the E8 shaping gain, matching QuIP#"
            } else {
                "scalar ahead on this draw — rerun with another seed/model"
            }
        );
        out.set("vq_beats_scalar_at_2", Json::Num((vq <= sc) as u8 as f64));
    }
    write_result("sweep_codebook", &out)?;
    Ok(())
}

/// Serving-memory sweep: contiguous vs paged KV caches through the
/// continuous-batching loop, on fp32 linears (the weight kernel is
/// irrelevant here — this sweep measures the memory system around it).
///
/// Phase 1: requests sharing a one-page "system prompt" prefix, run to
/// completion in both cache modes — KV bytes per active token (contig
/// allocates `max_seq` rows per sequence up front; the pool allocates
/// 16-token pages on demand and shares prefix pages), tokens/s, the
/// prefix-registry hit numbers, and a greedy-equality self-check (the
/// paged path must reproduce the contiguous tokens exactly).
///
/// Phase 2: a real `Server` over a deliberately tiny pool under
/// concurrent overload (some requests can never fit) — completed vs
/// shed counts, clean "overloaded" responses, server alive after.
/// Artifact-free; `--fast` shrinks request count and token budget.
fn sweep_serve(args: &Args) -> crate::Result<()> {
    use crate::coordinator::generate::{step_batch, ActiveSeq, GenParams};
    use crate::coordinator::server::{Client, EngineKind, Server, ServerConfig};
    use crate::engine::native::FpLinears;
    use crate::model::weights::Checkpoint;
    use crate::model::{KvCache, KvPool, ModelConfig};
    use std::time::{Duration, Instant};

    let fast = args.flag("fast");
    let cfg = ModelConfig::by_name(&args.opt_or("model", "s0"))
        .unwrap_or_else(|_| ModelConfig::sized("s0", 64, 2, 4, 256));
    let ck = Checkpoint::random(&cfg, 7);
    let model = Transformer::from_checkpoint(&ck)?;
    let lin = FpLinears { model: &model };
    let page_tokens = 16usize;
    let nseq = if fast { 8 } else { 16 };
    let max_tokens = if fast { 8 } else { 24 };
    // One full page of shared "system prompt" so the prefix registry has
    // a page-boundary key to hit, plus a unique 2-token user tail.
    let shared_len = page_tokens + 4;
    let prompts: Vec<Vec<u32>> = (0..nseq)
        .map(|c| {
            let mut p: Vec<u32> = (0..shared_len)
                .map(|i| ((i * 7) % (cfg.vocab - 1) + 1) as u32)
                .collect();
            p.push(((c * 31) % (cfg.vocab - 1) + 1) as u32);
            p.push(((c * 17 + 3) % (cfg.vocab - 1) + 1) as u32);
            p
        })
        .collect();
    anyhow::ensure!(
        prompts[0].len() + max_tokens <= cfg.max_seq,
        "sweep shape exceeds model context"
    );
    println!(
        "serve sweep — {} (d={} L={}), {} requests × {} new tokens, {}-token shared prefix, \
         page size {page_tokens}\n",
        cfg.name, cfg.d_model, cfg.n_layers, nseq, max_tokens, shared_len
    );

    let params = GenParams {
        max_tokens,
        ..Default::default()
    };
    let row_bytes = cfg.n_layers * 2 * cfg.d_model * 4; // K+V, f32, all layers
    let mut tp = TablePrinter::new(&[
        "kv cache", "tok/s", "KV bytes/active tok", "prefix hits", "tokens shared",
    ]);
    let mut out = Json::obj();
    let mut tokens_by_mode: Vec<Vec<Vec<u32>>> = Vec::new();
    for paged in [false, true] {
        let pool = KvPool::shared(
            cfg.n_layers,
            cfg.d_model,
            nseq * cfg.max_seq.div_ceil(page_tokens),
            page_tokens,
        );
        let mk = |prompt: &[u32]| -> crate::Result<ActiveSeq> {
            if paged {
                let table = pool
                    .lock()
                    .unwrap()
                    .try_admit(prompt, max_tokens)
                    .ok_or_else(|| anyhow::anyhow!("sweep pool sized to never shed"))?;
                Ok(ActiveSeq::with_cache(
                    &model,
                    prompt,
                    params.clone(),
                    KvCache::paged(&pool, table),
                ))
            } else {
                Ok(ActiveSeq::new(&model, prompt, params.clone()))
            }
        };
        let t0 = Instant::now();
        // First request runs alone — in paged mode its prefill registers
        // the shared prefix pages the rest then reuse.
        let mut seqs = vec![mk(&prompts[0])?];
        while step_batch(&model, &lin, &mut seqs).stepped > 0 {}
        for p in &prompts[1..] {
            seqs.push(mk(p)?);
        }
        while step_batch(&model, &lin, &mut seqs).stepped > 0 {}
        let secs = t0.elapsed().as_secs_f64();
        let toks: usize = seqs.iter().map(|s| s.tokens.len()).sum();
        let active_rows: usize = seqs.iter().map(|s| s.cache.len()).sum();
        let tps = toks as f64 / secs.max(1e-9);
        let snap = pool.lock().unwrap().snapshot();
        // Contig allocates max_seq rows per live sequence up front; the
        // pool's footprint is its peak page count.
        let kv_bytes = if paged {
            snap.peak_pages * page_tokens * row_bytes
        } else {
            nseq * cfg.max_seq * row_bytes
        };
        let bytes_per_tok = kv_bytes as f64 / active_rows.max(1) as f64;
        tp.row(vec![
            if paged { "paged" } else { "contig" }.to_string(),
            f2(tps),
            format!("{bytes_per_tok:.0}"),
            snap.prefix_hits.to_string(),
            snap.prefix_tokens_shared.to_string(),
        ]);
        let mut o = Json::obj();
        o.set("tokens_per_s", Json::Num(tps));
        o.set("kv_bytes_per_active_token", Json::Num(bytes_per_tok));
        o.set("prefix_hits", Json::Num(snap.prefix_hits as f64));
        o.set(
            "prefix_tokens_shared",
            Json::Num(snap.prefix_tokens_shared as f64),
        );
        o.set("peak_pages", Json::Num(snap.peak_pages as f64));
        out.set(if paged { "paged" } else { "contig" }, o);
        if paged {
            anyhow::ensure!(
                snap.prefix_hits as usize == nseq - 1,
                "every follow-up request should hit the shared prefix"
            );
        }
        tokens_by_mode.push(seqs.iter().map(|s| s.tokens.clone()).collect());
    }
    tp.print();
    anyhow::ensure!(
        tokens_by_mode[0] == tokens_by_mode[1],
        "paged decode diverged from contiguous (greedy tokens differ)"
    );
    println!("\ngreedy self-check: paged tokens == contiguous tokens for all requests");

    // Phase 2: synthetic overload against a real server. Half the
    // requests can never fit the 8-page pool (prompt 28 + reserve 16 >
    // 32 rows) and must be shed with a clean "overloaded" error; small
    // requests keep being served throughout.
    let server_model = std::sync::Arc::new(Transformer::from_checkpoint(&ck)?);
    let scfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 4,
        kv_pages: 8,
        page_tokens: 4,
        reserve_tokens: 16,
        admit_timeout: Duration::from_millis(30),
        ..Default::default()
    };
    let mut server = Server::start(server_model, EngineKind::auto(None), scfg)?;
    let addr = server.addr;
    let n_over = if fast { 4 } else { 8 };
    let handles: Vec<_> = (0..2 * n_over)
        .map(|i| {
            std::thread::spawn(move || {
                let len = if i % 2 == 0 { 4 } else { 28 };
                let prompt: Vec<u32> = (0..len).map(|j| (j % 30 + 1) as u32).collect();
                let mut c = Client::connect(&addr)?;
                c.request(&prompt, 8).map(|(t, _)| t.len())
            })
        })
        .collect();
    let mut ok = 0usize;
    let mut shed = 0usize;
    for h in handles {
        match h.join().expect("client thread") {
            Ok(_) => ok += 1,
            Err(e) if e.to_string().contains("overloaded") => shed += 1,
            Err(e) => anyhow::bail!("unexpected serve error under overload: {e}"),
        }
    }
    let shed_rate = shed as f64 / (ok + shed) as f64;
    let m = server.metrics.summary();
    println!(
        "overload: {ok} served, {shed} shed ({:.0}% shed rate), server metrics shed={} \
         evicted={}",
        shed_rate * 1e2,
        m.req_f64("shed")?,
        m.req_f64("evicted")?
    );
    anyhow::ensure!(shed >= 1, "overload phase produced no shed responses");
    // The server survived the overload and still answers.
    let mut c = Client::connect(&addr)?;
    let (t, _) = c.request(&[1, 2], 2)?;
    anyhow::ensure!(t.len() == 2, "server unhealthy after overload");
    // Scrape the Prometheus exposition once and fail the sweep on a
    // malformed scrape — the observability contract (DESIGN.md §9) is
    // exercised under real load, not just in unit tests.
    let scrape = Client::connect(&addr)?.scrape_metrics()?;
    crate::obs::registry::validate_prometheus_text(&scrape)?;
    anyhow::ensure!(
        scrape.contains("quip_completed_total") && scrape.contains("quip_shed_total"),
        "metrics scrape is missing serve counters"
    );
    println!("metrics scrape: {} lines, exposition valid", scrape.lines().count());
    server.shutdown();
    let mut o = Json::obj();
    o.set("served", Json::Num(ok as f64));
    o.set("shed", Json::Num(shed as f64));
    o.set("shed_rate", Json::Num(shed_rate));
    out.set("overload", o);

    write_result("sweep_serve", &out)?;
    Ok(())
}

fn argmin(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn argmin_works() {
        assert_eq!(super::argmin(&[3.0, 1.0, 2.0]), 1);
        assert_eq!(super::argmin(&[5.0]), 0);
    }
}
