//! Vocabulary: token-id ↔ string mapping, loaded from the build-time
//! `artifacts/data/vocab.json`.

use crate::util::json::Json;
use std::collections::HashMap;

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;

#[derive(Clone, Debug)]
pub struct Vocab {
    pub tokens: Vec<String>,
    map: HashMap<String, u32>,
}

impl Vocab {
    pub fn new(tokens: Vec<String>) -> Vocab {
        let map = tokens
            .iter()
            .enumerate()
            .map(|(i, t)| (t.clone(), i as u32))
            .collect();
        Vocab { tokens, map }
    }

    pub fn load(path: &std::path::Path) -> crate::Result<Vocab> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)?;
        let arr = j
            .get("tokens")
            .and_then(|t| t.as_arr())
            .ok_or_else(|| anyhow::anyhow!("vocab.json missing 'tokens'"))?;
        let tokens: Vec<String> = arr
            .iter()
            .map(|t| t.as_str().unwrap_or("<bad>").to_string())
            .collect();
        anyhow::ensure!(tokens.len() >= 3, "vocab too small");
        Ok(Vocab::new(tokens))
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn id(&self, tok: &str) -> Option<u32> {
        self.map.get(tok).copied()
    }

    pub fn token(&self, id: u32) -> &str {
        self.tokens
            .get(id as usize)
            .map(|s| s.as_str())
            .unwrap_or("<unk>")
    }

    /// Whitespace-split encode (synthlang tokens are whole words).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.split_whitespace()
            .filter_map(|w| self.id(w))
            .collect()
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&i| self.token(i))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Vocab {
        Vocab::new(
            ["<pad>", "<bos>", "<eos>", "the", "cat", "sits"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
        )
    }

    #[test]
    fn encode_decode_roundtrip() {
        let v = tiny();
        let ids = v.encode("the cat sits");
        assert_eq!(ids, vec![3, 4, 5]);
        assert_eq!(v.decode(&ids), "the cat sits");
    }

    #[test]
    fn unknown_words_dropped() {
        let v = tiny();
        assert_eq!(v.encode("the dog sits"), vec![3, 5]);
    }

    #[test]
    fn load_from_json() {
        let dir = std::env::temp_dir().join("quip_vocab_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("vocab.json");
        std::fs::write(
            &path,
            r#"{"tokens": ["<pad>", "<bos>", "<eos>", "a", "b"]}"#,
        )
        .unwrap();
        let v = Vocab::load(&path).unwrap();
        assert_eq!(v.len(), 5);
        assert_eq!(v.id("b"), Some(4));
    }
}
