//! Token streams: the `.bin` corpus format (written by synthlang.py),
//! sequence chunking for perplexity eval, and calibration sampling
//! (the paper uses 128 random 2048-token segments; we scale lengths to
//! the model's context).

use crate::util::rng::Rng;

/// Magic for the token binary format: "QTOK".
pub const TOK_MAGIC: u32 = 0x4B4F_5451;

/// A flat token stream (one split of the corpus).
#[derive(Clone, Debug)]
pub struct TokenStream {
    pub vocab_size: u32,
    pub tokens: Vec<u32>,
}

impl TokenStream {
    /// Load from the `QTOK` binary: magic u32, version u32, vocab u32,
    /// count u64, then u16 token ids.
    pub fn load(path: &std::path::Path) -> crate::Result<TokenStream> {
        let raw = std::fs::read(path)?;
        let mut r = crate::util::bytes::Reader::new(&raw);
        let magic = r.u32()?;
        anyhow::ensure!(magic == TOK_MAGIC, "bad token file magic {magic:#x}");
        let version = r.u32()?;
        anyhow::ensure!(version == 1, "unsupported token file version {version}");
        let vocab_size = r.u32()?;
        let n = r.u64()? as usize;
        let bytes = r.bytes(n * 2)?;
        let tokens: Vec<u32> = bytes
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes(c.try_into().unwrap()) as u32)
            .collect();
        Ok(TokenStream { vocab_size, tokens })
    }

    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        let mut w = crate::util::bytes::Writer::new();
        w.u32(TOK_MAGIC);
        w.u32(1);
        w.u32(self.vocab_size);
        w.u64(self.tokens.len() as u64);
        for &t in &self.tokens {
            w.bytes(&(t as u16).to_le_bytes());
        }
        crate::util::fsx::atomic_write(path, &w.buf)
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Non-overlapping sequences of length `seq_len` (for perplexity).
    /// `limit` caps the number of sequences (0 = all).
    pub fn sequences(&self, seq_len: usize, limit: usize) -> Vec<&[u32]> {
        let mut out = Vec::new();
        let mut pos = 0;
        while pos + seq_len <= self.tokens.len() {
            out.push(&self.tokens[pos..pos + seq_len]);
            pos += seq_len;
            if limit > 0 && out.len() >= limit {
                break;
            }
        }
        out
    }

    /// `count` random windows of length `seq_len` — the calibration set
    /// (paper §6: "128 random 2048 token segments").
    pub fn calibration(&self, seq_len: usize, count: usize, seed: u64) -> Vec<Vec<u32>> {
        let mut rng = Rng::new(seed);
        let max_start = self.tokens.len().saturating_sub(seq_len);
        assert!(max_start > 0, "stream shorter than seq_len");
        (0..count)
            .map(|_| {
                let s = rng.below(max_start + 1);
                self.tokens[s..s + seq_len].to_vec()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(n: usize) -> TokenStream {
        TokenStream {
            vocab_size: 64,
            tokens: (0..n as u32).map(|i| i % 64).collect(),
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("quip_tok_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let s = stream(1000);
        s.save(&path).unwrap();
        let s2 = TokenStream::load(&path).unwrap();
        assert_eq!(s2.vocab_size, 64);
        assert_eq!(s2.tokens, s.tokens);
    }

    #[test]
    fn sequences_are_disjoint_and_sized() {
        let s = stream(1000);
        let seqs = s.sequences(128, 0);
        assert_eq!(seqs.len(), 7); // floor(1000/128)
        for w in &seqs {
            assert_eq!(w.len(), 128);
        }
        assert_eq!(s.sequences(128, 3).len(), 3);
    }

    #[test]
    fn calibration_is_seeded_and_in_bounds() {
        let s = stream(500);
        let a = s.calibration(64, 10, 7);
        let b = s.calibration(64, 10, 7);
        assert_eq!(a, b);
        let c = s.calibration(64, 10, 8);
        assert_ne!(a, c);
        for w in &a {
            assert_eq!(w.len(), 64);
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("quip_tok_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a token file").unwrap();
        assert!(TokenStream::load(&path).is_err());
    }
}
