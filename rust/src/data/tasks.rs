//! Zero-shot task sets — the LAMBADA / ARC-Easy / PiQA / StoryCloze
//! analogs built from synthlang (see DESIGN.md §2 substitutions):
//!
//! * `Cloze`  — predict the deterministic final token of a context
//!   (LAMBADA-analog; scored by argmax accuracy).
//! * `Choice` — pick the most probable continuation among k options
//!   (2-way ≈ PiQA/StoryCloze, 4-way ≈ ARC-Easy; scored by summed
//!   log-probability).

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Cloze,
    Choice,
}

#[derive(Clone, Debug)]
pub struct TaskInstance {
    pub kind: TaskKind,
    /// Context token ids (starts with BOS).
    pub context: Vec<u32>,
    /// Cloze: single-element options = [answer token]. Choice: each option
    /// is a candidate continuation (token ids).
    pub options: Vec<Vec<u32>>,
    /// Index of the correct option (cloze: always 0).
    pub answer: usize,
}

#[derive(Clone, Debug)]
pub struct TaskSet {
    pub name: String,
    pub instances: Vec<TaskInstance>,
}

impl TaskSet {
    /// Load from the build-time `tasks.json`:
    /// `{"name": ..., "instances": [{"kind": "cloze"|"choice",
    ///   "context": [...], "options": [[...]], "answer": 0}, ...]}`
    pub fn load(path: &std::path::Path) -> crate::Result<TaskSet> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> crate::Result<TaskSet> {
        let j = Json::parse(text)?;
        let name = j.req_str("name")?.to_string();
        let mut instances = Vec::new();
        for inst in j
            .get("instances")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("tasks.json missing 'instances'"))?
        {
            let kind = match inst.req_str("kind")? {
                "cloze" => TaskKind::Cloze,
                "choice" => TaskKind::Choice,
                other => anyhow::bail!("unknown task kind '{other}'"),
            };
            let context: Vec<u32> = inst
                .req("context")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_f64().map(|v| v as u32))
                .collect();
            let options: Vec<Vec<u32>> = inst
                .req("options")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|o| {
                    o.as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|x| x.as_f64().map(|v| v as u32))
                        .collect()
                })
                .collect();
            let answer = inst.req_usize("answer")?;
            anyhow::ensure!(!options.is_empty() && answer < options.len());
            anyhow::ensure!(!context.is_empty());
            instances.push(TaskInstance {
                kind,
                context,
                options,
                answer,
            });
        }
        Ok(TaskSet { name, instances })
    }

    pub fn len(&self) -> usize {
        self.instances.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "cloze-analog",
      "instances": [
        {"kind": "cloze", "context": [1, 5, 9], "options": [[12]], "answer": 0},
        {"kind": "choice", "context": [1, 4], "options": [[7, 8], [9, 2]], "answer": 1}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let t = TaskSet::parse(SAMPLE).unwrap();
        assert_eq!(t.name, "cloze-analog");
        assert_eq!(t.len(), 2);
        assert_eq!(t.instances[0].kind, TaskKind::Cloze);
        assert_eq!(t.instances[1].options.len(), 2);
        assert_eq!(t.instances[1].answer, 1);
    }

    #[test]
    fn rejects_bad_answer_index() {
        let bad = r#"{"name": "x", "instances": [
            {"kind": "cloze", "context": [1], "options": [[2]], "answer": 3}]}"#;
        assert!(TaskSet::parse(bad).is_err());
    }

    #[test]
    fn rejects_unknown_kind() {
        let bad = r#"{"name": "x", "instances": [
            {"kind": "essay", "context": [1], "options": [[2]], "answer": 0}]}"#;
        assert!(TaskSet::parse(bad).is_err());
    }
}
