//! A small in-Rust synthetic-language generator, independent of the
//! build-time python generator. Used by unit/integration tests (so
//! `cargo test` never depends on `make artifacts`) and by the quickstart
//! example. Produces a first-order-Markov "language" with strong local
//! structure that tiny LMs can learn.

use super::dataset::TokenStream;
use crate::util::rng::Rng;

/// Generate a token stream over `vocab_size` tokens (≥ 8) with a banded,
/// sparse transition structure: each token prefers a small successor set.
pub fn markov_stream(vocab_size: u32, n_tokens: usize, seed: u64) -> TokenStream {
    assert!(vocab_size >= 8);
    let mut rng = Rng::new(seed);
    let v = vocab_size as usize;
    // Each token gets 4 preferred successors with weights [8, 4, 2, 1].
    let successors: Vec<[u32; 4]> = (0..v)
        .map(|_| {
            [
                rng.below(v) as u32,
                rng.below(v) as u32,
                rng.below(v) as u32,
                rng.below(v) as u32,
            ]
        })
        .collect();
    let mut tokens = Vec::with_capacity(n_tokens);
    let mut cur = rng.below(v) as u32;
    for _ in 0..n_tokens {
        tokens.push(cur);
        cur = if rng.coin(0.9) {
            let s = &successors[cur as usize];
            s[rng.weighted(&[8.0, 4.0, 2.0, 1.0])]
        } else {
            rng.below(v) as u32 // noise
        };
    }
    TokenStream {
        vocab_size,
        tokens,
    }
}

/// Empirical unigram entropy of a stream in nats (diagnostics for tests).
pub fn unigram_entropy(s: &TokenStream) -> f64 {
    let mut counts = vec![0usize; s.vocab_size as usize];
    for &t in &s.tokens {
        counts[t as usize] += 1;
    }
    let n = s.tokens.len() as f64;
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.ln()
        })
        .sum()
}

/// Empirical conditional (bigram) entropy in nats. Must be well below the
/// unigram entropy for a learnable stream.
pub fn bigram_entropy(s: &TokenStream) -> f64 {
    let v = s.vocab_size as usize;
    let mut pair = vec![0usize; v * v];
    let mut uni = vec![0usize; v];
    for w in s.tokens.windows(2) {
        pair[w[0] as usize * v + w[1] as usize] += 1;
        uni[w[0] as usize] += 1;
    }
    let total = (s.tokens.len() - 1) as f64;
    let mut h = 0.0;
    for a in 0..v {
        if uni[a] == 0 {
            continue;
        }
        for b in 0..v {
            let c = pair[a * v + b];
            if c == 0 {
                continue;
            }
            let p_ab = c as f64 / total;
            let p_b_given_a = c as f64 / uni[a] as f64;
            h -= p_ab * p_b_given_a.ln();
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_has_requested_shape() {
        let s = markov_stream(64, 10_000, 1);
        assert_eq!(s.tokens.len(), 10_000);
        assert!(s.tokens.iter().all(|&t| t < 64));
    }

    #[test]
    fn deterministic_by_seed() {
        let a = markov_stream(32, 1000, 5);
        let b = markov_stream(32, 1000, 5);
        assert_eq!(a.tokens, b.tokens);
        let c = markov_stream(32, 1000, 6);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn has_learnable_structure() {
        // Conditional entropy must be far below unigram entropy — that gap
        // is what a trained LM exploits, and what quantization must keep.
        let s = markov_stream(64, 50_000, 2);
        let h1 = unigram_entropy(&s);
        let h2 = bigram_entropy(&s);
        assert!(
            h2 < 0.75 * h1,
            "bigram entropy {h2:.3} not ≪ unigram {h1:.3}"
        );
    }
}
