//! Data substrate: the synthetic-language ("synthlang") corpus readers,
//! tokenizer vocabulary, dataset splits, calibration sampling, and the
//! zero-shot task sets.
//!
//! The corpus itself is *generated at build time* by
//! `python/compile/synthlang.py` (single source of truth, consumed here);
//! `gen` provides an independent in-Rust generator so unit tests do not
//! depend on artifacts.

pub mod tokenizer;
pub mod dataset;
pub mod tasks;
pub mod gen;

pub use dataset::TokenStream;
pub use tasks::{TaskInstance, TaskKind, TaskSet};
pub use tokenizer::Vocab;
