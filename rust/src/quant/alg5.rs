//! Algorithm 5 — "fixed" rounding via a convex program (paper §5.2).
//!
//! Solves
//!     minimize   tr(H RᵀR)
//!     over       R unit upper triangular
//!     subject to eᵢᵀRᵀR eᵢ ≤ 1 + c   ∀i
//! then rounds with *stochastic* Q and feedback U̇ = R⁻¹ − I. For large c
//! the solution is the LDL factor and this reduces to base QuIP
//! (Theorem 7 gives the finite-grid guarantee).
//!
//! Solver: projected gradient descent. The feasible set factorizes per
//! column — {R_kk = 1, strictly-lower = 0, ‖R_{1:k−1,k}‖² ≤ c} — so the
//! Euclidean projection is exact (shrink each column's strict-upper part);
//! that makes PGD simpler than the ADMM the paper suggests while reaching
//! the same optimum of this convex problem (documented in DESIGN.md §4).

use crate::linalg::ldl::udu;
use crate::linalg::solve::unit_upper_inverse;
use crate::linalg::Mat;

/// Result of solving problem (7).
pub struct Alg5Plan {
    /// The optimizer R (unit upper triangular).
    pub r: Mat,
    /// Feedback U̇ = R⁻¹ − I fed to the rounding core.
    pub u_dot: Mat,
    /// Final objective tr(H RᵀR).
    pub objective: f64,
    pub iterations: usize,
}

/// tr(H RᵀR).
pub fn objective(h: &Mat, r: &Mat) -> f64 {
    // tr(H RᵀR) = Σ_ij (R H)_ij R_ij? No: tr(H RᵀR) = tr(R H Rᵀ) = Σ_i (R H Rᵀ)_ii.
    let rh = r.matmul(h);
    let mut tr = 0.0;
    for i in 0..r.rows {
        tr += crate::linalg::matrix::dot(rh.row(i), r.row(i));
    }
    tr
}

/// Project onto {unit upper triangular, per-column strict-upper norm² ≤ c}.
fn project(r: &mut Mat, c: f64) {
    let n = r.rows;
    for i in 0..n {
        r[(i, i)] = 1.0;
        for j in 0..i {
            r[(i, j)] = 0.0;
        }
    }
    let bound = c.sqrt();
    for k in 0..n {
        let mut norm2 = 0.0;
        for i in 0..k {
            norm2 += r[(i, k)] * r[(i, k)];
        }
        let norm = norm2.sqrt();
        if norm > bound && norm > 0.0 {
            let scale = bound / norm;
            for i in 0..k {
                r[(i, k)] *= scale;
            }
        }
    }
}

/// Solve problem (7) with projected gradient descent.
///
/// * `c` — the per-column slack (paper's hyperparameter; Lemma 13 suggests
///   c = 2/log(4mn/δ)).
/// * Initialized at the projected LDL solution (the c = ∞ optimum).
pub fn solve(h: &Mat, c: f64, max_iters: usize, tol: f64) -> Alg5Plan {
    let n = h.rows;
    // Init: R = (U̇+I)⁻¹ from the LDL factorization — optimal when the
    // constraint is inactive.
    let f = udu(h, 1e-12);
    let mut r = unit_upper_inverse(&f.u);
    project(&mut r, c);

    // Step size from a Gershgorin bound on λmax(H) (Lipschitz const = 2λmax).
    let mut lmax: f64 = 0.0;
    for i in 0..n {
        let mut s = 0.0;
        for j in 0..n {
            s += h[(i, j)].abs();
        }
        lmax = lmax.max(s);
    }
    let step = 1.0 / (2.0 * lmax.max(1e-12));

    let mut prev = objective(h, &r);
    let mut iters = 0;
    for it in 0..max_iters {
        iters = it + 1;
        // ∇_R tr(H RᵀR) = 2 R H.
        let grad = r.matmul(h);
        for (x, g) in r.data.iter_mut().zip(&grad.data) {
            *x -= 2.0 * step * g;
        }
        project(&mut r, c);
        let cur = objective(h, &r);
        if (prev - cur).abs() <= tol * prev.abs().max(1e-12) {
            prev = cur;
            break;
        }
        prev = cur;
    }

    let rinv = unit_upper_inverse(&r);
    let mut u_dot = rinv;
    for i in 0..n {
        u_dot[(i, i)] = 0.0;
    }
    Alg5Plan {
        u_dot,
        objective: prev,
        iterations: iters,
        r,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::max_abs_diff;
    use crate::util::rng::Rng;
    use crate::util::testkit::{propcheck, random_spd};

    #[test]
    fn large_c_recovers_ldl_solution() {
        let mut rng = Rng::new(1);
        let h = random_spd(&mut rng, 10, 1e-2);
        let f = udu(&h, 1e-12);
        let plan = solve(&h, 1e9, 500, 1e-12);
        // Objective equals tr(D) (the unconstrained optimum, Lemma 8).
        let trd = f.trace_d();
        assert!(
            (plan.objective - trd).abs() < 1e-6 * trd,
            "objective {} vs tr(D) {}",
            plan.objective,
            trd
        );
        // And U̇ matches the LDL feedback.
        assert!(max_abs_diff(&plan.u_dot, &f.strictly_upper()) < 1e-4);
    }

    #[test]
    fn solution_is_feasible() {
        propcheck("alg5-feasible", 8, |rng| {
            let n = 6 + rng.below(10);
            let c = 0.1 + rng.next_f64();
            let h = random_spd(rng, n, 1e-2);
            let plan = solve(&h, c, 300, 1e-10);
            for k in 0..n {
                let mut norm2 = 1.0; // the unit diagonal
                for i in 0..k {
                    norm2 += plan.r[(i, k)] * plan.r[(i, k)];
                }
                assert!(norm2 <= 1.0 + c + 1e-8, "col {k}: {norm2} > 1+{c}");
            }
        });
    }

    #[test]
    fn objective_decreases_with_larger_c() {
        // Relaxing the constraint can only improve the optimum.
        let mut rng = Rng::new(3);
        let h = random_spd(&mut rng, 12, 1e-2);
        let tight = solve(&h, 0.05, 500, 1e-12).objective;
        let loose = solve(&h, 10.0, 500, 1e-12).objective;
        assert!(loose <= tight + 1e-9);
    }

    #[test]
    fn objective_bounded_by_tr_h_and_tr_d() {
        // R = I is feasible with objective tr(H); optimum ≤ tr(H).
        // tr(D) lower-bounds any feasible objective (global min).
        let mut rng = Rng::new(4);
        let h = random_spd(&mut rng, 10, 1e-2);
        let trd = udu(&h, 1e-12).trace_d();
        let plan = solve(&h, 0.5, 500, 1e-12);
        assert!(plan.objective <= h.trace() + 1e-9);
        assert!(plan.objective >= trd - 1e-9);
    }
}
