//! The Q subroutine of Eq. (2): nearest or unbiased stochastic rounding to
//! the integer grid, with the finite-grid clamp of Alg 3 line 3.

use crate::util::rng::Rng;

/// Which rounding subroutine Q to use inside an adaptive rounder.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundMode {
    /// Biased nearest rounding (the practical default; Table 15 shows it
    /// beats unbiased in perplexity).
    Nearest,
    /// Unbiased stochastic rounding: rounds z up with probability frac(z),
    /// so E[Q(z)] = z.
    Stochastic,
}

/// Round a scalar with the chosen mode (no clamp).
#[inline]
pub fn round(mode: RoundMode, z: f64, rng: &mut Rng) -> f64 {
    match mode {
        RoundMode::Nearest => z.round(),
        RoundMode::Stochastic => {
            let f = z.floor();
            let frac = z - f;
            if rng.next_f64() < frac {
                f + 1.0
            } else {
                f
            }
        }
    }
}

/// Round and clamp into [0, 2^b − 1].
#[inline]
pub fn round_clamp(mode: RoundMode, z: f64, bits: u32, rng: &mut Rng) -> f64 {
    super::grid::clamp_grid(round(mode, z, rng), bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rounds_half_away_from_even_ties() {
        let mut rng = Rng::new(0);
        assert_eq!(round(RoundMode::Nearest, 1.4, &mut rng), 1.0);
        assert_eq!(round(RoundMode::Nearest, 1.6, &mut rng), 2.0);
        assert_eq!(round(RoundMode::Nearest, -0.4, &mut rng), 0.0);
    }

    #[test]
    fn stochastic_is_unbiased() {
        let mut rng = Rng::new(1);
        let z = 2.3;
        let n = 200_000;
        let mean: f64 = (0..n)
            .map(|_| round(RoundMode::Stochastic, z, &mut rng))
            .sum::<f64>()
            / n as f64;
        assert!((mean - z).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn stochastic_on_integer_is_exact() {
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            assert_eq!(round(RoundMode::Stochastic, 3.0, &mut rng), 3.0);
        }
    }

    #[test]
    fn clamp_respects_grid() {
        let mut rng = Rng::new(3);
        assert_eq!(round_clamp(RoundMode::Nearest, 9.7, 2, &mut rng), 3.0);
        assert_eq!(round_clamp(RoundMode::Nearest, -4.2, 2, &mut rng), 0.0);
        assert_eq!(round_clamp(RoundMode::Nearest, 2.2, 2, &mut rng), 2.0);
    }
}
