//! Bit-packed storage of quantized codes — the `.qz` wire format.
//!
//! Two [`CodeLayout`]s share the `packed` bitstream:
//!
//! * **Scalar** — one integer code (value in [0, 2^b − 1]) per weight,
//!   packed LSB-first: true 2/3/4-bit storage, including the cross-byte
//!   3-bit case.
//! * **Vq** — one E8-style codebook index per
//!   [`VQ_GROUP`](super::grid::VQ_GROUP)-wide group of weights, `8·b`
//!   bits wide (the same b bits/weight), plus the stored codebook seed
//!   so decode regenerates the [`super::grid::Codebook`].
//!
//! A `QuantizedLayer` bundles codes + the post-processing state (seeds,
//! scales, grid); the whole model artifact is a sequence of layers.

use super::grid::{Codebook, VQ_GROUP};
use super::incoherence::PostState;
use super::rounder::VqCodes;
use crate::linalg::Mat;
use crate::util::bytes::{Reader, Writer};

/// `.qz` wire-format versions. v1 is the seed format (Kron transform
/// implied); v2 adds the per-layer transform kind and the container-level
/// CRC32 footer (see [`crate::model::quantized`]); v3 adds the per-layer
/// [`CodeLayout`] tag (scalar codes vs vector-codebook indices). Layers
/// always write the current version; readers accept all three.
pub const FORMAT_V1: u32 = 1;
pub const FORMAT_V2: u32 = 2;
pub const FORMAT_V3: u32 = 3;

/// How a layer's `packed` bitstream encodes the code matrix. `.qz` v3
/// layer records carry the tag; v1/v2 records are always scalar.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodeLayout {
    /// One integer code per weight, `bits` wide, LSB-first.
    Scalar,
    /// One codebook index per 8-wide group of weights (`8·bits` wide);
    /// `cb_seed` regenerates the E8-style codebook at decode time.
    Vq { cb_seed: u64 },
}

/// Pack `codes` (each < 2^bits) into an LSB-first bitstream.
pub fn pack_codes(codes: &[u8], bits: u32) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!((c as u32) < (1 << bits));
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + bits as usize > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += bits as usize;
    }
    out
}

/// Unpack `n` codes from an LSB-first bitstream.
pub fn unpack_codes(packed: &[u8], bits: u32, n: usize) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let mask = ((1u16 << bits) - 1) as u16;
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let lo = packed[byte] as u16 >> off;
        let v = if off + bits as usize > 8 {
            lo | ((packed[byte + 1] as u16) << (8 - off))
        } else {
            lo
        };
        out.push((v & mask) as u8);
        bitpos += bits as usize;
    }
    out
}

/// Pack group indices (`index_bits` wide each, up to 64) LSB-first into
/// a contiguous bitstream — the vq counterpart of [`pack_codes`]. At the
/// shipped widths (8·bits with even bits: 16/32/48/64) indices are
/// byte-aligned, but the packer is generic.
pub fn pack_group_indices(indices: &[u64], index_bits: u32) -> Vec<u8> {
    assert!((1..=64).contains(&index_bits));
    let total_bits = indices.len() * index_bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &ix in indices {
        debug_assert!(index_bits == 64 || ix < (1u64 << index_bits));
        let mut val = ix;
        let mut rem = index_bits as usize;
        let mut pos = bitpos;
        while rem > 0 {
            let byte = pos / 8;
            let off = pos % 8;
            let take = (8 - off).min(rem);
            out[byte] |= ((val & ((1u64 << take) - 1)) as u8) << off;
            val >>= take;
            pos += take;
            rem -= take;
        }
        bitpos += index_bits as usize;
    }
    out
}

/// Unpack `count` group indices from an LSB-first bitstream.
pub fn unpack_group_indices(packed: &[u8], index_bits: u32, count: usize) -> Vec<u64> {
    assert!((1..=64).contains(&index_bits));
    let mut out = Vec::with_capacity(count);
    let mut bitpos = 0usize;
    for _ in 0..count {
        let mut val = 0u64;
        let mut got = 0usize;
        let mut pos = bitpos;
        while got < index_bits as usize {
            let byte = pos / 8;
            let off = pos % 8;
            let take = (8 - off).min(index_bits as usize - got);
            let chunk = (packed[byte] as u64 >> off) & ((1u64 << take) - 1);
            val |= chunk << got;
            got += take;
            pos += take;
        }
        out.push(val);
        bitpos += index_bits as usize;
    }
    out
}

/// A quantized linear layer as stored on disk / held by the native engine.
#[derive(Clone)]
pub struct QuantizedLayer {
    pub name: String,
    pub bits: u32,
    pub m: usize,
    pub n: usize,
    /// Packed codes (scalar) or group indices (vq), row-major.
    pub packed: Vec<u8>,
    /// What `packed` contains; see [`CodeLayout`].
    pub layout: CodeLayout,
    pub post: PostState,
}

impl QuantizedLayer {
    /// Build a scalar-layout layer from a float code matrix (integer
    /// values) + post state.
    pub fn from_codes(name: &str, codes: &Mat, bits: u32, post: PostState) -> QuantizedLayer {
        let raw: Vec<u8> = codes.data.iter().map(|&c| c as u8).collect();
        QuantizedLayer {
            name: name.to_string(),
            bits,
            m: codes.rows,
            n: codes.cols,
            packed: pack_codes(&raw, bits),
            layout: CodeLayout::Scalar,
            post,
        }
    }

    /// Build a vector-quantized layer from the `vq` rounder's per-group
    /// codebook indices (row-major, ⌈n/8⌉ per row — see
    /// [`crate::quant::Rounded`]).
    pub fn from_vq_indices(
        name: &str,
        m: usize,
        n: usize,
        bits: u32,
        vq: &VqCodes,
        post: PostState,
    ) -> QuantizedLayer {
        assert!(
            bits % 2 == 0 && (2..=8).contains(&bits),
            "vq layers use even bit widths 2-8"
        );
        let gpr = n.div_ceil(VQ_GROUP);
        assert_eq!(vq.indices.len(), m * gpr, "one index per (row, 8-group)");
        QuantizedLayer {
            name: name.to_string(),
            bits,
            m,
            n,
            packed: pack_group_indices(&vq.indices, 8 * bits),
            layout: CodeLayout::Vq { cb_seed: vq.cb_seed },
            post,
        }
    }

    /// Unpack codes back to a float matrix: integer values for scalar
    /// layers, decoded codebook points for vq layers.
    pub fn codes(&self) -> Mat {
        match self.layout {
            CodeLayout::Scalar => {
                let raw = unpack_codes(&self.packed, self.bits, self.m * self.n);
                Mat {
                    rows: self.m,
                    cols: self.n,
                    data: raw.into_iter().map(|c| c as f64).collect(),
                }
            }
            CodeLayout::Vq { cb_seed } => {
                let cb = Codebook::e8(self.bits, cb_seed)
                    .expect("vq layer bits validated at construction/deserialize");
                let gpr = self.n.div_ceil(VQ_GROUP);
                let idxs = unpack_group_indices(&self.packed, 8 * self.bits, self.m * gpr);
                let mut data = vec![0.0f64; self.m * self.n];
                let mut buf = [0.0f64; VQ_GROUP];
                for i in 0..self.m {
                    for g in 0..gpr {
                        let r = (self.n - g * VQ_GROUP).min(VQ_GROUP);
                        cb.decode_group(idxs[i * gpr + g], &mut buf[..r]);
                        data[i * self.n + g * VQ_GROUP..i * self.n + g * VQ_GROUP + r]
                            .copy_from_slice(&buf[..r]);
                    }
                }
                Mat {
                    rows: self.m,
                    cols: self.n,
                    data,
                }
            }
        }
    }

    /// Unpack one row of codes (decode hot path; avoids full unpack).
    /// Scalar layout only — vq rows decode through the engine's LUT path.
    pub fn codes_row(&self, i: usize, out: &mut [u8]) {
        assert_eq!(
            self.layout,
            CodeLayout::Scalar,
            "codes_row reads scalar codes; vq layers decode via the codebook LUT"
        );
        assert_eq!(out.len(), self.n);
        let bits = self.bits as usize;
        let mask = ((1u16 << bits) - 1) as u16;
        let mut bitpos = i * self.n * bits;
        for slot in out.iter_mut() {
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let lo = self.packed[byte] as u16 >> off;
            let v = if off + bits > 8 {
                lo | ((self.packed[byte + 1] as u16) << (8 - off))
            } else {
                lo
            };
            *slot = (v & mask) as u8;
            bitpos += bits;
        }
    }

    /// Fully dequantize to original-space weights (cold path / tests).
    pub fn dequantize(&self) -> Mat {
        super::incoherence::postprocess(&self.codes(), &self.post)
    }

    /// Effective storage bits per weight (codes + metadata overhead).
    pub fn bits_per_weight(&self) -> f64 {
        let meta = 8.0 * (self.serialized_len() - self.packed.len()) as f64;
        (self.packed.len() as f64 * 8.0 + meta) / (self.m * self.n) as f64
    }

    fn serialized_len(&self) -> usize {
        let mut w = Writer::new();
        self.serialize(&mut w);
        w.buf.len()
    }

    /// Serialize in the current format ([`FORMAT_V3`]).
    pub fn serialize(&self, w: &mut Writer) {
        self.serialize_version(w, FORMAT_V3);
    }

    /// Serialize in an explicit format version. v1/v2 exist so tests can
    /// pin that pre-subsystem artifacts still load; v1 cannot represent
    /// non-Kron transforms (no transform field) and v1/v2 cannot
    /// represent vector-codebook layers (no layout field), so writing
    /// either is a refusal here rather than silent corruption at reload.
    pub fn serialize_version(&self, w: &mut Writer, version: u32) {
        assert!(
            version >= FORMAT_V2
                || !self.post.incoherent
                || self.post.transform == crate::linalg::TransformKind::Kron,
            "layer '{}' uses the {} transform, which the v1 .qz layout cannot represent",
            self.name,
            self.post.transform
        );
        assert!(
            version >= FORMAT_V3 || self.layout == CodeLayout::Scalar,
            "layer '{}' stores vector-codebook indices, which the v{} .qz layout cannot represent",
            self.name,
            version
        );
        w.string(&self.name);
        w.u32(self.bits);
        w.u64(self.m as u64);
        w.u64(self.n as u64);
        if version >= FORMAT_V3 {
            match self.layout {
                CodeLayout::Scalar => w.u8(0),
                CodeLayout::Vq { cb_seed } => {
                    w.u8(1);
                    w.u64(cb_seed);
                }
            }
        }
        w.u64(self.packed.len() as u64);
        w.bytes(&self.packed);
        self.post.serialize(w, version);
    }

    pub fn deserialize(r: &mut Reader, version: u32) -> crate::Result<QuantizedLayer> {
        let name = r.string()?;
        let bits = r.u32()?;
        anyhow::ensure!((1..=8).contains(&bits), "corrupt layer '{name}': {bits} bits");
        let m = r.u64()? as usize;
        let n = r.u64()? as usize;
        let layout = if version >= FORMAT_V3 {
            match r.u8()? {
                0 => CodeLayout::Scalar,
                1 => {
                    anyhow::ensure!(
                        bits % 2 == 0 && (2..=8).contains(&bits),
                        "corrupt layer '{name}': vq layout at {bits} bits"
                    );
                    CodeLayout::Vq { cb_seed: r.u64()? }
                }
                t => anyhow::bail!("corrupt layer '{name}': unknown code layout {t}"),
            }
        } else {
            CodeLayout::Scalar
        };
        let plen = r.u64()? as usize;
        // Checked arithmetic: corrupt v1 files have no CRC shield, so a
        // garbage m/n must not wrap into a passing bound.
        let need = match layout {
            CodeLayout::Scalar => m
                .checked_mul(n)
                .and_then(|mn| mn.checked_mul(bits as usize))
                .map(|b| b.div_ceil(8)),
            // One 8·bits-wide index per 8-group: exactly `bits` bytes.
            CodeLayout::Vq { .. } => m
                .checked_mul(n.div_ceil(VQ_GROUP))
                .and_then(|groups| groups.checked_mul(bits as usize)),
        };
        anyhow::ensure!(
            plen <= r.remaining() && need.is_some_and(|nb| plen >= nb),
            "corrupt layer '{name}': {plen}-byte code block for {m}x{n} @ {bits} bits"
        );
        let packed = r.bytes(plen)?.to_vec();
        let post = PostState::deserialize(r, version)?;
        Ok(QuantizedLayer {
            name,
            bits,
            m,
            n,
            packed,
            layout,
            post,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::incoherence::{preprocess, Processing};
    use crate::util::rng::Rng;
    use crate::util::testkit::{propcheck, random_hessian, random_mat};

    #[test]
    fn pack_unpack_roundtrip_all_widths() {
        propcheck("pack-roundtrip", 20, |rng| {
            let bits = 1 + rng.below(8) as u32;
            let n = 1 + rng.below(200);
            let codes: Vec<u8> = (0..n)
                .map(|_| rng.below(1usize << bits) as u8)
                .collect();
            let packed = pack_codes(&codes, bits);
            assert_eq!(packed.len(), (n * bits as usize).div_ceil(8));
            let back = unpack_codes(&packed, bits, n);
            assert_eq!(back, codes);
        });
    }

    #[test]
    fn three_bit_crosses_byte_boundaries() {
        let codes: Vec<u8> = (0..17).map(|i| (i % 8) as u8).collect();
        let packed = pack_codes(&codes, 3);
        assert_eq!(packed.len(), 7); // 51 bits → 7 bytes
        assert_eq!(unpack_codes(&packed, 3, 17), codes);
    }

    #[test]
    fn codes_row_matches_full_unpack() {
        let mut rng = Rng::new(3);
        let w = random_mat(&mut rng, 7, 13);
        let h = random_hessian(&mut rng, 13, 4, 1e-2);
        let pre = preprocess(&w, &h, 3, &Processing::incoherent(), 5);
        let codes = crate::quant::ldlq::round_matrix(
            &pre.wg,
            3,
            crate::quant::rounding::RoundMode::Nearest,
            0,
        );
        let layer = QuantizedLayer::from_codes("test", &codes, 3, pre.post);
        let full = layer.codes();
        let mut row = vec![0u8; 13];
        for i in 0..7 {
            layer.codes_row(i, &mut row);
            for j in 0..13 {
                assert_eq!(row[j] as f64, full[(i, j)]);
            }
        }
    }

    #[test]
    fn layer_serialization_roundtrip() {
        use crate::linalg::TransformKind;
        let mut rng = Rng::new(4);
        let w = random_mat(&mut rng, 6, 12);
        let h = random_hessian(&mut rng, 12, 4, 1e-2);
        for kind in [TransformKind::Kron, TransformKind::Hadamard] {
            let pre = preprocess(&w, &h, 2, &Processing::incoherent_with(kind), 9);
            let codes = crate::quant::ldlq::ldlq(
                &pre.wg,
                &pre.h,
                2,
                crate::quant::rounding::RoundMode::Nearest,
                9,
            );
            let layer = QuantizedLayer::from_codes("blk0.attn.q", &codes, 2, pre.post);
            let mut buf = Writer::new();
            layer.serialize(&mut buf);
            let mut r = Reader::new(&buf.buf);
            let layer2 = QuantizedLayer::deserialize(&mut r, FORMAT_V3).unwrap();
            assert_eq!(layer2.name, "blk0.attn.q");
            assert_eq!(layer2.layout, CodeLayout::Scalar);
            assert_eq!(layer2.post.transform, kind);
            assert_eq!(layer2.codes().data, layer.codes().data);
            assert_eq!(layer2.dequantize().data, layer.dequantize().data);
        }
    }

    #[test]
    fn v1_layer_bytes_still_deserialize() {
        // A layer written in the pre-subsystem v1 layout (no transform
        // byte) must load with TransformKind::Kron implied.
        let mut rng = Rng::new(14);
        let w = random_mat(&mut rng, 4, 8);
        let h = random_hessian(&mut rng, 8, 3, 1e-2);
        let pre = preprocess(&w, &h, 2, &Processing::incoherent(), 3);
        let codes = crate::quant::ldlq::round_matrix(
            &pre.wg,
            2,
            crate::quant::rounding::RoundMode::Nearest,
            0,
        );
        let layer = QuantizedLayer::from_codes("old", &codes, 2, pre.post);
        let mut buf = Writer::new();
        layer.serialize_version(&mut buf, FORMAT_V1);
        let mut r = Reader::new(&buf.buf);
        let layer2 = QuantizedLayer::deserialize(&mut r, FORMAT_V1).unwrap();
        assert_eq!(r.remaining(), 0);
        assert_eq!(layer2.post.transform, crate::linalg::TransformKind::Kron);
        assert_eq!(layer2.dequantize().data, layer.dequantize().data);
    }

    #[test]
    #[should_panic(expected = "cannot represent")]
    fn v1_refuses_non_kron_layers() {
        let mut rng = Rng::new(16);
        let w = random_mat(&mut rng, 4, 8);
        let h = random_hessian(&mut rng, 8, 3, 1e-2);
        let kind = crate::linalg::TransformKind::Hadamard;
        let pre = preprocess(&w, &h, 2, &Processing::incoherent_with(kind), 3);
        let codes = crate::quant::ldlq::round_matrix(
            &pre.wg,
            2,
            crate::quant::rounding::RoundMode::Nearest,
            0,
        );
        let layer = QuantizedLayer::from_codes("rht", &codes, 2, pre.post);
        let mut buf = Writer::new();
        layer.serialize_version(&mut buf, FORMAT_V1); // must refuse
    }

    #[test]
    fn truncated_layer_is_clean_error() {
        let mut rng = Rng::new(15);
        let w = random_mat(&mut rng, 4, 8);
        let h = random_hessian(&mut rng, 8, 3, 1e-2);
        let pre = preprocess(&w, &h, 2, &Processing::incoherent(), 3);
        let codes = crate::quant::ldlq::round_matrix(
            &pre.wg,
            2,
            crate::quant::rounding::RoundMode::Nearest,
            0,
        );
        let layer = QuantizedLayer::from_codes("t", &codes, 2, pre.post);
        let mut buf = Writer::new();
        layer.serialize(&mut buf);
        for cut in [1usize, 8, buf.buf.len() / 2, buf.buf.len() - 1] {
            let mut r = Reader::new(&buf.buf[..cut]);
            assert!(
                QuantizedLayer::deserialize(&mut r, FORMAT_V3).is_err(),
                "cut={cut} should fail cleanly"
            );
        }
    }

    #[test]
    fn roundtrip_5_to_8_bits_ragged_lengths() {
        // The wide widths: 5/6/7-bit codes straddle byte boundaries in
        // several phases; 8-bit is the byte-aligned degenerate case.
        for bits in [5u32, 6, 7, 8] {
            for n in [1usize, 3, 7, 8, 13, 31, 64, 100] {
                let codes: Vec<u8> = (0..n)
                    .map(|i| ((i * 11 + 5) % (1usize << bits)) as u8)
                    .collect();
                let packed = pack_codes(&codes, bits);
                assert_eq!(
                    packed.len(),
                    (n * bits as usize).div_ceil(8),
                    "bits={bits} n={n}: packed length"
                );
                let back = unpack_codes(&packed, bits, n);
                assert_eq!(back, codes, "bits={bits} n={n}");
                // Max-value codes: the mask must not leak neighbour bits.
                let top = vec![((1u16 << bits) - 1) as u8; n];
                assert_eq!(unpack_codes(&pack_codes(&top, bits), bits, n), top);
            }
        }
    }

    #[test]
    fn roundtrip_2_3_4_bits_ragged_lengths() {
        // The wire widths the .qz format actually ships, exercised on
        // lengths that are *not* multiples of 8 (so the final byte is
        // partially filled, and 3-bit codes straddle byte boundaries).
        for bits in [2u32, 3, 4] {
            for n in [1usize, 5, 7, 9, 13, 31, 57, 100, 257] {
                let codes: Vec<u8> = (0..n)
                    .map(|i| ((i * 7 + 3) % (1usize << bits)) as u8)
                    .collect();
                let packed = pack_codes(&codes, bits);
                assert_eq!(
                    packed.len(),
                    (n * bits as usize).div_ceil(8),
                    "bits={bits} n={n}: packed length"
                );
                let back = unpack_codes(&packed, bits, n);
                assert_eq!(back, codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn codes_stay_in_range_at_extremes() {
        // Max-value codes (all ones in every position) roundtrip exactly,
        // and every unpacked value respects the 2^bits bound — i.e. the
        // unpack mask never leaks bits from neighbouring codes or from
        // the zero padding of the final byte.
        for bits in [2u32, 3, 4] {
            let top = ((1u16 << bits) - 1) as u8;
            for n in [3usize, 8, 11, 29] {
                let codes = vec![top; n];
                let packed = pack_codes(&codes, bits);
                let back = unpack_codes(&packed, bits, n);
                assert_eq!(back, codes, "bits={bits} n={n} (all-max)");
                // Mixed extremes: alternate 0 / max.
                let codes: Vec<u8> =
                    (0..n).map(|i| if i % 2 == 0 { 0 } else { top }).collect();
                let back = unpack_codes(&pack_codes(&codes, bits), bits, n);
                assert_eq!(back, codes, "bits={bits} n={n} (alternating)");
                for &c in &back {
                    assert!((c as u32) < (1 << bits));
                }
            }
        }
    }

    #[test]
    fn layer_roundtrip_with_non_multiple_of_8_columns() {
        // A full QuantizedLayer roundtrip (Mat → pack → unpack → Mat) at
        // each shipped width, with a column count (7) that leaves ragged
        // rows in the bitstream.
        let mut rng = Rng::new(11);
        let w = random_mat(&mut rng, 3, 7);
        let h = random_hessian(&mut rng, 7, 3, 1e-2);
        for bits in [2u32, 3, 4] {
            let pre = preprocess(&w, &h, bits, &Processing::incoherent(), 2);
            let codes = crate::quant::ldlq::round_matrix(
                &pre.wg,
                bits,
                crate::quant::rounding::RoundMode::Nearest,
                0,
            );
            let layer = QuantizedLayer::from_codes("ragged", &codes, bits, pre.post.clone());
            assert_eq!(layer.packed.len(), (3 * 7 * bits as usize).div_ceil(8));
            let back = layer.codes();
            assert_eq!(back.data, codes.data, "bits={bits}");
            let qmax = crate::quant::grid::levels(bits) as f64;
            for &c in &back.data {
                assert!(c >= 0.0 && c <= qmax && c == c.round(), "bits={bits}: {c}");
            }
        }
    }

    #[test]
    fn two_bit_storage_is_compact() {
        let mut rng = Rng::new(5);
        let w = random_mat(&mut rng, 64, 64);
        let h = random_hessian(&mut rng, 64, 8, 1e-2);
        let pre = preprocess(&w, &h, 2, &Processing::incoherent(), 1);
        let codes = crate::quant::ldlq::round_matrix(
            &pre.wg,
            2,
            crate::quant::rounding::RoundMode::Nearest,
            0,
        );
        let layer = QuantizedLayer::from_codes("l", &codes, 2, pre.post);
        // 2-bit codes + small metadata: well under 3 bits/weight at 64×64.
        assert!(layer.bits_per_weight() < 3.5, "bpw={}", layer.bits_per_weight());
        assert_eq!(layer.packed.len(), 64 * 64 * 2 / 8);
    }

    #[test]
    fn group_index_roundtrip_at_vq_widths() {
        // The vq index widths: 16 bits (2 bits/weight) and 32 bits
        // (4 bits/weight) are the acceptance widths; 48/64 cover the
        // 6/8-bit stages, and 13 exercises the non-byte-aligned generic
        // path of the packer.
        for index_bits in [13u32, 16, 32, 48, 64] {
            for count in [1usize, 3, 7, 8, 100] {
                let mask = if index_bits == 64 {
                    u64::MAX
                } else {
                    (1u64 << index_bits) - 1
                };
                let idxs: Vec<u64> = (0..count)
                    .map(|i| {
                        (i as u64)
                            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                            .rotate_left(i as u32)
                            & mask
                    })
                    .collect();
                let packed = pack_group_indices(&idxs, index_bits);
                assert_eq!(
                    packed.len(),
                    (count * index_bits as usize).div_ceil(8),
                    "bits={index_bits} count={count}"
                );
                let back = unpack_group_indices(&packed, index_bits, count);
                assert_eq!(back, idxs, "bits={index_bits} count={count}");
                // All-ones indices must not leak into neighbours.
                let top = vec![mask; count];
                assert_eq!(
                    unpack_group_indices(&pack_group_indices(&top, index_bits), index_bits, count),
                    top
                );
            }
        }
    }

    /// Quantize a small layer with the vq rounder and return the layer.
    fn vq_layer(bits: u32, m: usize, n: usize, seed: u64) -> (QuantizedLayer, Mat) {
        use crate::quant::rounder::{RoundCtx, Rounder, VqRounder};
        let mut rng = Rng::new(seed);
        let w = random_mat(&mut rng, m, n).scale(0.1);
        let h = random_hessian(&mut rng, n, 4.max(n / 4), 1e-2);
        let pre = preprocess(&w, &h, bits, &Processing::incoherent(), seed);
        let ctx = RoundCtx {
            bits,
            seed,
            mode: crate::quant::rounding::RoundMode::Nearest,
            greedy_passes: 0,
            alg5_c: 0.3,
        };
        let rounded = VqRounder.round(&pre.wg, &pre.h, &ctx);
        let vq = rounded.vq.expect("vq indices");
        (
            QuantizedLayer::from_vq_indices("vql", m, n, bits, &vq, pre.post),
            rounded.codes,
        )
    }

    #[test]
    fn vq_layer_codes_and_v3_roundtrip() {
        // n = 20 leaves a ragged last group (8, 8, 4).
        for bits in [2u32, 4] {
            let (layer, codes) = vq_layer(bits, 5, 20, 9);
            assert!(matches!(layer.layout, CodeLayout::Vq { .. }));
            // Equal bitrate: ⌈20/8⌉ groups × bits bytes per row.
            assert_eq!(layer.packed.len(), 5 * 3 * bits as usize);
            // codes() decodes indices back to exactly the rounder's codes.
            assert_eq!(layer.codes().data, codes.data, "bits={bits}");
            // v3 serialize → deserialize preserves everything.
            let mut buf = Writer::new();
            layer.serialize(&mut buf);
            let mut r = Reader::new(&buf.buf);
            let layer2 = QuantizedLayer::deserialize(&mut r, FORMAT_V3).unwrap();
            assert_eq!(r.remaining(), 0);
            assert_eq!(layer2.layout, layer.layout);
            assert_eq!(layer2.packed, layer.packed);
            assert_eq!(layer2.codes().data, codes.data);
            assert_eq!(layer2.dequantize().data, layer.dequantize().data);
        }
    }

    #[test]
    fn v2_layer_bytes_still_deserialize() {
        // A scalar layer written in the v2 layout (no code-layout byte)
        // must load unchanged — pinned against real recorded v2 bytes.
        let mut rng = Rng::new(24);
        let w = random_mat(&mut rng, 4, 8);
        let h = random_hessian(&mut rng, 8, 3, 1e-2);
        let kind = crate::linalg::TransformKind::Hadamard;
        let pre = preprocess(&w, &h, 2, &Processing::incoherent_with(kind), 3);
        let codes = crate::quant::ldlq::round_matrix(
            &pre.wg,
            2,
            crate::quant::rounding::RoundMode::Nearest,
            0,
        );
        let layer = QuantizedLayer::from_codes("v2era", &codes, 2, pre.post);
        let mut v2 = Writer::new();
        layer.serialize_version(&mut v2, FORMAT_V2);
        let mut v3 = Writer::new();
        layer.serialize_version(&mut v3, FORMAT_V3);
        // v3 scalar records differ from v2 by exactly the layout byte.
        assert_eq!(v2.buf.len() + 1, v3.buf.len());
        let mut r = Reader::new(&v2.buf);
        let layer2 = QuantizedLayer::deserialize(&mut r, FORMAT_V2).unwrap();
        assert_eq!(r.remaining(), 0);
        assert_eq!(layer2.layout, CodeLayout::Scalar);
        assert_eq!(layer2.post.transform, kind);
        assert_eq!(layer2.dequantize().data, layer.dequantize().data);
    }

    #[test]
    #[should_panic(expected = "cannot represent")]
    fn v2_refuses_vq_layers() {
        let (layer, _) = vq_layer(2, 3, 16, 5);
        let mut buf = Writer::new();
        layer.serialize_version(&mut buf, FORMAT_V2); // must refuse
    }

    #[test]
    #[should_panic(expected = "cannot represent")]
    fn v1_refuses_vq_layers() {
        let (mut layer, _) = vq_layer(2, 3, 16, 5);
        // Even with a v1-representable transform, the layout is enough
        // to refuse.
        layer.post.incoherent = false;
        let mut buf = Writer::new();
        layer.serialize_version(&mut buf, FORMAT_V1); // must refuse
    }

    #[test]
    fn truncated_vq_layer_is_clean_error() {
        let (layer, _) = vq_layer(2, 4, 16, 7);
        let mut buf = Writer::new();
        layer.serialize(&mut buf);
        for cut in [1usize, 8, 20, buf.buf.len() / 2, buf.buf.len() - 1] {
            let mut r = Reader::new(&buf.buf[..cut]);
            assert!(
                QuantizedLayer::deserialize(&mut r, FORMAT_V3).is_err(),
                "cut={cut} should fail cleanly"
            );
        }
        // A corrupt layout tag is a clean error, not a panic.
        let mut bad = buf.buf.clone();
        // name("vql": 4+3 bytes) + bits(4) + m(8) + n(8) → layout at 27.
        assert_eq!(bad[27], 1, "layout byte location");
        bad[27] = 9;
        let mut r = Reader::new(&bad);
        let err = QuantizedLayer::deserialize(&mut r, FORMAT_V3).unwrap_err();
        assert!(err.to_string().contains("layout"), "{err}");
    }

    #[test]
    fn vq_and_scalar_layers_have_equal_bitrate() {
        // The acceptance bitrate condition: at n % 8 == 0 the vq payload
        // is byte-for-byte the same size as the scalar payload.
        for bits in [2u32, 4] {
            let (vql, _) = vq_layer(bits, 6, 24, 11);
            let mut rng = Rng::new(11);
            let w = random_mat(&mut rng, 6, 24);
            let h = random_hessian(&mut rng, 24, 6, 1e-2);
            let pre = preprocess(&w, &h, bits, &Processing::incoherent(), 11);
            let codes = crate::quant::ldlq::round_matrix(
                &pre.wg,
                bits,
                crate::quant::rounding::RoundMode::Nearest,
                0,
            );
            let scl = QuantizedLayer::from_codes("scl", &codes, bits, pre.post);
            assert_eq!(vql.packed.len(), scl.packed.len(), "bits={bits}");
        }
    }
}
