//! Greedy local search (paper §4.2 + Supplement B, Algorithm 4):
//! coordinate descent on the proxy loss restricted to the quantization
//! grid, visiting coordinates in the same order as LDLQ.
//!
//! Per coordinate (row i, column j), the unconstrained minimizer is
//!   z* = w_j − [(ŵ − w) H e_j − (ŵ_j − w_j) H_jj] / H_jj
//! which is then nearest-rounded and clamped. Used standalone ("Greedy")
//! or as a polish after LDLQ ("LDLQ-RG", "QuIP-RG").

use crate::linalg::Mat;
use crate::util::threadpool::{default_threads, parallel_map};

/// One or more greedy passes over grid-space weights.
///
/// * `wg` — target weights in grid coordinates.
/// * `init` — starting point (`wg` itself for the standalone method; the
///   LDLQ output when polishing). Must already be on-grid for polish mode.
/// * Returns integer codes.
pub fn greedy(wg: &Mat, init: &Mat, h: &Mat, bits: u32, passes: usize) -> Mat {
    let (m, n) = (wg.rows, wg.cols);
    assert_eq!(h.rows, n);
    let diag: Vec<f64> = h.diagonal();
    let qmax = super::grid::levels(bits) as f64;
    let rows = parallel_map(m, default_threads(), |i| {
        let w = wg.row(i);
        let mut what: Vec<f64> = init.row(i).to_vec();
        // r = ŵ − w (kept incrementally up to date).
        let mut r: Vec<f64> = what.iter().zip(w).map(|(a, b)| a - b).collect();
        // rh = r · H (incrementally updated: changing r[j] by δ adds δ·H[j,:]).
        let mut rh: Vec<f64> = h.transpose().matvec(&r); // H symmetric: rH = Hr
        for _pass in 0..passes {
            let mut changed = false;
            for j in 0..n {
                let hjj = diag[j];
                if hjj <= 1e-30 {
                    continue;
                }
                // Unconstrained coordinate minimizer.
                let z = w[j] - (rh[j] - r[j] * hjj) / hjj;
                let q = z.round().clamp(0.0, qmax);
                if q != what[j] {
                    let delta = q - what[j];
                    what[j] = q;
                    r[j] += delta;
                    // rh update: r changed in coordinate j.
                    let hrow = h.row(j);
                    for (t, &hv) in rh.iter_mut().zip(hrow) {
                        *t += delta * hv;
                    }
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }
        what
    });
    Mat::from_rows(&rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ldlq::{ldlq, round_matrix};
    use crate::quant::proxy::proxy_loss;
    use crate::quant::rounding::RoundMode;
    use crate::util::rng::Rng;
    use crate::util::testkit::{propcheck, random_spd};

    fn grid_weights(rng: &mut Rng, m: usize, n: usize, bits: u32) -> Mat {
        let q = super::super::grid::levels(bits) as f64;
        Mat::from_fn(m, n, |_, _| rng.uniform(0.0, q))
    }

    #[test]
    fn polish_never_increases_proxy() {
        // Greedy after LDLQ is a descent method (Supplement B).
        propcheck("greedy-descent", 10, |rng| {
            let bits = 2;
            let wg = grid_weights(rng, 6, 16, bits);
            let h = random_spd(rng, 16, 1e-2);
            let base = ldlq(&wg, &h, bits, RoundMode::Nearest, 0);
            let before = proxy_loss(&base, &wg, &h);
            let polished = greedy(&wg, &base, &h, bits, 10);
            let after = proxy_loss(&polished, &wg, &h);
            assert!(
                after <= before + 1e-9,
                "greedy increased proxy: {before} -> {after}"
            );
        });
    }

    #[test]
    fn standalone_greedy_beats_nearest_usually() {
        let mut wins = 0;
        let trials = 15;
        for t in 0..trials {
            let mut rng = Rng::new(200 + t);
            let wg = grid_weights(&mut rng, 8, 20, 2);
            let h = crate::util::testkit::random_hessian(&mut rng, 20, 5, 1e-3);
            let g = greedy(&wg, &wg.clone(), &h, 2, 10);
            let n = round_matrix(&wg, 2, RoundMode::Nearest, 0);
            if proxy_loss(&g, &wg, &h) <= proxy_loss(&n, &wg, &h) + 1e-12 {
                wins += 1;
            }
        }
        assert!(wins >= trials - 2, "greedy won only {wins}/{trials}");
    }

    #[test]
    fn output_on_grid() {
        let mut rng = Rng::new(9);
        let wg = grid_weights(&mut rng, 4, 10, 3);
        let h = random_spd(&mut rng, 10, 1e-2);
        let g = greedy(&wg, &wg.clone(), &h, 3, 5);
        for &c in &g.data {
            assert!(c >= 0.0 && c <= 7.0 && c == c.round());
        }
    }

    #[test]
    fn fixed_point_is_stable() {
        // Re-running greedy on its own output changes nothing.
        let mut rng = Rng::new(10);
        let wg = grid_weights(&mut rng, 3, 12, 2);
        let h = random_spd(&mut rng, 12, 1e-2);
        let once = greedy(&wg, &wg.clone(), &h, 2, 20);
        let twice = greedy(&wg, &once, &h, 2, 20);
        assert_eq!(once.data, twice.data);
    }

    #[test]
    fn coordinate_update_is_locally_optimal() {
        // After convergence, perturbing any single coordinate by ±1 (within
        // the grid) cannot lower the proxy loss.
        let mut rng = Rng::new(11);
        let wg = grid_weights(&mut rng, 1, 8, 2);
        let h = random_spd(&mut rng, 8, 1e-2);
        let sol = greedy(&wg, &wg.clone(), &h, 2, 50);
        let base = proxy_loss(&sol, &wg, &h);
        for j in 0..8 {
            for delta in [-1.0, 1.0] {
                let nv = sol[(0, j)] + delta;
                if !(0.0..=3.0).contains(&nv) {
                    continue;
                }
                let mut alt = sol.clone();
                alt[(0, j)] = nv;
                assert!(
                    proxy_loss(&alt, &wg, &h) >= base - 1e-9,
                    "coordinate {j} not locally optimal"
                );
            }
        }
    }
}
