//! The open rounding-algorithm API: an object-safe [`Rounder`] trait (one
//! impl per adaptive-rounding algorithm), and a name-based
//! [`RounderRegistry`] so new algorithms plug in without touching core
//! dispatch.
//!
//! The paper's Table 2 is a {rounder} × {processing} grid; follow-up work
//! (QuIP#'s lattice codebooks, CDQuant's coordinate descent) adds rows to
//! that grid. This module is the seam those rows plug into: implement
//! [`Rounder`], register it under a name, and every pipeline/harness
//! caller can use it.
//!
//! # The `Rounder` contract
//!
//! `round(wg, h, ctx)` is called *inside* incoherence processing
//! (Algorithm 1 has already run):
//!
//! * `wg` is the weight matrix **in grid coordinates** of the processed
//!   basis — every entry the rounder should ideally hit lies in
//!   `[0, 2^ctx.bits − 1]`. Scalar rounders return integer codes clamped
//!   to that range; vector rounders ([`VqRounder`]) return codebook
//!   points in the same grid coordinates plus their group indices (see
//!   [`Rounded`]).
//! * `h` is the proxy Hessian **conjugated into the same basis** (damped,
//!   rescaled and orthogonally transformed exactly like `wg`), so
//!   feedback terms computed from `h` are consistent with `wg`.
//! * `ctx.seed` keys all stochasticity; equal inputs and seeds must give
//!   byte-identical codes (artifacts are reproducible by construction).
//!
//! Post-processing (Algorithm 2) and proxy-loss bookkeeping happen in the
//! caller ([`super::quantize_layer_with`]); a rounder never sees the
//! original basis.

use super::alg5;
use super::greedy::greedy;
use super::grid::{codebook_seed, Codebook};
use super::ldlq::{ldlq, ldlq_vq, ldlq_with_feedback, round_matrix};
use super::optq::optq;
use super::reorder::Reorder;
use super::rounding::RoundMode;
use crate::linalg::Mat;
use std::sync::{Arc, OnceLock};

/// Per-call context handed to every rounder. See the module docs for what
/// is guaranteed about `wg`/`h` when `round` runs.
#[derive(Clone, Debug)]
pub struct RoundCtx {
    /// Grid width: codes lie in `[0, 2^bits − 1]`.
    pub bits: u32,
    /// Seed for all stochastic choices (forked per row inside the cores).
    pub seed: u64,
    /// The Q subroutine feedback rounders should use (nearest by default;
    /// stochastic when the config forces the Table-15 unbiased ablation).
    pub mode: RoundMode,
    /// Greedy polish passes (paper: 10, or 5 on the largest models).
    pub greedy_passes: usize,
    /// Algorithm 5's column-slack hyperparameter c.
    pub alg5_c: f64,
}

/// Output of one [`Rounder::round`] call: grid-space code values, plus
/// the codebook indices when the rounder quantized in vector groups.
pub struct Rounded {
    /// Grid-space code values — integers in `[0, 2^bits − 1]` for scalar
    /// rounders; E8 codebook points (half-integer-built reals, possibly
    /// outside the scalar grid range) for vector rounders.
    pub codes: Mat,
    /// `Some` iff the codes are vector-codebook points: the per-group
    /// indices that `.qz` v3 stores instead of per-weight scalar codes.
    pub vq: Option<VqCodes>,
}

impl Rounded {
    /// Wrap a scalar-grid code matrix (the seven classic rounders).
    pub fn scalar(codes: Mat) -> Rounded {
        Rounded { codes, vq: None }
    }
}

/// Vector-codebook indices produced by a group-rounding algorithm:
/// row-major, ⌈n/8⌉ per row, plus the seed that regenerates the
/// [`Codebook`] at decode time (stored in the `.qz` v3 layer record).
#[derive(Clone, Debug, PartialEq)]
pub struct VqCodes {
    pub indices: Vec<u64>,
    pub cb_seed: u64,
}

/// An adaptive-rounding algorithm, object-safe so registries and callers
/// can hold `dyn Rounder`.
pub trait Rounder: Send + Sync {
    /// Canonical (registry) name, e.g. `"ldlq"`.
    fn name(&self) -> &'static str;

    /// Whether the algorithm consults `h` (feedback / descent); `false`
    /// for memoryless per-entry rounding. Callers may skip Hessian
    /// collection entirely for rounders that return `false`.
    fn supports_feedback(&self) -> bool;

    /// Quantize grid-space weights to codes. See the module docs for the
    /// `wg`/`h` contract; scalar rounders return
    /// [`Rounded::scalar`]-wrapped integer codes, vector rounders also
    /// carry their group indices.
    fn round(&self, wg: &Mat, h: &Mat, ctx: &RoundCtx) -> Rounded;
}

/// Nearest rounding, no feedback (§3.2 "Near").
pub struct NearestRounder;

impl Rounder for NearestRounder {
    fn name(&self) -> &'static str {
        "near"
    }
    fn supports_feedback(&self) -> bool {
        false
    }
    fn round(&self, wg: &Mat, _h: &Mat, ctx: &RoundCtx) -> Rounded {
        Rounded::scalar(round_matrix(wg, ctx.bits, RoundMode::Nearest, ctx.seed))
    }
}

/// Unbiased stochastic rounding, no feedback (§3.2 "Stoch").
pub struct StochasticRounder;

impl Rounder for StochasticRounder {
    fn name(&self) -> &'static str {
        "stoch"
    }
    fn supports_feedback(&self) -> bool {
        false
    }
    fn round(&self, wg: &Mat, _h: &Mat, ctx: &RoundCtx) -> Rounded {
        Rounded::scalar(round_matrix(wg, ctx.bits, RoundMode::Stochastic, ctx.seed))
    }
}

/// LDLQ (§3.1): linear feedback from the UDUᵀ factors of `h`. With
/// incoherence processing this is QuIP.
pub struct LdlqRounder;

impl Rounder for LdlqRounder {
    fn name(&self) -> &'static str {
        "ldlq"
    }
    fn supports_feedback(&self) -> bool {
        true
    }
    fn round(&self, wg: &Mat, h: &Mat, ctx: &RoundCtx) -> Rounded {
        Rounded::scalar(ldlq(wg, h, ctx.bits, ctx.mode, ctx.seed))
    }
}

/// LDLQ with diag(H)-descending reorder + greedy polish passes
/// ("LDLQ-RG"; QuIP-RG when combined with incoherence processing).
pub struct LdlqRgRounder;

impl Rounder for LdlqRgRounder {
    fn name(&self) -> &'static str {
        "ldlq-rg"
    }
    fn supports_feedback(&self) -> bool {
        true
    }
    fn round(&self, wg: &Mat, h: &Mat, ctx: &RoundCtx) -> Rounded {
        let r = Reorder::by_diag_desc(h);
        let wgp = r.apply_w(wg);
        let hp = r.apply_h(h);
        let base = ldlq(&wgp, &hp, ctx.bits, ctx.mode, ctx.seed);
        let polished = greedy(&wgp, &base, &hp, ctx.bits, ctx.greedy_passes);
        Rounded::scalar(r.undo_w(&polished))
    }
}

/// Standalone greedy coordinate descent on the proxy loss (Algorithm 4;
/// the reference QuIP repo's `allbal`).
pub struct GreedyRounder;

impl Rounder for GreedyRounder {
    fn name(&self) -> &'static str {
        "greedy"
    }
    fn supports_feedback(&self) -> bool {
        true
    }
    fn round(&self, wg: &Mat, h: &Mat, ctx: &RoundCtx) -> Rounded {
        // Standalone mode: the target is its own starting point.
        Rounded::scalar(greedy(wg, wg, h, ctx.bits, ctx.greedy_passes))
    }
}

/// The literal OPTQ implementation (equivalent to LDLQ by Theorem 6; kept
/// for the cross-check and throughput comparisons). Falls back to LDLQ if
/// the Hessian inversion fails.
pub struct OptqRounder;

impl Rounder for OptqRounder {
    fn name(&self) -> &'static str {
        "optq"
    }
    fn supports_feedback(&self) -> bool {
        true
    }
    fn round(&self, wg: &Mat, h: &Mat, ctx: &RoundCtx) -> Rounded {
        Rounded::scalar(
            optq(wg, h, ctx.bits).unwrap_or_else(|_| ldlq(wg, h, ctx.bits, ctx.mode, ctx.seed)),
        )
    }
}

/// Algorithm 5 (§5.2): norm-capped convex-program feedback + stochastic
/// rounding (the reference repo's `ldlbal_admm`).
pub struct Alg5Rounder;

impl Rounder for Alg5Rounder {
    fn name(&self) -> &'static str {
        "alg5"
    }
    fn supports_feedback(&self) -> bool {
        true
    }
    fn round(&self, wg: &Mat, h: &Mat, ctx: &RoundCtx) -> Rounded {
        let plan = alg5::solve(h, ctx.alg5_c, 200, 1e-9);
        Rounded::scalar(ldlq_with_feedback(
            wg,
            &plan.u_dot,
            ctx.bits,
            RoundMode::Stochastic,
            ctx.seed,
        ))
    }
}

/// QuIP#-style vector quantization ("vq"): group-LDLQ feedback with
/// 8-wide column groups rounded jointly against a seeded E8-style
/// [`Codebook`] at the same bitrate as the scalar grid (see
/// [`super::grid`] and DESIGN.md §6). Even bit widths 2–8 only, and no
/// stochastic Q mode (nearest-codeword search is deterministic;
/// `ctx.mode` is ignored) — `QuantConfigBuilder::build` rejects both
/// misuses; this impl asserts on bits.
pub struct VqRounder;

impl Rounder for VqRounder {
    fn name(&self) -> &'static str {
        "vq"
    }
    fn supports_feedback(&self) -> bool {
        true
    }
    fn round(&self, wg: &Mat, h: &Mat, ctx: &RoundCtx) -> Rounded {
        let cb = Codebook::e8(ctx.bits, codebook_seed(ctx.seed)).expect(
            "vq rounder requires an even bit width in 2..=8 \
             (QuantConfigBuilder::build validates this)",
        );
        let (codes, indices) = ldlq_vq(wg, h, &cb);
        Rounded {
            codes,
            vq: Some(VqCodes {
                indices,
                cb_seed: cb.seed(),
            }),
        }
    }
}

struct Entry {
    rounder: Arc<dyn Rounder>,
    /// Accepted lookup names (canonical name included).
    aliases: Vec<String>,
}

/// Name → [`Rounder`] lookup with alias support. Lookups are
/// ASCII-case-insensitive.
pub struct RounderRegistry {
    entries: Vec<Entry>,
}

impl RounderRegistry {
    /// An empty registry (for fully custom rounder sets).
    pub fn new() -> RounderRegistry {
        RounderRegistry {
            entries: Vec::new(),
        }
    }

    /// A registry with the paper's seven algorithms under their CLI names
    /// plus the reference QuIP repo's upstream aliases
    /// (`allbal` → greedy, `ldlbal_admm` → alg5, `gptq` → optq) and the
    /// QuIP# vector-codebook rounder (`vq`, aliases `codebook`/`e8`).
    pub fn with_builtins() -> RounderRegistry {
        let mut r = RounderRegistry::new();
        r.register(NearestRounder, &["nearest"]);
        r.register(StochasticRounder, &["stochastic"]);
        r.register(LdlqRounder, &["quip"]);
        r.register(LdlqRgRounder, &["ldlqrg", "quip-rg"]);
        r.register(GreedyRounder, &["allbal"]);
        r.register(OptqRounder, &["gptq"]);
        r.register(Alg5Rounder, &["ldlbal_admm"]);
        r.register(VqRounder, &["codebook", "e8"]);
        r
    }

    /// The process-wide registry of builtin rounders. Custom rounders go
    /// in a local registry (or straight to
    /// [`super::quantize_layer_with`], which takes any `&dyn Rounder`).
    pub fn global() -> &'static RounderRegistry {
        static GLOBAL: OnceLock<RounderRegistry> = OnceLock::new();
        GLOBAL.get_or_init(RounderRegistry::with_builtins)
    }

    /// Register a rounder under its canonical name plus extra aliases.
    pub fn register<R: Rounder + 'static>(&mut self, rounder: R, extra_aliases: &[&str]) {
        self.register_arc(Arc::new(rounder), extra_aliases);
    }

    pub fn register_arc(&mut self, rounder: Arc<dyn Rounder>, extra_aliases: &[&str]) {
        let mut aliases = vec![rounder.name().to_string()];
        aliases.extend(extra_aliases.iter().map(|a| a.to_string()));
        self.entries.push(Entry { rounder, aliases });
    }

    /// Look up by canonical name or alias (case-insensitive).
    pub fn resolve(&self, name: &str) -> crate::Result<Arc<dyn Rounder>> {
        for e in &self.entries {
            if e.aliases.iter().any(|a| a.eq_ignore_ascii_case(name)) {
                return Ok(Arc::clone(&e.rounder));
            }
        }
        anyhow::bail!(
            "unknown rounder '{name}' (known: {})",
            self.known_names().join(", ")
        )
    }

    /// Canonical names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.rounder.name()).collect()
    }

    /// Every accepted lookup name (canonical + aliases), in order.
    pub fn known_names(&self) -> Vec<String> {
        self.entries
            .iter()
            .flat_map(|e| e.aliases.iter().cloned())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_layer, quantize_layer_with, Method, Processing, QuantConfig};
    use crate::util::rng::Rng;
    use crate::util::testkit::{random_hessian, random_mat};

    #[test]
    fn registry_resolves_all_aliases() {
        // Every CLI alias from the old `Method::parse` plus the upstream
        // reference-repo names, each mapped to its canonical rounder.
        let cases = [
            ("near", "near"),
            ("nearest", "near"),
            ("stoch", "stoch"),
            ("stochastic", "stoch"),
            ("ldlq", "ldlq"),
            ("quip", "ldlq"),
            ("ldlq-rg", "ldlq-rg"),
            ("ldlqrg", "ldlq-rg"),
            ("quip-rg", "ldlq-rg"),
            ("greedy", "greedy"),
            ("allbal", "greedy"),
            ("optq", "optq"),
            ("gptq", "optq"),
            ("alg5", "alg5"),
            ("ldlbal_admm", "alg5"),
            ("vq", "vq"),
            ("codebook", "vq"),
            ("e8", "vq"),
        ];
        let reg = RounderRegistry::global();
        for (alias, canonical) in cases {
            let r = reg.resolve(alias).unwrap();
            assert_eq!(r.name(), canonical, "alias '{alias}'");
            // Case-insensitive.
            let r = reg.resolve(&alias.to_ascii_uppercase()).unwrap();
            assert_eq!(r.name(), canonical, "alias '{alias}' (upper)");
            // Method::parse stays consistent with the registry.
            assert_eq!(Method::parse(alias).unwrap().name(), canonical);
        }
        assert!(reg.resolve("no-such-rounder").is_err());
    }

    #[test]
    fn registry_lists_eight_builtins() {
        let names = RounderRegistry::global().names();
        assert_eq!(
            names,
            vec!["near", "stoch", "ldlq", "ldlq-rg", "greedy", "optq", "alg5", "vq"]
        );
    }

    #[test]
    fn trait_dispatch_matches_enum_dispatch() {
        // The registry path must produce byte-identical codes to the
        // legacy `quantize_layer(Method)` shim for every builtin.
        let mut rng = Rng::new(21);
        let w = random_mat(&mut rng, 6, 12).scale(0.1);
        let h = random_hessian(&mut rng, 12, 4, 1e-3);
        for method in [
            Method::Nearest,
            Method::Stochastic,
            Method::Ldlq,
            Method::LdlqRg,
            Method::Greedy,
            Method::Optq,
            Method::Alg5,
            Method::Vq,
        ] {
            let cfg = QuantConfig {
                bits: 2,
                method,
                processing: Processing::incoherent(),
                greedy_passes: 3,
                ..Default::default()
            };
            let a = quantize_layer(&w, &h, &cfg, 77);
            let rounder = RounderRegistry::global().resolve(method.name()).unwrap();
            let b = quantize_layer_with(rounder.as_ref(), &w, &h, &cfg, 77);
            assert_eq!(a.codes.data, b.codes.data, "{}", method.name());
            assert_eq!(a.proxy_loss, b.proxy_loss, "{}", method.name());
        }
    }

    #[test]
    fn feedback_flags_match_algorithms() {
        let reg = RounderRegistry::global();
        assert!(!reg.resolve("near").unwrap().supports_feedback());
        assert!(!reg.resolve("stoch").unwrap().supports_feedback());
        for adaptive in ["ldlq", "ldlq-rg", "greedy", "optq", "alg5", "vq"] {
            assert!(reg.resolve(adaptive).unwrap().supports_feedback(), "{adaptive}");
        }
    }

    #[test]
    fn custom_rounder_plugs_in() {
        // The point of the open API: a new algorithm works end to end
        // without touching core dispatch.
        struct FloorRounder;
        impl Rounder for FloorRounder {
            fn name(&self) -> &'static str {
                "floor"
            }
            fn supports_feedback(&self) -> bool {
                false
            }
            fn round(&self, wg: &Mat, _h: &Mat, ctx: &RoundCtx) -> Rounded {
                let qmax = crate::quant::grid::levels(ctx.bits) as f64;
                Rounded::scalar(Mat {
                    rows: wg.rows,
                    cols: wg.cols,
                    data: wg.data.iter().map(|&z| z.floor().clamp(0.0, qmax)).collect(),
                })
            }
        }
        let mut reg = RounderRegistry::new();
        reg.register(FloorRounder, &["trunc"]);
        let r = reg.resolve("trunc").unwrap();
        assert_eq!(r.name(), "floor");

        let mut rng = Rng::new(4);
        let w = random_mat(&mut rng, 4, 8).scale(0.1);
        let h = random_hessian(&mut rng, 8, 3, 1e-3);
        let cfg = QuantConfig {
            bits: 2,
            ..Default::default()
        };
        let out = quantize_layer_with(r.as_ref(), &w, &h, &cfg, 1);
        assert_eq!(out.codes.rows, 4);
        for &c in &out.codes.data {
            assert!(c >= 0.0 && c <= 3.0 && c == c.round());
        }
        assert!(out.proxy_loss.is_finite());
    }

    #[test]
    fn vq_rounder_flows_through_the_layer_driver() {
        // End-to-end through quantize_layer_with: vq output carries one
        // index per 8-group, the codes are the decoded codebook points
        // (generally non-integer grid values), and the proxy loss is
        // finite under full incoherence processing.
        let mut rng = Rng::new(31);
        let w = random_mat(&mut rng, 6, 32).scale(0.1);
        let h = random_hessian(&mut rng, 32, 8, 1e-3);
        for bits in [2u32, 4] {
            let cfg = QuantConfig {
                bits,
                method: Method::Vq,
                processing: Processing::incoherent(),
                ..Default::default()
            };
            let out = quantize_layer_with(&VqRounder, &w, &h, &cfg, 77);
            let vq = out.vq.as_ref().expect("vq rounder must emit indices");
            assert_eq!(vq.indices.len(), 6 * 4, "one index per (row, 8-group)");
            assert_eq!(
                vq.cb_seed,
                crate::quant::grid::codebook_seed(77),
                "codebook seed derives from the layer seed"
            );
            // Decoding the indices reproduces the code matrix exactly.
            let cb = Codebook::e8(bits, vq.cb_seed).unwrap();
            for i in 0..6 {
                for g in 0..4 {
                    let mut vals = vec![0.0; 8];
                    cb.decode_group(vq.indices[i * 4 + g], &mut vals);
                    assert_eq!(&out.codes.row(i)[g * 8..(g + 1) * 8], &vals[..]);
                }
            }
            assert!(out.proxy_loss.is_finite() && out.proxy_loss >= 0.0);
            assert_eq!(out.w_hat.rows, 6);
            assert!(out.w_hat.data.iter().all(|x| x.is_finite()));
        }
        // Scalar rounders carry no indices.
        let cfg = QuantConfig::default();
        let out = quantize_layer_with(&LdlqRounder, &w, &h, &cfg, 77);
        assert!(out.vq.is_none());
    }
}
