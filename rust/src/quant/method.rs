//! Method × Processing composition — the paper's experiment grid
//! (Table 2): {Near, Stoch, LDLQ, LDLQ-RG, Greedy, OPTQ, Alg5}
//! × {Baseline, IncP}. `QuIP = LDLQ + IncP`, `QuIP-RG = LDLQ-RG + IncP`.

use super::alg5;
use super::greedy::greedy;
use super::incoherence::{postprocess, preprocess, PostState, Processing};
use super::ldlq::{ldlq, ldlq_with_feedback, round_matrix};
use super::optq::optq;
use super::proxy::proxy_loss;
use super::reorder::Reorder;
use super::rounding::RoundMode;
use crate::linalg::Mat;

/// The rounding core to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Nearest rounding, no feedback.
    Nearest,
    /// Stochastic rounding, no feedback.
    Stochastic,
    /// LDLQ (§3.1). With `Processing::incoherent()` this is QuIP.
    Ldlq,
    /// LDLQ with diag(H)-descending reorder + greedy polish passes.
    LdlqRg,
    /// Standalone greedy coordinate descent (Alg 4).
    Greedy,
    /// The literal OPTQ implementation (equivalent to LDLQ; kept for the
    /// Theorem-6 cross-check and for throughput comparisons).
    Optq,
    /// Algorithm 5: convex-program feedback + stochastic rounding.
    Alg5,
}

impl Method {
    pub fn parse(s: &str) -> crate::Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "near" | "nearest" => Method::Nearest,
            "stoch" | "stochastic" => Method::Stochastic,
            "ldlq" | "quip" => Method::Ldlq,
            "ldlq-rg" | "ldlqrg" | "quip-rg" => Method::LdlqRg,
            "greedy" => Method::Greedy,
            "optq" | "gptq" => Method::Optq,
            "alg5" => Method::Alg5,
            other => anyhow::bail!("unknown method '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Nearest => "near",
            Method::Stochastic => "stoch",
            Method::Ldlq => "ldlq",
            Method::LdlqRg => "ldlq-rg",
            Method::Greedy => "greedy",
            Method::Optq => "optq",
            Method::Alg5 => "alg5",
        }
    }
}

/// Full per-layer quantization configuration.
#[derive(Clone, Debug)]
pub struct QuantConfig {
    pub bits: u32,
    pub method: Method,
    pub processing: Processing,
    /// Greedy polish passes (paper: 10, or 5 on the largest models).
    pub greedy_passes: usize,
    /// Force the stochastic Q subroutine inside LDLQ (Table 15's
    /// unbiased-vs-biased ablation).
    pub force_stochastic: bool,
    /// Alg 5's column-slack hyperparameter c.
    pub alg5_c: f64,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            bits: 2,
            method: Method::Ldlq,
            processing: Processing::incoherent(),
            greedy_passes: 10,
            force_stochastic: false,
            alg5_c: 0.3,
        }
    }
}

/// Result of quantizing one layer.
pub struct LayerQuantOutput {
    /// Integer grid codes (values in [0, 2^b − 1], stored as f64).
    pub codes: Mat,
    /// Dequantized weights in the original coordinate system.
    pub w_hat: Mat,
    /// Post-processing state (seeds, scales, grid).
    pub post: PostState,
    /// tr((Ŵ−W)H̃(Ŵ−W)ᵀ) against the damped original-basis Hessian.
    pub proxy_loss: f64,
}

/// Quantize one linear layer: W (m×n) with proxy Hessian H (n×n).
/// `seed` keys the stochastic rounding and the incoherence orthogonals.
pub fn quantize_layer(w: &Mat, h: &Mat, cfg: &QuantConfig, seed: u64) -> LayerQuantOutput {
    let pre = preprocess(w, h, cfg.bits, &cfg.processing, seed);
    let mode = if cfg.force_stochastic {
        RoundMode::Stochastic
    } else {
        RoundMode::Nearest
    };

    let codes = match cfg.method {
        Method::Nearest => round_matrix(&pre.wg, cfg.bits, RoundMode::Nearest, seed),
        Method::Stochastic => round_matrix(&pre.wg, cfg.bits, RoundMode::Stochastic, seed),
        Method::Ldlq => ldlq(&pre.wg, &pre.h, cfg.bits, mode, seed),
        Method::Optq => optq(&pre.wg, &pre.h, cfg.bits)
            .unwrap_or_else(|_| ldlq(&pre.wg, &pre.h, cfg.bits, mode, seed)),
        Method::LdlqRg => {
            let r = Reorder::by_diag_desc(&pre.h);
            let wgp = r.apply_w(&pre.wg);
            let hp = r.apply_h(&pre.h);
            let base = ldlq(&wgp, &hp, cfg.bits, mode, seed);
            let polished = greedy(&wgp, &base, &hp, cfg.bits, cfg.greedy_passes);
            r.undo_w(&polished)
        }
        Method::Greedy => greedy(&pre.wg, &pre.wg.clone(), &pre.h, cfg.bits, cfg.greedy_passes),
        Method::Alg5 => {
            let plan = alg5::solve(&pre.h, cfg.alg5_c, 200, 1e-9);
            ldlq_with_feedback(&pre.wg, &plan.u_dot, cfg.bits, RoundMode::Stochastic, seed)
        }
    };

    let w_hat = postprocess(&codes, &pre.post);
    let loss = proxy_loss(&w_hat, w, &pre.h_damped);
    LayerQuantOutput {
        codes,
        w_hat,
        post: pre.post,
        proxy_loss: loss,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testkit::{random_hessian, random_mat};

    fn setup(seed: u64, m: usize, n: usize) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let w = random_mat(&mut rng, m, n).scale(0.1);
        let h = random_hessian(&mut rng, n, n / 4, 1e-3);
        (w, h)
    }

    #[test]
    fn all_methods_produce_valid_output() {
        let (w, h) = setup(1, 8, 16);
        for method in [
            Method::Nearest,
            Method::Stochastic,
            Method::Ldlq,
            Method::LdlqRg,
            Method::Greedy,
            Method::Optq,
            Method::Alg5,
        ] {
            for processing in [Processing::baseline(), Processing::incoherent()] {
                let cfg = QuantConfig {
                    bits: 2,
                    method,
                    processing,
                    greedy_passes: 3,
                    ..Default::default()
                };
                let out = quantize_layer(&w, &h, &cfg, 42);
                assert_eq!(out.w_hat.rows, 8);
                assert_eq!(out.w_hat.cols, 16);
                assert!(out.proxy_loss.is_finite() && out.proxy_loss >= 0.0);
                for &c in &out.codes.data {
                    assert!(c >= 0.0 && c <= 3.0 && c == c.round());
                }
            }
        }
    }

    #[test]
    fn quip_beats_baseline_near_at_2_bits() {
        // The headline phenomenon, in miniature: at 2 bits, LDLQ+IncP
        // (QuIP) has (much) lower proxy loss than baseline nearest on
        // outlier-heavy weights.
        let mut rng = Rng::new(7);
        let (m, n) = (16, 32);
        let mut w = random_mat(&mut rng, m, n).scale(0.02);
        for _ in 0..8 {
            let (i, j) = (rng.below(m), rng.below(n));
            w[(i, j)] = rng.uniform(-1.0, 1.0); // outliers
        }
        let h = random_hessian(&mut rng, n, 8, 1e-3);
        let quip = quantize_layer(
            &w,
            &h,
            &QuantConfig {
                bits: 2,
                method: Method::Ldlq,
                processing: Processing::incoherent(),
                ..Default::default()
            },
            1,
        );
        let near = quantize_layer(
            &w,
            &h,
            &QuantConfig {
                bits: 2,
                method: Method::Nearest,
                processing: Processing::baseline(),
                ..Default::default()
            },
            1,
        );
        assert!(
            quip.proxy_loss < near.proxy_loss,
            "QuIP {} vs baseline-near {}",
            quip.proxy_loss,
            near.proxy_loss
        );
    }

    #[test]
    fn optq_matches_ldlq_through_full_pipeline() {
        let (w, h) = setup(3, 6, 12);
        for processing in [Processing::baseline(), Processing::incoherent()] {
            let a = quantize_layer(
                &w,
                &h,
                &QuantConfig {
                    bits: 3,
                    method: Method::Ldlq,
                    processing: processing.clone(),
                    ..Default::default()
                },
                5,
            );
            let b = quantize_layer(
                &w,
                &h,
                &QuantConfig {
                    bits: 3,
                    method: Method::Optq,
                    processing,
                    ..Default::default()
                },
                5,
            );
            assert_eq!(a.codes.data, b.codes.data);
        }
    }

    #[test]
    fn higher_bits_lower_loss() {
        let (w, h) = setup(4, 8, 16);
        let mut last = f64::INFINITY;
        for bits in [2u32, 3, 4] {
            let out = quantize_layer(
                &w,
                &h,
                &QuantConfig {
                    bits,
                    method: Method::Ldlq,
                    processing: Processing::incoherent(),
                    ..Default::default()
                },
                9,
            );
            assert!(
                out.proxy_loss <= last * 1.05,
                "loss did not drop at {bits} bits"
            );
            last = out.proxy_loss;
        }
    }

    #[test]
    fn rg_polish_not_worse_than_plain_ldlq() {
        let (w, h) = setup(5, 10, 20);
        let plain = quantize_layer(
            &w,
            &h,
            &QuantConfig {
                bits: 2,
                method: Method::Ldlq,
                processing: Processing::incoherent(),
                ..Default::default()
            },
            2,
        );
        let rg = quantize_layer(
            &w,
            &h,
            &QuantConfig {
                bits: 2,
                method: Method::LdlqRg,
                processing: Processing::incoherent(),
                ..Default::default()
            },
            2,
        );
        // Greedy polish descends in the reordered basis; allow tiny slack
        // from the basis change.
        assert!(rg.proxy_loss <= plain.proxy_loss * 1.15);
    }
}
