//! Per-layer quantization: configuration ([`QuantConfig`] + builder), the
//! [`Method`] shorthand enum for the paper's seven builtin algorithms, and
//! the layer drivers [`quantize_layer_with`] (any [`Rounder`]) /
//! [`quantize_layer`] (legacy `Method`-keyed shim).
//!
//! The paper's experiment grid (Table 2) is {rounder} × {processing}:
//! `QuIP = LDLQ + IncP`, `QuIP-RG = LDLQ-RG + IncP`. Dispatch lives in
//! [`super::rounder`]: every algorithm is a [`Rounder`] impl resolved by
//! name through the [`RounderRegistry`], so new algorithms plug in
//! without editing this file.

use super::incoherence::{postprocess, preprocess, PostState, Processing};
use super::proxy::proxy_loss;
use super::rounder::{RoundCtx, Rounder, RounderRegistry};
use super::rounding::RoundMode;
use crate::linalg::Mat;

/// Shorthand for the eight builtin rounding algorithms. Kept for
/// config-struct ergonomics and the legacy [`quantize_layer`] shim; the
/// open-ended API is [`Rounder`] + [`RounderRegistry`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Nearest rounding, no feedback.
    Nearest,
    /// Stochastic rounding, no feedback.
    Stochastic,
    /// LDLQ (§3.1). With `Processing::incoherent()` this is QuIP.
    Ldlq,
    /// LDLQ with diag(H)-descending reorder + greedy polish passes.
    LdlqRg,
    /// Standalone greedy coordinate descent (Alg 4; upstream `allbal`).
    Greedy,
    /// The literal OPTQ implementation (equivalent to LDLQ; kept for the
    /// Theorem-6 cross-check and for throughput comparisons).
    Optq,
    /// Algorithm 5: convex-program feedback + stochastic rounding
    /// (upstream `ldlbal_admm`).
    Alg5,
    /// Vector quantization (QuIP#): group-LDLQ against a seeded E8-style
    /// codebook, 8 columns per index at the scalar bitrate. Even bit
    /// widths 2-8 only (validated by [`QuantConfigBuilder::build`]).
    Vq,
}

impl Method {
    /// Parse a method name or alias. Delegates to the
    /// [`RounderRegistry`], so the accepted names are exactly the
    /// registry's (including upstream aliases like `allbal`, `gptq`,
    /// `ldlbal_admm`).
    pub fn parse(s: &str) -> crate::Result<Method> {
        let rounder = RounderRegistry::global().resolve(s)?;
        Method::from_name(rounder.name()).ok_or_else(|| {
            anyhow::anyhow!(
                "rounder '{}' has no Method shorthand; use quantize_layer_with",
                rounder.name()
            )
        })
    }

    /// The canonical registry name of this method's rounder.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Nearest => "near",
            Method::Stochastic => "stoch",
            Method::Ldlq => "ldlq",
            Method::LdlqRg => "ldlq-rg",
            Method::Greedy => "greedy",
            Method::Optq => "optq",
            Method::Alg5 => "alg5",
            Method::Vq => "vq",
        }
    }

    /// Inverse of [`Method::name`] (canonical names only — aliases go
    /// through [`Method::parse`]).
    pub fn from_name(name: &str) -> Option<Method> {
        Some(match name {
            "near" => Method::Nearest,
            "stoch" => Method::Stochastic,
            "ldlq" => Method::Ldlq,
            "ldlq-rg" => Method::LdlqRg,
            "greedy" => Method::Greedy,
            "optq" => Method::Optq,
            "alg5" => Method::Alg5,
            "vq" => Method::Vq,
            _ => return None,
        })
    }

    /// Resolve this method's [`Rounder`] from the global registry.
    pub fn rounder(&self) -> std::sync::Arc<dyn Rounder> {
        RounderRegistry::global()
            .resolve(self.name())
            .expect("builtin rounder is always registered")
    }
}

/// Full per-layer quantization configuration. Construct with
/// [`QuantConfig::builder`] (name-based, alias-aware) or directly.
#[derive(Clone, Debug)]
pub struct QuantConfig {
    pub bits: u32,
    pub method: Method,
    pub processing: Processing,
    /// Greedy polish passes (paper: 10, or 5 on the largest models).
    pub greedy_passes: usize,
    /// Force the stochastic Q subroutine inside LDLQ (Table 15's
    /// unbiased-vs-biased ablation).
    pub force_stochastic: bool,
    /// Alg 5's column-slack hyperparameter c.
    pub alg5_c: f64,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            bits: 2,
            method: Method::Ldlq,
            processing: Processing::incoherent(),
            greedy_passes: 10,
            force_stochastic: false,
            alg5_c: 0.3,
        }
    }
}

impl QuantConfig {
    /// Start a fluent builder seeded with the paper defaults
    /// (2-bit QuIP: LDLQ + incoherence processing).
    pub fn builder() -> QuantConfigBuilder {
        QuantConfigBuilder {
            cfg: QuantConfig::default(),
            rounder_name: None,
        }
    }
}

/// Fluent builder for [`QuantConfig`]. `rounder` accepts any registry
/// name/alias; `build` fails on unknown names with the known list.
#[derive(Clone, Debug)]
pub struct QuantConfigBuilder {
    cfg: QuantConfig,
    rounder_name: Option<String>,
}

impl QuantConfigBuilder {
    pub fn bits(mut self, bits: u32) -> Self {
        self.cfg.bits = bits;
        self
    }

    /// Select the rounding algorithm by registry name or alias
    /// (`"ldlq"`, `"quip"`, `"gptq"`, `"allbal"`, …). Resolved at
    /// [`build`](Self::build) time.
    pub fn rounder(mut self, name: &str) -> Self {
        self.rounder_name = Some(name.to_string());
        self
    }

    /// Select the rounding algorithm by enum shorthand.
    pub fn method(mut self, method: Method) -> Self {
        self.cfg.method = method;
        self.rounder_name = None;
        self
    }

    pub fn processing(mut self, processing: Processing) -> Self {
        self.cfg.processing = processing;
        self
    }

    /// Select the incoherence-transform backend (CLI `--transform`).
    /// Overrides whatever the current processing carries; disabling the
    /// incoherence step entirely is `processing.incoherent = false`, not
    /// a transform kind.
    pub fn transform(mut self, kind: crate::linalg::TransformKind) -> Self {
        self.cfg.processing.transform = kind;
        self
    }

    pub fn greedy_passes(mut self, passes: usize) -> Self {
        self.cfg.greedy_passes = passes;
        self
    }

    pub fn force_stochastic(mut self, on: bool) -> Self {
        self.cfg.force_stochastic = on;
        self
    }

    pub fn alg5_c(mut self, c: f64) -> Self {
        self.cfg.alg5_c = c;
        self
    }

    pub fn build(mut self) -> crate::Result<QuantConfig> {
        if let Some(name) = &self.rounder_name {
            self.cfg.method = Method::parse(name)?;
        }
        if self.cfg.method == Method::Vq {
            anyhow::ensure!(
                self.cfg.bits % 2 == 0 && (2..=8).contains(&self.cfg.bits),
                "the vq rounder supports even bit widths 2-8 (16 codebook \
                 index bits per residual stage across an 8-group); got {} bits",
                self.cfg.bits
            );
            anyhow::ensure!(
                !self.cfg.force_stochastic,
                "the vq rounder is deterministic nearest-codeword search and \
                 has no stochastic mode; drop --stochastic or pick a scalar \
                 rounder for the Table-15 ablation"
            );
        }
        Ok(self.cfg)
    }
}

/// Per-stage wall-clock of one layer quantization (EXPERIMENTS.md
/// §Perf 4). Factorization time is credited by the `linalg::ldl` /
/// `linalg::chol` entry points through the thread-local
/// [`crate::util::stagetimer`] ledger; round time is the remainder of the
/// rounder call.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StageTimings {
    /// Seconds inside LDL/Cholesky factorizations during rounding.
    pub factorize_seconds: f64,
    /// Seconds in the rounding core outside the factorizations.
    pub round_seconds: f64,
}

/// Result of quantizing one layer.
pub struct LayerQuantOutput {
    /// Grid-space codes: integers in [0, 2^b − 1] for scalar rounders,
    /// decoded codebook points for vector rounders (stored as f64).
    pub codes: Mat,
    /// Vector-codebook indices when the rounder quantized in groups
    /// ([`Method::Vq`]); the `.qz` v3 payload. `None` for scalar codes.
    pub vq: Option<crate::quant::rounder::VqCodes>,
    /// Dequantized weights in the original coordinate system.
    pub w_hat: Mat,
    /// Post-processing state (seeds, scales, grid).
    pub post: PostState,
    /// tr((Ŵ−W)H̃(Ŵ−W)ᵀ) against the damped original-basis Hessian.
    pub proxy_loss: f64,
    /// Factorize/round wall-clock split of the rounder call.
    pub stages: StageTimings,
}

impl LayerQuantOutput {
    /// Package into a `.qz` layer record: vector-rounded outputs store
    /// their per-group codebook indices ([`CodeLayout::Vq`]), scalar
    /// outputs bit-pack integer codes. The bit width comes from the
    /// fitted grid (always the config's `bits`).
    ///
    /// [`CodeLayout::Vq`]: crate::quant::CodeLayout::Vq
    pub fn into_layer(self, name: &str) -> crate::quant::packed::QuantizedLayer {
        use crate::quant::packed::QuantizedLayer;
        let bits = self.post.grid.bits();
        match &self.vq {
            Some(vq) => QuantizedLayer::from_vq_indices(
                name,
                self.codes.rows,
                self.codes.cols,
                bits,
                vq,
                self.post,
            ),
            None => QuantizedLayer::from_codes(name, &self.codes, bits, self.post),
        }
    }
}

/// Quantize one linear layer with an explicit [`Rounder`]: W (m×n) with
/// proxy Hessian H (n×n). Runs Algorithm 1 pre-processing, hands the
/// grid-space problem to `rounder` (see the [`super::rounder`] contract),
/// then inverts the processing and reports the original-basis proxy loss.
/// `seed` keys the stochastic rounding and the incoherence orthogonals.
pub fn quantize_layer_with(
    rounder: &dyn Rounder,
    w: &Mat,
    h: &Mat,
    cfg: &QuantConfig,
    seed: u64,
) -> LayerQuantOutput {
    let pre = preprocess(w, h, cfg.bits, &cfg.processing, seed);
    let ctx = RoundCtx {
        bits: cfg.bits,
        seed,
        mode: if cfg.force_stochastic {
            RoundMode::Stochastic
        } else {
            RoundMode::Nearest
        },
        greedy_passes: cfg.greedy_passes,
        alg5_c: cfg.alg5_c,
    };
    // Drain residue (e.g. the pipeline's Cholesky probe) so the ledger
    // measures only this rounder call, then split factorize from round.
    let _ = crate::util::stagetimer::take_factorize();
    let t_round = std::time::Instant::now();
    let rounded = rounder.round(&pre.wg, &pre.h, &ctx);
    let round_total = t_round.elapsed().as_secs_f64();
    let factorize_seconds = crate::util::stagetimer::take_factorize();
    let crate::quant::rounder::Rounded { codes, vq } = rounded;
    let w_hat = postprocess(&codes, &pre.post);
    let loss = proxy_loss(&w_hat, w, &pre.h_damped);
    LayerQuantOutput {
        codes,
        vq,
        w_hat,
        post: pre.post,
        proxy_loss: loss,
        stages: StageTimings {
            factorize_seconds,
            round_seconds: (round_total - factorize_seconds).max(0.0),
        },
    }
}

/// Compatibility shim: quantize one layer keyed by `cfg.method`. Prefer
/// [`quantize_layer_with`] (or the coordinator's `QuantSession`) — this
/// merely resolves the method's rounder from the global registry.
pub fn quantize_layer(w: &Mat, h: &Mat, cfg: &QuantConfig, seed: u64) -> LayerQuantOutput {
    quantize_layer_with(cfg.method.rounder().as_ref(), w, h, cfg, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testkit::{random_hessian, random_mat};

    fn setup(seed: u64, m: usize, n: usize) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let w = random_mat(&mut rng, m, n).scale(0.1);
        let h = random_hessian(&mut rng, n, n / 4, 1e-3);
        (w, h)
    }

    #[test]
    fn all_methods_produce_valid_output() {
        let (w, h) = setup(1, 8, 16);
        for method in [
            Method::Nearest,
            Method::Stochastic,
            Method::Ldlq,
            Method::LdlqRg,
            Method::Greedy,
            Method::Optq,
            Method::Alg5,
        ] {
            for processing in [Processing::baseline(), Processing::incoherent()] {
                let cfg = QuantConfig {
                    bits: 2,
                    method,
                    processing,
                    greedy_passes: 3,
                    ..Default::default()
                };
                let out = quantize_layer(&w, &h, &cfg, 42);
                assert_eq!(out.w_hat.rows, 8);
                assert_eq!(out.w_hat.cols, 16);
                assert!(out.proxy_loss.is_finite() && out.proxy_loss >= 0.0);
                for &c in &out.codes.data {
                    assert!(c >= 0.0 && c <= 3.0 && c == c.round());
                }
            }
        }
    }

    #[test]
    fn vq_method_produces_valid_output() {
        // Vq codes are codebook points, not integers, so it gets its own
        // validity check next to `all_methods_produce_valid_output`.
        let (w, h) = setup(13, 8, 16);
        for processing in [Processing::baseline(), Processing::incoherent()] {
            for bits in [2u32, 4] {
                let cfg = QuantConfig {
                    bits,
                    method: Method::Vq,
                    processing: processing.clone(),
                    ..Default::default()
                };
                let out = quantize_layer(&w, &h, &cfg, 42);
                assert_eq!(out.w_hat.rows, 8);
                assert_eq!(out.w_hat.cols, 16);
                assert!(out.proxy_loss.is_finite() && out.proxy_loss >= 0.0);
                let vq = out.vq.expect("vq indices");
                assert_eq!(vq.indices.len(), 8 * 2);
                // Codes are half-integer grid-space reals.
                for &c in &out.codes.data {
                    assert!(c.is_finite() && (2.0 * c) == (2.0 * c).round());
                }
            }
        }
    }

    #[test]
    fn builder_validates_vq_bit_widths() {
        for bits in [3u32, 5, 7] {
            let err = QuantConfig::builder()
                .bits(bits)
                .rounder("vq")
                .build()
                .unwrap_err()
                .to_string();
            assert!(err.contains("even bit widths"), "bits={bits}: {err}");
        }
        for bits in [2u32, 4, 6, 8] {
            let cfg = QuantConfig::builder().bits(bits).rounder("vq").build().unwrap();
            assert_eq!(cfg.method, Method::Vq);
        }
        // vq has no stochastic Q mode: the Table-15 ablation flag is a
        // clean error, not a silent no-op.
        let err = QuantConfig::builder()
            .rounder("vq")
            .force_stochastic(true)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("stochastic"), "{err}");
        // Aliases resolve to the same method.
        assert_eq!(
            QuantConfig::builder().rounder("codebook").build().unwrap().method,
            Method::Vq
        );
        assert_eq!(
            QuantConfig::builder().rounder("e8").build().unwrap().method,
            Method::Vq
        );
    }

    #[test]
    fn quip_beats_baseline_near_at_2_bits() {
        // The headline phenomenon, in miniature: at 2 bits, LDLQ+IncP
        // (QuIP) has (much) lower proxy loss than baseline nearest on
        // outlier-heavy weights.
        let mut rng = Rng::new(7);
        let (m, n) = (16, 32);
        let mut w = random_mat(&mut rng, m, n).scale(0.02);
        for _ in 0..8 {
            let (i, j) = (rng.below(m), rng.below(n));
            w[(i, j)] = rng.uniform(-1.0, 1.0); // outliers
        }
        let h = random_hessian(&mut rng, n, 8, 1e-3);
        let quip = quantize_layer(
            &w,
            &h,
            &QuantConfig {
                bits: 2,
                method: Method::Ldlq,
                processing: Processing::incoherent(),
                ..Default::default()
            },
            1,
        );
        let near = quantize_layer(
            &w,
            &h,
            &QuantConfig {
                bits: 2,
                method: Method::Nearest,
                processing: Processing::baseline(),
                ..Default::default()
            },
            1,
        );
        assert!(
            quip.proxy_loss < near.proxy_loss,
            "QuIP {} vs baseline-near {}",
            quip.proxy_loss,
            near.proxy_loss
        );
    }

    #[test]
    fn optq_matches_ldlq_through_full_pipeline() {
        let (w, h) = setup(3, 6, 12);
        for processing in [Processing::baseline(), Processing::incoherent()] {
            let a = quantize_layer(
                &w,
                &h,
                &QuantConfig {
                    bits: 3,
                    method: Method::Ldlq,
                    processing: processing.clone(),
                    ..Default::default()
                },
                5,
            );
            let b = quantize_layer(
                &w,
                &h,
                &QuantConfig {
                    bits: 3,
                    method: Method::Optq,
                    processing,
                    ..Default::default()
                },
                5,
            );
            assert_eq!(a.codes.data, b.codes.data);
        }
    }

    #[test]
    fn higher_bits_lower_loss() {
        let (w, h) = setup(4, 8, 16);
        let mut last = f64::INFINITY;
        for bits in [2u32, 3, 4] {
            let out = quantize_layer(
                &w,
                &h,
                &QuantConfig {
                    bits,
                    method: Method::Ldlq,
                    processing: Processing::incoherent(),
                    ..Default::default()
                },
                9,
            );
            assert!(
                out.proxy_loss <= last * 1.05,
                "loss did not drop at {bits} bits"
            );
            last = out.proxy_loss;
        }
    }

    #[test]
    fn rg_polish_not_worse_than_plain_ldlq() {
        let (w, h) = setup(5, 10, 20);
        let plain = quantize_layer(
            &w,
            &h,
            &QuantConfig {
                bits: 2,
                method: Method::Ldlq,
                processing: Processing::incoherent(),
                ..Default::default()
            },
            2,
        );
        let rg = quantize_layer(
            &w,
            &h,
            &QuantConfig {
                bits: 2,
                method: Method::LdlqRg,
                processing: Processing::incoherent(),
                ..Default::default()
            },
            2,
        );
        // Greedy polish descends in the reordered basis; allow tiny slack
        // from the basis change.
        assert!(rg.proxy_loss <= plain.proxy_loss * 1.15);
    }

    #[test]
    fn stage_timings_split_the_rounder_call() {
        let (w, h) = setup(11, 8, 96); // n > LDL_BLOCK: blocked factor path
        let ldlq = quantize_layer(
            &w,
            &h,
            &QuantConfig {
                bits: 2,
                method: Method::Ldlq,
                ..Default::default()
            },
            3,
        );
        assert!(ldlq.stages.factorize_seconds >= 0.0);
        assert!(ldlq.stages.round_seconds >= 0.0);
        // Nearest rounding never factors: the ledger must stay empty.
        let near = quantize_layer(
            &w,
            &h,
            &QuantConfig {
                bits: 2,
                method: Method::Nearest,
                ..Default::default()
            },
            3,
        );
        assert_eq!(near.stages.factorize_seconds, 0.0);
        assert!(near.stages.round_seconds >= 0.0);
    }

    #[test]
    fn builder_resolves_aliases_and_defaults() {
        let cfg = QuantConfig::builder().build().unwrap();
        assert_eq!(cfg.bits, 2);
        assert_eq!(cfg.method, Method::Ldlq);
        assert!(cfg.processing.incoherent);

        let cfg = QuantConfig::builder()
            .bits(3)
            .rounder("gptq")
            .processing(Processing::baseline())
            .greedy_passes(4)
            .force_stochastic(true)
            .alg5_c(0.7)
            .build()
            .unwrap();
        assert_eq!(cfg.bits, 3);
        assert_eq!(cfg.method, Method::Optq);
        assert!(!cfg.processing.incoherent);
        assert_eq!(cfg.greedy_passes, 4);
        assert!(cfg.force_stochastic);
        assert_eq!(cfg.alg5_c, 0.7);

        // Upstream names resolve too; unknown names fail with context.
        assert_eq!(
            QuantConfig::builder().rounder("allbal").build().unwrap().method,
            Method::Greedy
        );
        assert_eq!(
            QuantConfig::builder()
                .rounder("ldlbal_admm")
                .build()
                .unwrap()
                .method,
            Method::Alg5
        );
        assert!(QuantConfig::builder().rounder("bogus").build().is_err());
    }

    #[test]
    fn builder_method_and_rounder_are_equivalent() {
        let a = QuantConfig::builder().method(Method::LdlqRg).build().unwrap();
        let b = QuantConfig::builder().rounder("quip-rg").build().unwrap();
        assert_eq!(a.method, b.method);
    }

    #[test]
    fn builder_selects_transform_backend() {
        use crate::linalg::TransformKind;
        let cfg = QuantConfig::builder().build().unwrap();
        assert_eq!(cfg.processing.transform, TransformKind::Kron);
        let cfg = QuantConfig::builder().transform(TransformKind::Hadamard).build().unwrap();
        assert_eq!(cfg.processing.transform, TransformKind::Hadamard);
        assert!(cfg.processing.incoherent);
    }

    #[test]
    fn hadamard_pipeline_produces_valid_output_at_all_bits() {
        use crate::linalg::TransformKind;
        let (w, h) = setup(9, 8, 16);
        for bits in [2u32, 3, 4] {
            let out = quantize_layer(
                &w,
                &h,
                &QuantConfig {
                    bits,
                    method: Method::Ldlq,
                    processing: Processing::incoherent_with(TransformKind::Hadamard),
                    ..Default::default()
                },
                42,
            );
            assert!(out.proxy_loss.is_finite() && out.proxy_loss >= 0.0);
            let top = crate::quant::grid::levels(bits) as f64;
            for &c in &out.codes.data {
                assert!(c >= 0.0 && c <= top && c == c.round(), "bits={bits}: {c}");
            }
            assert_eq!(out.post.transform, TransformKind::Hadamard);
        }
    }
}
