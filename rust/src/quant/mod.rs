//! The paper's contribution: adaptive rounding with linear feedback (LDLQ,
//! §3), incoherence processing (§4), the greedy polish (Alg 4), the literal
//! OPTQ algorithm (§5.1, for the Theorem-6 equivalence check), and the
//! finite-grid "fixed" procedure (Alg 5, §5.2).
//!
//! Public API shape: rounding algorithms are [`Rounder`] impls resolved by
//! name through the [`RounderRegistry`] (see [`rounder`] for the trait
//! contract); the incoherence step is a pluggable transform backend
//! ([`TransformKind`]: the paper's Kronecker operator or the QuIP#
//! randomized Hadamard transform, selected via
//! [`Processing::incoherent_with`] / `QuantConfigBuilder::transform`);
//! what a rounder rounds *to* is a [`Codebook`] — the scalar integer grid
//! or the QuIP#-style E8 vector codebook behind the `vq` rounder (see
//! [`grid`] and DESIGN.md §6); per-layer configuration is built with
//! [`QuantConfig::builder`]; [`quantize_layer_with`] drives one layer
//! through preprocess → round → postprocess. [`quantize_layer`] is the
//! legacy `Method`-keyed shim kept for transition-era call sites.

pub mod grid;
pub mod rounding;
pub mod ldlq;
pub mod optq;
pub mod greedy;
pub mod reorder;
pub mod incoherence;
pub mod alg5;
pub mod proxy;
pub mod rounder;
pub mod method;
pub mod packed;

pub use crate::linalg::TransformKind;
pub use grid::{codebook_seed, Codebook, GridMap, VqLut, VQ_GROUP};
pub use incoherence::{PostState, Processing};
pub use method::{
    quantize_layer, quantize_layer_with, LayerQuantOutput, Method, QuantConfig,
    QuantConfigBuilder, StageTimings,
};
pub use packed::CodeLayout;
pub use proxy::proxy_loss;
pub use rounder::{RoundCtx, Rounded, Rounder, RounderRegistry, VqCodes};
pub use rounding::RoundMode;
