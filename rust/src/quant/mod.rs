//! The paper's contribution: adaptive rounding with linear feedback (LDLQ,
//! §3), incoherence processing (§4), the greedy polish (Alg 4), the literal
//! OPTQ algorithm (§5.1, for the Theorem-6 equivalence check), and the
//! finite-grid "fixed" procedure (Alg 5, §5.2).

pub mod grid;
pub mod rounding;
pub mod ldlq;
pub mod optq;
pub mod greedy;
pub mod reorder;
pub mod incoherence;
pub mod alg5;
pub mod proxy;
pub mod method;
pub mod packed;

pub use grid::GridMap;
pub use incoherence::{PostState, Processing};
pub use method::{quantize_layer, LayerQuantOutput, Method, QuantConfig};
pub use proxy::proxy_loss;
pub use rounding::RoundMode;
