//! Incoherence processing — QuIP Algorithms 1 (pre) and 2 (post).
//!
//! Pre-processing, in order (each step toggleable; Table 3 ablates them):
//!   1. H ← H + α·mean(diag H)·I                (baseline damping, OPTQ's)
//!   2. diagonal rescale: W ← W·D̃, H ← D̃⁻¹HD̃⁻¹ with
//!      D̃ᵢ = Hᵢᵢ^{1/4}/‖W_{:,i}‖^{1/2} — the minimizer of
//!      (Σᵢ Hᵢᵢ/dᵢ)(Σⱼ ‖W_{:,j}‖²dⱼ) over dᵢ = D̃ᵢ² (Supplement B.1)
//!   3. incoherence: W ← U W Vᵀ, H ← V H Vᵀ with U, V seeded fast
//!      orthogonal operators from the transform subsystem — the paper's
//!      two-factor Kronecker operator or the QuIP# randomized Hadamard
//!      transform, selected by [`Processing::transform`]
//!      (see [`crate::linalg::transform`])
//!   4. quantization range: s = ρ‖W‖_F/√(mn) (Alg 1 line 6) and map to the
//!      grid; baseline uses per-row min-max instead.
//!
//! Post-processing inverts in reverse order. Only *seeds* (plus the
//! transform kind) are stored for the orthogonal factors — they regenerate
//! exactly (see `util::rng`).

use super::grid::GridMap;
use crate::linalg::{make_transform, Mat, TransformKind};

/// Which processing steps to apply around the rounding core.
#[derive(Clone, Debug)]
pub struct Processing {
    /// Conjugate by seeded random orthogonal operators (step 3).
    pub incoherent: bool,
    /// Which fast orthogonal operator family to conjugate with. Ignored
    /// when `incoherent` is off.
    pub transform: TransformKind,
    /// Diagonal rescale (step 2).
    pub rescale: bool,
    /// ‖W‖_F-based symmetric global quantization range (step 4); when
    /// false, per-row min-max (the OPTQ baseline).
    pub frob_range: bool,
    /// Random permutation inside the fast orthogonal multiply (Table 5).
    pub permute: bool,
    /// Hessian damping fraction α (both processings use it; paper's
    /// baseline default 0.01).
    pub alpha: f64,
    /// Quantization-range multiplier ρ (paper tunes ρ = 2.4).
    pub rho: f64,
}

impl Processing {
    /// OPTQ-style baseline: damping only, per-row min-max grid.
    pub fn baseline() -> Processing {
        Processing {
            incoherent: false,
            transform: TransformKind::Kron,
            rescale: false,
            frob_range: false,
            permute: false,
            alpha: 0.01,
            rho: 2.4,
        }
    }

    /// Full QuIP incoherence processing ("IncP") with the paper's
    /// Kronecker operator.
    pub fn incoherent() -> Processing {
        Processing {
            incoherent: true,
            transform: TransformKind::Kron,
            rescale: true,
            frob_range: true,
            permute: true,
            alpha: 0.01,
            rho: 2.4,
        }
    }

    /// Full IncP with an explicit transform backend (e.g. the QuIP#
    /// randomized Hadamard transform).
    pub fn incoherent_with(transform: TransformKind) -> Processing {
        Processing {
            transform,
            ..Processing::incoherent()
        }
    }
}

impl Default for Processing {
    fn default() -> Self {
        Processing::incoherent()
    }
}

/// Everything needed to undo pre-processing on quantized codes. Stored in
/// artifacts (seeds + small vectors only — the orthogonal matrices are
/// regenerated).
#[derive(Clone, Debug)]
pub struct PostState {
    pub m: usize,
    pub n: usize,
    pub incoherent: bool,
    /// Which transform family `u_seed`/`v_seed` regenerate. `.qz` v1
    /// artifacts predate the field and deserialize as `Kron`.
    pub transform: TransformKind,
    pub permute: bool,
    pub u_seed: u64,
    pub v_seed: u64,
    /// D̃ of step 2 (None when rescale disabled).
    pub d_tilde: Option<Vec<f64>>,
    pub grid: GridMap,
}

/// Output of Algorithm 1.
pub struct Preprocessed {
    /// Grid-space weights ready for the rounding core.
    pub wg: Mat,
    /// Hessian in the processed basis (feeds the LDL factorization).
    pub h: Mat,
    /// Damped Hessian in the *original* basis (for proxy-loss reporting).
    pub h_damped: Mat,
    pub post: PostState,
}

/// The diagonal bump [`damp`] adds: α·mean(diag H), floored at 1e-12 so
/// exactly-dead input dimensions still get LDL pivots. The single
/// authority for the damping magnitude — the pipeline's non-PD recovery
/// re-damps its probe matrix in place with this same formula, so the
/// probe stays bit-consistent with the matrix the quantizer factors.
pub fn damp_bump(h: &Mat, alpha: f64) -> f64 {
    let mean_diag = h.trace() / h.rows.max(1) as f64;
    (alpha * mean_diag).max(1e-12)
}

/// Step 1 of Algorithm 1 — the damped Hessian H + α·mean(diag H)·I (see
/// [`damp_bump`]). Exposed so the pipeline's non-PD recovery can probe
/// exactly the matrix the quantizer will factor.
pub fn damp(h: &Mat, alpha: f64) -> Mat {
    let mut hd = h.symmetrize();
    let bump = damp_bump(h, alpha);
    for i in 0..h.rows {
        hd[(i, i)] += bump;
    }
    hd
}

/// Algorithm 1: incoherence pre-processing.
pub fn preprocess(w: &Mat, h: &Mat, bits: u32, p: &Processing, seed: u64) -> Preprocessed {
    let (m, n) = (w.rows, w.cols);
    assert_eq!(h.rows, n, "H must be n×n for W m×n");

    // Step 1 — damping (also: any exactly-dead input dimension gets a
    // nonzero diagonal so LDL pivots exist).
    let hd = damp(h, p.alpha);
    let h_damped = hd.clone();

    // Step 2 — diagonal rescale.
    let mut wp = w.clone();
    let mut hp = hd;
    let d_tilde = if p.rescale {
        let mut d = vec![1.0f64; n];
        for j in 0..n {
            let hjj = hp[(j, j)];
            let cn = {
                let mut s = 0.0;
                for i in 0..m {
                    s += wp[(i, j)] * wp[(i, j)];
                }
                s.sqrt()
            };
            if hjj > 1e-30 && cn > 1e-30 {
                d[j] = hjj.powf(0.25) / cn.sqrt();
            }
        }
        // Normalize so the geometric mean of D̃ is 1 (pure conditioning;
        // keeps weight magnitudes in a stable range).
        let log_mean: f64 = d.iter().map(|x| x.ln()).sum::<f64>() / n as f64;
        let norm = (-log_mean).exp();
        for x in d.iter_mut() {
            *x *= norm;
        }
        wp = wp.scale_cols(&d);
        let inv: Vec<f64> = d.iter().map(|x| 1.0 / x).collect();
        hp = hp.scale_rows(&inv).scale_cols(&inv);
        Some(d)
    } else {
        None
    };

    // Step 3 — incoherence via seeded fast orthogonal conjugation.
    let u_seed = seed ^ 0x5157_4950_5F55_5F31; // "QuIP_U_1"
    let v_seed = seed ^ 0x5157_4950_5F56_5F32; // "QuIP_V_2"
    if p.incoherent {
        let u = make_transform(p.transform, u_seed, m, p.permute);
        let v = make_transform(p.transform, v_seed, n, p.permute);
        // W ← U W Vᵀ
        wp = v.forward_mat_right_t(&u.forward_mat_left(&wp));
        // H ← V H Vᵀ
        hp = v.conj_sym(&hp).symmetrize();
    }

    // Step 4 — quantization range / grid map.
    let grid = if p.frob_range {
        GridMap::fit_global(&wp, bits, p.rho)
    } else {
        GridMap::fit_per_row(&wp, bits)
    };
    let wg = grid.to_grid(&wp);

    Preprocessed {
        wg,
        h: hp,
        h_damped,
        post: PostState {
            m,
            n,
            incoherent: p.incoherent,
            transform: p.transform,
            permute: p.permute,
            u_seed,
            v_seed,
            d_tilde,
            grid,
        },
    }
}

/// Algorithm 2: incoherence post-processing. Takes integer grid codes and
/// returns dequantized weights in the original coordinate system.
pub fn postprocess(codes: &Mat, post: &PostState) -> Mat {
    let mut w = post.grid.from_grid(codes);
    if post.incoherent {
        let u = make_transform(post.transform, post.u_seed, post.m, post.permute);
        let v = make_transform(post.transform, post.v_seed, post.n, post.permute);
        // W ← Uᵀ W V
        w = v.inverse_mat_right(&u.inverse_mat_left(&w));
    }
    if let Some(d) = &post.d_tilde {
        let inv: Vec<f64> = d.iter().map(|x| 1.0 / x).collect();
        w = w.scale_cols(&inv);
    }
    w
}

impl PostState {
    /// Serialize in the given `.qz` format version (see
    /// [`super::packed`]): v2 records the transform kind after the
    /// `incoherent` flag; v1 predates the subsystem (Kron implied) and is
    /// only written by tests pinning back-compat.
    pub fn serialize(&self, w: &mut crate::util::bytes::Writer, version: u32) {
        w.u64(self.m as u64);
        w.u64(self.n as u64);
        w.u8(self.incoherent as u8);
        if version >= super::packed::FORMAT_V2 {
            w.u8(self.transform.as_u8());
        }
        w.u8(self.permute as u8);
        w.u64(self.u_seed);
        w.u64(self.v_seed);
        match &self.d_tilde {
            Some(d) => {
                w.u8(1);
                w.f64s(d);
            }
            None => w.u8(0),
        }
        self.grid.serialize(w);
    }

    pub fn deserialize(
        r: &mut crate::util::bytes::Reader,
        version: u32,
    ) -> crate::Result<PostState> {
        let m = r.u64()? as usize;
        let n = r.u64()? as usize;
        let incoherent = r.u8()? != 0;
        let transform = if version >= super::packed::FORMAT_V2 {
            TransformKind::from_u8(r.u8()?)?
        } else {
            TransformKind::Kron
        };
        let permute = r.u8()? != 0;
        let u_seed = r.u64()?;
        let v_seed = r.u64()?;
        let d_tilde = if r.u8()? != 0 { Some(r.f64s()?) } else { None };
        let grid = GridMap::deserialize(r)?;
        Ok(PostState {
            m,
            n,
            incoherent,
            transform,
            permute,
            u_seed,
            v_seed,
            d_tilde,
            grid,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::proxy::proxy_loss;
    use crate::util::rng::Rng;
    use crate::util::testkit::{propcheck, random_hessian, random_mat};

    #[test]
    fn identity_processing_roundtrips_weights() {
        // With everything off and 8 bits, post(pre(W)) ≈ W up to grid
        // resolution when codes = exact grid values.
        let mut rng = Rng::new(1);
        let w = random_mat(&mut rng, 6, 12);
        let h = random_hessian(&mut rng, 12, 4, 1e-3);
        let mut p = Processing::baseline();
        p.alpha = 0.0;
        let pre = preprocess(&w, &h, 8, &p, 0);
        let back = postprocess(&pre.wg, &pre.post);
        for (a, b) in back.data.iter().zip(&w.data) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn full_incp_roundtrips_weights_without_rounding() {
        for kind in [TransformKind::Kron, TransformKind::Hadamard] {
            propcheck("incp-roundtrip", 8, |rng| {
                let m = 4 + rng.below(8);
                let n = 6 + rng.below(10);
                let w = random_mat(rng, m, n);
                let h = random_hessian(rng, n, 3, 1e-3);
                let p = Processing::incoherent_with(kind);
                let pre = preprocess(&w, &h, 8, &p, 0xBEEF);
                assert_eq!(pre.post.transform, kind);
                // Feed the *continuous* grid values through post — must
                // invert pre exactly (orthogonal + diagonal + affine are
                // all inverted).
                let back = postprocess(&pre.wg, &pre.post);
                for (a, b) in back.data.iter().zip(&w.data) {
                    assert!((a - b).abs() < 1e-8, "{kind}: {a} vs {b}");
                }
            });
        }
    }

    #[test]
    fn conjugation_preserves_proxy_loss() {
        // tr(ΔHΔᵀ) invariance (§4 "this transformation preserves the proxy
        // quadratic form"), checked end to end through pre/post.
        propcheck("incp-proxy-invariant", 6, |rng| {
            let (m, n) = (6, 12);
            let w = random_mat(rng, m, n);
            let h = random_hessian(rng, n, 4, 1e-2);
            let mut p = Processing::incoherent_with(if rng.coin(0.5) {
                TransformKind::Kron
            } else {
                TransformKind::Hadamard
            });
            p.rescale = false; // isolate the orthogonal step
            p.frob_range = true;
            let pre = preprocess(&w, &h, 4, &p, 7);
            // Perturb grid weights, map back, compare proxy in both bases.
            let mut codes = pre.wg.clone();
            for x in codes.data.iter_mut() {
                *x = (*x + rng.uniform(-0.5, 0.5)).clamp(0.0, 15.0);
            }
            let loss_grid = proxy_loss(&codes, &pre.wg, &pre.h);
            // Map grid-space loss to weight-space: multiply by row_scale².
            let scale = pre.post.grid.row_scale(0);
            let loss_grid_ws = loss_grid * scale * scale;
            let w_hat = postprocess(&codes, &pre.post);
            let loss_orig = proxy_loss(&w_hat, &w, &pre.h_damped);
            assert!(
                (loss_grid_ws - loss_orig).abs() <= 1e-6 * loss_orig.max(1e-12),
                "grid {loss_grid_ws} vs orig {loss_orig}"
            );
        });
    }

    #[test]
    fn incoherence_reduces_max_entries() {
        // Fig 2's phenomenon: after processing, max|W_ij| shrinks toward
        // μ‖W‖_F/√(mn). Use a spiky W (outliers) to see the effect clearly.
        let mut rng = Rng::new(5);
        let (m, n) = (16, 24);
        let mut w = random_mat(&mut rng, m, n).scale(0.05);
        w[(3, 7)] = 4.0; // outlier
        w[(11, 2)] = -5.0;
        let h = random_hessian(&mut rng, n, 6, 1e-3);
        for kind in [TransformKind::Kron, TransformKind::Hadamard] {
            let mut p = Processing::incoherent_with(kind);
            p.rescale = false;
            let pre = preprocess(&w, &h, 8, &p, 3);
            // Recover processed-space W from continuous grid coords.
            let w_proc = pre.post.grid.from_grid(&pre.wg);
            assert!(
                w_proc.max_abs() < w.max_abs() * 0.5,
                "{kind}: processed max {} vs original {}",
                w_proc.max_abs(),
                w.max_abs()
            );
        }
    }

    #[test]
    fn rescale_minimizes_product_objective() {
        // D̃ should (approximately) minimize tr(H')·‖W'‖_F² among diagonal
        // rescalings; check stationarity vs random perturbations.
        let mut rng = Rng::new(6);
        let (m, n) = (8, 10);
        let w = random_mat(&mut rng, m, n);
        let h = random_hessian(&mut rng, n, 4, 1e-2);
        let mut p = Processing::baseline();
        p.rescale = true;
        let pre = preprocess(&w, &h, 8, &p, 0);
        let d = pre.post.d_tilde.clone().unwrap();
        let objective = |dv: &[f64]| {
            let wp = w.scale_cols(dv);
            let inv: Vec<f64> = dv.iter().map(|x| 1.0 / x).collect();
            let hp = pre.h_damped.scale_rows(&inv).scale_cols(&inv);
            hp.trace() * wp.frob_norm().powi(2)
        };
        let base = objective(&d);
        for _ in 0..20 {
            let mut d2 = d.clone();
            for x in d2.iter_mut() {
                *x *= 1.0 + rng.uniform(-0.2, 0.2);
            }
            assert!(objective(&d2) >= base * (1.0 - 1e-9), "perturbation improved objective");
        }
    }

    #[test]
    fn poststate_serialization_roundtrip() {
        use crate::quant::packed::FORMAT_V2;
        let mut rng = Rng::new(7);
        let w = random_mat(&mut rng, 6, 9);
        let h = random_hessian(&mut rng, 9, 3, 1e-2);
        for kind in [TransformKind::Kron, TransformKind::Hadamard] {
            let pre = preprocess(&w, &h, 2, &Processing::incoherent_with(kind), 42);
            let mut buf = crate::util::bytes::Writer::new();
            pre.post.serialize(&mut buf, FORMAT_V2);
            let mut r = crate::util::bytes::Reader::new(&buf.buf);
            let post2 = PostState::deserialize(&mut r, FORMAT_V2).unwrap();
            assert_eq!(post2.transform, kind);
            let codes = Mat::from_fn(6, 9, |i, j| (((i + j) % 4) as f64).min(3.0));
            let a = postprocess(&codes, &pre.post);
            let b = postprocess(&codes, &post2);
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn v1_poststate_bytes_deserialize_as_kron() {
        use crate::quant::packed::{FORMAT_V1, FORMAT_V2};
        let mut rng = Rng::new(8);
        let w = random_mat(&mut rng, 5, 8);
        let h = random_hessian(&mut rng, 8, 3, 1e-2);
        let pre = preprocess(&w, &h, 2, &Processing::incoherent(), 13);
        // v1 layout omits the transform byte entirely.
        let mut buf = crate::util::bytes::Writer::new();
        pre.post.serialize(&mut buf, FORMAT_V1);
        let mut buf2 = crate::util::bytes::Writer::new();
        pre.post.serialize(&mut buf2, FORMAT_V2);
        assert_eq!(buf.buf.len() + 1, buf2.buf.len());
        let mut r = crate::util::bytes::Reader::new(&buf.buf);
        let post2 = PostState::deserialize(&mut r, FORMAT_V1).unwrap();
        assert_eq!(post2.transform, TransformKind::Kron);
        assert_eq!(r.remaining(), 0);
        let codes = Mat::from_fn(5, 8, |i, j| ((i + j) % 4) as f64);
        assert_eq!(postprocess(&codes, &pre.post).data, postprocess(&codes, &post2).data);
    }
}
