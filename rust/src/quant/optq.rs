//! A literal transcription of OPTQ (Frantar et al., 2023) — kept separate
//! from `ldlq` so Theorem 6 ("OPTQ is a special case of LDLQ") can be
//! verified *empirically* against an independent implementation, exactly
//! as the paper does in Supplement C.2.
//!
//! OPTQ: invert H, Cholesky-decompose the inverse (upper form), then for
//! each column k: nearest-round, scale the error by 1/Uinv_kk, and subtract
//! the scaled error times Uinv_{k,k+1:} from the remaining columns.

use crate::linalg::chol::{cholesky, spd_inverse};
use crate::linalg::Mat;
use crate::quant::rounding::{round_clamp, RoundMode};
use crate::util::rng::Rng;

/// OPTQ on grid-space weights `wg` with Hessian `h`. Returns integer codes.
/// `h` must be positive definite (add damping first, as OPTQ does).
pub fn optq(wg: &Mat, h: &Mat, bits: u32) -> crate::Result<Mat> {
    let (m, n) = (wg.rows, wg.cols);
    // Hinv = H⁻¹; Hinv = Uᵀ U with U upper triangular (torch's
    // cholesky(..., upper=True) convention used by the reference repo).
    let hinv = spd_inverse(h)?;
    let l = cholesky(&hinv)?;
    let u = l.transpose();

    let mut rng = Rng::new(0); // unused for nearest rounding
    let mut w = wg.clone();
    let mut codes = Mat::zeros(m, n);
    for k in 0..n {
        let d = u[(k, k)];
        for i in 0..m {
            let wik = w[(i, k)];
            let q = round_clamp(RoundMode::Nearest, wik, bits, &mut rng);
            codes[(i, k)] = q;
            let err = (wik - q) / d;
            // Update remaining columns of row i.
            let urow = u.row(k);
            let wrow = w.row_mut(i);
            for j in (k + 1)..n {
                wrow[j] -= err * urow[j];
            }
        }
    }
    Ok(codes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ldlq::ldlq;
    use crate::util::rng::Rng;
    use crate::util::testkit::{propcheck, random_spd};

    /// Theorem 6 (empirical form): OPTQ and LDLQ produce *identical*
    /// quantized outputs. The paper checks W ~ Unif[0,1]^{1000×1000}; we
    /// check many smaller random instances plus one large one.
    #[test]
    fn optq_equiv_ldlq_small() {
        propcheck("optq-equiv", 15, |rng| {
            let n = 8 + rng.below(12);
            let m = 4 + rng.below(8);
            let bits = 2 + rng.below(3) as u32;
            let h = random_spd(rng, n, 1e-2);
            let q = super::super::grid::levels(bits) as f64;
            let wg = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, q));
            let a = optq(&wg, &h, bits).unwrap();
            let b = ldlq(&wg, &h, bits, RoundMode::Nearest, 0);
            assert_eq!(a.data, b.data, "OPTQ != LDLQ (m={m}, n={n}, b={bits})");
        });
    }

    #[test]
    fn optq_equiv_ldlq_large() {
        // Scaled-down version of the paper's 1000×1000 check (C.2);
        // `quip table optq` runs the full size.
        let mut rng = Rng::new(1000);
        let n = 200;
        let m = 64;
        let h = random_spd(&mut rng, n, 1e-2);
        let wg = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 15.0));
        let a = optq(&wg, &h, 4).unwrap();
        let b = ldlq(&wg, &h, 4, RoundMode::Nearest, 0);
        let mismatches = a
            .data
            .iter()
            .zip(&b.data)
            .filter(|(x, y)| x != y)
            .count();
        assert_eq!(mismatches, 0, "{mismatches}/{} codes differ", a.data.len());
    }
}
