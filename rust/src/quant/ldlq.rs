//! LDLQ — adaptive rounding with linear feedback (paper §3.1, Alg 3 l.3).
//!
//! For each row w of W (rows are independent → parallel):
//!
//!   ŵ_k = clamp(Q(w_k + (w_{1:k−1} − ŵ_{1:k−1}) · U̇_{1:k−1,k}), 0, 2^b−1)
//!
//! with U̇ the strictly-upper factor of H = (U̇+I) D (U̇+I)ᵀ. The feedback
//! matrix can also be supplied directly (Alg 5 passes U̇ = R⁻¹ − I; nearest
//! / stochastic baselines pass U̇ = 0 by calling `round_matrix`).
//!
//! [`ldlq_vq`] is the vector-codebook variant (QuIP#): the same feedback
//! recurrence, but columns round jointly in
//! [`VQ_GROUP`](super::grid::VQ_GROUP)-wide groups against an E8-style
//! [`Codebook`] instead of coordinate-wise to the scalar grid (see
//! `quant::grid` and DESIGN.md §6).

use super::grid::Codebook;
use super::rounding::{round_clamp, RoundMode};
use crate::linalg::ldl::udu;
use crate::linalg::Mat;
use crate::util::rng::Rng;
use crate::util::threadpool::{default_threads, parallel_map};

/// Quantize `wg` (already in grid coordinates) with linear feedback from
/// `u_dot` (strictly upper triangular, n×n). Returns integer grid codes.
pub fn ldlq_with_feedback(
    wg: &Mat,
    u_dot: &Mat,
    bits: u32,
    mode: RoundMode,
    seed: u64,
) -> Mat {
    let (m, n) = (wg.rows, wg.cols);
    assert_eq!(u_dot.rows, n);
    assert_eq!(u_dot.cols, n);
    // Transpose the feedback so column k is contiguous (hot inner loop).
    let ut = u_dot.transpose();
    let root = Rng::new(seed);
    let rows = parallel_map(m, default_threads(), |i| {
        let mut rng = root.fork(i as u64);
        let w = wg.row(i);
        let mut what = vec![0.0f64; n];
        let mut err = vec![0.0f64; n]; // w_j − ŵ_j for j < k
        for k in 0..n {
            let fb = crate::linalg::matrix::dot(&err[..k], &ut.row(k)[..k]);
            let v = w[k] + fb;
            let q = round_clamp(mode, v, bits, &mut rng);
            what[k] = q;
            err[k] = w[k] - q;
        }
        what
    });
    Mat::from_rows(&rows)
}

/// Full LDLQ: factor H (UDUᵀ) and round with the LDL feedback. The
/// factorization runs on the blocked threaded LDL kernel above one panel
/// (see `linalg::ldl`; EXPERIMENTS.md §Perf 4), so at LLM widths both the
/// factor and the row-parallel rounding scale with cores; its wall-clock
/// is credited to the `factorize` stage of the pipeline's
/// `LayerStageTimings`.
pub fn ldlq(wg: &Mat, h: &Mat, bits: u32, mode: RoundMode, seed: u64) -> Mat {
    let f = udu(h, 1e-12);
    ldlq_with_feedback(wg, &f.strictly_upper(), bits, mode, seed)
}

/// Blocked LDLQ ("lazy batch", as in the OPTQ reference implementation):
/// process columns in blocks of `block`; within a block run the exact
/// sequential recurrence against the block-local triangle, then push the
/// block's accumulated feedback into all later columns in one pass
/// (better locality at large n; same flops). Produces codes numerically
/// equal to `ldlq_with_feedback` up to f64 summation order.
pub fn ldlq_with_feedback_blocked(
    wg: &Mat,
    u_dot: &Mat,
    bits: u32,
    mode: RoundMode,
    seed: u64,
    block: usize,
) -> Mat {
    let (m, n) = (wg.rows, wg.cols);
    let block = block.max(1);
    let ut = u_dot.transpose(); // ut[k][j] = u_dot[j][k]
    let root = Rng::new(seed);
    let rows = parallel_map(m, default_threads(), |i| {
        let mut rng = root.fork(i as u64);
        let w = wg.row(i);
        let mut what = vec![0.0f64; n];
        let mut err = vec![0.0f64; n];
        // acc[k] = feedback contribution from *finished blocks* to col k.
        let mut acc = vec![0.0f64; n];
        let mut k0 = 0;
        while k0 < n {
            let k1 = (k0 + block).min(n);
            for k in k0..k1 {
                // In-block feedback (exact recurrence) + carried prefix.
                let fb = acc[k]
                    + crate::linalg::matrix::dot(&err[k0..k], &ut.row(k)[k0..k]);
                let v = w[k] + fb;
                let q = round_clamp(mode, v, bits, &mut rng);
                what[k] = q;
                err[k] = w[k] - q;
            }
            // Push this block's errors into all later columns at once.
            for k in k1..n {
                acc[k] +=
                    crate::linalg::matrix::dot(&err[k0..k1], &ut.row(k)[k0..k1]);
            }
            k0 = k1;
        }
        what
    });
    Mat::from_rows(&rows)
}

/// Group-LDLQ against a vector [`Codebook`] (the QuIP# lattice-codebook
/// step): columns are processed in
/// [`VQ_GROUP`](super::grid::VQ_GROUP)-wide groups; each group's
/// feedback-corrected targets `w_k + acc_k` are rounded *jointly* to the
/// nearest codebook point (no intra-group scalar feedback — the
/// groups-of-columns variant of the per-coordinate LDLQ step), then the
/// group's errors `w − ŵ` propagate to all later columns through U̇
/// exactly as in [`ldlq_with_feedback_blocked`]. Returns the decoded
/// grid-space code values plus one codebook index per (row, group),
/// row-major — the `.qz` v3 payload.
pub fn ldlq_vq_with_feedback(wg: &Mat, u_dot: &Mat, cb: &Codebook) -> (Mat, Vec<u64>) {
    let (m, n) = (wg.rows, wg.cols);
    assert_eq!(u_dot.rows, n);
    assert_eq!(u_dot.cols, n);
    let dim = cb.dim();
    let groups = n.div_ceil(dim);
    let ut = u_dot.transpose();
    let rows = parallel_map(m, default_threads(), |i| {
        let w = wg.row(i);
        let mut what = vec![0.0f64; n];
        let mut err = vec![0.0f64; n];
        // acc[k] = feedback contribution from finished groups to col k.
        let mut acc = vec![0.0f64; n];
        let mut idxs = Vec::with_capacity(groups);
        let mut target = vec![0.0f64; dim];
        let mut k0 = 0;
        while k0 < n {
            let k1 = (k0 + dim).min(n);
            for k in k0..k1 {
                target[k - k0] = w[k] + acc[k];
            }
            idxs.push(cb.round_group(&target[..k1 - k0], &mut what[k0..k1]));
            for k in k0..k1 {
                err[k] = w[k] - what[k];
            }
            for k in k1..n {
                acc[k] += crate::linalg::matrix::dot(&err[k0..k1], &ut.row(k)[k0..k1]);
            }
            k0 = k1;
        }
        (what, idxs)
    });
    let mut codes = Vec::with_capacity(m);
    let mut indices = Vec::with_capacity(m * groups);
    for (what, idxs) in rows {
        codes.push(what);
        indices.extend(idxs);
    }
    (Mat::from_rows(&codes), indices)
}

/// Full vector-codebook LDLQ: factor H (UDUᵀ) and run
/// [`ldlq_vq_with_feedback`] with the LDL feedback.
pub fn ldlq_vq(wg: &Mat, h: &Mat, cb: &Codebook) -> (Mat, Vec<u64>) {
    let f = udu(h, 1e-12);
    ldlq_vq_with_feedback(wg, &f.strictly_upper(), cb)
}

/// Plain rounding (zero feedback) — the Near / Stoch baselines of §3.2.
pub fn round_matrix(wg: &Mat, bits: u32, mode: RoundMode, seed: u64) -> Mat {
    let root = Rng::new(seed);
    let rows = parallel_map(wg.rows, default_threads(), |i| {
        let mut rng = root.fork(i as u64);
        wg.row(i)
            .iter()
            .map(|&z| round_clamp(mode, z, bits, &mut rng))
            .collect::<Vec<f64>>()
    });
    Mat::from_rows(&rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::proxy::proxy_loss;
    use crate::util::testkit::{propcheck, random_mat, random_spd};

    /// Grid-space W with entries in [0, 2^b−1].
    fn grid_weights(rng: &mut Rng, m: usize, n: usize, bits: u32) -> Mat {
        let q = super::super::grid::levels(bits) as f64;
        Mat::from_fn(m, n, |_, _| rng.uniform(0.0, q))
    }
    use crate::util::rng::Rng;

    #[test]
    fn identity_h_reduces_to_nearest() {
        let mut rng = Rng::new(1);
        let wg = grid_weights(&mut rng, 4, 10, 4);
        let h = Mat::eye(10);
        let a = ldlq(&wg, &h, 4, RoundMode::Nearest, 0);
        let b = round_matrix(&wg, 4, RoundMode::Nearest, 0);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn codes_are_integers_in_range() {
        propcheck("ldlq-range", 10, |rng| {
            let bits = 2 + (rng.below(3) as u32); // 2..4
            let wg = grid_weights(rng, 5, 12, bits);
            let h = random_spd(rng, 12, 1e-2);
            let codes = ldlq(&wg, &h, bits, RoundMode::Nearest, 7);
            let q = super::super::grid::levels(bits) as f64;
            for &c in &codes.data {
                assert!(c >= 0.0 && c <= q && c == c.round());
            }
        });
    }

    #[test]
    fn ldlq_beats_nearest_on_correlated_h() {
        // Theorem 1: LDLQ proxy ≤ Near proxy (m/12 tr D vs m/12 tr H on
        // average). Check on random correlated Hessians.
        let mut wins = 0;
        let trials = 20;
        for t in 0..trials {
            let mut rng = Rng::new(100 + t);
            let wg = grid_weights(&mut rng, 8, 24, 2);
            let h = crate::util::testkit::random_hessian(&mut rng, 24, 6, 1e-3);
            let lq = ldlq(&wg, &h, 2, RoundMode::Nearest, t as u64);
            let nq = round_matrix(&wg, 2, RoundMode::Nearest, t as u64);
            let pl = proxy_loss(&lq, &wg, &h);
            let pn = proxy_loss(&nq, &wg, &h);
            if pl <= pn + 1e-12 {
                wins += 1;
            }
        }
        assert!(wins >= trials - 2, "LDLQ won only {wins}/{trials}");
    }

    #[test]
    fn average_proxy_matches_theorem1_rate() {
        // For W ~ Unif over the grid and H SPD, E proxy ≈ (m/12)·tr(D)
        // for nearest rounding (Theorem 1). Statistical check.
        let mut rng = Rng::new(42);
        let n = 16;
        let h = random_spd(&mut rng, n, 1e-2);
        let f = crate::linalg::ldl::udu(&h, 1e-12);
        let trd = f.trace_d();
        let m = 256;
        // Large grid (8 bits) so clamping never binds and we are in the
        // "rounding to integers" regime of the theorem.
        let wg = Mat::from_fn(m, n, |_, _| rng.uniform(64.0, 192.0));
        let codes = ldlq(&wg, &h, 8, RoundMode::Nearest, 3);
        let loss = proxy_loss(&codes, &wg, &h);
        let expected = m as f64 / 12.0 * trd;
        let ratio = loss / expected;
        assert!(
            (0.8..1.25).contains(&ratio),
            "loss={loss} expected≈{expected} ratio={ratio}"
        );
    }

    #[test]
    fn stochastic_average_rate_is_m_over_6() {
        let mut rng = Rng::new(43);
        let n = 16;
        let h = random_spd(&mut rng, n, 1e-2);
        let trd = crate::linalg::ldl::udu(&h, 1e-12).trace_d();
        let m = 256;
        let wg = Mat::from_fn(m, n, |_, _| rng.uniform(64.0, 192.0));
        let codes = ldlq(&wg, &h, 8, RoundMode::Stochastic, 4);
        let loss = proxy_loss(&codes, &wg, &h);
        let expected = m as f64 / 6.0 * trd;
        let ratio = loss / expected;
        assert!(
            (0.75..1.3).contains(&ratio),
            "loss={loss} expected≈{expected} ratio={ratio}"
        );
    }

    #[test]
    fn blocked_matches_unblocked() {
        propcheck("ldlq-blocked", 8, |rng| {
            let n = 10 + rng.below(40);
            let m = 3 + rng.below(6);
            let bits = 2 + rng.below(3) as u32;
            let wg = grid_weights(rng, m, n, bits);
            let h = random_spd(rng, n, 1e-2);
            let f = crate::linalg::ldl::udu(&h, 1e-12);
            let u = f.strictly_upper();
            let a = ldlq_with_feedback(&wg, &u, bits, RoundMode::Nearest, 0);
            for block in [1usize, 7, 16, 1000] {
                let b = super::ldlq_with_feedback_blocked(
                    &wg, &u, bits, RoundMode::Nearest, 0, block,
                );
                // Same codes up to summation-order ties: compare proxy.
                let pa = proxy_loss(&a, &wg, &h);
                let pb = proxy_loss(&b, &wg, &h);
                assert!(
                    (pa - pb).abs() <= 1e-6 * pa.max(1.0),
                    "block {block}: {pa} vs {pb}"
                );
            }
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(5);
        let wg = grid_weights(&mut rng, 3, 8, 2);
        let h = random_spd(&mut rng, 8, 1e-2);
        let a = ldlq(&wg, &h, 2, RoundMode::Stochastic, 9);
        let b = ldlq(&wg, &h, 2, RoundMode::Stochastic, 9);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn feedback_only_uses_preceding_columns() {
        // Changing column k of W must not change codes for columns < k.
        let mut rng = Rng::new(6);
        let wg = grid_weights(&mut rng, 2, 10, 3);
        let h = random_spd(&mut rng, 10, 1e-2);
        let base = ldlq(&wg, &h, 3, RoundMode::Nearest, 1);
        let mut w2 = wg.clone();
        w2[(0, 7)] += 1.0;
        let alt = ldlq(&w2, &h, 3, RoundMode::Nearest, 1);
        for j in 0..7 {
            assert_eq!(base[(0, j)], alt[(0, j)], "col {j} changed");
        }
        let _ = random_mat(&mut rng, 1, 1);
    }

    #[test]
    fn vq_identity_h_is_pure_group_rounding() {
        // With H = I the feedback vanishes and group-LDLQ reduces to
        // independent nearest-codeword rounding of each 8-group.
        let mut rng = Rng::new(21);
        let wg = grid_weights(&mut rng, 4, 24, 2);
        let cb = Codebook::e8(2, 9).unwrap();
        let (codes, indices) = ldlq_vq(&wg, &Mat::eye(24), &cb);
        assert_eq!(indices.len(), 4 * 3);
        for i in 0..4 {
            for g in 0..3 {
                let mut want = vec![0.0; 8];
                let idx = cb.round_group(&wg.row(i)[g * 8..(g + 1) * 8], &mut want);
                assert_eq!(idx, indices[i * 3 + g]);
                assert_eq!(&codes.row(i)[g * 8..(g + 1) * 8], &want[..]);
            }
        }
    }

    #[test]
    fn vq_indices_decode_to_codes() {
        // The returned indices are exactly the returned code values —
        // including a ragged last group (n = 20 → groups of 8, 8, 4).
        let mut rng = Rng::new(22);
        let wg = grid_weights(&mut rng, 5, 20, 2);
        let h = random_spd(&mut rng, 20, 1e-2);
        let cb = Codebook::e8(2, 3).unwrap();
        let (codes, indices) = ldlq_vq(&wg, &h, &cb);
        let gpr = 20usize.div_ceil(8);
        assert_eq!(indices.len(), 5 * gpr);
        for i in 0..5 {
            for g in 0..gpr {
                let r = (20 - g * 8).min(8);
                let mut vals = vec![0.0; r];
                cb.decode_group(indices[i * gpr + g], &mut vals);
                assert_eq!(&codes.row(i)[g * 8..g * 8 + r], &vals[..], "i={i} g={g}");
            }
        }
    }

    #[test]
    fn vq_deterministic_given_inputs() {
        let mut rng = Rng::new(23);
        let wg = grid_weights(&mut rng, 3, 16, 4);
        let h = random_spd(&mut rng, 16, 1e-2);
        let cb = Codebook::e8(4, 7).unwrap();
        let (a, ia) = ldlq_vq(&wg, &h, &cb);
        let (b, ib) = ldlq_vq(&wg, &h, &cb);
        assert_eq!(a.data, b.data);
        assert_eq!(ia, ib);
    }

    #[test]
    fn vq_beats_scalar_ldlq_on_gaussian_grid_weights() {
        // The lattice shaping gain (QuIP#): on Gaussian-ish grid-space
        // weights — the shape incoherence processing produces — the
        // 2-bit E8 codebook's proxy loss beats scalar LDLQ at the same
        // bitrate on most draws, and clearly on aggregate.
        let trials = 20;
        let mut wins = 0;
        let (mut total_vq, mut total_sc) = (0.0, 0.0);
        for t in 0..trials {
            let mut rng = Rng::new(300 + t);
            // center 1.5, σ ≈ 1.5/ρ as the Frobenius grid map yields.
            let wg = Mat::from_fn(8, 32, |_, _| 1.5 + (1.5 / 2.4) * rng.normal());
            let h = crate::util::testkit::random_hessian(&mut rng, 32, 8, 1e-3);
            let cb = Codebook::e8(2, t as u64).unwrap();
            let (vq_codes, _) = ldlq_vq(&wg, &h, &cb);
            let sc_codes = ldlq(&wg, &h, 2, RoundMode::Nearest, t as u64);
            let pv = proxy_loss(&vq_codes, &wg, &h);
            let ps = proxy_loss(&sc_codes, &wg, &h);
            total_vq += pv;
            total_sc += ps;
            if pv <= ps + 1e-12 {
                wins += 1;
            }
        }
        assert!(wins >= trials - 4, "vq won only {wins}/{trials}");
        assert!(
            total_vq < total_sc,
            "aggregate vq proxy {total_vq} not below scalar {total_sc}"
        );
    }
}
