//! The adaptive-rounding proxy objective (Eq. 1):
//! ℓ(Ŵ) = tr((Ŵ − W) H (Ŵ − W)ᵀ).

use crate::linalg::Mat;

/// tr((Ŵ − W) H (Ŵ − W)ᵀ) — both matrices in the *same* coordinate
/// system (grid or weight space; the caller is responsible for matching H).
pub fn proxy_loss(w_hat: &Mat, w: &Mat, h: &Mat) -> f64 {
    assert_eq!((w_hat.rows, w_hat.cols), (w.rows, w.cols));
    assert_eq!(h.rows, w.cols);
    let delta = w_hat.sub(w);
    // Σ_rows δ H δᵀ, computed as row·(H·rowᵀ) without forming ΔHΔᵀ.
    let dh = crate::linalg::gemm::matmul_bt(&delta, &h.transpose()); // Δ·H
    let mut total = 0.0;
    for i in 0..delta.rows {
        total += crate::linalg::matrix::dot(dh.row(i), delta.row(i));
    }
    total
}

/// Proxy loss for a single row delta (used by greedy updates' tests).
pub fn proxy_loss_row(delta: &[f64], h: &Mat) -> f64 {
    let hd = h.matvec(delta);
    crate::linalg::matrix::dot(delta, &hd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testkit::{assert_close, random_mat, random_spd};

    #[test]
    fn zero_delta_zero_loss() {
        let mut rng = Rng::new(1);
        let w = random_mat(&mut rng, 4, 6);
        let h = random_spd(&mut rng, 6, 1e-2);
        assert_eq!(proxy_loss(&w, &w, &h), 0.0);
    }

    #[test]
    fn matches_naive_trace() {
        let mut rng = Rng::new(2);
        let w = random_mat(&mut rng, 5, 7);
        let what = random_mat(&mut rng, 5, 7);
        let h = random_spd(&mut rng, 7, 1e-2);
        let delta = what.sub(&w);
        let naive = delta
            .matmul_naive(&h)
            .matmul_naive(&delta.transpose())
            .trace();
        assert_close(proxy_loss(&what, &w, &h), naive, 1e-9);
    }

    #[test]
    fn nonnegative_for_psd_h() {
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            let w = random_mat(&mut rng, 3, 9);
            let what = random_mat(&mut rng, 3, 9);
            let h = crate::util::testkit::random_low_rank_psd(&mut rng, 9, 2);
            assert!(proxy_loss(&what, &w, &h) >= -1e-10);
        }
    }

    #[test]
    fn row_version_sums_to_total() {
        let mut rng = Rng::new(4);
        let w = random_mat(&mut rng, 4, 5);
        let what = random_mat(&mut rng, 4, 5);
        let h = random_spd(&mut rng, 5, 1e-2);
        let total = proxy_loss(&what, &w, &h);
        let mut sum = 0.0;
        for i in 0..4 {
            let delta: Vec<f64> = what
                .row(i)
                .iter()
                .zip(w.row(i))
                .map(|(a, b)| a - b)
                .collect();
            sum += proxy_loss_row(&delta, &h);
        }
        assert_close(total, sum, 1e-9);
    }
}
