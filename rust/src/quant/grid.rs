//! b-bit quantization grids, the affine maps into/out of grid
//! coordinates, and the [`Codebook`] abstraction that rounding targets
//! plug into.
//!
//! Two layers live here:
//!
//! * [`GridMap`] — the affine map between real weights and *grid
//!   coordinates* (per-row min-max, or QuIP's Frobenius-based symmetric
//!   global range). Processing decides how real weights map onto the
//!   grid; rounders work entirely in grid space.
//! * [`Codebook`] — what a rounder rounds *to* once it is in grid space:
//!   either the scalar integer grid {0, …, 2^b − 1} (one code per
//!   weight), or an E8-style 8-dimensional vector codebook (one index
//!   per [`VQ_GROUP`]-wide group of weights, QuIP#'s lattice-codebook
//!   idea). Both sit behind the same `round_group`/`decode_group`
//!   interface, so quantizer code is codebook-agnostic.
//!
//! The E8-style construction, nearest-neighbor search and index layout
//! are documented in DESIGN.md §6; the `.qz` v3 storage of codebook
//! indices is in [`super::packed`].

use crate::linalg::Mat;
use crate::util::rng::splitmix64;

/// Number of grid levels for b bits.
pub fn levels(bits: u32) -> u32 {
    assert!((1..=8).contains(&bits), "bits must be in 1..=8");
    (1u32 << bits) - 1
}

/// Clamp a grid-space value into [0, 2^b − 1].
#[inline]
pub fn clamp_grid(x: f64, bits: u32) -> f64 {
    x.clamp(0.0, levels(bits) as f64)
}

/// How real-valued weights map to grid coordinates.
#[derive(Clone, Debug)]
pub enum GridMap {
    /// Per-row asymmetric min-max (the OPTQ-style baseline):
    /// g = (w − lo_i)/(hi_i − lo_i) · (2^b − 1).
    PerRow { lo: Vec<f64>, hi: Vec<f64>, bits: u32 },
    /// QuIP's incoherence-based symmetric global range (Alg 1 line 6):
    /// g = ((w/s) + 1)/2 · (2^b − 1) with s = ρ‖W‖_F/√(mn).
    Global { s: f64, bits: u32 },
}

impl GridMap {
    /// Fit a per-row min-max map to W.
    pub fn fit_per_row(w: &Mat, bits: u32) -> GridMap {
        let mut lo = Vec::with_capacity(w.rows);
        let mut hi = Vec::with_capacity(w.rows);
        for i in 0..w.rows {
            let row = w.row(i);
            let mut l = f64::INFINITY;
            let mut h = f64::NEG_INFINITY;
            for &x in row {
                l = l.min(x);
                h = h.max(x);
            }
            if !l.is_finite() || !h.is_finite() || h - l < 1e-30 {
                // Degenerate row (constant): pick any non-empty range.
                l = l.min(0.0) - 0.5;
                h = h.max(0.0) + 0.5;
            }
            lo.push(l);
            hi.push(h);
        }
        GridMap::PerRow { lo, hi, bits }
    }

    /// Fit QuIP's global Frobenius-based map: s = ρ‖W‖_F/√(mn).
    pub fn fit_global(w: &Mat, bits: u32, rho: f64) -> GridMap {
        let s = rho * w.frob_norm() / ((w.rows * w.cols) as f64).sqrt();
        let s = if s > 1e-30 { s } else { 1.0 };
        GridMap::Global { s, bits }
    }

    pub fn bits(&self) -> u32 {
        match self {
            GridMap::PerRow { bits, .. } | GridMap::Global { bits, .. } => *bits,
        }
    }

    /// Map weights to (continuous) grid coordinates. No clamping — the
    /// rounding step clamps (the clamp is exactly the finite-grid issue
    /// §5.2 studies).
    pub fn to_grid(&self, w: &Mat) -> Mat {
        let q = levels(self.bits()) as f64;
        match self {
            GridMap::PerRow { lo, hi, .. } => {
                let mut g = w.clone();
                for i in 0..w.rows {
                    let (l, h) = (lo[i], hi[i]);
                    let inv = q / (h - l);
                    for x in g.row_mut(i) {
                        *x = (*x - l) * inv;
                    }
                }
                g
            }
            GridMap::Global { s, .. } => {
                let mut g = w.clone();
                for x in g.data.iter_mut() {
                    *x = ((*x / s) + 1.0) * 0.5 * q;
                }
                g
            }
        }
    }

    /// Map (integer) grid codes back to real weights (Alg 2 line 2).
    pub fn from_grid(&self, g: &Mat) -> Mat {
        let q = levels(self.bits()) as f64;
        match self {
            GridMap::PerRow { lo, hi, .. } => {
                let mut w = g.clone();
                for i in 0..w.rows {
                    let (l, h) = (lo[i], hi[i]);
                    let scale = (h - l) / q;
                    for x in w.row_mut(i) {
                        *x = *x * scale + l;
                    }
                }
                w
            }
            GridMap::Global { s, .. } => {
                let mut w = g.clone();
                for x in w.data.iter_mut() {
                    *x = s * ((*x / q) * 2.0 - 1.0);
                }
                w
            }
        }
    }

    /// Per-row scale factor grid→real (the Jacobian of `from_grid`); used
    /// to map grid-space proxy losses back to weight space.
    pub fn row_scale(&self, i: usize) -> f64 {
        let q = levels(self.bits()) as f64;
        match self {
            GridMap::PerRow { lo, hi, .. } => (hi[i] - lo[i]) / q,
            GridMap::Global { s, .. } => 2.0 * s / q,
        }
    }

    pub fn serialize(&self, w: &mut crate::util::bytes::Writer) {
        match self {
            GridMap::PerRow { lo, hi, bits } => {
                w.u8(0);
                w.u32(*bits);
                w.f64s(lo);
                w.f64s(hi);
            }
            GridMap::Global { s, bits } => {
                w.u8(1);
                w.u32(*bits);
                w.f64(*s);
            }
        }
    }

    pub fn deserialize(r: &mut crate::util::bytes::Reader) -> crate::Result<GridMap> {
        match r.u8()? {
            0 => {
                let bits = r.u32()?;
                let lo = r.f64s()?;
                let hi = r.f64s()?;
                Ok(GridMap::PerRow { lo, hi, bits })
            }
            1 => {
                let bits = r.u32()?;
                let s = r.f64()?;
                Ok(GridMap::Global { s, bits })
            }
            t => anyhow::bail!("unknown GridMap tag {t}"),
        }
    }
}

/// Number of weights covered by one vector-codebook index: the codebook
/// dimension of the E8-style construction (QuIP# quantizes in groups of
/// 8 along the LDLQ column order).
pub const VQ_GROUP: usize = 8;

/// Base codewords in the E8-style codebook: 8 index bits select one of
/// 256 nonnegative magnitude vectors; 8 more flip per-coordinate signs.
const E8_BASE: usize = 256;

/// Derive the codebook-construction seed from a layer's quantization
/// seed. Shared by the `vq` rounder (which builds the codebook it rounds
/// against) and the pipeline's artifact packing (which records the same
/// seed in the `.qz` v3 layer so decode regenerates the codebook).
pub fn codebook_seed(layer_seed: u64) -> u64 {
    layer_seed ^ 0x4538_5F43_4F44_4245 // "E8_CODBE"
}

/// Enumerate the seeded E8-style base table: the [`E8_BASE`] lowest-norm
/// vectors with coordinates in {0.5, 1.5, 2.5, 3.5} whose integer parts
/// sum to an even number (the D8 parity constraint that gives the E8
/// lattice its packing gain — see DESIGN.md §6). `seed` breaks norm ties
/// deterministically, so equal-norm orbit members are cut reproducibly.
fn e8_base_table(seed: u64) -> Vec<f64> {
    // 4^8 = 65536 candidate integer-part vectors; the parity constraint
    // keeps 32768. Norm key in quarter units: Σ (2p_j + 1)².
    let mut cands: Vec<(u32, u64, u16)> = Vec::with_capacity(32768);
    for code in 0u32..(1 << 16) {
        let mut sum = 0u32;
        let mut norm = 0u32;
        for j in 0..VQ_GROUP {
            let p = (code >> (2 * j)) & 3;
            sum += p;
            norm += (2 * p + 1) * (2 * p + 1);
        }
        if sum % 2 == 0 {
            let mut s = seed ^ (code as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let tie = splitmix64(&mut s);
            cands.push((norm, tie, code as u16));
        }
    }
    cands.sort_unstable();
    let mut base = Vec::with_capacity(E8_BASE * VQ_GROUP);
    for &(_, _, code) in cands.iter().take(E8_BASE) {
        for j in 0..VQ_GROUP {
            let p = (code >> (2 * j)) & 3;
            base.push(p as f64 + 0.5);
        }
    }
    base
}

/// What a rounder rounds to in grid space: the scalar integer grid, or a
/// seeded E8-style vector codebook. One `round_group` call quantizes
/// [`Codebook::dim`] consecutive grid-space values to the nearest
/// representable point and returns the packed index
/// ([`Codebook::index_bits`] wide) that [`Codebook::decode_group`]
/// expands back.
///
/// # E8-style construction
///
/// At `b` bits per weight the vector codebook spends `8·b` index bits
/// per 8-wide group, in `b/2` residual stages of 16 bits each. A stage
/// word is `(base << 8) | signs`: 8 sign bits (bit j set ⇒ coordinate j
/// negative) and 8 bits selecting one of 256 nonnegative half-integer
/// base vectors (seeded lowest-norm shell of the D8+½ coset — see
/// [`codebook_seed`] / DESIGN.md §6). Stage `s` (coarsest first, stored
/// at index bits `[16·s, 16·s+16)`) contributes
/// `(2^b−1)/3 · 4^(−s) ×` its codeword — the coarsest stage spans the
/// grid half-range (scale exactly 1 at 2 bits), each deeper stage
/// refines 4× — and the sum, recentered on the grid midpoint, is the
/// decoded grid-space value. Nearest-neighbor search is exact per
/// stage: signs fold the target into the nonnegative orthant (valid
/// because every base coordinate is ≥ 0.5), then a 256-entry scan picks
/// the base vector.
///
/// Decoded values are *grid-space reals*, not integers: the codebook can
/// place mass outside [0, 2^b − 1] for isolated outlier coordinates
/// while the parity constraint prunes improbable combinations — that is
/// the lattice shaping gain over the scalar grid at equal bitrate.
#[derive(Clone, Debug)]
pub enum Codebook {
    /// The scalar integer grid {0, …, 2^b − 1}: `dim` 1, nearest-with-
    /// clamp rounding — the existing grids behind the common interface.
    Scalar { bits: u32 },
    /// The seeded E8-style vector codebook described above.
    E8 {
        bits: u32,
        seed: u64,
        /// Residual stages = bits/2 (each stage spends 16 index bits).
        stages: u32,
        /// 256 × [`VQ_GROUP`] nonnegative magnitudes, flattened.
        base: Vec<f64>,
    },
}

impl Codebook {
    /// The scalar integer grid at `bits` (nearest rounding + clamp —
    /// exactly [`super::rounding::round_clamp`] with `Nearest`).
    pub fn scalar(bits: u32) -> Codebook {
        let _ = levels(bits); // validate 1..=8
        Codebook::Scalar { bits }
    }

    /// Seeded E8-style vector codebook. Even bit widths 2–8 only: each
    /// 16-bit residual stage spends 2 bits/weight across the 8-group.
    pub fn e8(bits: u32, seed: u64) -> crate::Result<Codebook> {
        anyhow::ensure!(
            bits % 2 == 0 && (2..=8).contains(&bits),
            "vector codebook supports even bit widths 2-8 \
             (16 index bits per residual stage across an 8-group), got {bits}"
        );
        Ok(Codebook::E8 {
            bits,
            seed,
            stages: bits / 2,
            base: e8_base_table(seed),
        })
    }

    /// Weights covered per index: 1 (scalar) or [`VQ_GROUP`].
    pub fn dim(&self) -> usize {
        match self {
            Codebook::Scalar { .. } => 1,
            Codebook::E8 { .. } => VQ_GROUP,
        }
    }

    pub fn bits(&self) -> u32 {
        match self {
            Codebook::Scalar { bits } | Codebook::E8 { bits, .. } => *bits,
        }
    }

    /// Construction seed (0 for the unseeded scalar grid).
    pub fn seed(&self) -> u64 {
        match self {
            Codebook::Scalar { .. } => 0,
            Codebook::E8 { seed, .. } => *seed,
        }
    }

    /// Index width per group: `bits · dim` — both variants spend exactly
    /// `bits` per weight (equal bitrate by construction).
    pub fn index_bits(&self) -> u32 {
        self.bits() * self.dim() as u32
    }

    /// Grid midpoint (2^b − 1)/2 — the E8 codebook is centered here.
    pub fn center(&self) -> f64 {
        levels(self.bits()) as f64 / 2.0
    }

    /// Coarsest-stage scale `(2^b − 1)/3`: normalizes the base shell
    /// (reach ±3.5) to the grid half-range, so every bit width sees
    /// 2-bit-shaped targets at stage 0 and each deeper stage refines 4×.
    /// Exactly 1 at 2 bits — and an exact dyadic×integer value at every
    /// even width (5, 21, 85), so decoded values stay exact in f32.
    fn stage0_scale(&self) -> f64 {
        levels(self.bits()) as f64 / 3.0
    }

    /// Quantize `target` (grid-space, `len ≤ dim`; shorter only for a
    /// layer's ragged last group) to the nearest representable point.
    /// Writes the decoded grid-space values to `out` and returns the
    /// group index. Deterministic: NN ties break to the lowest base
    /// index, zero coordinates fold to positive sign.
    pub fn round_group(&self, target: &[f64], out: &mut [f64]) -> u64 {
        assert_eq!(target.len(), out.len());
        match self {
            Codebook::Scalar { bits } => {
                assert_eq!(target.len(), 1, "scalar codebook rounds one value");
                let q = clamp_grid(target[0].round(), *bits);
                out[0] = q;
                q as u64
            }
            Codebook::E8 { stages, base, .. } => {
                let r = target.len();
                assert!((1..=VQ_GROUP).contains(&r), "group of {r} exceeds dim 8");
                let c = self.center();
                let scale0 = self.stage0_scale();
                let mut resid = [0.0f64; VQ_GROUP];
                for j in 0..r {
                    resid[j] = target[j] - c;
                }
                let mut decoded = [0.0f64; VQ_GROUP];
                let mut idx = 0u64;
                for s in 0..*stages {
                    let scale = scale0 / 4f64.powi(s as i32);
                    // Fold into the nonnegative orthant; record signs.
                    let mut fold = [0.0f64; VQ_GROUP];
                    let mut signs = 0u64;
                    for j in 0..r {
                        let v = resid[j] / scale;
                        if v < 0.0 {
                            signs |= 1 << j;
                            fold[j] = -v;
                        } else {
                            fold[j] = v;
                        }
                    }
                    // Exact NN over the base shell (first r coords).
                    let mut best = 0usize;
                    let mut best_d = f64::INFINITY;
                    for e in 0..E8_BASE {
                        let row = &base[e * VQ_GROUP..e * VQ_GROUP + r];
                        let mut d = 0.0;
                        for j in 0..r {
                            let t = fold[j] - row[j];
                            d += t * t;
                        }
                        if d < best_d {
                            best_d = d;
                            best = e;
                        }
                    }
                    idx |= (((best as u64) << 8) | signs) << (16 * s);
                    for j in 0..r {
                        let mag = base[best * VQ_GROUP + j];
                        let v = if (signs >> j) & 1 == 1 { -mag } else { mag };
                        decoded[j] += scale * v;
                        resid[j] -= scale * v;
                    }
                }
                for j in 0..r {
                    out[j] = c + decoded[j];
                }
                idx
            }
        }
    }

    /// Expand a group index back to grid-space values (`out.len() ≤ dim`;
    /// shorter only for a ragged last group).
    pub fn decode_group(&self, idx: u64, out: &mut [f64]) {
        match self {
            Codebook::Scalar { bits } => {
                assert_eq!(out.len(), 1);
                out[0] = clamp_grid(idx as f64, *bits);
            }
            Codebook::E8 { stages, base, .. } => {
                let r = out.len();
                assert!((1..=VQ_GROUP).contains(&r));
                let c = self.center();
                let scale0 = self.stage0_scale();
                for (j, o) in out.iter_mut().enumerate() {
                    let mut acc = c;
                    for s in 0..*stages {
                        let word = (idx >> (16 * s)) & 0xFFFF;
                        let mag = base[((word >> 8) as usize & 0xFF) * VQ_GROUP + j];
                        let scale = scale0 / 4f64.powi(s as i32);
                        acc += if (word >> j) & 1 == 1 { -scale * mag } else { scale * mag };
                    }
                    *o = acc;
                }
            }
        }
    }

    /// The f32 decode table for the engine hot path: `None` for the
    /// scalar grid (codes decode through the bit-unpack kernels), the
    /// per-layer LUT for E8 layers.
    pub fn lut_f32(&self) -> Option<VqLut> {
        match self {
            Codebook::Scalar { .. } => None,
            Codebook::E8 { stages, base, .. } => Some(VqLut {
                base: base.iter().map(|&x| x as f32).collect(),
                scales: (0..*stages)
                    .map(|s| (self.stage0_scale() / 4f64.powi(s as i32)) as f32)
                    .collect(),
                center: self.center() as f32,
            }),
        }
    }
}

/// Per-layer f32 expansion table for E8 indices: the 256×8 base
/// magnitudes plus stage scales and the grid center. Built once per
/// [`Codebook`] by [`Codebook::lut_f32`]; `decode` is the allocation-free
/// inner step of the engine's fused decode kernels.
#[derive(Clone, Debug)]
pub struct VqLut {
    base: Vec<f32>,
    /// One scale per residual stage, coarsest first.
    scales: Vec<f32>,
    center: f32,
}

impl VqLut {
    /// Expand one group index into grid-space f32 values
    /// (`out.len() ≤ 8`; shorter only for a ragged last group).
    #[inline]
    pub fn decode(&self, idx: u64, out: &mut [f32]) {
        for (j, o) in out.iter_mut().enumerate() {
            let mut acc = self.center;
            for (s, &scale) in self.scales.iter().enumerate() {
                let word = (idx >> (16 * s)) & 0xFFFF;
                let mag = self.base[((word >> 8) as usize & 0xFF) * VQ_GROUP + j];
                acc += if (word >> j) & 1 == 1 { -scale * mag } else { scale * mag };
            }
            *o = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testkit::{propcheck, random_mat};

    #[test]
    fn levels_values() {
        assert_eq!(levels(2), 3);
        assert_eq!(levels(3), 7);
        assert_eq!(levels(4), 15);
    }

    #[test]
    fn per_row_to_from_inverse_on_grid_points() {
        propcheck("grid-perrow-inv", 20, |rng| {
            let w = random_mat(rng, 4, 9);
            for bits in [2u32, 3, 4] {
                let g = GridMap::fit_per_row(&w, bits);
                let wg = g.to_grid(&w);
                let back = g.from_grid(&wg);
                for (a, b) in back.data.iter().zip(&w.data) {
                    assert!((a - b).abs() < 1e-10);
                }
            }
        });
    }

    #[test]
    fn global_map_round_trip() {
        propcheck("grid-global-inv", 20, |rng| {
            let w = random_mat(rng, 5, 8);
            let g = GridMap::fit_global(&w, 4, 2.4);
            let back = g.from_grid(&g.to_grid(&w));
            for (a, b) in back.data.iter().zip(&w.data) {
                assert!((a - b).abs() < 1e-10);
            }
        });
    }

    #[test]
    fn per_row_stays_in_range_after_round() {
        let mut rng = Rng::new(3);
        let w = random_mat(&mut rng, 6, 12);
        let g = GridMap::fit_per_row(&w, 2);
        let wg = g.to_grid(&w);
        for &x in &wg.data {
            assert!(x >= -1e-9 && x <= 3.0 + 1e-9);
        }
    }

    #[test]
    fn constant_row_does_not_blow_up() {
        let w = Mat::from_fn(2, 4, |i, _| i as f64); // row 0 all zeros
        let g = GridMap::fit_per_row(&w, 4);
        let wg = g.to_grid(&w);
        assert!(wg.data.iter().all(|x| x.is_finite()));
        let back = g.from_grid(&wg);
        for (a, b) in back.data.iter().zip(&w.data) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn serialize_roundtrip() {
        let mut rng = Rng::new(4);
        let w = random_mat(&mut rng, 3, 5);
        for g in [GridMap::fit_per_row(&w, 3), GridMap::fit_global(&w, 2, 2.4)] {
            let mut buf = crate::util::bytes::Writer::new();
            g.serialize(&mut buf);
            let mut r = crate::util::bytes::Reader::new(&buf.buf);
            let g2 = GridMap::deserialize(&mut r).unwrap();
            let wg1 = g.to_grid(&w);
            let wg2 = g2.to_grid(&w);
            assert_eq!(wg1.data, wg2.data);
        }
    }

    #[test]
    fn e8_base_has_parity_structure() {
        let cb = Codebook::e8(2, 7).unwrap();
        let Codebook::E8 { base, .. } = &cb else {
            panic!("e8 constructor returned scalar")
        };
        assert_eq!(base.len(), 256 * VQ_GROUP);
        for e in 0..256 {
            let row = &base[e * VQ_GROUP..(e + 1) * VQ_GROUP];
            let mut int_sum = 0i64;
            for &x in row {
                // Every coordinate is a positive half-integer ≤ 3.5.
                assert!((0.5..=3.5).contains(&x) && (2.0 * x) == (2.0 * x).round());
                int_sum += (x - 0.5) as i64;
            }
            assert_eq!(int_sum % 2, 0, "entry {e} breaks the D8 parity constraint");
        }
        // Sorted by norm: the first entry is the all-½ vector.
        assert!(base[..VQ_GROUP].iter().all(|&x| x == 0.5));
    }

    #[test]
    fn codebook_is_seed_deterministic() {
        let a = Codebook::e8(2, 42).unwrap();
        let b = Codebook::e8(2, 42).unwrap();
        let (Codebook::E8 { base: ba, .. }, Codebook::E8 { base: bb, .. }) = (&a, &b) else {
            unreachable!()
        };
        assert_eq!(ba, bb);
        // The low-norm shell below the tie-broken cut is seed-independent.
        let c = Codebook::e8(2, 43).unwrap();
        let Codebook::E8 { base: bc, .. } = &c else { unreachable!() };
        assert_eq!(&ba[..VQ_GROUP], &bc[..VQ_GROUP]);
    }

    #[test]
    fn odd_or_out_of_range_bits_rejected() {
        for bits in [1u32, 3, 5, 7] {
            assert!(Codebook::e8(bits, 0).is_err(), "bits={bits}");
        }
        for bits in [2u32, 4, 6, 8] {
            let cb = Codebook::e8(bits, 0).unwrap();
            assert_eq!(cb.index_bits(), 8 * bits);
            assert_eq!(cb.dim(), VQ_GROUP);
        }
    }

    #[test]
    fn round_decode_group_roundtrips() {
        // decode(round(t)) must reproduce exactly the values round wrote.
        let mut rng = Rng::new(9);
        for bits in [2u32, 4] {
            let cb = Codebook::e8(bits, 5).unwrap();
            for _ in 0..200 {
                let t: Vec<f64> = (0..8)
                    .map(|_| rng.uniform(-1.0, levels(bits) as f64 + 1.0))
                    .collect();
                let mut out = vec![0.0; 8];
                let idx = cb.round_group(&t, &mut out);
                assert!(cb.index_bits() == 64 || idx < 1u64 << cb.index_bits());
                let mut back = vec![0.0; 8];
                cb.decode_group(idx, &mut back);
                assert_eq!(out, back);
                // Single-stage only: re-rounding a codebook point is a
                // fixed point (distance 0 to itself). Multi-stage greedy
                // residual search is not idempotent in general.
                if bits == 2 {
                    let mut again = vec![0.0; 8];
                    let idx2 = cb.round_group(&out, &mut again);
                    assert_eq!(idx2, idx);
                    assert_eq!(again, out);
                }
            }
        }
    }

    #[test]
    fn nn_is_no_worse_than_random_codewords() {
        // round_group must return a point at least as close as any other
        // codebook point (spot-checked against random indices).
        let mut rng = Rng::new(11);
        let cb = Codebook::e8(2, 3).unwrap();
        for _ in 0..50 {
            let t: Vec<f64> = (0..8).map(|_| rng.uniform(-0.5, 3.5)).collect();
            let mut got = vec![0.0; 8];
            cb.round_group(&t, &mut got);
            let d_got: f64 = t.iter().zip(&got).map(|(a, b)| (a - b) * (a - b)).sum();
            for _ in 0..100 {
                let idx = (rng.below(1 << 16)) as u64;
                let mut other = vec![0.0; 8];
                cb.decode_group(idx, &mut other);
                let d: f64 = t.iter().zip(&other).map(|(a, b)| (a - b) * (a - b)).sum();
                assert!(d_got <= d + 1e-12, "NN missed: {d_got} vs {d}");
            }
        }
    }

    #[test]
    fn four_bit_residual_refines_two_bit() {
        // Each codebook is evaluated on its own grid scale (targets
        // centered on its midpoint with σ = half-range/ρ, the shape the
        // Frobenius grid map produces); the *relative* error — MSE over
        // target variance — must drop sharply with the extra residual
        // stage (per-coordinate step (2^b−1)/3·4^(1−b/2)·1 vs grid span).
        let mut rng = Rng::new(13);
        let mut rel = Vec::new();
        for bits in [2u32, 4] {
            let cb = Codebook::e8(bits, 1).unwrap();
            let c = cb.center();
            let sigma = c / 2.4;
            let (mut err, mut var) = (0.0, 0.0);
            for _ in 0..200 {
                let t: Vec<f64> = (0..8).map(|_| c + sigma * rng.normal()).collect();
                let mut out = vec![0.0; 8];
                cb.round_group(&t, &mut out);
                err += t.iter().zip(&out).map(|(a, b)| (a - b) * (a - b)).sum::<f64>();
                var += t.iter().map(|a| (a - c) * (a - c)).sum::<f64>();
            }
            rel.push(err / var);
        }
        assert!(
            rel[1] < rel[0] * 0.25,
            "4-bit residual stage barely helped: rel {rel:?}"
        );
    }

    #[test]
    fn scalar_codebook_matches_round_clamp() {
        let mut rng = Rng::new(15);
        for bits in [2u32, 3, 4] {
            let cb = Codebook::scalar(bits);
            assert_eq!(cb.dim(), 1);
            assert_eq!(cb.index_bits(), bits);
            for _ in 0..100 {
                let t = rng.uniform(-2.0, levels(bits) as f64 + 2.0);
                let mut out = [0.0];
                let idx = cb.round_group(&[t], &mut out);
                let want = crate::quant::rounding::round_clamp(
                    crate::quant::rounding::RoundMode::Nearest,
                    t,
                    bits,
                    &mut Rng::new(0),
                );
                assert_eq!(out[0], want);
                assert_eq!(idx, want as u64);
                let mut back = [0.0];
                cb.decode_group(idx, &mut back);
                assert_eq!(back[0], want);
            }
        }
    }

    #[test]
    fn ragged_group_uses_leading_coords() {
        let cb = Codebook::e8(2, 21).unwrap();
        let mut rng = Rng::new(17);
        for r in 1..=7usize {
            let t: Vec<f64> = (0..r).map(|_| rng.uniform(0.0, 3.0)).collect();
            let mut out = vec![0.0; r];
            let idx = cb.round_group(&t, &mut out);
            let mut back = vec![0.0; r];
            cb.decode_group(idx, &mut back);
            assert_eq!(out, back, "r={r}");
            // Ragged-group signs beyond r are canonical zero.
            assert_eq!((idx & 0xFF) >> r, 0, "r={r}: stray sign bits");
        }
    }

    #[test]
    fn lut_matches_f64_decode() {
        let mut rng = Rng::new(19);
        for bits in [2u32, 4] {
            let cb = Codebook::e8(bits, 77).unwrap();
            let lut = cb.lut_f32().unwrap();
            for _ in 0..100 {
                let t: Vec<f64> = (0..8).map(|_| rng.uniform(-1.0, 4.0)).collect();
                let mut out = vec![0.0; 8];
                let idx = cb.round_group(&t, &mut out);
                let mut f = vec![0.0f32; 8];
                lut.decode(idx, &mut f);
                for (a, b) in f.iter().zip(&out) {
                    // Half-integer sums at these magnitudes are exact in f32.
                    assert_eq!(*a as f64, *b);
                }
            }
        }
        assert!(Codebook::scalar(2).lut_f32().is_none());
    }
}
