//! b-bit quantization grids and the affine maps into/out of grid
//! coordinates. LDLQ and friends always round to the integer grid
//! {0, …, 2^b − 1}; processing decides how real weights map onto it.

use crate::linalg::Mat;

/// Number of grid levels for b bits.
pub fn levels(bits: u32) -> u32 {
    assert!((1..=8).contains(&bits), "bits must be in 1..=8");
    (1u32 << bits) - 1
}

/// Clamp a grid-space value into [0, 2^b − 1].
#[inline]
pub fn clamp_grid(x: f64, bits: u32) -> f64 {
    x.clamp(0.0, levels(bits) as f64)
}

/// How real-valued weights map to grid coordinates.
#[derive(Clone, Debug)]
pub enum GridMap {
    /// Per-row asymmetric min-max (the OPTQ-style baseline):
    /// g = (w − lo_i)/(hi_i − lo_i) · (2^b − 1).
    PerRow { lo: Vec<f64>, hi: Vec<f64>, bits: u32 },
    /// QuIP's incoherence-based symmetric global range (Alg 1 line 6):
    /// g = ((w/s) + 1)/2 · (2^b − 1) with s = ρ‖W‖_F/√(mn).
    Global { s: f64, bits: u32 },
}

impl GridMap {
    /// Fit a per-row min-max map to W.
    pub fn fit_per_row(w: &Mat, bits: u32) -> GridMap {
        let mut lo = Vec::with_capacity(w.rows);
        let mut hi = Vec::with_capacity(w.rows);
        for i in 0..w.rows {
            let row = w.row(i);
            let mut l = f64::INFINITY;
            let mut h = f64::NEG_INFINITY;
            for &x in row {
                l = l.min(x);
                h = h.max(x);
            }
            if !l.is_finite() || !h.is_finite() || h - l < 1e-30 {
                // Degenerate row (constant): pick any non-empty range.
                l = l.min(0.0) - 0.5;
                h = h.max(0.0) + 0.5;
            }
            lo.push(l);
            hi.push(h);
        }
        GridMap::PerRow { lo, hi, bits }
    }

    /// Fit QuIP's global Frobenius-based map: s = ρ‖W‖_F/√(mn).
    pub fn fit_global(w: &Mat, bits: u32, rho: f64) -> GridMap {
        let s = rho * w.frob_norm() / ((w.rows * w.cols) as f64).sqrt();
        let s = if s > 1e-30 { s } else { 1.0 };
        GridMap::Global { s, bits }
    }

    pub fn bits(&self) -> u32 {
        match self {
            GridMap::PerRow { bits, .. } | GridMap::Global { bits, .. } => *bits,
        }
    }

    /// Map weights to (continuous) grid coordinates. No clamping — the
    /// rounding step clamps (the clamp is exactly the finite-grid issue
    /// §5.2 studies).
    pub fn to_grid(&self, w: &Mat) -> Mat {
        let q = levels(self.bits()) as f64;
        match self {
            GridMap::PerRow { lo, hi, .. } => {
                let mut g = w.clone();
                for i in 0..w.rows {
                    let (l, h) = (lo[i], hi[i]);
                    let inv = q / (h - l);
                    for x in g.row_mut(i) {
                        *x = (*x - l) * inv;
                    }
                }
                g
            }
            GridMap::Global { s, .. } => {
                let mut g = w.clone();
                for x in g.data.iter_mut() {
                    *x = ((*x / s) + 1.0) * 0.5 * q;
                }
                g
            }
        }
    }

    /// Map (integer) grid codes back to real weights (Alg 2 line 2).
    pub fn from_grid(&self, g: &Mat) -> Mat {
        let q = levels(self.bits()) as f64;
        match self {
            GridMap::PerRow { lo, hi, .. } => {
                let mut w = g.clone();
                for i in 0..w.rows {
                    let (l, h) = (lo[i], hi[i]);
                    let scale = (h - l) / q;
                    for x in w.row_mut(i) {
                        *x = *x * scale + l;
                    }
                }
                w
            }
            GridMap::Global { s, .. } => {
                let mut w = g.clone();
                for x in w.data.iter_mut() {
                    *x = s * ((*x / q) * 2.0 - 1.0);
                }
                w
            }
        }
    }

    /// Per-row scale factor grid→real (the Jacobian of `from_grid`); used
    /// to map grid-space proxy losses back to weight space.
    pub fn row_scale(&self, i: usize) -> f64 {
        let q = levels(self.bits()) as f64;
        match self {
            GridMap::PerRow { lo, hi, .. } => (hi[i] - lo[i]) / q,
            GridMap::Global { s, .. } => 2.0 * s / q,
        }
    }

    pub fn serialize(&self, w: &mut crate::util::bytes::Writer) {
        match self {
            GridMap::PerRow { lo, hi, bits } => {
                w.u8(0);
                w.u32(*bits);
                w.f64s(lo);
                w.f64s(hi);
            }
            GridMap::Global { s, bits } => {
                w.u8(1);
                w.u32(*bits);
                w.f64(*s);
            }
        }
    }

    pub fn deserialize(r: &mut crate::util::bytes::Reader) -> crate::Result<GridMap> {
        match r.u8()? {
            0 => {
                let bits = r.u32()?;
                let lo = r.f64s()?;
                let hi = r.f64s()?;
                Ok(GridMap::PerRow { lo, hi, bits })
            }
            1 => {
                let bits = r.u32()?;
                let s = r.f64()?;
                Ok(GridMap::Global { s, bits })
            }
            t => anyhow::bail!("unknown GridMap tag {t}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testkit::{propcheck, random_mat};

    #[test]
    fn levels_values() {
        assert_eq!(levels(2), 3);
        assert_eq!(levels(3), 7);
        assert_eq!(levels(4), 15);
    }

    #[test]
    fn per_row_to_from_inverse_on_grid_points() {
        propcheck("grid-perrow-inv", 20, |rng| {
            let w = random_mat(rng, 4, 9);
            for bits in [2u32, 3, 4] {
                let g = GridMap::fit_per_row(&w, bits);
                let wg = g.to_grid(&w);
                let back = g.from_grid(&wg);
                for (a, b) in back.data.iter().zip(&w.data) {
                    assert!((a - b).abs() < 1e-10);
                }
            }
        });
    }

    #[test]
    fn global_map_round_trip() {
        propcheck("grid-global-inv", 20, |rng| {
            let w = random_mat(rng, 5, 8);
            let g = GridMap::fit_global(&w, 4, 2.4);
            let back = g.from_grid(&g.to_grid(&w));
            for (a, b) in back.data.iter().zip(&w.data) {
                assert!((a - b).abs() < 1e-10);
            }
        });
    }

    #[test]
    fn per_row_stays_in_range_after_round() {
        let mut rng = Rng::new(3);
        let w = random_mat(&mut rng, 6, 12);
        let g = GridMap::fit_per_row(&w, 2);
        let wg = g.to_grid(&w);
        for &x in &wg.data {
            assert!(x >= -1e-9 && x <= 3.0 + 1e-9);
        }
    }

    #[test]
    fn constant_row_does_not_blow_up() {
        let w = Mat::from_fn(2, 4, |i, _| i as f64); // row 0 all zeros
        let g = GridMap::fit_per_row(&w, 4);
        let wg = g.to_grid(&w);
        assert!(wg.data.iter().all(|x| x.is_finite()));
        let back = g.from_grid(&wg);
        for (a, b) in back.data.iter().zip(&w.data) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn serialize_roundtrip() {
        let mut rng = Rng::new(4);
        let w = random_mat(&mut rng, 3, 5);
        for g in [GridMap::fit_per_row(&w, 3), GridMap::fit_global(&w, 2, 2.4)] {
            let mut buf = crate::util::bytes::Writer::new();
            g.serialize(&mut buf);
            let mut r = crate::util::bytes::Reader::new(&buf.buf);
            let g2 = GridMap::deserialize(&mut r).unwrap();
            let wg1 = g.to_grid(&w);
            let wg2 = g2.to_grid(&w);
            assert_eq!(wg1.data, wg2.data);
        }
    }
}
