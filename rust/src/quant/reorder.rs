//! Column reordering for LDLQ-RG: quantize high-curvature columns first by
//! sorting on diag(H) (descending), run LDLQ in the permuted basis, then
//! un-permute. (The paper: "LDLQ-RG re-orders the weights based on diag(H)
//! to modify the quantization order and adds further greedy updates".)

use crate::linalg::Mat;

/// A column reordering and its inverse.
#[derive(Clone, Debug)]
pub struct Reorder {
    /// perm[j] = original index of the column placed at position j.
    pub perm: Vec<usize>,
    pub inv: Vec<usize>,
}

impl Reorder {
    /// Sort columns by diag(H) descending.
    pub fn by_diag_desc(h: &Mat) -> Reorder {
        let d = h.diagonal();
        let mut perm: Vec<usize> = (0..d.len()).collect();
        perm.sort_by(|&a, &b| d[b].partial_cmp(&d[a]).unwrap());
        Self::from_perm(perm)
    }

    pub fn from_perm(perm: Vec<usize>) -> Reorder {
        let mut inv = vec![0usize; perm.len()];
        for (j, &p) in perm.iter().enumerate() {
            inv[p] = j;
        }
        Reorder { perm, inv }
    }

    /// Apply to weights: permuted W columns.
    pub fn apply_w(&self, w: &Mat) -> Mat {
        w.permute_cols(&self.perm)
    }

    /// Apply to Hessian: P H Pᵀ in the same basis.
    pub fn apply_h(&self, h: &Mat) -> Mat {
        h.permute_sym(&self.perm)
    }

    /// Undo on quantized output.
    pub fn undo_w(&self, w: &Mat) -> Mat {
        w.permute_cols(&self.inv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::proxy::proxy_loss;
    use crate::util::rng::Rng;
    use crate::util::testkit::{random_mat, random_spd};

    #[test]
    fn perm_sorts_diag_desc() {
        let mut rng = Rng::new(1);
        let h = random_spd(&mut rng, 12, 1e-2);
        let r = Reorder::by_diag_desc(&h);
        let hp = r.apply_h(&h);
        let d = hp.diagonal();
        for k in 1..d.len() {
            assert!(d[k - 1] >= d[k] - 1e-12);
        }
    }

    #[test]
    fn roundtrip_identity() {
        let mut rng = Rng::new(2);
        let w = random_mat(&mut rng, 5, 9);
        let h = random_spd(&mut rng, 9, 1e-2);
        let r = Reorder::by_diag_desc(&h);
        let back = r.undo_w(&r.apply_w(&w));
        assert_eq!(back.data, w.data);
    }

    #[test]
    fn proxy_invariant_under_reorder() {
        // tr(ΔHΔᵀ) is invariant to a simultaneous column/sym permutation.
        let mut rng = Rng::new(3);
        let w = random_mat(&mut rng, 4, 10);
        let what = random_mat(&mut rng, 4, 10);
        let h = random_spd(&mut rng, 10, 1e-2);
        let r = Reorder::by_diag_desc(&h);
        let a = proxy_loss(&what, &w, &h);
        let b = proxy_loss(&r.apply_w(&what), &r.apply_w(&w), &r.apply_h(&h));
        assert!((a - b).abs() < 1e-9);
    }
}
