//! Proxy-Hessian collection: H = (2/N) Σ x xᵀ over calibration
//! activations, accumulated in f64, with the paper's damping
//! H ← H + α·mean(diag H)·I applied downstream (quant::incoherence).
//!
//! Incoming f32 activation rows are buffered into a [`PANEL`]-row panel
//! and flushed through the blocked threaded rank-k kernel
//! [`crate::linalg::gemm::syrk_acc_upper`] instead of the old scalar
//! one-row-at-a-time rank-1 triple loop (kept as
//! [`accumulate_reference`] for equivalence tests and the `quip sweep
//! quant` baseline). Panel boundaries depend only on the stream position,
//! so the accumulated Hessian is bit-identical no matter how rows are
//! split across [`HessianAccum::add_rows`] calls. Measured speedup:
//! EXPERIMENTS.md §Perf 4.

use crate::linalg::gemm::{mirror_upper, syrk_acc_upper};
use crate::linalg::Mat;
use crate::util::bytes::{Reader, Writer};
use std::collections::BTreeMap;
use std::time::Instant;

pub mod sharded;

/// Rows per rank-k flush. Fixed (not tunable) so that flush boundaries —
/// and therefore f64 summation order — are a pure function of the stream
/// position.
pub const PANEL: usize = 128;

/// Streaming accumulator for one layer's proxy Hessian.
pub struct HessianAccum {
    pub n: usize,
    /// Σ x xᵀ (upper triangle maintained, mirrored on finish).
    sum: Mat,
    pub count: usize,
    /// Buffered rows (< PANEL) awaiting the next rank-k flush.
    pending: Vec<f32>,
    /// Reusable f64 conversion buffer for one panel.
    panel: Vec<f64>,
    /// Rows that have gone through a timed rank-k flush (multiples of
    /// PANEL); the sub-panel tail applied inside `finish` is untimed and
    /// excluded from the bandwidth figure.
    flushed: usize,
    /// Wall-clock spent accumulating (buffer copies + rank-k flushes);
    /// feeds the pipeline's per-layer stage timings.
    pub seconds: f64,
}

impl HessianAccum {
    pub fn new(n: usize) -> HessianAccum {
        HessianAccum {
            n,
            sum: Mat::zeros(n, n),
            count: 0,
            pending: Vec::new(),
            panel: Vec::new(),
            flushed: 0,
            seconds: 0.0,
        }
    }

    /// Add a batch of activation rows (row-major `rows × n`, f32 as
    /// produced by the model forward). Full panels flush straight from
    /// the input slice; only the sub-panel remainder is buffered.
    pub fn add_rows(&mut self, rows: &[f32], n: usize) {
        assert_eq!(n, self.n, "activation dim mismatch");
        assert_eq!(rows.len() % n, 0);
        let t0 = Instant::now();
        let r = rows.len() / n;
        let mut off = 0;
        // Top up the pending panel first (stream order).
        if !self.pending.is_empty() {
            let take = (PANEL * n - self.pending.len()).min(rows.len());
            self.pending.extend_from_slice(&rows[..take]);
            off = take;
            if self.pending.len() == PANEL * n {
                Self::flush(&mut self.sum, &mut self.panel, &self.pending, n);
                self.pending.clear();
                self.flushed += PANEL;
            }
        }
        while rows.len() - off >= PANEL * n {
            Self::flush(&mut self.sum, &mut self.panel, &rows[off..off + PANEL * n], n);
            off += PANEL * n;
            self.flushed += PANEL;
        }
        self.pending.extend_from_slice(&rows[off..]);
        self.count += r;
        self.seconds += t0.elapsed().as_secs_f64();
    }

    /// Flush one panel of f32 rows through the blocked rank-k kernel.
    fn flush(sum: &mut Mat, panel: &mut Vec<f64>, src: &[f32], n: usize) {
        panel.clear();
        panel.extend(src.iter().map(|&x| x as f64));
        syrk_acc_upper(src.len() / n, n, panel, sum);
    }

    /// Finalize: H = (2/N) Σ x xᵀ, symmetric. Non-destructive — the
    /// sub-panel remainder is applied to a copy, so streaming can
    /// continue afterwards.
    pub fn finish(&self) -> Mat {
        let mut h = self.sum.clone();
        if !self.pending.is_empty() {
            let tail: Vec<f64> = self.pending.iter().map(|&x| x as f64).collect();
            syrk_acc_upper(tail.len() / self.n, self.n, &tail, &mut h);
        }
        mirror_upper(&mut h);
        let scale = if self.count > 0 {
            2.0 / self.count as f64
        } else {
            1.0
        };
        h.scale(scale)
    }

    /// Resident bytes of this accumulator's deterministic state: the n×n
    /// f64 sum plus the buffered sub-panel f32 rows. This is the figure
    /// the sharded store's memory budget accounts against — fixed-size
    /// bookkeeping (counts, the reusable conversion buffer) is excluded
    /// so the accounting is a pure function of (n, stream position) and
    /// identical across runs.
    pub fn mem_bytes(&self) -> usize {
        self.n * self.n * 8 + self.pending.len() * 4
    }

    /// Serialize the complete streaming state. [`restore`](Self::restore)
    /// rebuilds an accumulator that continues the stream — and finishes —
    /// bit-identically to one that never left memory: the f64 sum and the
    /// pending f32 rows roundtrip exactly, and flush boundaries depend
    /// only on the stream position, which `count` preserves.
    pub fn snapshot(&self, w: &mut Writer) {
        w.u64(self.n as u64);
        w.u64(self.count as u64);
        w.u64(self.flushed as u64);
        w.f64(self.seconds);
        w.f64s(&self.sum.data);
        w.f32s(&self.pending);
    }

    /// Rebuild an accumulator from a [`snapshot`](Self::snapshot).
    pub fn restore(r: &mut Reader) -> crate::Result<HessianAccum> {
        let n = r.u64()? as usize;
        let count = r.u64()? as usize;
        let flushed = r.u64()? as usize;
        let seconds = r.f64()?;
        let data = r.f64s()?;
        anyhow::ensure!(
            n >= 1 && data.len() == n * n,
            "hessian snapshot: sum has {} entries, expected {n}×{n}",
            data.len()
        );
        let pending = r.f32s()?;
        anyhow::ensure!(
            pending.len() % n == 0 && pending.len() < PANEL * n,
            "hessian snapshot: pending buffer of {} f32s is not a sub-panel of {n}-wide rows",
            pending.len()
        );
        Ok(HessianAccum {
            n,
            sum: Mat {
                rows: n,
                cols: n,
                data,
            },
            count,
            pending,
            panel: Vec::new(),
            flushed,
            seconds,
        })
    }

    /// Effective accumulate bandwidth in GB/s: each accumulated row
    /// streams the n²/2-entry f64 upper triangle of the accumulator
    /// (read + write ⇒ n²·8 bytes per row). Defined against the scalar
    /// rank-1 kernel's traffic, so panel reuse shows up as bandwidth
    /// above DRAM speed. Only rows that went through a *timed* panel
    /// flush count — the sub-panel tail is applied untimed inside
    /// [`finish`](Self::finish) — so streams shorter than [`PANEL`] rows
    /// report 0 rather than a fictitious figure.
    pub fn effective_gbps(&self) -> f64 {
        if self.flushed == 0 {
            return 0.0;
        }
        let bytes = self.flushed as f64 * (self.n * self.n) as f64 * 8.0;
        bytes / self.seconds.max(1e-9) / 1e9
    }
}

/// The scalar rank-1 baseline (the pre-§Perf-4 kernel): one row at a
/// time, upper triangle, mirrored and scaled like
/// [`HessianAccum::finish`]. Kept for blocked-vs-scalar equivalence tests
/// and as the baseline leg of `quip sweep quant`.
pub fn accumulate_reference(rows: &[f32], n: usize) -> Mat {
    assert_eq!(rows.len() % n, 0);
    let r = rows.len() / n;
    let mut sum = Mat::zeros(n, n);
    for t in 0..r {
        let x = &rows[t * n..(t + 1) * n];
        for i in 0..n {
            let xi = x[i] as f64;
            if xi == 0.0 {
                continue;
            }
            let srow = &mut sum.data[i * n..(i + 1) * n];
            for j in i..n {
                srow[j] += xi * x[j] as f64;
            }
        }
    }
    mirror_upper(&mut sum);
    let scale = if r > 0 { 2.0 / r as f64 } else { 1.0 };
    sum.scale(scale)
}

/// A set of accumulators keyed by the model's Hessian-sharing keys.
///
/// A `BTreeMap` (not `HashMap`) so any future iteration over the set is
/// in deterministic key order — the quantization pipeline's outputs must
/// not depend on hash-seed ordering (see `tools/preflight.py`'s
/// determinism check). Today the map is keyed-lookup only.
pub struct HessianSet {
    pub accums: BTreeMap<String, HessianAccum>,
}

impl HessianSet {
    /// One accumulator per distinct hkey of the model's linear specs.
    pub fn for_model(cfg: &crate::model::ModelConfig) -> HessianSet {
        let mut accums = BTreeMap::new();
        for spec in cfg.linear_specs() {
            accums
                .entry(spec.hkey.clone())
                .or_insert_with(|| HessianAccum::new(spec.in_dim));
        }
        HessianSet { accums }
    }

    /// The sink closure to pass to `Transformer::forward`.
    pub fn sink(&mut self) -> impl FnMut(&str, &[f32], usize) + '_ {
        move |hkey: &str, rows: &[f32], n: usize| {
            if let Some(acc) = self.accums.get_mut(hkey) {
                acc.add_rows(rows, n);
            }
        }
    }

    pub fn finish(&self, hkey: &str) -> crate::Result<Mat> {
        Ok(self
            .accums
            .get(hkey)
            .ok_or_else(|| anyhow::anyhow!("no Hessian accumulator for '{hkey}'"))?
            .finish())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matches_direct_computation() {
        let mut rng = Rng::new(1);
        let n = 8;
        let rows = 40;
        let x: Vec<f32> = (0..rows * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let mut acc = HessianAccum::new(n);
        // Feed in two chunks to exercise streaming.
        acc.add_rows(&x[..15 * n], n);
        acc.add_rows(&x[15 * n..], n);
        let h = acc.finish();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0f64;
                for t in 0..rows {
                    s += x[t * n + i] as f64 * x[t * n + j] as f64;
                }
                let expect = 2.0 * s / rows as f64;
                assert!((h[(i, j)] - expect).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn hessian_is_psd() {
        let mut rng = Rng::new(2);
        let n = 12;
        let mut acc = HessianAccum::new(n);
        let x: Vec<f32> = (0..30 * n).map(|_| rng.normal() as f32).collect();
        acc.add_rows(&x, n);
        let h = acc.finish();
        let e = crate::linalg::eigen::eigen_sym(&h, 1e-12, 50);
        assert!(e.values[0] > -1e-8, "min eig {}", e.values[0]);
    }

    #[test]
    fn rank_bounded_by_sample_count() {
        // With fewer samples than dims, H is exactly low-rank — the regime
        // Figure 1 observes.
        let mut rng = Rng::new(3);
        let n = 16;
        let mut acc = HessianAccum::new(n);
        let x: Vec<f32> = (0..4 * n).map(|_| rng.normal() as f32).collect();
        acc.add_rows(&x, n);
        let h = acc.finish();
        let e = crate::linalg::eigen::eigen_sym(&h, 1e-12, 60);
        let nonzero = e.values.iter().filter(|&&l| l > 1e-8).count();
        assert!(nonzero <= 4);
    }

    #[test]
    fn bit_identical_regardless_of_add_rows_split() {
        // Panel flush boundaries are a pure function of the stream
        // position, so any way of chunking the same row stream across
        // add_rows calls must produce bit-identical Hessians — including
        // splits that straddle the PANEL boundary.
        let mut rng = Rng::new(9);
        let n = 24;
        let total = 2 * PANEL + 37; // two full panels + a remainder
        let x: Vec<f32> = (0..total * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let mut whole = HessianAccum::new(n);
        whole.add_rows(&x, n);
        let reference = whole.finish();
        let splits: &[&[usize]] = &[
            &[1, total - 1],
            &[PANEL, PANEL, 37],
            &[PANEL - 1, 2, total - PANEL - 1],
            &[7, 130, total - 137],
        ];
        for split in splits {
            assert_eq!(split.iter().sum::<usize>(), total);
            let mut acc = HessianAccum::new(n);
            let mut off = 0;
            for &chunk in *split {
                acc.add_rows(&x[off * n..(off + chunk) * n], n);
                off += chunk;
            }
            let h = acc.finish();
            assert_eq!(h.data, reference.data, "split {split:?} changed bits");
            assert_eq!(acc.count, total);
        }
    }

    #[test]
    fn blocked_accumulator_matches_scalar_reference() {
        // Equivalence up to f64 summation order against the rank-1
        // baseline, at sizes that are not panel/block multiples.
        let mut rng = Rng::new(10);
        for &(rows, n) in &[(1usize, 7usize), (33, 33), (PANEL + 9, 130), (300, 65)] {
            let x: Vec<f32> = (0..rows * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            let mut acc = HessianAccum::new(n);
            acc.add_rows(&x, n);
            let h = acc.finish();
            let h_ref = accumulate_reference(&x, n);
            let scale = h_ref.max_abs().max(1.0);
            assert!(
                crate::linalg::matrix::max_abs_diff(&h, &h_ref) < 1e-12 * scale,
                "rows={rows} n={n}"
            );
        }
    }

    #[test]
    fn finish_is_non_destructive_mid_stream() {
        // finish() with a partial panel pending must not consume it: more
        // rows can stream in afterwards and the final H is unchanged.
        let mut rng = Rng::new(11);
        let n = 8;
        let x: Vec<f32> = (0..40 * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let mut acc = HessianAccum::new(n);
        acc.add_rows(&x[..15 * n], n);
        let _mid = acc.finish();
        acc.add_rows(&x[15 * n..], n);
        let mut whole = HessianAccum::new(n);
        whole.add_rows(&x, n);
        assert_eq!(acc.finish().data, whole.finish().data);
    }

    #[test]
    fn snapshot_restore_mid_stream_is_bit_identical() {
        // Spill fidelity: freeze the accumulator mid-stream (partial
        // panel pending), restore it, continue streaming — the final H
        // must match an uninterrupted accumulator bit for bit, and the
        // bandwidth bookkeeping must survive the roundtrip.
        let mut rng = Rng::new(12);
        let n = 16;
        let total = PANEL + 53;
        let x: Vec<f32> = (0..total * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let split = PANEL + 11; // mid-stream, partial panel pending
        let mut acc = HessianAccum::new(n);
        acc.add_rows(&x[..split * n], n);
        let mut w = crate::util::bytes::Writer::new();
        acc.snapshot(&mut w);
        let bytes_before = acc.mem_bytes();
        drop(acc);
        let mut back =
            HessianAccum::restore(&mut crate::util::bytes::Reader::new(&w.buf)).unwrap();
        assert_eq!(back.count, split);
        assert_eq!(back.mem_bytes(), bytes_before);
        back.add_rows(&x[split * n..], n);
        let mut whole = HessianAccum::new(n);
        whole.add_rows(&x, n);
        assert_eq!(back.finish().data, whole.finish().data);
        assert_eq!(back.count, whole.count);
    }

    #[test]
    fn restore_rejects_malformed_snapshots() {
        let mut acc = HessianAccum::new(4);
        acc.add_rows(&[1.0; 8], 4);
        let mut w = crate::util::bytes::Writer::new();
        acc.snapshot(&mut w);
        // Truncation anywhere inside the snapshot is a clean error.
        for cut in [0, 8, 20, w.buf.len() - 1] {
            assert!(
                HessianAccum::restore(&mut crate::util::bytes::Reader::new(&w.buf[..cut]))
                    .is_err(),
                "cut at {cut} must fail"
            );
        }
        // A sum-length/n mismatch is caught, not trusted.
        let mut bad = crate::util::bytes::Writer::new();
        bad.u64(5); // n = 5 but the 4×4 sum follows
        bad.bytes(&w.buf[8..]);
        assert!(HessianAccum::restore(&mut crate::util::bytes::Reader::new(&bad.buf)).is_err());
    }

    #[test]
    fn set_routes_by_hkey() {
        let cfg = crate::model::ModelConfig::sized("t", 16, 2, 4, 32);
        let mut set = HessianSet::for_model(&cfg);
        {
            let mut sink = set.sink();
            sink("blk0.attn.in", &vec![1.0f32; 16 * 3], 16);
            sink("nonexistent", &vec![1.0f32; 16], 16); // silently ignored
        }
        assert_eq!(set.accums["blk0.attn.in"].count, 3);
        assert_eq!(set.accums["blk1.mlp.w2.in"].count, 0);
        assert!(set.finish("blk0.attn.in").is_ok());
        assert!(set.finish("bogus").is_err());
    }
}
