//! Proxy-Hessian collection: H = (2/N) Σ x xᵀ over calibration
//! activations, accumulated in f64, with the paper's damping
//! H ← H + α·mean(diag H)·I applied downstream (quant::incoherence).

use crate::linalg::Mat;
use std::collections::HashMap;

/// Streaming accumulator for one layer's proxy Hessian.
pub struct HessianAccum {
    pub n: usize,
    /// Σ x xᵀ (upper triangle maintained, mirrored on finish).
    sum: Mat,
    pub count: usize,
}

impl HessianAccum {
    pub fn new(n: usize) -> HessianAccum {
        HessianAccum {
            n,
            sum: Mat::zeros(n, n),
            count: 0,
        }
    }

    /// Add a batch of activation rows (row-major `rows × n`, f32 as
    /// produced by the model forward).
    pub fn add_rows(&mut self, rows: &[f32], n: usize) {
        assert_eq!(n, self.n, "activation dim mismatch");
        assert_eq!(rows.len() % n, 0);
        let r = rows.len() / n;
        for t in 0..r {
            let x = &rows[t * n..(t + 1) * n];
            for i in 0..n {
                let xi = x[i] as f64;
                if xi == 0.0 {
                    continue;
                }
                let srow = &mut self.sum.data[i * n..(i + 1) * n];
                for j in i..n {
                    srow[j] += xi * x[j] as f64;
                }
            }
        }
        self.count += r;
    }

    /// Finalize: H = (2/N) Σ x xᵀ, symmetric.
    pub fn finish(&self) -> Mat {
        let mut h = self.sum.clone();
        // Mirror the upper triangle.
        for i in 0..self.n {
            for j in 0..i {
                h[(i, j)] = h[(j, i)];
            }
        }
        let scale = if self.count > 0 {
            2.0 / self.count as f64
        } else {
            1.0
        };
        h.scale(scale)
    }
}

/// A set of accumulators keyed by the model's Hessian-sharing keys.
pub struct HessianSet {
    pub accums: HashMap<String, HessianAccum>,
}

impl HessianSet {
    /// One accumulator per distinct hkey of the model's linear specs.
    pub fn for_model(cfg: &crate::model::ModelConfig) -> HessianSet {
        let mut accums = HashMap::new();
        for spec in cfg.linear_specs() {
            accums
                .entry(spec.hkey.clone())
                .or_insert_with(|| HessianAccum::new(spec.in_dim));
        }
        HessianSet { accums }
    }

    /// The sink closure to pass to `Transformer::forward`.
    pub fn sink(&mut self) -> impl FnMut(&str, &[f32], usize) + '_ {
        move |hkey: &str, rows: &[f32], n: usize| {
            if let Some(acc) = self.accums.get_mut(hkey) {
                acc.add_rows(rows, n);
            }
        }
    }

    pub fn finish(&self, hkey: &str) -> crate::Result<Mat> {
        Ok(self
            .accums
            .get(hkey)
            .ok_or_else(|| anyhow::anyhow!("no Hessian accumulator for '{hkey}'"))?
            .finish())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matches_direct_computation() {
        let mut rng = Rng::new(1);
        let n = 8;
        let rows = 40;
        let x: Vec<f32> = (0..rows * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let mut acc = HessianAccum::new(n);
        // Feed in two chunks to exercise streaming.
        acc.add_rows(&x[..15 * n], n);
        acc.add_rows(&x[15 * n..], n);
        let h = acc.finish();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0f64;
                for t in 0..rows {
                    s += x[t * n + i] as f64 * x[t * n + j] as f64;
                }
                let expect = 2.0 * s / rows as f64;
                assert!((h[(i, j)] - expect).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn hessian_is_psd() {
        let mut rng = Rng::new(2);
        let n = 12;
        let mut acc = HessianAccum::new(n);
        let x: Vec<f32> = (0..30 * n).map(|_| rng.normal() as f32).collect();
        acc.add_rows(&x, n);
        let h = acc.finish();
        let e = crate::linalg::eigen::eigen_sym(&h, 1e-12, 50);
        assert!(e.values[0] > -1e-8, "min eig {}", e.values[0]);
    }

    #[test]
    fn rank_bounded_by_sample_count() {
        // With fewer samples than dims, H is exactly low-rank — the regime
        // Figure 1 observes.
        let mut rng = Rng::new(3);
        let n = 16;
        let mut acc = HessianAccum::new(n);
        let x: Vec<f32> = (0..4 * n).map(|_| rng.normal() as f32).collect();
        acc.add_rows(&x, n);
        let h = acc.finish();
        let e = crate::linalg::eigen::eigen_sym(&h, 1e-12, 60);
        let nonzero = e.values.iter().filter(|&&l| l > 1e-8).count();
        assert!(nonzero <= 4);
    }

    #[test]
    fn set_routes_by_hkey() {
        let cfg = crate::model::ModelConfig::sized("t", 16, 2, 4, 32);
        let mut set = HessianSet::for_model(&cfg);
        {
            let mut sink = set.sink();
            sink("blk0.attn.in", &vec![1.0f32; 16 * 3], 16);
            sink("nonexistent", &vec![1.0f32; 16], 16); // silently ignored
        }
        assert_eq!(set.accums["blk0.attn.in"].count, 3);
        assert_eq!(set.accums["blk1.mlp.w2.in"].count, 0);
        assert!(set.finish("blk0.attn.in").is_ok());
        assert!(set.finish("bogus").is_err());
    }
}
