//! Budget-bounded Hessian accumulation: the spill/stream layer under the
//! sharded quantization pipeline (DESIGN.md §11).
//!
//! A [`ShardedHessianStore`] owns one [`HessianAccum`] per Hessian-sharing
//! key of the block being calibrated and keeps their total resident bytes
//! under a configured budget: when an `add_rows` pushes residency over
//! the line, least-recently-streamed accumulators are *spilled* — their
//! exact streaming state serialized through
//! [`HessianAccum::snapshot`] and written with
//! [`crate::util::fsx::atomic_write`] — and transparently reloaded the
//! next time their key streams rows or is finished. Because the snapshot
//! roundtrips the f64 sum and pending f32 rows exactly, and panel flush
//! boundaries depend only on the stream position, a spilled-and-reloaded
//! accumulator finishes **bit-identically** to one that never left
//! memory, for any budget and any chunking of the row stream (pinned by
//! the tests below and by `rust/tests/determinism.rs`).
//!
//! Spill files are CRC-framed like `.qzp` journal records:
//!
//! ```text
//! file := magic "QSP1" | len u32 | crc u32 | payload (len bytes)
//! payload := HessianAccum snapshot        (crc = crc32(payload))
//! ```
//!
//! A short file is a torn write (the atomic rename makes this close to
//! impossible, but the `hessian.spill` fault point can produce one on
//! purpose) and a full-length file with a bad CRC is bit rot; both are
//! clean, distinguishable errors — never garbage Hessians. Eviction order
//! is deterministic (a monotone use counter, ties broken by `BTreeMap`
//! key order), so which keys spill — and therefore every byte that
//! touches disk — is a pure function of the stream, not of timing.

use super::HessianAccum;
use crate::linalg::Mat;
use crate::obs::registry::{Counter, Gauge, MetricRegistry};
use crate::util::bytes::{Reader, Writer};
use crate::util::crc32::crc32;
use crate::util::fault::{FaultInjector, FaultMode};
use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Magic prefix of a spill file.
const SPILL_MAGIC: &[u8; 4] = b"QSP1";

/// The store's metric handles (DESIGN.md §9 registry). Registering twice
/// on the same registry returns the same underlying handles, so the
/// peak-bytes gauge keeps its high-water mark across per-block stores.
pub struct ShardMetrics {
    /// High-water mark of resident accumulator bytes (post-eviction).
    pub peak_bytes: Gauge,
    /// Accumulator spill writes.
    pub spill_total: Counter,
    /// Bytes written to spill files.
    pub spill_bytes_total: Counter,
    /// Accumulator reloads from spill files (streaming or finishing).
    pub spill_load_total: Counter,
}

impl ShardMetrics {
    pub fn register(reg: &MetricRegistry) -> ShardMetrics {
        ShardMetrics {
            peak_bytes: reg.gauge(
                "quip_hessian_peak_bytes",
                "High-water mark of resident Hessian accumulator bytes",
            ),
            spill_total: reg.counter(
                "quip_hessian_spill_total",
                "Hessian accumulator spill writes",
            ),
            spill_bytes_total: reg.counter(
                "quip_hessian_spill_bytes_total",
                "Bytes written to Hessian spill files",
            ),
            spill_load_total: reg.counter(
                "quip_hessian_spill_load_total",
                "Hessian accumulator reloads from spill files",
            ),
        }
    }
}

/// One key's accumulator: resident (`accum` is `Some`) or spilled to its
/// spill file (`accum` is `None`).
struct Slot {
    dim: usize,
    accum: Option<HessianAccum>,
    /// A spill file for this key exists on disk (for `Drop` cleanup; the
    /// file is only *read* while `accum` is `None`).
    ever_spilled: bool,
    /// Deterministic recency: the store's use counter at the key's last
    /// `add_rows`. Never-streamed slots stay at 0 and evict first, in
    /// `BTreeMap` key order.
    last_use: u64,
    /// Accumulation stats mirrored after every `add_rows` so per-layer
    /// stage timings survive spills.
    seconds: f64,
    gbps: f64,
}

/// Deterministic, budget-bounded set of streaming Hessian accumulators
/// with LRU spill to CRC-framed files. See the module docs.
pub struct ShardedHessianStore {
    slots: BTreeMap<String, Slot>,
    /// Resident-byte budget; 0 means unlimited (nothing ever spills).
    budget: usize,
    dir: PathBuf,
    faults: Option<Arc<FaultInjector>>,
    metrics: Option<ShardMetrics>,
    clock: u64,
    peak: usize,
    spills: usize,
    /// First deferred error. The activation-capture sink cannot return
    /// `Result`, so `add_rows` records failures here and
    /// [`check`](Self::check) surfaces them after the forward pass.
    poisoned: Option<String>,
}

impl ShardedHessianStore {
    /// One accumulator per `(hkey, input dim)`; `budget_bytes = 0` means
    /// unlimited. `dir` holds spill files and is only created when
    /// something actually spills.
    pub fn new(keys: &[(String, usize)], budget_bytes: usize, dir: &Path) -> ShardedHessianStore {
        let mut slots = BTreeMap::new();
        for (key, dim) in keys {
            slots.entry(key.clone()).or_insert_with(|| Slot {
                dim: *dim,
                accum: Some(HessianAccum::new(*dim)),
                ever_spilled: false,
                last_use: 0,
                seconds: 0.0,
                gbps: 0.0,
            });
        }
        ShardedHessianStore {
            slots,
            budget: budget_bytes,
            dir: dir.to_path_buf(),
            faults: None,
            metrics: None,
            clock: 0,
            peak: 0,
            spills: 0,
            poisoned: None,
        }
    }

    /// Arm the `hessian.spill` fault point (fires per spill write).
    pub fn with_faults(mut self, faults: Option<Arc<FaultInjector>>) -> Self {
        self.faults = faults;
        self
    }

    /// Attach metric handles (peak gauge + spill counters).
    pub fn with_metrics(mut self, metrics: Option<ShardMetrics>) -> Self {
        self.metrics = metrics;
        self
    }

    /// The spill file for `key`: a sanitized name plus the key's CRC so
    /// distinct keys can never collide after sanitization.
    fn spill_path(dir: &Path, key: &str) -> PathBuf {
        let safe: String = key
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .collect();
        dir.join(format!("{safe}_{:08x}.qsp", crc32(key.as_bytes())))
    }

    /// Stream activation rows into `hkey`'s accumulator, reloading it
    /// from its spill file if necessary and spilling others to stay under
    /// budget. Unknown keys are ignored (the capture sink sees every
    /// hkey; the store only tracks its block's). Errors are deferred —
    /// call [`check`](Self::check) after the forward pass.
    pub fn add_rows(&mut self, hkey: &str, rows: &[f32], n: usize) {
        if self.poisoned.is_some() {
            return;
        }
        if let Err(e) = self.try_add(hkey, rows, n) {
            self.poisoned = Some(format!("hessian store, key '{hkey}': {e}"));
        }
    }

    fn try_add(&mut self, hkey: &str, rows: &[f32], n: usize) -> crate::Result<()> {
        if !self.slots.contains_key(hkey) {
            return Ok(());
        }
        self.clock += 1;
        let clock = self.clock;
        let loaded = {
            let slot = self.slots.get_mut(hkey).expect("checked above");
            anyhow::ensure!(
                slot.dim == n,
                "activation dim {n} does not match accumulator dim {}",
                slot.dim
            );
            let loaded = if slot.accum.is_none() {
                slot.accum = Some(read_spill(&Self::spill_path(&self.dir, hkey))?);
                true
            } else {
                false
            };
            let acc = slot.accum.as_mut().expect("just ensured resident");
            acc.add_rows(rows, n);
            slot.seconds = acc.seconds;
            slot.gbps = acc.effective_gbps();
            slot.last_use = clock;
            loaded
        };
        if loaded {
            if let Some(m) = &self.metrics {
                m.spill_load_total.inc();
            }
        }
        self.enforce_budget(hkey)?;
        let resident = self.resident_bytes();
        if resident > self.peak {
            self.peak = resident;
        }
        if let Some(m) = &self.metrics {
            m.peak_bytes.fetch_max(resident as u64, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Surface any error deferred by [`add_rows`](Self::add_rows). Call
    /// once per captured forward pass; the store stays poisoned (further
    /// `add_rows` are no-ops) after the first failure.
    pub fn check(&self) -> crate::Result<()> {
        match &self.poisoned {
            Some(e) => anyhow::bail!("{e}"),
            None => Ok(()),
        }
    }

    /// Spill least-recently-streamed accumulators (never `keep`, which
    /// just streamed) until residency fits the budget. With only `keep`
    /// resident the loop stops, so the effective bound is
    /// `max(budget, largest single accumulator)`.
    fn enforce_budget(&mut self, keep: &str) -> crate::Result<()> {
        if self.budget == 0 {
            return Ok(());
        }
        while self.resident_bytes() > self.budget {
            let victim = self
                .slots
                .iter()
                .filter(|(k, s)| s.accum.is_some() && k.as_str() != keep)
                .min_by_key(|(_, s)| s.last_use)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => self.spill(&k)?,
                None => break,
            }
        }
        Ok(())
    }

    /// Write one slot's streaming state to its spill file and drop the
    /// resident accumulator. The `hessian.spill` fault point fires here.
    fn spill(&mut self, key: &str) -> crate::Result<()> {
        let path = Self::spill_path(&self.dir, key);
        let slot = self
            .slots
            .get_mut(key)
            .ok_or_else(|| anyhow::anyhow!("spill of unknown key '{key}'"))?;
        let acc = slot
            .accum
            .take()
            .ok_or_else(|| anyhow::anyhow!("spill of non-resident key '{key}'"))?;
        slot.ever_spilled = true;
        let wrote = write_spill(&path, &acc, self.faults.as_deref())?;
        self.spills += 1;
        if let Some(m) = &self.metrics {
            m.spill_total.inc();
            m.spill_bytes_total.fetch_add(wrote as u64, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Finalize `hkey`'s Hessian: `finish()` on the resident accumulator,
    /// or read + finish its spill file. Takes `&self` so a worker pool
    /// can finish different keys concurrently; at most one finished n×n
    /// matrix per worker is ever materialized at once.
    pub fn finish(&self, hkey: &str) -> crate::Result<Mat> {
        let slot = self
            .slots
            .get(hkey)
            .ok_or_else(|| anyhow::anyhow!("no Hessian accumulator for '{hkey}'"))?;
        match &slot.accum {
            Some(acc) => Ok(acc.finish()),
            None => {
                let acc = read_spill(&Self::spill_path(&self.dir, hkey))?;
                anyhow::ensure!(
                    acc.n == slot.dim,
                    "spill file for '{hkey}' has dim {} instead of {}",
                    acc.n,
                    slot.dim
                );
                if let Some(m) = &self.metrics {
                    m.spill_load_total.inc();
                }
                Ok(acc.finish())
            }
        }
    }

    /// Accumulation stats for `hkey` — (seconds, effective GB/s) —
    /// mirrored at the last `add_rows`, so they survive spills.
    pub fn stats(&self, hkey: &str) -> (f64, f64) {
        self.slots
            .get(hkey)
            .map(|s| (s.seconds, s.gbps))
            .unwrap_or((0.0, 0.0))
    }

    /// Bytes of currently-resident accumulator state (the budget's view:
    /// n×n f64 sums + pending sub-panel rows).
    pub fn resident_bytes(&self) -> usize {
        self.slots
            .values()
            .filter_map(|s| s.accum.as_ref())
            .map(|a| a.mem_bytes())
            .sum()
    }

    /// High-water mark of [`resident_bytes`](Self::resident_bytes) over
    /// the store's lifetime (measured post-eviction, so it is bounded by
    /// `max(budget, largest single accumulator)`).
    pub fn peak_bytes(&self) -> usize {
        self.peak
    }

    /// Number of spill writes performed.
    pub fn spill_count(&self) -> usize {
        self.spills
    }
}

impl Drop for ShardedHessianStore {
    fn drop(&mut self) {
        // Best-effort cleanup: spill files are scratch state, not
        // artifacts. A killed process skips this; a later session simply
        // overwrites the stale files (they are never read unless this
        // store spilled them itself).
        let mut any = false;
        for (key, slot) in &self.slots {
            if slot.ever_spilled {
                let _ = std::fs::remove_file(Self::spill_path(&self.dir, key));
                any = true;
            }
        }
        if any {
            let _ = std::fs::remove_dir(&self.dir);
        }
    }
}

/// Serialize `acc` and write it to `path` (atomically, except under an
/// armed `hessian.spill` torn fault, which persists a seeded prefix in
/// place — the on-disk state a power cut would leave). Returns the bytes
/// written.
fn write_spill(
    path: &Path,
    acc: &HessianAccum,
    faults: Option<&FaultInjector>,
) -> crate::Result<usize> {
    let mut w = Writer::new();
    acc.snapshot(&mut w);
    let payload = w.buf;
    let mut bytes = Vec::with_capacity(12 + payload.len());
    bytes.extend_from_slice(SPILL_MAGIC);
    bytes.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    bytes.extend_from_slice(&crc32(&payload).to_le_bytes());
    bytes.extend_from_slice(&payload);
    if let Some(f) = faults {
        match f.check("hessian.spill") {
            Some(FaultMode::Torn) => {
                let keep = f.torn_len("hessian.spill", bytes.len());
                if let Some(parent) = path.parent() {
                    std::fs::create_dir_all(parent)?;
                }
                let mut file = std::fs::File::create(path)?;
                file.write_all(&bytes[..keep])?;
                file.sync_data()?;
                return f.die("hessian.spill", FaultMode::Torn).map(|_| 0);
            }
            // preflight: allow(panic, "the panic fault mode exists to panic on purpose")
            Some(FaultMode::Panic) => panic!("fault injected: hessian.spill (panic)"),
            Some(mode) => return f.die("hessian.spill", mode).map(|_| 0),
            None => {}
        }
    }
    crate::util::fsx::atomic_write(path, &bytes)?;
    Ok(bytes.len())
}

/// Read and validate one spill file. A short file is reported as torn, a
/// full-length file with a CRC mismatch as corruption; both refuse
/// cleanly rather than feed a damaged Hessian to the rounder.
pub fn read_spill(path: &Path) -> crate::Result<HessianAccum> {
    let buf = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("reading spill file {path:?}: {e}"))?;
    anyhow::ensure!(
        buf.len() >= 12,
        "spill file {path:?}: {} bytes is shorter than the header (torn write?)",
        buf.len()
    );
    anyhow::ensure!(
        &buf[..4] == SPILL_MAGIC,
        "spill file {path:?}: bad magic {:02x?}",
        &buf[..4]
    );
    let len = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]) as usize;
    let stored_crc = u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]);
    anyhow::ensure!(
        buf.len() == 12 + len,
        "spill file {path:?}: payload is {} of {len} bytes (torn write?)",
        buf.len().saturating_sub(12)
    );
    let payload = &buf[12..];
    let actual = crc32(payload);
    anyhow::ensure!(
        stored_crc == actual,
        "spill file {path:?}: CRC mismatch (stored {stored_crc:08x}, computed {actual:08x}) \
         — refusing to accumulate on a damaged Hessian"
    );
    let mut r = Reader::new(payload);
    let acc = HessianAccum::restore(&mut r)?;
    anyhow::ensure!(
        r.remaining() == 0,
        "spill file {path:?}: {} trailing bytes",
        r.remaining()
    );
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hessian::PANEL;
    use crate::util::fault::FaultSpec;
    use crate::util::rng::Rng;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("quip_spill_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// Per-key row streams: three 16-dim keys with different lengths so
    /// spills interleave with partial panels.
    fn streams(n: usize) -> Vec<(String, Vec<f32>)> {
        let mut rng = Rng::new(77);
        ["a", "b", "c"]
            .iter()
            .enumerate()
            .map(|(i, k)| {
                let rows = PANEL + 11 * (i + 1);
                let data: Vec<f32> =
                    (0..rows * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
                (k.to_string(), data)
            })
            .collect()
    }

    fn keys(n: usize) -> Vec<(String, usize)> {
        vec![("a".into(), n), ("b".into(), n), ("c".into(), n)]
    }

    /// Budget that fits roughly one-and-a-half 16-dim accumulators, so a
    /// three-key stream must spill.
    fn tiny_budget(n: usize) -> usize {
        n * n * 8 * 3 / 2
    }

    #[test]
    fn finish_is_bit_identical_across_chunkings_and_budgets() {
        // The tentpole invariant at store granularity: any chunking of
        // the interleaved row stream {1 row at a time, ragged, all at
        // once} × {unlimited, spill-forcing} budgets must finish every
        // key bit-identically to a plain in-memory accumulator.
        let n = 16;
        let streams = streams(n);
        let reference: Vec<Vec<f64>> = streams
            .iter()
            .map(|(_, data)| {
                let mut acc = HessianAccum::new(n);
                acc.add_rows(data, n);
                acc.finish().data
            })
            .collect();
        let chunkings: &[&[usize]] = &[&[1], &[7, 30, 130, 1], &[usize::MAX]];
        for (ci, chunking) in chunkings.iter().enumerate() {
            for &budget in &[0usize, tiny_budget(n)] {
                let dir = tmpdir(&format!("chunk{ci}_{budget}"));
                let mut store = ShardedHessianStore::new(&keys(n), budget, &dir);
                // Interleave keys round-robin, each advancing through its
                // own stream by the chunking's repeating pattern.
                let mut offsets = vec![0usize; streams.len()];
                let mut pat = vec![0usize; streams.len()];
                loop {
                    let mut progressed = false;
                    for (si, (key, data)) in streams.iter().enumerate() {
                        let total = data.len() / n;
                        if offsets[si] >= total {
                            continue;
                        }
                        let want = chunking[pat[si] % chunking.len()];
                        pat[si] += 1;
                        let take = want.min(total - offsets[si]);
                        let lo = offsets[si] * n;
                        store.add_rows(key, &data[lo..lo + take * n], n);
                        offsets[si] += take;
                        progressed = true;
                    }
                    if !progressed {
                        break;
                    }
                }
                store.check().unwrap();
                if budget > 0 {
                    assert!(store.spill_count() > 0, "tiny budget must force spills");
                    assert!(
                        store.peak_bytes() <= budget.max(n * n * 8 + PANEL * n * 4),
                        "peak {} over bound",
                        store.peak_bytes()
                    );
                } else {
                    assert_eq!(store.spill_count(), 0, "unlimited budget never spills");
                }
                for ((key, _), want) in streams.iter().zip(&reference) {
                    let h = store.finish(key).unwrap();
                    assert_eq!(
                        &h.data, want,
                        "chunking {ci} budget {budget} key {key} changed bits"
                    );
                }
            }
        }
    }

    #[test]
    fn stats_survive_spills() {
        let n = 16;
        let dir = tmpdir("stats");
        let mut store = ShardedHessianStore::new(&keys(n), tiny_budget(n), &dir);
        for (key, data) in &streams(n) {
            store.add_rows(key, data, n);
        }
        store.check().unwrap();
        assert!(store.spill_count() > 0);
        for (key, _) in &streams(n) {
            let (seconds, gbps) = store.stats(key);
            assert!(seconds > 0.0, "{key}: accumulate seconds lost across spill");
            assert!(gbps.is_finite() && gbps >= 0.0);
        }
        assert_eq!(store.stats("nope"), (0.0, 0.0));
    }

    #[test]
    fn unknown_keys_are_ignored_and_dim_mismatch_poisons() {
        let n = 16;
        let dir = tmpdir("poison");
        let mut store = ShardedHessianStore::new(&keys(n), 0, &dir);
        store.add_rows("unknown", &vec![1.0; 8], 8);
        store.check().unwrap();
        store.add_rows("a", &vec![1.0; 8], 8); // dim 8 ≠ 16
        let err = store.check().unwrap_err().to_string();
        assert!(err.contains("dim"), "{err}");
        // Poisoned stores stay poisoned; later good rows don't mask it.
        store.add_rows("a", &vec![1.0; n], n);
        assert!(store.check().is_err());
    }

    #[test]
    fn spill_files_torture_truncated_corrupt_magic() {
        // Mirror the .qzp torn-tail tests: every damaged-file shape must
        // be a clean, named error.
        let n = 8;
        let dir = tmpdir("torture");
        let mut acc = HessianAccum::new(n);
        let mut rng = Rng::new(5);
        let rows: Vec<f32> = (0..(PANEL + 3) * n).map(|_| rng.normal() as f32).collect();
        acc.add_rows(&rows, n);
        let path = dir.join("victim.qsp");
        write_spill(&path, &acc, None).unwrap();
        let good = std::fs::read(&path).unwrap();
        // Pristine file roundtrips bit-identically.
        assert_eq!(read_spill(&path).unwrap().finish().data, acc.finish().data);
        // Truncation at every framing boundary and mid-payload: torn.
        for cut in [0usize, 3, 11, 12, good.len() / 2, good.len() - 1] {
            std::fs::write(&path, &good[..cut]).unwrap();
            let err = read_spill(&path).unwrap_err().to_string();
            assert!(err.contains("torn") || err.contains("truncated"), "cut {cut}: {err}");
        }
        // Full-length, one payload bit flipped: CRC refusal.
        let mut bad = good.clone();
        let mid = 12 + (bad.len() - 12) / 2;
        bad[mid] ^= 0x40;
        std::fs::write(&path, &bad).unwrap();
        let err = read_spill(&path).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        std::fs::write(&path, &bad).unwrap();
        let err = read_spill(&path).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        // Missing file.
        std::fs::remove_file(&path).unwrap();
        assert!(read_spill(&path).is_err());
    }

    #[test]
    fn spill_fault_point_kills_and_tears() {
        let n = 16;
        // Kill mode: the spill write dies before touching disk and the
        // error surfaces through check(), naming the point.
        let dir = tmpdir("fault_kill");
        let faults = Arc::new(FaultInjector::new(
            vec![FaultSpec::parse("hessian.spill@1").unwrap()],
            true,
            0x5EED,
        ));
        let mut store = ShardedHessianStore::new(&keys(n), tiny_budget(n), &dir)
            .with_faults(Some(Arc::clone(&faults)));
        for (key, data) in &streams(n) {
            store.add_rows(key, data, n);
        }
        let err = store.check().unwrap_err().to_string();
        assert!(err.contains("fault injected: hessian.spill"), "{err}");

        // Torn mode: a seeded prefix lands on disk, read_spill refuses
        // it, and a clean re-run overwrites it and finishes identically.
        let dir = tmpdir("fault_torn");
        let faults = Arc::new(FaultInjector::new(
            vec![FaultSpec::parse("hessian.spill@1:torn").unwrap()],
            true,
            0x5EED,
        ));
        let mut store = ShardedHessianStore::new(&keys(n), tiny_budget(n), &dir)
            .with_faults(Some(Arc::clone(&faults)));
        let streams = streams(n);
        for (key, data) in &streams {
            store.add_rows(key, data, n);
        }
        assert!(store.check().is_err());
        let torn: Vec<PathBuf> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .collect();
        assert_eq!(torn.len(), 1, "exactly the torn spill file on disk");
        assert!(read_spill(&torn[0]).is_err(), "torn spill must not read back");
        drop(store);
        // The wreck re-collects cleanly: same dir, no faults, stale torn
        // file overwritten, bit-identical finish.
        let mut store = ShardedHessianStore::new(&keys(n), tiny_budget(n), &dir);
        for (key, data) in &streams {
            store.add_rows(key, data, n);
        }
        store.check().unwrap();
        for (key, data) in &streams {
            let mut acc = HessianAccum::new(n);
            acc.add_rows(data, n);
            assert_eq!(store.finish(key).unwrap().data, acc.finish().data);
        }
    }

    #[test]
    fn metrics_report_peak_and_spills() {
        let n = 16;
        let reg = MetricRegistry::new();
        let dir = tmpdir("metrics");
        let mut store = ShardedHessianStore::new(&keys(n), tiny_budget(n), &dir)
            .with_metrics(Some(ShardMetrics::register(&reg)));
        for (key, data) in &streams(n) {
            store.add_rows(key, data, n);
        }
        store.check().unwrap();
        let m = ShardMetrics::register(&reg); // same handles
        assert_eq!(m.peak_bytes.get() as usize, store.peak_bytes());
        assert!(m.peak_bytes.get() > 0);
        assert_eq!(m.spill_total.get() as usize, store.spill_count());
        assert!(m.spill_bytes_total.get() > 0);
        let text = reg.render_prometheus();
        assert!(text.contains("quip_hessian_peak_bytes"), "{text}");
        assert!(text.contains("quip_hessian_spill_total"), "{text}");
    }

    #[test]
    fn drop_cleans_spill_files() {
        let n = 16;
        let dir = tmpdir("cleanup");
        {
            let mut store = ShardedHessianStore::new(&keys(n), tiny_budget(n), &dir);
            for (key, data) in &streams(n) {
                store.add_rows(key, data, n);
            }
            store.check().unwrap();
            assert!(store.spill_count() > 0);
            assert!(dir.exists(), "spill dir created on demand");
        }
        assert!(!dir.exists(), "drop removes spill files and the empty dir");
    }
}
