//! `quip` — the command-line entry point.
//!
//! ```text
//! quip quantize --model s1 --bits 2 [--rounder ldlq] [--transform kron]
//!               [--baseline] [--out path.qz]
//!               [--checkpoint-dir DIR [--resume]]
//!               [--hessian-mem-budget BYTES] [--layer-workers N]
//!               [--inject-fault point@n[:kill|torn|panic]]...
//!               # --checkpoint-dir journals each finished block (.qzp +
//!               # manifest, DESIGN.md §10); --resume replays it and
//!               # continues — byte-identical to an uninterrupted run.
//!               # --hessian-mem-budget caps resident Hessian accumulator
//!               # bytes (k/m/g suffixes; 0 = unlimited), spilling cold
//!               # accumulators to CRC-framed files; --layer-workers sets
//!               # the across-layer quantization pool size (0 = auto).
//!               # Either way the artifact is bit-identical (DESIGN.md
//!               # §11). --inject-fault (repeatable) arms deterministic
//!               # crash points (hard mode: the process exits 137).
//! quip eval     --model s1 [--qz path.qz]
//! quip gen      --model s1 [--qz path.qz] --prompt "3,17,9" --max-tokens 32
//! quip serve    --model s1 [--qz path.qz] [--addr 127.0.0.1:7077]
//!               [--max-batch 8] [--contig] [--kv-pages N] [--page-tokens 16]
//!               [--reserve-tokens 32] [--admit-timeout-ms 2000]
//!               [--trace-out trace.json] [--drain-timeout-ms 5000]
//!               # paged KV pool with prefix sharing + admission control
//!               # (default); --contig = contiguous per-sequence caches.
//!               # The TCP protocol also answers the control commands
//!               # `metrics` (Prometheus text exposition, `# EOF`
//!               # terminated), `stats` (one-line JSON summary), `healthz`
//!               # and `shutdown` (graceful drain: stop admission, finish
//!               # in-flight requests within --drain-timeout-ms, flush
//!               # --trace-out, exit); --trace-out writes Chrome
//!               # trace-event JSON (chrome://tracing / Perfetto) on
//!               # shutdown and periodically while serving
//! quip pjrt     --model s0 [--bits 2]          # AOT artifact smoke-run
//! quip inspect  <file.qz>                      # artifact introspection
//! quip table    <1|2|3|4|5|6|14|15|16|optq|all> [--fast]
//! quip figure   <1|2|3|4|5|all> [--fast]
//! quip sweep    <rho|calib|greedy|batch|transform|quant|codebook|serve|session>
//!               [--fast]
//!               # batch = serving tokens/sec vs batch size;
//!               # transform = kron vs hadamard incoherence backends;
//!               # quant = quantize-throughput stages, scalar vs blocked
//!               #         (accumulate / factorize / round);
//!               # codebook = scalar-LDLQ vs E8-style vq at equal bitrate;
//!               # serve = contig vs paged KV (bytes/token, tok/s,
//!               #         prefix sharing, shed rate under overload);
//!               # session = crash-resume drill: quantize, kill at a
//!               #         seeded block boundary, resume, verify the
//!               #         artifact is byte-identical + report overhead;
//!               # batch, transform, quant, codebook, serve, session are
//!               # artifact-free
//! quip info
//! ```
//!
//! `--rounder` (alias `--method`) accepts any `RounderRegistry` name or
//! alias: `near[est]`, `stoch[astic]`, `ldlq`/`quip`, `ldlq-rg`/`quip-rg`,
//! `greedy`/`allbal`, `optq`/`gptq`, `alg5`/`ldlbal_admm`,
//! `vq`/`codebook`/`e8` (the QuIP#-style E8 vector codebook; even bit
//! widths only). `--transform` picks the incoherence backend: `kron` (the
//! paper's Kronecker operator, default), `hadamard` (the QuIP# randomized
//! Hadamard transform), or `none` (skip the conjugation step). Flags are
//! assembled into a `QuantConfig` with `QuantConfig::builder()` —
//! `quant_config` below is the one place CLI names meet the quantization
//! API.

use quip::coordinator::server::{EngineKind, Server, ServerConfig};
use quip::engine::native::{FpLinears, QuantLinears};
use quip::harness::{env::Env, run_figure, run_table};
use quip::model::quantized::QuantizedModel;
use quip::model::Transformer;
use quip::quant::{Processing, QuantConfig};
use quip::util::cli::Args;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let result = match args.pos(0) {
        Some("quantize") => cmd_quantize(&args),
        Some("eval") => cmd_eval(&args),
        Some("gen") => cmd_gen(&args),
        Some("serve") => cmd_serve(&args),
        Some("pjrt") => cmd_pjrt(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("table") => run_table(args.pos(1).unwrap_or("all"), &args),
        Some("sweep") => {
            quip::harness::sweeps::run_sweep(args.pos(1).unwrap_or("rho"), &args)
        }
        Some("figure") => run_figure(args.pos(1).unwrap_or("all"), &args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: quip <quantize|eval|gen|serve|pjrt|inspect|table|figure|sweep|info> \
                 [options]"
            );
            eprintln!("see `quip info` and README.md");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// CLI flags → [`QuantConfig`], via the builder + rounder registry.
/// `--rounder` / `--method` are interchangeable (`--rounder` is the
/// canonical spelling; `--method` predates the registry).
/// `--transform {kron,hadamard,none}` selects the incoherence backend;
/// `none` keeps the rest of IncP but skips the conjugation step.
fn quant_config(args: &Args) -> quip::Result<QuantConfig> {
    let mut processing = if args.flag("baseline") {
        Processing::baseline()
    } else {
        Processing::incoherent()
    };
    match args.opt_or("transform", "kron").as_str() {
        "none" => processing.incoherent = false,
        name => processing.transform = quip::linalg::TransformKind::parse(name)?,
    }
    let rounder = args
        .opt("rounder")
        .map(str::to_string)
        .unwrap_or_else(|| args.opt_or("method", "ldlq"));
    QuantConfig::builder()
        .bits(args.opt_usize("bits", 2) as u32)
        .rounder(&rounder)
        .processing(processing)
        .greedy_passes(args.opt_usize("greedy-passes", 5))
        .force_stochastic(args.flag("stochastic"))
        .alg5_c(args.opt_f64("alg5-c", 0.3))
        .build()
}

/// Every `--inject-fault point@n[:mode]` occurrence on the command line
/// (the option may repeat to arm several fault points at once).
fn fault_specs(args: &Args) -> Vec<String> {
    args.options
        .iter()
        .filter(|(k, _)| k == "inject-fault")
        .map(|(_, v)| v.clone())
        .collect()
}

/// The checkpoint/resume + fault-injection quantization path (DESIGN.md
/// §10): drives a [`quip::coordinator::QuantSession`] directly so the
/// `.qzp` journal, `--resume` replay and hard-mode fault points all
/// compose. `--inject-fault` kills are *hard* here — the process exits
/// 137 exactly like a real crash; rerun with `--resume` to continue.
fn quantize_with_session(
    args: &Args,
    env: &Env,
    model: &str,
    quant: QuantConfig,
) -> quip::Result<(QuantizedModel, f64)> {
    use quip::coordinator::{PipelineConfig, QuantSession};
    let ck = env.checkpoint(model)?;
    let calib = env.calibration(ck.config.max_seq.min(128))?;
    let specs = fault_specs(args);
    let faults = if specs.is_empty() {
        None
    } else {
        Some(Arc::new(quip::util::fault::FaultInjector::from_args(
            &specs,
            false, // hard: fire = process exit, like a real crash
            args.opt_u64("fault-seed", 0x5EED),
        )?))
    };
    let pcfg = PipelineConfig {
        quant,
        calib_seqs: env.calib_seqs,
        calib_seq_len: 128,
        seed: 0x5155_4950,
        faults,
        hessian_mem_budget: args.opt_bytes("hessian-mem-budget", 0),
        layer_workers: args.opt_usize("layer-workers", 0),
    };
    let session = match args.opt("checkpoint-dir") {
        None => {
            anyhow::ensure!(!args.flag("resume"), "--resume requires --checkpoint-dir");
            QuantSession::new(&ck, pcfg)?
        }
        Some(dir) => {
            let dir = std::path::Path::new(dir);
            if args.flag("resume") {
                QuantSession::resume(&ck, pcfg, dir)?
            } else {
                QuantSession::new(&ck, pcfg)?.with_checkpoint_dir(dir)?
            }
        }
    };
    let (qm, report) = session.run(&calib)?;
    for (block, error) in &report.failed_blocks {
        eprintln!("warning: block {block} failed and was skipped: {error}");
    }
    Ok((qm, report.total_proxy()))
}

fn cmd_quantize(args: &Args) -> quip::Result<()> {
    let env = Env::load(args)?;
    let model = args.opt_or("model", "s1");
    let cfg = quant_config(args)?;
    let bits = cfg.bits;
    println!(
        "quantizing {model} to {bits} bits with {} + {}",
        cfg.method.name(),
        if cfg.processing.incoherent {
            format!("IncP/{}", cfg.processing.transform)
        } else {
            "baseline".to_string()
        }
    );
    let t0 = std::time::Instant::now();
    let (qm, proxy) = if args.opt("checkpoint-dir").is_some()
        || args.flag("resume")
        || args.opt("hessian-mem-budget").is_some()
        || args.opt("layer-workers").is_some()
        || !fault_specs(args).is_empty()
    {
        quantize_with_session(args, &env, &model, cfg)?
    } else {
        env.quantize(&model, cfg)?
    };
    let out = args.opt_or(
        "out",
        &format!("results/{model}_q{bits}_{}.qz", qm.recipe),
    );
    let path = std::path::PathBuf::from(&out);
    qm.save(&path)?;
    println!(
        "done in {:.1}s — total proxy loss {proxy:.4}, {:.2} bits/weight → {out}",
        t0.elapsed().as_secs_f64(),
        qm.bits_per_weight()
    );
    Ok(())
}

fn load_model_pair(
    args: &Args,
    env: &Env,
) -> quip::Result<(Transformer, Option<QuantizedModel>)> {
    let model = args.opt_or("model", "s1");
    let ck = env.checkpoint(&model)?;
    let mut m = Transformer::from_checkpoint(&ck)?;
    let qm = if let Some(path) = args.opt("qz") {
        let qm = QuantizedModel::load(std::path::Path::new(path))?;
        qm.apply_to(&mut m)?;
        Some(qm)
    } else {
        None
    };
    Ok((m, qm))
}

fn cmd_eval(args: &Args) -> quip::Result<()> {
    let env = Env::load(args)?;
    let (m, qm) = load_model_pair(args, &env)?;
    println!(
        "evaluating {} ({})",
        m.cfg.name,
        qm.as_ref().map(|q| q.recipe.as_str()).unwrap_or("fp32")
    );
    let r = env.evaluate(&m);
    for s in quip::harness::env::SPLITS {
        println!("  ppl[{s}] = {:.3}", r.ppl[s]);
    }
    for t in quip::harness::env::TASKS {
        println!("  acc[{t}] = {:.1}%", 100.0 * r.acc[t]);
    }
    Ok(())
}

fn cmd_gen(args: &Args) -> quip::Result<()> {
    let env = Env::load(args)?;
    let (m, qm) = load_model_pair(args, &env)?;
    let vocab = quip::data::Vocab::load(&env.registry.vocab())?;
    let prompt: Vec<u32> = args
        .opt_or("prompt", "1")
        .split(',')
        .filter_map(|t| t.trim().parse().ok())
        .collect();
    let params = quip::coordinator::generate::GenParams {
        max_tokens: args.opt_usize("max-tokens", 32),
        temperature: args.opt_f64("temperature", 0.0),
        seed: args.opt_u64("seed", 0),
        stop_token: None,
    };
    let gen = match &qm {
        Some(q) => {
            let lin = QuantLinears::from_model(q)?;
            quip::coordinator::generate::generate(&m, &lin, &prompt, &params)
        }
        None => {
            let lin = FpLinears { model: &m };
            quip::coordinator::generate::generate(&m, &lin, &prompt, &params)
        }
    };
    println!("prompt : {}", vocab.decode(&prompt));
    println!("output : {}", vocab.decode(&gen.tokens));
    println!(
        "prefill {:.1}ms, decode {:.2}ms/token",
        gen.prefill_seconds * 1e3,
        gen.decode_seconds * 1e3 / gen.tokens.len().max(1) as f64
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> quip::Result<()> {
    let env = Env::load(args)?;
    let (m, qm) = load_model_pair(args, &env)?;
    let engine = EngineKind::auto(qm);
    let defaults = ServerConfig::default();
    let cfg = ServerConfig {
        addr: args.opt_or("addr", "127.0.0.1:7077"),
        max_batch: args.opt_usize("max-batch", 8),
        // Paged KV pool (default); --contig restores per-sequence
        // max_seq-sized caches. --kv-pages 0 auto-sizes the pool so an
        // admitted sequence can never stall mid-flight.
        paged: !args.flag("contig"),
        kv_pages: args.opt_usize("kv-pages", 0),
        page_tokens: args.opt_usize("page-tokens", defaults.page_tokens),
        reserve_tokens: args.opt_usize("reserve-tokens", defaults.reserve_tokens),
        admit_timeout: std::time::Duration::from_millis(
            args.opt_u64("admit-timeout-ms", defaults.admit_timeout.as_millis() as u64),
        ),
        trace_out: args.opt("trace-out").map(str::to_string),
        drain_timeout: std::time::Duration::from_millis(
            args.opt_u64("drain-timeout-ms", defaults.drain_timeout.as_millis() as u64),
        ),
        ..defaults
    };
    let trace_out = cfg.trace_out.clone();
    let mut server = Server::start(Arc::new(m), engine, cfg)?;
    println!("serving on {} — newline-JSON protocol; Ctrl-C to stop", server.addr);
    println!(
        "control commands: metrics (Prometheus), stats (JSON), healthz, \
         shutdown (graceful drain)"
    );
    let mut last_report = std::time::Instant::now();
    while !server.draining() {
        std::thread::sleep(std::time::Duration::from_millis(250));
        if last_report.elapsed() >= std::time::Duration::from_secs(5) {
            last_report = std::time::Instant::now();
            println!("metrics: {}", server.metrics.summary());
            // Periodic flush so a killed process still leaves a usable
            // trace; shutdown() writes the final version of the same file.
            if let Some(path) = &trace_out {
                if let Err(e) = server.trace.write_chrome_trace(path) {
                    eprintln!("warning: trace flush to {path} failed: {e:#}");
                }
            }
        }
    }
    // A client sent `shutdown`: in-flight requests finish (bounded by the
    // drain budget), then join the threads and flush the final trace.
    println!("shutdown requested — draining in-flight requests");
    server.shutdown();
    println!("drained; final metrics: {}", server.metrics.summary());
    Ok(())
}

fn cmd_pjrt(args: &Args) -> quip::Result<()> {
    use quip::engine::PjrtLm;
    use quip::runtime::PjrtRuntime;
    let env = Env::load(args)?;
    let model = args.opt_or("model", "s0");
    let ck = env.checkpoint(&model)?;
    let rt = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    // fp32 artifact
    let spec = env
        .registry
        .find_fp32(&model, 1)
        .ok_or_else(|| anyhow::anyhow!("no fp32 artifact for {model} (run make artifacts)"))?;
    let lm = PjrtLm::fp32(&rt, spec, &ck)?;
    let stream = &env.splits["wiki"];
    let seq = stream.tokens[..spec.seq].to_vec();
    let t0 = std::time::Instant::now();
    let logits = lm.logits(&[seq.clone()])?;
    println!(
        "fp32 forward ok: {} logits in {:.1}ms",
        logits.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );

    // quantized artifact
    let bits = args.opt_usize("bits", 2) as u32;
    if let Some(qspec) = env.registry.find_quant(&model, bits) {
        let (qm, _) = env.quantize(
            &model,
            QuantConfig::builder()
                .bits(bits)
                .rounder("quip")
                .processing(Processing::incoherent())
                .build()?,
        )?;
        let qlm = PjrtLm::quant(&rt, qspec, &ck, &qm)?;
        let t1 = std::time::Instant::now();
        let qlogits = qlm.logits(&[seq])?;
        println!(
            "quant-{bits} forward ok: {} logits in {:.1}ms (Pallas kernel inside)",
            qlogits.len(),
            t1.elapsed().as_secs_f64() * 1e3
        );
        // Cross-check against the native dequantized model.
        let mut m = Transformer::from_checkpoint(&ck)?;
        qm.apply_to(&mut m)?;
        let native = m.forward(&stream.tokens[..spec.seq.min(m.cfg.max_seq)], None);
        let v = m.cfg.vocab;
        let mut max_rel: f64 = 0.0;
        for i in 0..native.len().min(qlogits.len()) {
            let d = (native[i] as f64 - qlogits[i] as f64).abs();
            max_rel = max_rel.max(d);
        }
        println!("native vs PJRT max |Δlogit| = {max_rel:.4} over {}x{v}", spec.seq);
    } else {
        println!("no quant artifact for {model} @ {bits} bits");
    }
    Ok(())
}

/// `quip inspect <file.qz>` — artifact introspection.
fn cmd_inspect(args: &Args) -> quip::Result<()> {
    let path = args
        .pos(1)
        .ok_or_else(|| anyhow::anyhow!("usage: quip inspect <file.qz>"))?;
    let qm = QuantizedModel::load(std::path::Path::new(path))?;
    println!("quantized model: {} ({})", qm.config.name, qm.recipe);
    println!(
        "  d={} layers={} heads={} dff={} vocab={}",
        qm.config.d_model, qm.config.n_layers, qm.config.n_heads, qm.config.d_ff, qm.config.vocab
    );
    println!(
        "  bits={}  layers={}  {:.3} bits/weight (incl. metadata)",
        qm.bits,
        qm.layers.len(),
        qm.bits_per_weight()
    );
    let total: usize = qm.layers.iter().map(|l| l.m * l.n).sum();
    println!("  quantized params: {total}");
    for l in qm.layers.iter().take(8) {
        println!(
            "  {:<16} {:>4}x{:<4}  packed {:>7}B  codes={} transform={} rescale={} grid={}",
            l.name,
            l.m,
            l.n,
            l.packed.len(),
            match l.layout {
                quip::quant::CodeLayout::Scalar => "scalar",
                quip::quant::CodeLayout::Vq { .. } => "vq8",
            },
            if l.post.incoherent {
                l.post.transform.name()
            } else {
                "none"
            },
            l.post.d_tilde.is_some(),
            match &l.post.grid {
                quip::quant::GridMap::PerRow { .. } => "per-row",
                quip::quant::GridMap::Global { .. } => "frobenius",
            }
        );
    }
    if qm.layers.len() > 8 {
        println!("  … {} more layers", qm.layers.len() - 8);
    }
    Ok(())
}

fn cmd_info(args: &Args) -> quip::Result<()> {
    println!("QuIP reproduction — three-layer Rust + JAX + Pallas stack");
    println!("models:");
    for cfg in quip::model::ModelConfig::series() {
        println!(
            "  {}  d={} L={} heads={} dff={}  ~{:.1}M params",
            cfg.name,
            cfg.d_model,
            cfg.n_layers,
            cfg.n_heads,
            cfg.d_ff,
            cfg.param_count() as f64 / 1e6
        );
    }
    match Env::load(args) {
        Ok(env) => {
            println!("artifacts: {} HLO artifacts", env.registry.artifacts.len());
            for a in &env.registry.artifacts {
                println!(
                    "  {} {} bits={} batch={}",
                    a.kind,
                    a.file.file_name().unwrap_or_default().to_string_lossy(),
                    a.bits,
                    a.batch
                );
            }
        }
        Err(e) => println!("artifacts: not built ({e})"),
    }
    Ok(())
}
