//! # QuIP — Quantization with Incoherence Processing
//!
//! A production-shaped reproduction of *QuIP: 2-Bit Quantization of Large
//! Language Models With Guarantees* (Chee, Cai, Kuleshov, De Sa — NeurIPS
//! 2023) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the run-time system: the complete QuIP
//!   quantization algorithm suite ([`quant`]), the Hessian-collection
//!   pipeline and serving coordinator ([`coordinator`]) — including a
//!   continuous-batching server whose fused batch kernel decodes packed
//!   2/3/4-bit codes tile-by-tile once per batch
//!   ([`engine::native::decode_step_batch`]) — a pure-Rust transformer
//!   inference engine and a PJRT engine executing AOT-compiled
//!   JAX/Pallas artifacts ([`engine`], [`runtime`]).
//! * **Layer 2 (python/compile/model.py)** — the JAX model forward lowered
//!   once, at build time, to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — the Pallas dequant-matmul
//!   kernel called by the JAX model.
//!
//! Python never runs on the request path: `make artifacts` produces
//! checkpoints + HLO text once, and the `quip` binary is self-contained
//! afterwards.
//!
//! ## Quickstart
//!
//! Rounding algorithms are [`quant::Rounder`] impls resolved by name (any
//! CLI alias works: `quip`, `gptq`, `allbal`, `vq`, …) through the
//! [`quant::RounderRegistry`]; the incoherence step is a pluggable
//! [`linalg::Transform`] backend selected by [`linalg::TransformKind`] —
//! the paper's Kronecker operator (`kron`, default) or QuIP#'s randomized
//! Hadamard transform (`hadamard`, O(n log n) with tighter incoherence
//! concentration); what rounders round *to* is a [`quant::Codebook`] —
//! the scalar integer grid, or the `vq` rounder's seeded E8-style
//! 8-dimensional vector codebook (QuIP#'s lattice-codebook idea, stored
//! as per-group indices in `.qz` v3 and decoded through a per-layer LUT;
//! DESIGN.md §6); configuration comes from
//! [`quant::QuantConfig::builder`]:
//!
//! ```no_run
//! use quip::linalg::Mat;
//! use quip::quant::{quantize_layer_with, Processing, QuantConfig, RounderRegistry};
//! use quip::util::rng::Rng;
//!
//! fn main() -> quip::Result<()> {
//!     let mut rng = Rng::new(0);
//!     let w = Mat::from_fn(16, 64, |_, _| rng.uniform(-1.0, 1.0));
//!     let h = quip::util::testkit::random_spd(&mut rng, 64, 1e-2);
//!
//!     let cfg = QuantConfig::builder()
//!         .bits(2)
//!         .rounder("quip") // alias of "ldlq"; try "gptq", "allbal", …
//!         .processing(Processing::incoherent())
//!         .build()?;
//!     let rounder = RounderRegistry::global().resolve("quip")?;
//!     let out = quantize_layer_with(rounder.as_ref(), &w, &h, &cfg, 0xC0FFEE);
//!     println!("proxy loss = {}", out.proxy_loss);
//!     Ok(())
//! }
//! ```
//!
//! Whole models go through the coordinator's
//! [`coordinator::QuantSession`]: explicit `collect_hessians` →
//! `quantize_block` → `swap_weights` stages per transformer block, typed
//! [`coordinator::PipelineEvent`] progress streaming — including
//! per-layer stage timings (Hessian-accumulate GB/s, factorize ms, round
//! ms; benchmark with `quip sweep quant`, numbers in EXPERIMENTS.md
//! §Perf 4) — and per-block cancellation. `coordinator::quantize_model`
//! is the one-shot wrapper.
//!
//! New rounding algorithms implement [`quant::Rounder`] (see the
//! `quant::rounder` module docs for the `wg`/`h` preprocessed-basis
//! contract) and register under a name — no core dispatch changes. New
//! incoherence operators implement [`linalg::Transform`] (seed-only
//! serialization, f64 matrix conjugation + f32 fused inference applies)
//! and gain a [`linalg::TransformKind`] code; quantizer, `.qz` artifacts
//! (v2 added the per-layer transform kind + CRC-32 footer, v3 adds the
//! per-layer code layout; v1 loads as `kron`, v1/v2 load as scalar) and
//! the native engine pick them up through [`linalg::make_transform`].
//!
//! Observability is first-class: every serving counter/gauge/histogram
//! lives in a central [`obs::registry::MetricRegistry`] with Prometheus
//! text exposition (the server's `metrics` protocol command), and both
//! the request path and the quantize pipeline record spans into an
//! [`obs::trace::TraceSink`] exported as Chrome trace-event JSON
//! (`quip serve --trace-out`); DESIGN.md §9.
//!
//! Repo-level documentation: README.md (build/CLI/repo map), DESIGN.md
//! (substrate substitutions, numerics, paper → substrate mapping),
//! EXPERIMENTS.md (measured results), PAPER.md (the source abstract).

pub mod util;
pub mod obs;
pub mod linalg;
pub mod quant;
pub mod hessian;
pub mod data;
pub mod model;
pub mod engine;
pub mod runtime;
pub mod coordinator;
pub mod harness;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
