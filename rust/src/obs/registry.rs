//! Central metric registry: named counter/gauge/histogram handles with a
//! lock-free record path and deterministic Prometheus text exposition.
//!
//! Handles are cheap `Arc`-shared atomics handed out once at
//! registration; recording (`fetch_add`/`store`/`record`) touches only
//! the atomics. The catalog itself is a BTreeMap keyed by metric name so
//! [`MetricRegistry::render_prometheus`] iterates in sorted order —
//! exposition is a pure function of the recorded state.
//!
//! Metric names are validated *statically* by the
//! `tools/preflight/checks/metricnames.py` lint: every name registered
//! in non-test code must be unique, `snake_case`, and match the
//! Prometheus grammar `[a-z_][a-z0-9_]*`. The registry itself is
//! therefore free to treat re-registration of an existing name as a
//! lookup (it returns the existing handle).

use crate::util::sync::lock_unpoisoned;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Number of log-spaced histogram buckets (the last is the overflow).
pub const BUCKETS: usize = 40;
/// Lower edge of the histogram's log-spaced region, in seconds: bucket 0
/// covers `[0, BASE)`, bucket i (for 1 ≤ i < BUCKETS−1) covers
/// `[BASE·GROWTH^(i−1), BASE·GROWTH^i)`, and the final bucket is the
/// `+Inf` overflow. (The upper edge of bucket i is `BASE·GROWTH^i`.)
pub const BASE: f64 = 1e-5;
/// Geometric growth factor between consecutive bucket edges.
pub const GROWTH: f64 = 1.45;

/// Map a sample to its bucket under the scheme documented on [`BASE`]:
/// `[0, BASE)` → 0, `[BASE·GROWTH^(i−1), BASE·GROWTH^i)` → i, overflow
/// → `BUCKETS − 1`.
pub fn bucket_index(seconds: f64) -> usize {
    let mut idx = 0usize;
    let mut bound = BASE;
    while idx < BUCKETS - 1 && seconds >= bound {
        bound *= GROWTH;
        idx += 1;
    }
    idx
}

/// Approximate quantile from per-bucket counts. Returns the *upper edge*
/// (`BASE·GROWTH^i` for bucket i) of the first bucket at which the
/// cumulative count reaches `⌈q·total⌉`, or 0.0 when empty. Because the
/// edge returned is the upper one, the estimate biases high by at most
/// one bucket factor (×[`GROWTH`]).
pub fn quantile_from(counts: &[u64], q: f64) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let target = (q * total as f64).ceil() as u64;
    let mut acc = 0u64;
    let mut bound = BASE;
    for &c in counts.iter() {
        acc += c;
        if acc >= target {
            return bound;
        }
        bound *= GROWTH;
    }
    bound
}

/// Monotonically increasing metric. The API mirrors `AtomicU64` so call
/// sites written against raw atomics keep working unchanged.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    fn new() -> Counter {
        Counter(Arc::new(AtomicU64::new(0)))
    }

    pub fn fetch_add(&self, v: u64, order: Ordering) -> u64 {
        self.0.fetch_add(v, order)
    }

    pub fn load(&self, order: Ordering) -> u64 {
        self.0.load(order)
    }

    /// Convenience for the common `fetch_add(1, Relaxed)`.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Set-to-current-value metric (pool occupancy, high-water marks). Same
/// `AtomicU64`-shaped API as [`Counter`], plus `store`/`fetch_max`.
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    fn new() -> Gauge {
        Gauge(Arc::new(AtomicU64::new(0)))
    }

    pub fn store(&self, v: u64, order: Ordering) {
        self.0.store(v, order);
    }

    pub fn load(&self, order: Ordering) -> u64 {
        self.0.load(order)
    }

    pub fn fetch_add(&self, v: u64, order: Ordering) -> u64 {
        self.0.fetch_add(v, order)
    }

    pub fn fetch_max(&self, v: u64, order: Ordering) -> u64 {
        self.0.fetch_max(v, order)
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

struct HistogramCore {
    counts: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
    count: AtomicU64,
}

/// Log-spaced latency histogram (seconds). Sample sums are kept in
/// integer microseconds so the record path stays a pair of relaxed
/// atomic adds.
#[derive(Clone)]
pub struct Histogram(Arc<HistogramCore>);

impl Histogram {
    fn new() -> Histogram {
        Histogram(Arc::new(HistogramCore {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }))
    }

    pub fn record(&self, seconds: f64) {
        self.0.counts[bucket_index(seconds)].fetch_add(1, Ordering::Relaxed);
        self.0
            .sum_us
            .fetch_add((seconds * 1e6) as u64, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Per-bucket counts (not cumulative), oldest-to-largest edge.
    pub fn counts(&self) -> Vec<u64> {
        self.0
            .counts
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum_seconds(&self) -> f64 {
        self.0.sum_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Mean over *recorded samples* (the histogram's own count, never an
    /// external counter — see `Metrics::mean_latency`).
    pub fn mean_seconds(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_seconds() / n as f64
    }

    pub fn quantile(&self, q: f64) -> f64 {
        quantile_from(&self.counts(), q)
    }
}

/// Central catalog of named metrics. Registration and rendering lock;
/// recording through the returned handles never does.
pub struct MetricRegistry {
    counters: Mutex<BTreeMap<String, (String, Counter)>>,
    gauges: Mutex<BTreeMap<String, (String, Gauge)>>,
    histograms: Mutex<BTreeMap<String, (String, Histogram)>>,
}

impl MetricRegistry {
    pub fn new() -> MetricRegistry {
        MetricRegistry {
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            histograms: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn shared() -> Arc<MetricRegistry> {
        Arc::new(MetricRegistry::new())
    }

    /// Register (or look up) a counter. Names must satisfy the
    /// metric-name policy checked by preflight; re-registering a name
    /// returns the existing handle.
    pub fn counter(&self, name: &str, help: &str) -> Counter {
        let mut map = lock_unpoisoned(&self.counters);
        map.entry(name.to_string())
            .or_insert_with(|| (help.to_string(), Counter::new()))
            .1
            .clone()
    }

    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        let mut map = lock_unpoisoned(&self.gauges);
        map.entry(name.to_string())
            .or_insert_with(|| (help.to_string(), Gauge::new()))
            .1
            .clone()
    }

    pub fn histogram(&self, name: &str, help: &str) -> Histogram {
        let mut map = lock_unpoisoned(&self.histograms);
        map.entry(name.to_string())
            .or_insert_with(|| (help.to_string(), Histogram::new()))
            .1
            .clone()
    }

    /// Render the whole catalog in Prometheus text exposition format:
    /// one `# HELP`/`# TYPE` header pair per family, families in sorted
    /// name order (counters, gauges and histograms interleaved), and
    /// cumulative `_bucket`/`_sum`/`_count` series for histograms. Ends
    /// with an OpenMetrics-style `# EOF` line so line-oriented clients
    /// know where the scrape stops.
    pub fn render_prometheus(&self) -> String {
        // Snapshot each family under its lock, then render lock-free.
        let counters: Vec<(String, String, u64)> = lock_unpoisoned(&self.counters)
            .iter()
            .map(|(n, (h, c))| (n.clone(), h.clone(), c.get()))
            .collect();
        let gauges: Vec<(String, String, u64)> = lock_unpoisoned(&self.gauges)
            .iter()
            .map(|(n, (h, g))| (n.clone(), h.clone(), g.get()))
            .collect();
        let hists: Vec<(String, String, Histogram)> = lock_unpoisoned(&self.histograms)
            .iter()
            .map(|(n, (h, hist))| (n.clone(), h.clone(), hist.clone()))
            .collect();

        let mut blocks: Vec<(String, String)> = Vec::new();
        for (name, help, v) in &counters {
            blocks.push((
                name.clone(),
                format!("# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"),
            ));
        }
        for (name, help, v) in &gauges {
            blocks.push((
                name.clone(),
                format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"),
            ));
        }
        for (name, help, hist) in &hists {
            let counts = hist.counts();
            let mut s = format!("# HELP {name} {help}\n# TYPE {name} histogram\n");
            let mut acc = 0u64;
            let mut bound = BASE;
            for (i, &c) in counts.iter().enumerate() {
                acc += c;
                if i + 1 == counts.len() {
                    s.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {acc}\n"));
                } else {
                    s.push_str(&format!("{name}_bucket{{le=\"{bound}\"}} {acc}\n"));
                    bound *= GROWTH;
                }
            }
            s.push_str(&format!("{name}_sum {}\n", hist.sum_seconds()));
            s.push_str(&format!("{name}_count {}\n", hist.count()));
            blocks.push((name.clone(), s));
        }
        blocks.sort_by(|a, b| a.0.cmp(&b.0));
        let mut out = String::new();
        for (_, block) in blocks {
            out.push_str(&block);
        }
        out.push_str("# EOF\n");
        out
    }
}

impl Default for MetricRegistry {
    fn default() -> Self {
        Self::new()
    }
}

/// Structural validation of a Prometheus text exposition, used by the
/// serve sweep's scrape self-check and the golden tests. Verifies that
/// every sample line parses as `name[{labels}] value`, every sample
/// belongs to a `# TYPE`-declared family, histogram buckets are
/// cumulative (non-decreasing) and end at `+Inf` equal to `_count`, and
/// every histogram carries `_sum`/`_count`.
pub fn validate_prometheus_text(text: &str) -> crate::Result<()> {
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    // histogram name -> (last cumulative value, saw +Inf, inf value)
    let mut hist_state: BTreeMap<String, (u64, bool, u64)> = BTreeMap::new();
    let mut hist_sum: BTreeMap<String, bool> = BTreeMap::new();
    let mut hist_count: BTreeMap<String, u64> = BTreeMap::new();

    fn valid_name(name: &str) -> bool {
        !name.is_empty()
            && name
                .chars()
                .next()
                .is_some_and(|c| c.is_ascii_lowercase() || c == '_')
            && name
                .chars()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
    }

    for (lineno, line) in text.lines().enumerate() {
        let n = lineno + 1;
        if line.is_empty() || line == "# EOF" {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.splitn(2, ' ');
            let name = it.next().unwrap_or("");
            let kind = it.next().unwrap_or("");
            anyhow::ensure!(valid_name(name), "line {n}: bad family name `{name}`");
            anyhow::ensure!(
                matches!(kind, "counter" | "gauge" | "histogram"),
                "line {n}: unknown metric type `{kind}`"
            );
            anyhow::ensure!(
                types.insert(name.to_string(), kind.to_string()).is_none(),
                "line {n}: duplicate # TYPE for `{name}`"
            );
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP or other comment
        }
        // Sample: name[{labels}] value
        let (name_part, value_part) = match line.rsplit_once(' ') {
            Some(pair) => pair,
            None => anyhow::bail!("line {n}: sample has no value: `{line}`"),
        };
        let value: f64 = value_part
            .parse()
            .map_err(|_| anyhow::anyhow!("line {n}: unparseable value `{value_part}`"))?;
        let (name, labels) = match name_part.split_once('{') {
            Some((name, rest)) => {
                anyhow::ensure!(rest.ends_with('}'), "line {n}: unclosed label set");
                (name, Some(&rest[..rest.len() - 1]))
            }
            None => (name_part, None),
        };
        // Resolve the sample to its family (histograms suffix the name).
        let family = if let Some(base) = name.strip_suffix("_bucket") {
            anyhow::ensure!(
                types.get(base).map(String::as_str) == Some("histogram"),
                "line {n}: `_bucket` sample for non-histogram `{base}`"
            );
            let le = labels
                .and_then(|l| l.strip_prefix("le=\""))
                .and_then(|l| l.strip_suffix('"'))
                .ok_or_else(|| anyhow::anyhow!("line {n}: bucket without le label"))?;
            let cum = value as u64;
            let st = hist_state.entry(base.to_string()).or_insert((0, false, 0));
            anyhow::ensure!(
                cum >= st.0,
                "line {n}: bucket series for `{base}` not cumulative ({cum} < {})",
                st.0
            );
            st.0 = cum;
            if le == "+Inf" {
                st.1 = true;
                st.2 = cum;
            } else {
                anyhow::ensure!(!st.1, "line {n}: bucket after +Inf for `{base}`");
                anyhow::ensure!(
                    le.parse::<f64>().is_ok(),
                    "line {n}: unparseable le bound `{le}`"
                );
            }
            base
        } else if let Some(base) = name.strip_suffix("_sum") {
            if types.get(base).map(String::as_str) == Some("histogram") {
                hist_sum.insert(base.to_string(), true);
                base
            } else {
                name // a plain metric that merely ends in _sum
            }
        } else if let Some(base) = name.strip_suffix("_count") {
            if types.get(base).map(String::as_str) == Some("histogram") {
                hist_count.insert(base.to_string(), value as u64);
                base
            } else {
                name
            }
        } else {
            name
        };
        anyhow::ensure!(valid_name(family), "line {n}: bad metric name `{family}`");
        anyhow::ensure!(
            types.contains_key(family),
            "line {n}: sample `{name}` has no # TYPE declaration"
        );
        anyhow::ensure!(value.is_finite(), "line {n}: non-finite value");
    }
    for (name, kind) in &types {
        if kind != "histogram" {
            continue;
        }
        let st = hist_state
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("histogram `{name}` has no buckets"))?;
        anyhow::ensure!(st.1, "histogram `{name}` missing +Inf bucket");
        anyhow::ensure!(
            hist_sum.contains_key(name),
            "histogram `{name}` missing _sum"
        );
        let count = hist_count
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("histogram `{name}` missing _count"))?;
        anyhow::ensure!(
            *count == st.2,
            "histogram `{name}`: +Inf bucket {} != _count {count}",
            st.2
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_record_lock_free_and_render() {
        let reg = MetricRegistry::new();
        let c = reg.counter("reqs_total", "Requests seen.");
        let g = reg.gauge("pool_pages", "Pages in use.");
        c.inc();
        c.fetch_add(2, Ordering::Relaxed);
        g.set(7);
        assert_eq!(c.get(), 3);
        assert_eq!(g.get(), 7);
        let text = reg.render_prometheus();
        assert!(text.contains("# TYPE reqs_total counter"));
        assert!(text.contains("reqs_total 3"));
        assert!(text.contains("# TYPE pool_pages gauge"));
        assert!(text.contains("pool_pages 7"));
        assert!(text.ends_with("# EOF\n"));
        validate_prometheus_text(&text).unwrap();
    }

    #[test]
    fn reregistration_returns_the_same_handle() {
        let reg = MetricRegistry::new();
        let a = reg.counter("shared_total", "x");
        let b = reg.counter("shared_total", "x");
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2, "both handles view one atom");
    }

    #[test]
    fn render_golden_output_is_sorted_and_exact() {
        // Registration order is deliberately unsorted; exposition must
        // come out in name order with exact header/sample shape.
        let reg = MetricRegistry::new();
        let z = reg.counter("zz_total", "Last alphabetically.");
        let a = reg.gauge("aa_level", "First alphabetically.");
        z.fetch_add(5, Ordering::Relaxed);
        a.set(2);
        let expect = "# HELP aa_level First alphabetically.\n\
                      # TYPE aa_level gauge\n\
                      aa_level 2\n\
                      # HELP zz_total Last alphabetically.\n\
                      # TYPE zz_total counter\n\
                      zz_total 5\n\
                      # EOF\n";
        assert_eq!(reg.render_prometheus(), expect);
    }

    #[test]
    fn histogram_exposition_is_cumulative_and_consistent() {
        let reg = MetricRegistry::new();
        let h = reg.histogram("lat_seconds", "Latency.");
        for &s in &[1e-6, 5e-5, 5e-5, 1e-3, 2.0, 100.0] {
            h.record(s);
        }
        let text = reg.render_prometheus();
        validate_prometheus_text(&text).unwrap();
        // Cumulative buckets: non-decreasing, ending at +Inf == count.
        let cum: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("lat_seconds_bucket"))
            .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
            .collect();
        assert_eq!(cum.len(), BUCKETS);
        assert!(cum.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*cum.last().unwrap(), 6);
        assert!(text.contains("lat_seconds_count 6"));
        // First bucket [0, 1e-5) holds exactly the 1e-6 sample.
        assert_eq!(cum[0], 1);
        // _sum is the microsecond-truncated sample total.
        let sum_line = text
            .lines()
            .find(|l| l.starts_with("lat_seconds_sum"))
            .unwrap();
        let sum: f64 = sum_line.rsplit(' ').next().unwrap().parse().unwrap();
        assert!((sum - 102.001101).abs() < 1e-5, "sum={sum}");
    }

    #[test]
    fn histogram_mean_uses_its_own_count() {
        let h = MetricRegistry::new().histogram("m_seconds", "x");
        for _ in 0..10 {
            h.record(0.01);
        }
        assert!((h.mean_seconds() - 0.01).abs() < 1e-3);
        assert_eq!(h.count(), 10);
    }

    #[test]
    fn bucket_index_matches_documented_edges() {
        // Bucket 0 is [0, BASE); bucket i is [BASE·G^(i-1), BASE·G^i).
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(BASE * 0.999), 0);
        assert_eq!(bucket_index(BASE), 1);
        assert_eq!(bucket_index(BASE * GROWTH * 0.999), 1);
        assert_eq!(bucket_index(BASE * GROWTH), 2);
        assert_eq!(bucket_index(1e9), BUCKETS - 1);
    }

    #[test]
    fn quantile_returns_upper_edge() {
        let mut counts = vec![0u64; BUCKETS];
        counts[0] = 4; // all mass in [0, BASE)
        assert_eq!(quantile_from(&counts, 0.5), BASE);
        counts[2] = 96; // p95 lands in bucket 2 → upper edge BASE·G²
        let p95 = quantile_from(&counts, 0.95);
        assert!((p95 - BASE * GROWTH * GROWTH).abs() < 1e-12);
    }

    #[test]
    fn validator_rejects_malformed_exposition() {
        assert!(validate_prometheus_text("no_type_decl 1\n").is_err());
        assert!(validate_prometheus_text("# TYPE x widget\n").is_err());
        let non_cumulative = "# TYPE h histogram\n\
                              h_bucket{le=\"0.1\"} 5\n\
                              h_bucket{le=\"+Inf\"} 3\n\
                              h_sum 1\nh_count 3\n";
        assert!(validate_prometheus_text(non_cumulative).is_err());
        let inf_vs_count = "# TYPE h histogram\n\
                            h_bucket{le=\"+Inf\"} 3\n\
                            h_sum 1\nh_count 4\n";
        assert!(validate_prometheus_text(inf_vs_count).is_err());
    }
}
