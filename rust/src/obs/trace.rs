//! Lightweight span tracing with Chrome trace-event export.
//!
//! A [`TraceSink`] owns one monotonic epoch and a bounded ring buffer of
//! events. Producers record *complete* spans (`ph:"X"`, a start + a
//! duration) or *instant* events (`ph:"i"`) tagged with a `tid` lane —
//! the per-request trace id minted at admission for serve spans, or the
//! block index for quantize spans. Everything shares the sink's single
//! timeline, so a serve run and a quantize run traced into the same sink
//! line up in one Chrome (`chrome://tracing` / Perfetto) view.
//!
//! The ring is bounded: when full, the *oldest* events are dropped and
//! counted (`dropped_events` in the export), never the newest — a
//! long-running server keeps the recent window. Recording takes one
//! short mutex hold; nothing on the serve path ever blocks on a full
//! buffer or on export.
//!
//! The module also hosts the thread-local *stage ledger*
//! ([`credit_stage`]/[`take_stage`]): a named wall-clock accumulator
//! that lets leaf kernels (factorization, the batched decode linears)
//! credit time to the span their caller is about to record without
//! widening any trait signatures. `util::stagetimer` is a façade over
//! this ledger.

use crate::util::json::Json;
use crate::util::sync::lock_unpoisoned;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Default ring capacity (events). At ~6 events per request this is tens
/// of thousands of requests of history.
pub const DEFAULT_TRACE_CAPACITY: usize = 65_536;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Phase {
    /// Complete span: `ts` + `dur`.
    Complete,
    /// Instant event at `ts`.
    Instant,
}

struct TraceEvent {
    name: String,
    cat: &'static str,
    phase: Phase,
    ts_us: u64,
    dur_us: u64,
    tid: u64,
    args: Vec<(String, Json)>,
}

struct Ring {
    events: VecDeque<TraceEvent>,
    dropped: u64,
}

/// Bounded collector of trace events on one shared monotonic timeline.
pub struct TraceSink {
    epoch: Instant,
    capacity: usize,
    next_trace: AtomicU64,
    ring: Mutex<Ring>,
}

impl TraceSink {
    pub fn new(capacity: usize) -> TraceSink {
        TraceSink {
            epoch: Instant::now(),
            capacity: capacity.max(1),
            next_trace: AtomicU64::new(1),
            ring: Mutex::new(Ring {
                events: VecDeque::new(),
                dropped: 0,
            }),
        }
    }

    pub fn shared(capacity: usize) -> Arc<TraceSink> {
        Arc::new(TraceSink::new(capacity))
    }

    /// Microseconds since this sink's epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Convert an externally captured [`Instant`] onto this timeline
    /// (clamped to 0 for instants predating the sink).
    pub fn ts_of(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Mint a fresh per-request trace id (used as the Chrome `tid` lane).
    pub fn mint_trace(&self) -> u64 {
        self.next_trace.fetch_add(1, Ordering::Relaxed)
    }

    fn push(&self, ev: TraceEvent) {
        let mut ring = lock_unpoisoned(&self.ring);
        if ring.events.len() >= self.capacity {
            ring.events.pop_front();
            ring.dropped += 1;
        }
        ring.events.push_back(ev);
    }

    /// Record a complete span from explicit timestamps (µs on this
    /// sink's timeline). Use [`TraceSink::span`] when the span brackets
    /// live code instead.
    pub fn complete(
        &self,
        tid: u64,
        name: &str,
        cat: &'static str,
        start_us: u64,
        dur_us: u64,
        args: Vec<(String, Json)>,
    ) {
        self.push(TraceEvent {
            name: name.to_string(),
            cat,
            phase: Phase::Complete,
            ts_us: start_us,
            dur_us,
            tid,
            args,
        });
    }

    /// Record an instant event (shed, eviction, damping escalation).
    pub fn instant(&self, tid: u64, name: &str, cat: &'static str, args: Vec<(String, Json)>) {
        self.push(TraceEvent {
            name: name.to_string(),
            cat,
            phase: Phase::Instant,
            ts_us: self.now_us(),
            dur_us: 0,
            tid,
            args,
        });
    }

    /// Open a live span; the returned guard records a complete event
    /// spanning its own lifetime when dropped.
    pub fn span(&self, tid: u64, name: &str, cat: &'static str) -> SpanGuard<'_> {
        SpanGuard {
            sink: self,
            name: name.to_string(),
            cat,
            tid,
            start_us: self.now_us(),
            args: Vec::new(),
        }
    }

    /// Events currently buffered (post-drop).
    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.ring).events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events evicted from the ring so far.
    pub fn dropped(&self) -> u64 {
        lock_unpoisoned(&self.ring).dropped
    }

    /// Export the buffered events as Chrome trace-event JSON (the
    /// object form: `{"traceEvents": [...]}`), loadable in
    /// `chrome://tracing` or Perfetto. Buffered order is preserved.
    pub fn to_chrome_json(&self) -> Json {
        let ring = lock_unpoisoned(&self.ring);
        let events: Vec<Json> = ring
            .events
            .iter()
            .map(|ev| {
                let mut o = Json::obj();
                o.set("name", Json::Str(ev.name.clone()));
                o.set("cat", Json::Str(ev.cat.to_string()));
                o.set(
                    "ph",
                    Json::Str(
                        match ev.phase {
                            Phase::Complete => "X",
                            Phase::Instant => "i",
                        }
                        .to_string(),
                    ),
                );
                o.set("ts", Json::Num(ev.ts_us as f64));
                if ev.phase == Phase::Complete {
                    o.set("dur", Json::Num(ev.dur_us as f64));
                } else {
                    o.set("s", Json::Str("t".to_string()));
                }
                o.set("pid", Json::Num(1.0));
                o.set("tid", Json::Num(ev.tid as f64));
                if !ev.args.is_empty() {
                    let mut a = Json::obj();
                    for (k, v) in &ev.args {
                        a.set(k, v.clone());
                    }
                    o.set("args", a);
                }
                o
            })
            .collect();
        let mut out = Json::obj();
        out.set("traceEvents", Json::Arr(events));
        out.set("displayTimeUnit", Json::Str("ms".to_string()));
        out.set("dropped_events", Json::Num(ring.dropped as f64));
        out
    }

    /// Write the Chrome trace JSON to `path` (overwrites atomically, so
    /// a kill mid-flush never leaves a half-written trace).
    pub fn write_chrome_trace(&self, path: &str) -> crate::Result<()> {
        crate::util::fsx::atomic_write(
            std::path::Path::new(path),
            self.to_chrome_json().to_string().as_bytes(),
        )
    }
}

/// Live span: records one complete event over its lifetime on drop.
pub struct SpanGuard<'a> {
    sink: &'a TraceSink,
    name: String,
    cat: &'static str,
    tid: u64,
    start_us: u64,
    args: Vec<(String, Json)>,
}

impl SpanGuard<'_> {
    /// Attach an argument shown in the trace viewer's detail pane.
    pub fn arg(&mut self, key: &str, value: Json) {
        self.args.push((key.to_string(), value));
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        let end = self.sink.now_us();
        self.sink.complete(
            self.tid,
            &self.name,
            self.cat,
            self.start_us,
            end.saturating_sub(self.start_us),
            std::mem::take(&mut self.args),
        );
    }
}

// --- thread-local stage ledger -----------------------------------------
//
// Leaf kernels credit named wall-clock here; the caller that owns the
// enclosing span drains the ledger and attaches the split as span args.
// A small Vec (not a map) keeps it allocation-light and deterministic;
// the stage set is tiny ("factorize", "decode_linear", …).

thread_local! {
    static STAGE_LEDGER: RefCell<Vec<(&'static str, f64)>> = const { RefCell::new(Vec::new()) };
}

/// Credit `seconds` of work to `stage` on the current thread's ledger.
pub fn credit_stage(stage: &'static str, seconds: f64) {
    STAGE_LEDGER.with(|l| {
        let mut ledger = l.borrow_mut();
        for (name, total) in ledger.iter_mut() {
            if *name == stage {
                *total += seconds;
                return;
            }
        }
        ledger.push((stage, seconds));
    });
}

/// Drain `stage` from the current thread's ledger, returning the total
/// credited since the last drain (0.0 when nothing was credited).
pub fn take_stage(stage: &str) -> f64 {
    STAGE_LEDGER.with(|l| {
        let mut ledger = l.borrow_mut();
        for (name, total) in ledger.iter_mut() {
            if *name == stage {
                return std::mem::take(total);
            }
        }
        0.0
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chrome_export_is_wellformed_json() {
        let sink = TraceSink::new(64);
        let tid = sink.mint_trace();
        {
            let mut s = sink.span(tid, "prefill", "serve");
            s.arg("tokens", Json::Num(12.0));
        }
        sink.instant(0, "shed", "serve", vec![("id".into(), Json::Num(3.0))]);
        let text = sink.to_chrome_json().to_string();
        let j = Json::parse(&text).unwrap();
        let events = j.req("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        let span = &events[0];
        assert_eq!(span.req_str("ph").unwrap(), "X");
        assert_eq!(span.req_str("name").unwrap(), "prefill");
        assert!(span.req_f64("dur").unwrap() >= 0.0);
        assert_eq!(
            span.req("args").unwrap().req_f64("tokens").unwrap(),
            12.0
        );
        let inst = &events[1];
        assert_eq!(inst.req_str("ph").unwrap(), "i");
        assert!(inst.get("dur").is_none());
        assert_eq!(j.req_f64("dropped_events").unwrap(), 0.0);
    }

    #[test]
    fn span_nesting_roundtrips_through_export() {
        // An inner span opened and closed inside an outer one must come
        // back from the JSON with its interval contained in the outer's.
        let sink = TraceSink::new(64);
        let tid = sink.mint_trace();
        {
            let _outer = sink.span(tid, "outer", "test");
            std::thread::sleep(std::time::Duration::from_millis(2));
            {
                let _inner = sink.span(tid, "inner", "test");
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let j = Json::parse(&sink.to_chrome_json().to_string()).unwrap();
        let events = j.req("traceEvents").unwrap().as_arr().unwrap();
        // Inner drops first, so it is buffered before outer.
        let find = |name: &str| -> (f64, f64) {
            let e = events
                .iter()
                .find(|e| e.req_str("name").unwrap() == name)
                .unwrap();
            let ts = e.req_f64("ts").unwrap();
            (ts, ts + e.req_f64("dur").unwrap())
        };
        let (i0, i1) = find("inner");
        let (o0, o1) = find("outer");
        assert!(o0 <= i0 && i1 <= o1, "inner [{i0},{i1}] ⊄ outer [{o0},{o1}]");
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let sink = TraceSink::new(4);
        for i in 0..10u64 {
            sink.instant(0, &format!("e{i}"), "test", Vec::new());
        }
        assert_eq!(sink.len(), 4);
        assert_eq!(sink.dropped(), 6);
        let j = sink.to_chrome_json();
        let events = j.req("traceEvents").unwrap().as_arr().unwrap();
        // The newest four survive.
        assert_eq!(events[0].req_str("name").unwrap(), "e6");
        assert_eq!(events[3].req_str("name").unwrap(), "e9");
        assert_eq!(j.req_f64("dropped_events").unwrap(), 6.0);
    }

    #[test]
    fn trace_ids_are_unique_and_timeline_monotonic() {
        let sink = TraceSink::new(16);
        let a = sink.mint_trace();
        let b = sink.mint_trace();
        assert_ne!(a, b);
        let t0 = sink.now_us();
        std::thread::sleep(std::time::Duration::from_millis(1));
        assert!(sink.now_us() > t0);
        // Instants predating the sink clamp to 0 instead of panicking.
        let early = Instant::now()
            .checked_sub(std::time::Duration::from_secs(3600))
            .unwrap_or_else(Instant::now);
        let _ = sink.ts_of(early);
    }

    #[test]
    fn stage_ledger_accumulates_and_drains_per_stage() {
        let _ = take_stage("alpha");
        let _ = take_stage("beta");
        credit_stage("alpha", 0.25);
        credit_stage("beta", 1.0);
        credit_stage("alpha", 0.5);
        assert!((take_stage("alpha") - 0.75).abs() < 1e-12);
        assert_eq!(take_stage("alpha"), 0.0);
        assert!((take_stage("beta") - 1.0).abs() < 1e-12);
        let other = std::thread::spawn(|| take_stage("alpha")).join().unwrap();
        assert_eq!(other, 0.0, "ledger is per-thread");
    }

    #[test]
    fn write_chrome_trace_to_file() {
        let sink = TraceSink::new(16);
        {
            let _s = sink.span(sink.mint_trace(), "work", "test");
        }
        let path = std::env::temp_dir().join(format!(
            "quip_trace_test_{}.json",
            std::process::id()
        ));
        let path = path.to_string_lossy().to_string();
        sink.write_chrome_trace(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(
            j.req("traceEvents").unwrap().as_arr().unwrap().len(),
            1
        );
        let _ = std::fs::remove_file(&path);
    }
}
