//! Crate-wide observability: a central metric registry with Prometheus
//! text exposition ([`registry`]) and lightweight span tracing with
//! Chrome trace-event export ([`trace`]).
//!
//! Design constraints (DESIGN.md §9):
//!
//! * **Lock-free record path.** Handles ([`registry::Counter`],
//!   [`registry::Gauge`], [`registry::Histogram`]) are `Arc`-shared
//!   atomics; recording never takes the catalog lock. Only registration
//!   and rendering lock, and both are off the request path.
//! * **Deterministic rendering.** The catalog is BTreeMap-keyed and
//!   exposition iterates names in sorted order, per the repo-wide
//!   determinism policy — two scrapes of the same state are
//!   byte-identical.
//! * **Bounded tracing.** Spans land in a fixed-capacity ring buffer
//!   ([`trace::TraceSink`]); under pressure the oldest events are
//!   dropped and counted, never the newest, and the serve path never
//!   blocks on a full buffer.
//!
//! The serving metrics façade (`coordinator::metrics::Metrics`) is built
//! on these handles; `quip serve` exposes the registry through the
//! `metrics` protocol command and the sink through `--trace-out`.

pub mod registry;
pub mod trace;
