//! Autoregressive generation against the native engine (fp32 or
//! quantized linears) with greedy or temperature sampling.
//!
//! Two shapes: [`generate`] runs one request to completion with
//! single-token decode steps; [`ActiveSeq`]/[`step_batch`] are the
//! continuous-batching substrate — many sequences advance one token per
//! step through [`decode_step_batch`], new sequences join at token
//! boundaries (their prompt tokens are just the first tokens fed) and
//! finished ones leave. [`generate_batch`] drives a fixed request set
//! through that loop; the serving coordinator adds dynamic admission.

use crate::engine::native::{decode_step_batch, decode_step_with, LinearOps};
use crate::model::transformer::{KvCache, Transformer};
use crate::util::rng::Rng;
use std::collections::VecDeque;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct GenParams {
    pub max_tokens: usize,
    /// 0.0 = greedy.
    pub temperature: f64,
    pub seed: u64,
    /// Stop when this token is produced (e.g. EOS).
    pub stop_token: Option<u32>,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            max_tokens: 32,
            temperature: 0.0,
            seed: 0,
            stop_token: None,
        }
    }
}

/// Generation output with timing for the serving metrics.
pub struct Generation {
    pub tokens: Vec<u32>,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
}

/// Generate a continuation of `prompt`.
pub fn generate(
    model: &Transformer,
    lin: &dyn LinearOps,
    prompt: &[u32],
    params: &GenParams,
) -> Generation {
    assert!(!prompt.is_empty(), "empty prompt");
    let mut cache = model.new_cache();
    let mut rng = Rng::new(params.seed);
    let budget = model.cfg.max_seq.saturating_sub(prompt.len());
    let max_new = params.max_tokens.min(budget);

    let t0 = std::time::Instant::now();
    // Prefill: feed prompt tokens (decode-style; the native engine has no
    // batched prefill matmul path — PJRT covers that).
    let mut logits = vec![0.0f32; model.cfg.vocab];
    for &tok in prompt {
        logits = decode_step_with(model, lin, &mut cache, tok);
    }
    let prefill_seconds = t0.elapsed().as_secs_f64();

    let t1 = std::time::Instant::now();
    let mut out = Vec::new();
    for _ in 0..max_new {
        let next = sample(&logits, params.temperature, &mut rng);
        out.push(next);
        if params.stop_token == Some(next) {
            break;
        }
        if cache.len >= model.cfg.max_seq {
            break;
        }
        logits = decode_step_with(model, lin, &mut cache, next);
    }
    Generation {
        tokens: out,
        prefill_seconds,
        decode_seconds: t1.elapsed().as_secs_f64(),
    }
}

/// One in-flight sequence of the continuous-batching loop: its KV cache,
/// the tokens still to be fed (prompt first, then each sampled token),
/// and the tokens generated so far.
pub struct ActiveSeq {
    pub cache: KvCache,
    /// Tokens not yet fed to the model. Non-empty while the sequence is
    /// alive: prompt tokens during prefill, then the last sampled token.
    feed: VecDeque<u32>,
    /// Generated (sampled) tokens.
    pub tokens: Vec<u32>,
    pub params: GenParams,
    rng: Rng,
    pub done: bool,
    max_new: usize,
    prompt_len: usize,
    born: Instant,
    prefill_seconds: f64,
    finished_seconds: f64,
}

impl ActiveSeq {
    pub fn new(model: &Transformer, prompt: &[u32], params: GenParams) -> ActiveSeq {
        assert!(!prompt.is_empty(), "empty prompt");
        let budget = model.cfg.max_seq.saturating_sub(prompt.len());
        let max_new = params.max_tokens.min(budget);
        let rng = Rng::new(params.seed);
        ActiveSeq {
            cache: model.new_cache(),
            feed: prompt.iter().copied().collect(),
            tokens: Vec::new(),
            rng,
            done: false,
            max_new,
            prompt_len: prompt.len(),
            born: Instant::now(),
            prefill_seconds: 0.0,
            finished_seconds: 0.0,
            params,
        }
    }

    /// Still consuming prompt tokens?
    pub fn prefilling(&self) -> bool {
        self.cache.len + self.feed.len() <= self.prompt_len
    }

    fn finish(&mut self) {
        self.done = true;
        self.finished_seconds = self.born.elapsed().as_secs_f64();
    }

    /// Package the finished sequence as a [`Generation`].
    pub fn into_generation(self) -> Generation {
        Generation {
            tokens: self.tokens,
            prefill_seconds: self.prefill_seconds,
            decode_seconds: (self.finished_seconds - self.prefill_seconds).max(0.0),
        }
    }
}

/// Advance every non-done sequence by one token (batched decode +
/// per-sequence sampling at prompt end). Returns the number of sequences
/// stepped — the batch size of this step, which the serving metrics
/// record as batch occupancy.
pub fn step_batch(model: &Transformer, lin: &dyn LinearOps, seqs: &mut [ActiveSeq]) -> usize {
    let mut ids = Vec::new();
    let mut toks = Vec::new();
    let mut caches: Vec<&mut KvCache> = Vec::new();
    for (i, s) in seqs.iter_mut().enumerate() {
        if s.done {
            continue;
        }
        let t = s.feed.pop_front().expect("live sequence has a token to feed");
        ids.push(i);
        toks.push(t);
        caches.push(&mut s.cache);
    }
    if ids.is_empty() {
        return 0;
    }
    let logits = decode_step_batch(model, lin, &mut caches, &toks);
    let v = model.cfg.vocab;
    for (k, &i) in ids.iter().enumerate() {
        let s = &mut seqs[i];
        if !s.feed.is_empty() {
            continue; // still prefilling; these logits are not sampled
        }
        if s.prefill_seconds == 0.0 {
            s.prefill_seconds = s.born.elapsed().as_secs_f64();
        }
        if s.tokens.len() >= s.max_new {
            s.finish(); // zero-budget request (prompt fills the context)
            continue;
        }
        let row = &logits[k * v..(k + 1) * v];
        let next = sample(row, s.params.temperature, &mut s.rng);
        s.tokens.push(next);
        if s.params.stop_token == Some(next)
            || s.tokens.len() >= s.max_new
            || s.cache.len >= model.cfg.max_seq
        {
            s.finish();
        } else {
            s.feed.push_back(next);
        }
    }
    ids.len()
}

/// Generate continuations for a fixed set of prompts through the
/// continuous-batching loop: all sequences advance together, finished
/// ones drop out of the batch. Semantically equivalent to calling
/// [`generate`] per prompt (identical tokens for greedy sampling).
pub fn generate_batch(
    model: &Transformer,
    lin: &dyn LinearOps,
    prompts: &[Vec<u32>],
    params: &GenParams,
) -> Vec<Generation> {
    let mut seqs: Vec<ActiveSeq> = prompts
        .iter()
        .map(|p| ActiveSeq::new(model, p, params.clone()))
        .collect();
    while step_batch(model, lin, &mut seqs) > 0 {}
    seqs.into_iter().map(ActiveSeq::into_generation).collect()
}

/// Sample a token from logits.
pub fn sample(logits: &[f32], temperature: f64, rng: &mut Rng) -> u32 {
    if temperature <= 0.0 {
        return logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as u32)
            .unwrap_or(0);
    }
    let maxv = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x)) as f64;
    let weights: Vec<f64> = logits
        .iter()
        .map(|&x| ((x as f64 - maxv) / temperature).exp())
        .collect();
    rng.weighted(&weights) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::native::FpLinears;
    use crate::model::weights::Checkpoint;
    use crate::model::ModelConfig;

    fn tiny() -> Transformer {
        let cfg = ModelConfig::sized("t", 32, 2, 4, 64);
        Transformer::from_checkpoint(&Checkpoint::random(&cfg, 5)).unwrap()
    }

    #[test]
    fn greedy_is_deterministic() {
        let m = tiny();
        let lin = FpLinears { model: &m };
        let p = GenParams {
            max_tokens: 8,
            ..Default::default()
        };
        let a = generate(&m, &lin, &[1, 2, 3], &p);
        let b = generate(&m, &lin, &[1, 2, 3], &p);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.tokens.len(), 8);
    }

    #[test]
    fn respects_context_budget() {
        let m = tiny();
        let lin = FpLinears { model: &m };
        let prompt: Vec<u32> = (0..100).map(|i| (i % 50) as u32).collect();
        let p = GenParams {
            max_tokens: 1000,
            ..Default::default()
        };
        let g = generate(&m, &lin, &prompt, &p);
        assert!(prompt.len() + g.tokens.len() <= m.cfg.max_seq);
    }

    #[test]
    fn stop_token_halts() {
        let m = tiny();
        let lin = FpLinears { model: &m };
        // Find the greedy first token, then use it as the stop token.
        let p0 = GenParams {
            max_tokens: 1,
            ..Default::default()
        };
        let first = generate(&m, &lin, &[1, 2], &p0).tokens[0];
        let p = GenParams {
            max_tokens: 16,
            stop_token: Some(first),
            ..Default::default()
        };
        let g = generate(&m, &lin, &[1, 2], &p);
        assert_eq!(g.tokens, vec![first]);
    }

    #[test]
    fn generate_batch_matches_sequential_generate() {
        // Continuous batching is a scheduling change, not a semantic one:
        // greedy decode must produce identical tokens per prompt, for
        // prompts of different lengths finishing at different steps.
        let m = tiny();
        let lin = FpLinears { model: &m };
        let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![9], vec![4, 8, 15, 16, 23]];
        let p = GenParams {
            max_tokens: 7,
            ..Default::default()
        };
        let batched = generate_batch(&m, &lin, &prompts, &p);
        assert_eq!(batched.len(), prompts.len());
        for (prompt, got) in prompts.iter().zip(&batched) {
            let want = generate(&m, &lin, prompt, &p);
            assert_eq!(got.tokens, want.tokens, "prompt {prompt:?}");
        }
    }

    #[test]
    fn generate_batch_respects_stop_and_budget() {
        let m = tiny();
        let lin = FpLinears { model: &m };
        // Find each prompt's greedy first token, use it as its stop token.
        let p1 = GenParams {
            max_tokens: 1,
            ..Default::default()
        };
        let first = generate(&m, &lin, &[1, 2], &p1).tokens[0];
        let p = GenParams {
            max_tokens: 16,
            stop_token: Some(first),
            ..Default::default()
        };
        let long: Vec<u32> = (0..100).map(|i| (i % 50) as u32).collect();
        let gens = generate_batch(&m, &lin, &[vec![1, 2], long.clone()], &p);
        assert_eq!(gens[0].tokens, vec![first]);
        assert!(long.len() + gens[1].tokens.len() <= m.cfg.max_seq);
    }

    #[test]
    fn temperature_sampling_varies_with_seed() {
        let m = tiny();
        let lin = FpLinears { model: &m };
        let mk = |seed| GenParams {
            max_tokens: 12,
            temperature: 2.0,
            seed,
            ..Default::default()
        };
        let a = generate(&m, &lin, &[1, 2, 3], &mk(1)).tokens;
        let b = generate(&m, &lin, &[1, 2, 3], &mk(2)).tokens;
        assert_ne!(a, b);
    }
}
