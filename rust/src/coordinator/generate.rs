//! Autoregressive generation against the native engine (fp32 or
//! quantized linears) with greedy or temperature sampling.

use crate::engine::native::{decode_step_with, LinearOps};
use crate::model::transformer::Transformer;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct GenParams {
    pub max_tokens: usize,
    /// 0.0 = greedy.
    pub temperature: f64,
    pub seed: u64,
    /// Stop when this token is produced (e.g. EOS).
    pub stop_token: Option<u32>,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            max_tokens: 32,
            temperature: 0.0,
            seed: 0,
            stop_token: None,
        }
    }
}

/// Generation output with timing for the serving metrics.
pub struct Generation {
    pub tokens: Vec<u32>,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
}

/// Generate a continuation of `prompt`.
pub fn generate(
    model: &Transformer,
    lin: &dyn LinearOps,
    prompt: &[u32],
    params: &GenParams,
) -> Generation {
    assert!(!prompt.is_empty(), "empty prompt");
    let mut cache = model.new_cache();
    let mut rng = Rng::new(params.seed);
    let budget = model.cfg.max_seq.saturating_sub(prompt.len());
    let max_new = params.max_tokens.min(budget);

    let t0 = std::time::Instant::now();
    // Prefill: feed prompt tokens (decode-style; the native engine has no
    // batched prefill matmul path — PJRT covers that).
    let mut logits = vec![0.0f32; model.cfg.vocab];
    for &tok in prompt {
        logits = decode_step_with(model, lin, &mut cache, tok);
    }
    let prefill_seconds = t0.elapsed().as_secs_f64();

    let t1 = std::time::Instant::now();
    let mut out = Vec::new();
    for _ in 0..max_new {
        let next = sample(&logits, params.temperature, &mut rng);
        out.push(next);
        if params.stop_token == Some(next) {
            break;
        }
        if cache.len >= model.cfg.max_seq {
            break;
        }
        logits = decode_step_with(model, lin, &mut cache, next);
    }
    Generation {
        tokens: out,
        prefill_seconds,
        decode_seconds: t1.elapsed().as_secs_f64(),
    }
}

/// Sample a token from logits.
pub fn sample(logits: &[f32], temperature: f64, rng: &mut Rng) -> u32 {
    if temperature <= 0.0 {
        return logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as u32)
            .unwrap_or(0);
    }
    let maxv = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x)) as f64;
    let weights: Vec<f64> = logits
        .iter()
        .map(|&x| ((x as f64 - maxv) / temperature).exp())
        .collect();
    rng.weighted(&weights) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::native::FpLinears;
    use crate::model::weights::Checkpoint;
    use crate::model::ModelConfig;

    fn tiny() -> Transformer {
        let cfg = ModelConfig::sized("t", 32, 2, 4, 64);
        Transformer::from_checkpoint(&Checkpoint::random(&cfg, 5)).unwrap()
    }

    #[test]
    fn greedy_is_deterministic() {
        let m = tiny();
        let lin = FpLinears { model: &m };
        let p = GenParams {
            max_tokens: 8,
            ..Default::default()
        };
        let a = generate(&m, &lin, &[1, 2, 3], &p);
        let b = generate(&m, &lin, &[1, 2, 3], &p);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.tokens.len(), 8);
    }

    #[test]
    fn respects_context_budget() {
        let m = tiny();
        let lin = FpLinears { model: &m };
        let prompt: Vec<u32> = (0..100).map(|i| (i % 50) as u32).collect();
        let p = GenParams {
            max_tokens: 1000,
            ..Default::default()
        };
        let g = generate(&m, &lin, &prompt, &p);
        assert!(prompt.len() + g.tokens.len() <= m.cfg.max_seq);
    }

    #[test]
    fn stop_token_halts() {
        let m = tiny();
        let lin = FpLinears { model: &m };
        // Find the greedy first token, then use it as the stop token.
        let p0 = GenParams {
            max_tokens: 1,
            ..Default::default()
        };
        let first = generate(&m, &lin, &[1, 2], &p0).tokens[0];
        let p = GenParams {
            max_tokens: 16,
            stop_token: Some(first),
            ..Default::default()
        };
        let g = generate(&m, &lin, &[1, 2], &p);
        assert_eq!(g.tokens, vec![first]);
    }

    #[test]
    fn temperature_sampling_varies_with_seed() {
        let m = tiny();
        let lin = FpLinears { model: &m };
        let mk = |seed| GenParams {
            max_tokens: 12,
            temperature: 2.0,
            seed,
            ..Default::default()
        };
        let a = generate(&m, &lin, &[1, 2, 3], &mk(1)).tokens;
        let b = generate(&m, &lin, &[1, 2, 3], &mk(2)).tokens;
        assert_ne!(a, b);
    }
}
