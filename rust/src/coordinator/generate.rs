//! Autoregressive generation against the native engine (fp32 or
//! quantized linears) with greedy or temperature sampling.
//!
//! Two shapes: [`generate`] runs one request to completion with
//! single-token decode steps; [`ActiveSeq`]/[`step_batch`] are the
//! continuous-batching substrate — many sequences advance one token per
//! step through [`decode_step_batch`], new sequences join at token
//! boundaries (their prompt tokens are just the first tokens fed) and
//! finished ones leave. [`generate_batch`] drives a fixed request set
//! through that loop; the serving coordinator adds dynamic admission.

use crate::engine::native::{decode_step_batch, decode_step_with, LinearOps};
use crate::model::transformer::{KvCache, Transformer};
use crate::util::rng::Rng;
use std::collections::VecDeque;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct GenParams {
    pub max_tokens: usize,
    /// 0.0 = greedy.
    pub temperature: f64,
    pub seed: u64,
    /// Stop when this token is produced (e.g. EOS).
    pub stop_token: Option<u32>,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            max_tokens: 32,
            temperature: 0.0,
            seed: 0,
            stop_token: None,
        }
    }
}

/// Why a sequence stopped. `Stop` means the model produced the stop
/// token; `Length` means the request's `max_tokens` budget or the
/// model context (`max_seq`) was exhausted. Reported per response so
/// clients can tell a completed answer from a truncated one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    Stop,
    Length,
}

impl FinishReason {
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Stop => "stop",
            FinishReason::Length => "length",
        }
    }
}

/// Generation output with timing for the serving metrics.
pub struct Generation {
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
}

/// Generate a continuation of `prompt`.
pub fn generate(
    model: &Transformer,
    lin: &dyn LinearOps,
    prompt: &[u32],
    params: &GenParams,
) -> Generation {
    assert!(!prompt.is_empty(), "empty prompt");
    let mut cache = model.new_cache();
    let mut rng = Rng::new(params.seed);
    let budget = model.cfg.max_seq.saturating_sub(prompt.len());
    let max_new = params.max_tokens.min(budget);

    let t0 = std::time::Instant::now();
    // Prefill: feed prompt tokens (decode-style; the native engine has no
    // batched prefill matmul path — PJRT covers that).
    let mut logits = vec![0.0f32; model.cfg.vocab];
    for &tok in prompt {
        logits = decode_step_with(model, lin, &mut cache, tok);
    }
    let prefill_seconds = t0.elapsed().as_secs_f64();

    let t1 = std::time::Instant::now();
    let mut out = Vec::new();
    let mut finish = FinishReason::Length;
    for _ in 0..max_new {
        let next = sample(&logits, params.temperature, &mut rng);
        out.push(next);
        if params.stop_token == Some(next) {
            finish = FinishReason::Stop;
            break;
        }
        if out.len() >= max_new || cache.len() >= model.cfg.max_seq {
            break;
        }
        logits = decode_step_with(model, lin, &mut cache, next);
    }
    Generation {
        tokens: out,
        finish,
        prefill_seconds,
        decode_seconds: t1.elapsed().as_secs_f64(),
    }
}

/// One in-flight sequence of the continuous-batching loop: its KV cache,
/// the tokens still to be fed (prompt first, then each sampled token),
/// and the tokens generated so far.
pub struct ActiveSeq {
    pub cache: KvCache,
    /// Tokens not yet fed to the model. Non-empty while the sequence is
    /// alive: prompt tokens during prefill, then the last sampled token.
    feed: VecDeque<u32>,
    /// Generated (sampled) tokens.
    pub tokens: Vec<u32>,
    pub params: GenParams,
    rng: Rng,
    pub done: bool,
    /// Why the sequence finished (set exactly when `done` flips).
    pub finish: Option<FinishReason>,
    /// Set when the KV pool could not reserve this sequence's next slot;
    /// the sequence sat out the last step and retries on the next one.
    pub stalled: bool,
    max_new: usize,
    prompt_len: usize,
    born: Instant,
    prefill_seconds: f64,
    finished_seconds: f64,
}

impl ActiveSeq {
    pub fn new(model: &Transformer, prompt: &[u32], params: GenParams) -> ActiveSeq {
        ActiveSeq::with_cache(model, prompt, params, model.new_cache())
    }

    /// Build a sequence over a caller-provided cache — the serving path,
    /// where the cache is paged and may already hold a shared prompt
    /// prefix (from [`crate::model::KvPool::try_admit`]). Only the
    /// unshared prompt tail `prompt[cache.len()..]` is fed.
    pub fn with_cache(
        model: &Transformer,
        prompt: &[u32],
        params: GenParams,
        cache: KvCache,
    ) -> ActiveSeq {
        assert!(!prompt.is_empty(), "empty prompt");
        let shared = cache.len();
        assert!(
            shared < prompt.len(),
            "shared prefix ({shared}) must leave at least the last prompt token"
        );
        let budget = model.cfg.max_seq.saturating_sub(prompt.len());
        let max_new = params.max_tokens.min(budget);
        let rng = Rng::new(params.seed);
        ActiveSeq {
            cache,
            feed: prompt[shared..].iter().copied().collect(),
            tokens: Vec::new(),
            rng,
            done: false,
            finish: None,
            stalled: false,
            max_new,
            prompt_len: prompt.len(),
            born: Instant::now(),
            prefill_seconds: 0.0,
            finished_seconds: 0.0,
            params,
        }
    }

    /// Still consuming prompt tokens?
    pub fn prefilling(&self) -> bool {
        self.cache.len() + self.feed.len() <= self.prompt_len
    }

    /// Prompt length this sequence was admitted with (span/report
    /// attribution; the KV cache may hold fewer rows under prefix
    /// sharing).
    pub fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    /// Seconds from admission to the end of prefill (0.0 until the first
    /// token is sampled).
    pub fn prefill_seconds(&self) -> f64 {
        self.prefill_seconds
    }

    fn finish(&mut self, reason: FinishReason) {
        self.done = true;
        self.finish = Some(reason);
        self.finished_seconds = self.born.elapsed().as_secs_f64();
    }

    /// Package the finished sequence as a [`Generation`].
    pub fn into_generation(self) -> Generation {
        Generation {
            tokens: self.tokens,
            finish: self.finish.unwrap_or(FinishReason::Length),
            prefill_seconds: self.prefill_seconds,
            decode_seconds: (self.finished_seconds - self.prefill_seconds).max(0.0),
        }
    }
}

/// Outcome of one continuous-batching step: how many sequences advanced
/// (the batch occupancy the serving metrics record) and how many were
/// stalled by KV-pool exhaustion and sat the step out.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepReport {
    pub stepped: usize,
    pub stalled: usize,
}

/// Advance every non-done sequence by one token (batched decode +
/// per-sequence sampling at prompt end). A sequence whose KV cache
/// cannot reserve its next slot (paged pool exhausted) is marked
/// [`ActiveSeq::stalled`] and skipped this step — it retries when pages
/// free up; the serving scheduler sheds it if the stall never clears.
pub fn step_batch(model: &Transformer, lin: &dyn LinearOps, seqs: &mut [ActiveSeq]) -> StepReport {
    let mut ids = Vec::new();
    let mut toks = Vec::new();
    let mut caches: Vec<&mut KvCache> = Vec::new();
    let mut stalled = 0usize;
    for (i, s) in seqs.iter_mut().enumerate() {
        if s.done {
            continue;
        }
        // Pre-reserve the write slot; decode_step_batch panics on
        // exhaustion, so admission to the batch happens here.
        if s.cache.ensure_append().is_err() {
            s.stalled = true;
            stalled += 1;
            continue;
        }
        s.stalled = false;
        // Batching invariant: a sequence is only live (!done) while it has
        // a pending feed token — step_batch refills `feed` with the sampled
        // token before the next round. A miss here is a scheduler bug, not
        // a load condition, so it must not be shed silently.
        // preflight: allow(panic, "batching invariant: live sequences always hold a feed token")
        let t = s.feed.pop_front().expect("live sequence has a token to feed");
        ids.push(i);
        toks.push(t);
        caches.push(&mut s.cache);
    }
    if ids.is_empty() {
        return StepReport { stepped: 0, stalled };
    }
    let logits = decode_step_batch(model, lin, &mut caches, &toks);
    let v = model.cfg.vocab;
    for (k, &i) in ids.iter().enumerate() {
        let s = &mut seqs[i];
        if !s.feed.is_empty() {
            continue; // still prefilling; these logits are not sampled
        }
        if s.prefill_seconds == 0.0 {
            s.prefill_seconds = s.born.elapsed().as_secs_f64();
        }
        if s.tokens.len() >= s.max_new {
            // Zero-budget request (prompt fills the context).
            s.finish(FinishReason::Length);
            continue;
        }
        let row = &logits[k * v..(k + 1) * v];
        let next = sample(row, s.params.temperature, &mut s.rng);
        s.tokens.push(next);
        if s.params.stop_token == Some(next) {
            s.finish(FinishReason::Stop);
        } else if s.tokens.len() >= s.max_new || s.cache.len() >= model.cfg.max_seq {
            s.finish(FinishReason::Length);
        } else {
            s.feed.push_back(next);
        }
    }
    StepReport {
        stepped: ids.len(),
        stalled,
    }
}

/// Generate continuations for a fixed set of prompts through the
/// continuous-batching loop: all sequences advance together, finished
/// ones drop out of the batch. Semantically equivalent to calling
/// [`generate`] per prompt (identical tokens for greedy sampling).
pub fn generate_batch(
    model: &Transformer,
    lin: &dyn LinearOps,
    prompts: &[Vec<u32>],
    params: &GenParams,
) -> Vec<Generation> {
    let mut seqs: Vec<ActiveSeq> = prompts
        .iter()
        .map(|p| ActiveSeq::new(model, p, params.clone()))
        .collect();
    // Contiguous caches never stall; a caller handing in paged sequences
    // must size the pool (the serving scheduler sheds instead).
    while step_batch(model, lin, &mut seqs).stepped > 0 {}
    seqs.into_iter().map(ActiveSeq::into_generation).collect()
}

/// Sample a token from logits.
pub fn sample(logits: &[f32], temperature: f64, rng: &mut Rng) -> u32 {
    if temperature <= 0.0 {
        return logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as u32)
            .unwrap_or(0);
    }
    let maxv = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x)) as f64;
    let weights: Vec<f64> = logits
        .iter()
        .map(|&x| ((x as f64 - maxv) / temperature).exp())
        .collect();
    rng.weighted(&weights) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::native::FpLinears;
    use crate::model::weights::Checkpoint;
    use crate::model::ModelConfig;

    fn tiny() -> Transformer {
        let cfg = ModelConfig::sized("t", 32, 2, 4, 64);
        Transformer::from_checkpoint(&Checkpoint::random(&cfg, 5)).unwrap()
    }

    #[test]
    fn greedy_is_deterministic() {
        let m = tiny();
        let lin = FpLinears { model: &m };
        let p = GenParams {
            max_tokens: 8,
            ..Default::default()
        };
        let a = generate(&m, &lin, &[1, 2, 3], &p);
        let b = generate(&m, &lin, &[1, 2, 3], &p);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.tokens.len(), 8);
    }

    #[test]
    fn respects_context_budget() {
        let m = tiny();
        let lin = FpLinears { model: &m };
        let prompt: Vec<u32> = (0..100).map(|i| (i % 50) as u32).collect();
        let p = GenParams {
            max_tokens: 1000,
            ..Default::default()
        };
        let g = generate(&m, &lin, &prompt, &p);
        assert!(prompt.len() + g.tokens.len() <= m.cfg.max_seq);
    }

    #[test]
    fn stop_token_halts() {
        let m = tiny();
        let lin = FpLinears { model: &m };
        // Find the greedy first token, then use it as the stop token.
        let p0 = GenParams {
            max_tokens: 1,
            ..Default::default()
        };
        let first = generate(&m, &lin, &[1, 2], &p0).tokens[0];
        let p = GenParams {
            max_tokens: 16,
            stop_token: Some(first),
            ..Default::default()
        };
        let g = generate(&m, &lin, &[1, 2], &p);
        assert_eq!(g.tokens, vec![first]);
    }

    #[test]
    fn generate_batch_matches_sequential_generate() {
        // Continuous batching is a scheduling change, not a semantic one:
        // greedy decode must produce identical tokens per prompt, for
        // prompts of different lengths finishing at different steps.
        let m = tiny();
        let lin = FpLinears { model: &m };
        let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![9], vec![4, 8, 15, 16, 23]];
        let p = GenParams {
            max_tokens: 7,
            ..Default::default()
        };
        let batched = generate_batch(&m, &lin, &prompts, &p);
        assert_eq!(batched.len(), prompts.len());
        for (prompt, got) in prompts.iter().zip(&batched) {
            let want = generate(&m, &lin, prompt, &p);
            assert_eq!(got.tokens, want.tokens, "prompt {prompt:?}");
        }
    }

    #[test]
    fn generate_batch_respects_stop_and_budget() {
        let m = tiny();
        let lin = FpLinears { model: &m };
        // Find each prompt's greedy first token, use it as its stop token.
        let p1 = GenParams {
            max_tokens: 1,
            ..Default::default()
        };
        let first = generate(&m, &lin, &[1, 2], &p1).tokens[0];
        let p = GenParams {
            max_tokens: 16,
            stop_token: Some(first),
            ..Default::default()
        };
        let long: Vec<u32> = (0..100).map(|i| (i % 50) as u32).collect();
        let gens = generate_batch(&m, &lin, &[vec![1, 2], long.clone()], &p);
        assert_eq!(gens[0].tokens, vec![first]);
        assert!(long.len() + gens[1].tokens.len() <= m.cfg.max_seq);
    }

    #[test]
    fn finish_reason_distinguishes_stop_from_length() {
        let m = tiny();
        let lin = FpLinears { model: &m };
        let p0 = GenParams {
            max_tokens: 1,
            ..Default::default()
        };
        let first = generate(&m, &lin, &[1, 2], &p0).tokens[0];
        // Budget exhaustion (no stop token) reports "length"...
        let g = generate(&m, &lin, &[1, 2], &p0);
        assert_eq!(g.finish, FinishReason::Length);
        assert_eq!(g.finish.as_str(), "length");
        // ...producing the stop token reports "stop", in both the
        // single-request and the continuous-batching paths.
        let p = GenParams {
            max_tokens: 16,
            stop_token: Some(first),
            ..Default::default()
        };
        let g = generate(&m, &lin, &[1, 2], &p);
        assert_eq!(g.finish, FinishReason::Stop);
        let long: Vec<u32> = (0..120).map(|i| (i % 50) as u32).collect();
        let gens = generate_batch(&m, &lin, &[vec![1, 2], long], &p);
        assert_eq!(gens[0].finish, FinishReason::Stop);
        // The long prompt hits max_seq before 16 tokens: length-finished.
        assert_eq!(gens[1].finish, FinishReason::Length);
        assert_eq!(gens[1].finish.as_str(), "length");
    }

    #[test]
    fn paged_batch_generation_matches_contiguous() {
        // The continuous-batching loop over paged caches produces the
        // same greedy tokens as plain generate() per prompt.
        let m = tiny();
        let lin = FpLinears { model: &m };
        let pool = crate::model::KvPool::shared(m.cfg.n_layers, m.cfg.d_model, 64, 4);
        let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![9], vec![4, 8, 15, 16, 23]];
        let p = GenParams {
            max_tokens: 7,
            ..Default::default()
        };
        let mut seqs: Vec<ActiveSeq> = prompts
            .iter()
            .map(|pr| ActiveSeq::with_cache(&m, pr, p.clone(), m.new_paged_cache(&pool)))
            .collect();
        while step_batch(&m, &lin, &mut seqs).stepped > 0 {}
        for (prompt, seq) in prompts.iter().zip(seqs) {
            let want = generate(&m, &lin, prompt, &p);
            let got = seq.into_generation();
            assert_eq!(got.tokens, want.tokens, "prompt {prompt:?}");
            assert_eq!(got.finish, want.finish);
        }
        assert_eq!(pool.lock().unwrap().pages_in_use(), 0, "drops released pages");
    }

    #[test]
    fn stalled_sequence_resumes_when_pages_free_up() {
        // One-page pool: sequence A holds the page, B stalls instead of
        // panicking, then proceeds once A is dropped and its page freed.
        let m = tiny();
        let lin = FpLinears { model: &m };
        let pool = crate::model::KvPool::shared(m.cfg.n_layers, m.cfg.d_model, 1, 4);
        let p = GenParams {
            max_tokens: 2,
            ..Default::default()
        };
        let mut seqs = vec![
            ActiveSeq::with_cache(&m, &[1, 2], p.clone(), m.new_paged_cache(&pool)),
            ActiveSeq::with_cache(&m, &[1, 2], p.clone(), m.new_paged_cache(&pool)),
        ];
        let r = step_batch(&m, &lin, &mut seqs);
        assert_eq!((r.stepped, r.stalled), (1, 1));
        assert!(seqs[1].stalled && !seqs[1].done);
        while !seqs[0].done {
            step_batch(&m, &lin, &mut seqs);
        }
        // A: 2 prompt + 2 generated = len 3 fed, fits the single page.
        let a = seqs.remove(0).into_generation();
        let r = step_batch(&m, &lin, &mut seqs);
        assert_eq!((r.stepped, r.stalled), (1, 0));
        assert!(!seqs[0].stalled);
        while step_batch(&m, &lin, &mut seqs).stepped > 0 {}
        let b = seqs.remove(0).into_generation();
        assert_eq!(a.tokens, b.tokens, "same prompt, same greedy tokens");
    }

    #[test]
    fn temperature_sampling_varies_with_seed() {
        let m = tiny();
        let lin = FpLinears { model: &m };
        let mk = |seed| GenParams {
            max_tokens: 12,
            temperature: 2.0,
            seed,
            ..Default::default()
        };
        let a = generate(&m, &lin, &[1, 2, 3], &mk(1)).tokens;
        let b = generate(&m, &lin, &[1, 2, 3], &mk(2)).tokens;
        assert_ne!(a, b);
    }
}
