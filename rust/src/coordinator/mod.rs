//! Layer-3 coordination: the quantization pipeline (a staged
//! [`QuantSession`] — block-by-block Hessian collection through the
//! already-quantized prefix, per-layer jobs on the thread pool, typed
//! [`PipelineEvent`] progress — the paper's §6 setup), its crash-safety
//! layer (the `.qzp` block journal + config-fingerprint manifest behind
//! checkpoint/resume, DESIGN.md §10), and the serving side (TCP server,
//! request router, dynamic batcher, generation loop, metrics).

pub mod pipeline;
pub mod checkpoint;
pub mod generate;
pub mod batcher;
pub mod metrics;
pub mod server;

pub use pipeline::{
    quantize_model, PipelineConfig, PipelineControl, PipelineEvent, PipelineReport, QuantSession,
};
