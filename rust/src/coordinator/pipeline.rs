//! The quantization pipeline (paper §6 setup):
//!
//! "quantization is performed one Transformer block at a time: loaded
//!  into memory, the Hessian computed, and then the weights quantized.
//!  The current block's inputs are then passed through the quantized
//!  block to produce inputs for the following block."
//!
//! Concretely: for block b, the calibration set is run through the model
//! whose blocks < b are already quantized; the captured activations feed
//! per-hkey Hessian accumulators; the block's six layers are quantized in
//! parallel on the thread pool; their dequantized weights replace the
//! block's weights; repeat.

use crate::hessian::HessianSet;
use crate::linalg::Mat;
use crate::model::quantized::QuantizedModel;
use crate::model::weights::Checkpoint;
use crate::model::Transformer;
use crate::quant::packed::QuantizedLayer;
use crate::quant::{quantize_layer, QuantConfig};
use crate::util::json::Json;
use crate::util::threadpool::{default_threads, parallel_map};

#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub quant: QuantConfig,
    /// Calibration windows (the paper uses 128 segments; scaled here).
    pub calib_seqs: usize,
    pub calib_seq_len: usize,
    pub seed: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            quant: QuantConfig::default(),
            calib_seqs: 32,
            calib_seq_len: 128,
            seed: 0x5155_4950,
        }
    }
}

/// Per-layer record in the pipeline report.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub proxy_loss: f64,
    pub seconds: f64,
}

pub struct PipelineReport {
    pub layers: Vec<LayerReport>,
    pub total_seconds: f64,
}

impl PipelineReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("total_seconds", Json::Num(self.total_seconds));
        j.set(
            "layers",
            Json::Arr(
                self.layers
                    .iter()
                    .map(|l| {
                        let mut o = Json::obj();
                        o.set("name", Json::Str(l.name.clone()));
                        o.set("proxy_loss", Json::Num(l.proxy_loss));
                        o.set("seconds", Json::Num(l.seconds));
                        o
                    })
                    .collect(),
            ),
        );
        j
    }

    pub fn total_proxy(&self) -> f64 {
        self.layers.iter().map(|l| l.proxy_loss).sum()
    }
}

/// Quantize a whole model from its checkpoint with the given calibration
/// sequences. Returns the quantized artifact + report.
pub fn quantize_model(
    ck: &Checkpoint,
    calib: &[Vec<u32>],
    cfg: &PipelineConfig,
) -> crate::Result<(QuantizedModel, PipelineReport)> {
    let t0 = std::time::Instant::now();
    let mut model = Transformer::from_checkpoint(ck)?;
    let specs = ck.config.linear_specs();
    let mut layers: Vec<QuantizedLayer> = Vec::with_capacity(specs.len());
    let mut reports = Vec::new();

    for b in 0..ck.config.n_layers {
        // 1. Hessians for this block from the quantized-prefix model.
        let block_prefix = format!("blk{b}.");
        let mut hset = HessianSet::for_model(&ck.config);
        {
            let mut sink = |hkey: &str, rows: &[f32], n: usize| {
                if hkey.starts_with(&block_prefix) {
                    if let Some(acc) = hset.accums.get_mut(hkey) {
                        acc.add_rows(rows, n);
                    }
                }
            };
            for seq in calib {
                model.forward(seq, Some(&mut sink));
            }
        }

        // 2. Quantize the block's layers in parallel.
        let block_specs: Vec<_> = specs
            .iter()
            .filter(|s| s.name.starts_with(&block_prefix))
            .cloned()
            .collect();
        let weights: Vec<Mat> = block_specs
            .iter()
            .map(|s| {
                let wdata = model.get_weight(&s.name).unwrap();
                Mat {
                    rows: s.out_dim,
                    cols: s.in_dim,
                    data: wdata.iter().map(|&x| x as f64).collect(),
                }
            })
            .collect();
        let hessians: Vec<Mat> = block_specs
            .iter()
            .map(|s| hset.finish(&s.hkey))
            .collect::<crate::Result<_>>()?;

        let qcfg = cfg.quant.clone();
        let seed = cfg.seed;
        let results = parallel_map(block_specs.len(), default_threads(), |i| {
            let t = std::time::Instant::now();
            let layer_seed = seed
                .wrapping_mul(0x100000001B3)
                .wrapping_add((b * 16 + i) as u64);
            let out = quantize_layer(&weights[i], &hessians[i], &qcfg, layer_seed);
            (out, t.elapsed().as_secs_f64())
        });

        // 3. Swap quantized weights into the running model.
        for (spec, (out, secs)) in block_specs.iter().zip(results) {
            let data: Vec<f32> = out.w_hat.data.iter().map(|&x| x as f32).collect();
            model.set_weight(&spec.name, data)?;
            reports.push(LayerReport {
                name: spec.name.clone(),
                proxy_loss: out.proxy_loss,
                seconds: secs,
            });
            layers.push(QuantizedLayer::from_codes(
                &spec.name,
                &out.codes,
                cfg.quant.bits,
                out.post,
            ));
        }
        crate::log_info!(
            "block {b}: quantized {} layers ({:.1}s elapsed)",
            block_specs.len(),
            t0.elapsed().as_secs_f64()
        );
    }

    let recipe = format!(
        "{}+{}",
        cfg.quant.method.name(),
        if cfg.quant.processing.incoherent {
            "incp"
        } else {
            "baseline"
        }
    );
    Ok((
        QuantizedModel {
            config: ck.config.clone(),
            bits: cfg.quant.bits,
            recipe,
            layers,
        },
        PipelineReport {
            layers: reports,
            total_seconds: t0.elapsed().as_secs_f64(),
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen::markov_stream;
    use crate::model::ModelConfig;
    use crate::quant::{Method, Processing};

    fn run_pipeline(bits: u32, method: Method, processing: Processing) -> (QuantizedModel, PipelineReport, Checkpoint) {
        let cfg = ModelConfig::sized("t", 32, 2, 4, 64);
        let ck = Checkpoint::random(&cfg, 1);
        let stream = markov_stream(cfg.vocab as u32, 4_000, 2);
        let calib = stream.calibration(24, 4, 3);
        let pcfg = PipelineConfig {
            quant: QuantConfig {
                bits,
                method,
                processing,
                greedy_passes: 2,
                ..Default::default()
            },
            calib_seqs: 4,
            calib_seq_len: 24,
            seed: 7,
        };
        let (qm, report) = quantize_model(&ck, &calib, &pcfg).unwrap();
        (qm, report, ck)
    }

    #[test]
    fn pipeline_produces_all_layers() {
        let (qm, report, ck) = run_pipeline(2, Method::Ldlq, Processing::incoherent());
        assert_eq!(qm.layers.len(), ck.config.linear_specs().len());
        assert_eq!(report.layers.len(), qm.layers.len());
        assert!(report.layers.iter().all(|l| l.proxy_loss.is_finite()));
        // Applying the artifact reproduces a working model.
        let mut m = Transformer::from_checkpoint(&ck).unwrap();
        qm.apply_to(&mut m).unwrap();
        let logits = m.forward(&[1, 2, 3], None);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn quip_proxy_below_baseline_near() {
        let (_, quip, _) = run_pipeline(2, Method::Ldlq, Processing::incoherent());
        let (_, near, _) = run_pipeline(2, Method::Nearest, Processing::baseline());
        assert!(
            quip.total_proxy() < near.total_proxy(),
            "quip {} vs near {}",
            quip.total_proxy(),
            near.total_proxy()
        );
    }

    #[test]
    fn report_serializes() {
        let (_, report, _) = run_pipeline(4, Method::Ldlq, Processing::baseline());
        let j = report.to_json();
        assert!(j.get("layers").is_some());
        assert!(Json::parse(&j.pretty()).is_ok());
    }
}
