//! The quantization pipeline (paper §6 setup):
//!
//! "quantization is performed one Transformer block at a time: loaded
//!  into memory, the Hessian computed, and then the weights quantized.
//!  The current block's inputs are then passed through the quantized
//!  block to produce inputs for the following block."
//!
//! The pipeline is a [`QuantSession`] with three explicit stages per
//! block — [`collect_hessians`](QuantSession::collect_hessians) →
//! [`quantize_block`](QuantSession::quantize_block) →
//! [`swap_weights`](QuantSession::swap_weights) — emitting typed
//! [`PipelineEvent`]s through an observer callback. That gives callers
//! progress streaming, per-block cancellation (return
//! [`PipelineControl::Stop`] from the observer) and crash safety:
//! [`QuantSession::with_checkpoint_dir`] journals each completed block to
//! a `.qzp` file (see [`super::checkpoint`]) and
//! [`QuantSession::resume`] replays it, so a killed multi-hour run
//! restarts from its last durable block with a byte-identical final
//! artifact (pinned by test). A worker panic or unusable Hessian poisons
//! only its block: the block is retried once with escalated damping, then
//! reported via [`PipelineEvent::BlockFailed`] while the session degrades
//! gracefully. [`quantize_model`] is the one-shot wrapper.
//!
//! Internally [`step`](QuantSession::step) runs *sharded* (DESIGN.md
//! §11): activations stream into a budget-bounded
//! [`ShardedHessianStore`](crate::hessian::sharded::ShardedHessianStore)
//! that spills cold accumulators to CRC-framed files
//! (`--hessian-mem-budget`), and the block's layers are quantized by a
//! work-stealing across-layer worker pool (`--layer-workers`) that loads
//! each layer's finished Hessian on demand. Spill schedule, flush
//! boundaries, and per-layer seeds are pure functions of the stream and
//! spec order — never of worker timing — so quantized bytes are
//! bit-identical for any budget × worker count × spill state
//! (`rust/tests/determinism.rs`).

use super::checkpoint::{BlockRecord, CheckpointJournal, Fingerprint, LayerRecord};
use crate::hessian::sharded::{ShardMetrics, ShardedHessianStore};
use crate::hessian::{HessianAccum, HessianSet};
use crate::linalg::Mat;
use crate::model::quantized::QuantizedModel;
use crate::model::weights::Checkpoint;
use crate::model::{LinearSpec, Transformer};
use crate::obs::registry::MetricRegistry;
use crate::obs::trace::TraceSink;
use crate::quant::packed::QuantizedLayer;
use crate::quant::{quantize_layer_with, QuantConfig, Rounder};
use crate::util::json::Json;
use crate::util::threadpool::{default_threads, parallel_map, parallel_map_traced, ItemTiming};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub quant: QuantConfig,
    /// Calibration windows (the paper uses 128 segments; scaled here).
    pub calib_seqs: usize,
    pub calib_seq_len: usize,
    pub seed: u64,
    /// Armed fault points (`--inject-fault point@n[:mode]`) for
    /// crash-safety testing; `None` in production runs.
    pub faults: Option<Arc<crate::util::fault::FaultInjector>>,
    /// Resident-byte budget for the block's Hessian accumulators
    /// (`--hessian-mem-budget`, DESIGN.md §11); 0 = unlimited (nothing
    /// spills). Accumulators over budget spill to CRC-framed files and
    /// stream back on demand — quantized bytes are identical either way
    /// (pinned by `rust/tests/determinism.rs`).
    pub hessian_mem_budget: usize,
    /// Across-layer worker count for the block's quantization pool
    /// (`--layer-workers`); 0 = auto
    /// ([`default_threads`]).
    pub layer_workers: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            quant: QuantConfig::default(),
            calib_seqs: 32,
            calib_seq_len: 128,
            seed: 0x5155_4950,
            faults: None,
            hessian_mem_budget: 0,
            layer_workers: 0,
        }
    }
}

/// Typed progress events, emitted in stream order: for each block b,
/// `BlockStarted(b)`, then per linear spec of b an optional
/// `HessianDamped` warning (non-PD recovery escalated that layer's
/// damping), a `LayerStageTimings` breakdown, and a `LayerDone`, then
/// `BlockDone(b)`.
#[derive(Clone, Debug, PartialEq)]
pub enum PipelineEvent {
    BlockStarted {
        block: usize,
        /// Linear layers this block will quantize.
        layers: usize,
    },
    /// Warning: the layer's Hessian was not positive definite at the
    /// configured damping (Cholesky/LDL failure, or non-finite / negative
    /// proxy output); the layer was retried with damping escalated to
    /// `alpha` instead of aborting the session.
    HessianDamped {
        block: usize,
        name: String,
        /// The damping α that made the layer quantize.
        alpha: f64,
    },
    /// Per-stage wall-clock of one layer (EXPERIMENTS.md §Perf 4):
    /// Hessian accumulation (for this layer's hkey accumulator, shared
    /// across layers with the same input), the LDL/Cholesky
    /// factorizations inside the rounder, and the remaining rounding
    /// time. Emitted immediately before the layer's `LayerDone`.
    LayerStageTimings {
        block: usize,
        name: String,
        /// Wall-clock of the hkey's Hessian accumulation this block.
        accumulate_seconds: f64,
        /// Effective accumulate bandwidth (see
        /// [`crate::hessian::HessianAccum::effective_gbps`]).
        accumulate_gbps: f64,
        /// Seconds inside LDL/Cholesky factorizations while rounding.
        factorize_seconds: f64,
        /// Seconds in the rounding core outside the factorizations.
        round_seconds: f64,
    },
    LayerDone {
        block: usize,
        name: String,
        proxy_loss: f64,
        seconds: f64,
    },
    BlockDone {
        block: usize,
        seconds: f64,
    },
    /// The block failed even after one retry with escalated damping
    /// (worker panic, unusable Hessians, injected fault). Emitted instead
    /// of `BlockDone`; the session skips the block — its weights stay
    /// fp32 in the running model — and continues with the next one, so a
    /// single poisoned block degrades the artifact instead of aborting
    /// the run. [`PipelineReport::failed_blocks`] lists the failed set.
    BlockFailed {
        block: usize,
        error: String,
    },
}

/// Observer verdict: keep going, or cancel after the current stage. A
/// cancelled session still yields a consistent partial artifact through
/// [`QuantSession::finish`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineControl {
    Continue,
    Stop,
}

/// Per-layer record in the pipeline report.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub proxy_loss: f64,
    pub seconds: f64,
    /// Stage breakdown (§Perf 4): Hessian accumulate / factorize / round.
    pub accumulate_seconds: f64,
    pub factorize_seconds: f64,
    pub round_seconds: f64,
}

pub struct PipelineReport {
    pub layers: Vec<LayerReport>,
    pub total_seconds: f64,
    /// Blocks that failed their retry and were skipped (block index +
    /// error). Empty on a fully healthy run.
    pub failed_blocks: Vec<(usize, String)>,
}

impl PipelineReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("total_seconds", Json::Num(self.total_seconds));
        j.set(
            "failed_blocks",
            Json::Arr(
                self.failed_blocks
                    .iter()
                    .map(|(b, e)| {
                        let mut o = Json::obj();
                        o.set("block", Json::Num(*b as f64));
                        o.set("error", Json::Str(e.clone()));
                        o
                    })
                    .collect(),
            ),
        );
        j.set(
            "layers",
            Json::Arr(
                self.layers
                    .iter()
                    .map(|l| {
                        let mut o = Json::obj();
                        o.set("name", Json::Str(l.name.clone()));
                        o.set("proxy_loss", Json::Num(l.proxy_loss));
                        o.set("seconds", Json::Num(l.seconds));
                        o.set("accumulate_seconds", Json::Num(l.accumulate_seconds));
                        o.set("factorize_seconds", Json::Num(l.factorize_seconds));
                        o.set("round_seconds", Json::Num(l.round_seconds));
                        o
                    })
                    .collect(),
            ),
        );
        j
    }

    pub fn total_proxy(&self) -> f64 {
        self.layers.iter().map(|l| l.proxy_loss).sum()
    }
}

/// Per-layer result inside a [`BlockOutput`]: the quantizer output, its
/// wall-clock, the escalated damping α (when non-PD recovery ran), and
/// the layer's hkey Hessian-accumulation stats from stage 1.
struct LayerResult {
    lq: crate::quant::LayerQuantOutput,
    seconds: f64,
    damped: Option<f64>,
    accumulate_seconds: f64,
    accumulate_gbps: f64,
    /// Worker-pool scheduling of this layer's job (sharded path only;
    /// `None` through the legacy staged API). Observability, never an
    /// input to quantized bytes.
    pool: Option<ItemTiming>,
}

/// The quantized output of one block, produced by
/// [`QuantSession::quantize_block`] and consumed by
/// [`QuantSession::swap_weights`].
pub struct BlockOutput {
    pub block: usize,
    specs: Vec<LinearSpec>,
    results: Vec<LayerResult>,
}

/// Quantize one layer, recovering from a non-PD / unusable Hessian by
/// escalating the damping α → 10α → 100α (the whole-session abort this
/// replaces: one bad layer Hessian used to panic or poison the artifact).
/// A Cholesky probe of the damped Hessian detects non-PD inputs before
/// the rounder sees them; non-finite or negative proxy output (indefinite
/// H slipping through the factorization) also triggers escalation.
/// Returns the output and `Some(final α)` when escalation was needed.
pub fn quantize_layer_robust(
    rounder: &dyn Rounder,
    w: &Mat,
    h: &Mat,
    cfg: &QuantConfig,
    seed: u64,
) -> crate::Result<(crate::quant::LayerQuantOutput, Option<f64>)> {
    // Escalation base: the configured α, floored so α = 0 configs still
    // get meaningful damping on retry.
    let base = cfg.processing.alpha.max(1e-3);
    // Escalation retries re-damp the already-symmetrized copy in place
    // (diagonal += Δbump, magnitude from the shared
    // `incoherence::damp_bump`) instead of re-cloning the n×n matrix from
    // scratch each attempt; the first attempt's probe is bit-identical to
    // `incoherence::damp(h, α)`, escalated probes differ from a fresh
    // damp only in the last ulp of the diagonal.
    let mut damped = h.symmetrize();
    let mut applied_bump = 0.0f64;
    for escalation in 0..3u32 {
        let alpha = if escalation == 0 {
            cfg.processing.alpha
        } else {
            base * 10f64.powi(escalation as i32)
        };
        // PD probe: the damped matrix the quantizer will factor. Probing
        // every attempt (not just retries) is deliberate: an indefinite H
        // can slip through LDL's pivot clamping and produce finite codes
        // with an accidentally-positive proxy, which the output checks
        // below cannot distinguish from health. One extra Cholesky per
        // layer is noise next to the rounding cost, and this is the
        // offline quantization path, not serving.
        let bump = crate::quant::incoherence::damp_bump(h, alpha);
        for i in 0..damped.rows {
            damped[(i, i)] += bump - applied_bump;
        }
        applied_bump = bump;
        if crate::linalg::chol::cholesky(&damped).is_err() {
            continue;
        }
        let mut cfg_try = cfg.clone();
        cfg_try.processing.alpha = alpha;
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            quantize_layer_with(rounder, w, h, &cfg_try, seed)
        }));
        match out {
            Ok(out)
                if out.proxy_loss.is_finite()
                    && out.proxy_loss >= -1e-6 * out.proxy_loss.abs().max(1.0)
                    && out.w_hat.data.iter().all(|x| x.is_finite()) =>
            {
                return Ok((out, (escalation > 0).then_some(alpha)));
            }
            _ => {}
        }
    }
    anyhow::bail!(
        "Hessian not usable even at 100× escalated damping (base α = {base}); \
         the calibration data for this layer is likely corrupt"
    )
}

/// Best-effort text of a caught panic payload (`panic!` with a string or
/// format args; anything else reports as opaque).
fn panic_text(p: &(dyn std::any::Any + Send)) -> &str {
    p.downcast_ref::<&str>()
        .copied()
        .or_else(|| p.downcast_ref::<String>().map(|s| s.as_str()))
        .unwrap_or("<non-string panic payload>")
}

/// A block-by-block quantization session over one checkpoint.
///
/// The session owns a running copy of the model; after block b is
/// swapped, blocks > b see calibration activations produced by the
/// already-quantized prefix (the paper's §6 scheme). Drive it with
/// [`run`](QuantSession::run), or stage-by-stage:
///
/// ```no_run
/// # fn main() -> quip::Result<()> {
/// use quip::coordinator::pipeline::{PipelineConfig, PipelineControl, QuantSession};
/// # let ck = quip::model::Checkpoint::random(&quip::model::ModelConfig::sized("t", 32, 2, 4, 64), 0);
/// # let calib: Vec<Vec<u32>> = vec![vec![1, 2, 3]];
/// let mut session = QuantSession::new(&ck, PipelineConfig::default())?
///     .on_event(|ev| {
///         println!("{ev:?}");
///         PipelineControl::Continue
///     });
/// for block in 0..session.n_blocks() {
///     let hset = session.collect_hessians(block, &calib)?;
///     let out = session.quantize_block(block, &hset)?;
///     session.swap_weights(out)?;
/// }
/// let (qm, report) = session.finish();
/// # let _ = (qm, report);
/// # Ok(())
/// # }
/// ```
pub struct QuantSession<'a> {
    ck: &'a Checkpoint,
    cfg: PipelineConfig,
    rounder: Arc<dyn Rounder>,
    model: Transformer,
    specs: Vec<LinearSpec>,
    layers: Vec<QuantizedLayer>,
    reports: Vec<LayerReport>,
    next_block: usize,
    cancelled: bool,
    t0: Instant,
    observer: Option<Box<dyn FnMut(&PipelineEvent) -> PipelineControl + 'a>>,
    trace: Option<Arc<TraceSink>>,
    journal: Option<CheckpointJournal>,
    failed: Vec<(usize, String)>,
    metrics: Option<Arc<MetricRegistry>>,
}

impl<'a> QuantSession<'a> {
    pub fn new(ck: &'a Checkpoint, cfg: PipelineConfig) -> crate::Result<QuantSession<'a>> {
        Ok(QuantSession {
            rounder: cfg.quant.method.rounder(),
            model: Transformer::from_checkpoint(ck)?,
            specs: ck.config.linear_specs(),
            layers: Vec::new(),
            reports: Vec::new(),
            next_block: 0,
            cancelled: false,
            t0: Instant::now(),
            observer: None,
            trace: None,
            journal: None,
            failed: Vec::new(),
            metrics: None,
            ck,
            cfg,
        })
    }

    /// The config fingerprint this session would stamp on a checkpoint
    /// manifest (see [`Fingerprint`]). Captures every knob that changes
    /// quantized bytes, so resume can refuse incompatible sessions.
    pub fn fingerprint(&self) -> Fingerprint {
        Fingerprint {
            bits: self.cfg.quant.bits,
            rounder: self.rounder.name().to_string(),
            transform: self.cfg.quant.processing.transform.to_string(),
            incoherent: self.cfg.quant.processing.incoherent,
            stochastic: self.cfg.quant.force_stochastic,
            greedy_passes: self.cfg.quant.greedy_passes,
            alg5_c: self.cfg.quant.alg5_c,
            seed: self.cfg.seed,
            calib_seqs: self.cfg.calib_seqs,
            calib_seq_len: self.cfg.calib_seq_len,
            model: self.ck.config.name.clone(),
            shape_hash: crate::util::crc32::crc32(
                self.ck.config.to_json().to_string().as_bytes(),
            ),
            hessian_mem_budget: self.cfg.hessian_mem_budget as u64,
            layer_workers: self.cfg.layer_workers,
        }
    }

    /// Checkpoint this session into `dir`: write the fingerprint manifest
    /// and start a fresh `.qzp` journal that
    /// [`swap_weights`](Self::swap_weights) appends each completed block
    /// to. Apply any
    /// [`with_rounder`](Self::with_rounder) override *before* this call
    /// so the fingerprint names the rounder actually used.
    pub fn with_checkpoint_dir(mut self, dir: &std::path::Path) -> crate::Result<Self> {
        let fp = self.fingerprint();
        self.journal = Some(CheckpointJournal::create(
            dir,
            &fp,
            self.cfg.faults.clone(),
        )?);
        Ok(self)
    }

    /// Resume a checkpointed session from `dir`: verify the fingerprint
    /// matches `cfg` (refusing on any difference, or on journal CRC
    /// damage), replay every journaled block into the running model —
    /// dequantizing the stored codes reproduces the exact f32 weights the
    /// original `swap_weights` installed, so downstream Hessians and the
    /// final artifact are byte-identical to an uninterrupted run — and
    /// position the session at the first unjournaled block. A torn tail
    /// record (interrupted append) is dropped; that block re-quantizes.
    pub fn resume(
        ck: &'a Checkpoint,
        cfg: PipelineConfig,
        dir: &std::path::Path,
    ) -> crate::Result<QuantSession<'a>> {
        let mut session = QuantSession::new(ck, cfg)?;
        let fp = session.fingerprint();
        let (journal, records) =
            CheckpointJournal::open(dir, &fp, session.cfg.faults.clone())?;
        for rec in records {
            match rec {
                BlockRecord::Completed { layers, .. } => {
                    for lr in layers {
                        let wd = lr.layer.dequantize();
                        let data: Vec<f32> = wd.data.iter().map(|&x| x as f32).collect();
                        session.model.set_weight(&lr.layer.name, data)?;
                        session.reports.push(LayerReport {
                            name: lr.layer.name.clone(),
                            proxy_loss: lr.proxy_loss,
                            seconds: lr.seconds,
                            accumulate_seconds: lr.accumulate_seconds,
                            factorize_seconds: lr.factorize_seconds,
                            round_seconds: lr.round_seconds,
                        });
                        session.layers.push(lr.layer);
                    }
                }
                BlockRecord::Failed { block, error } => {
                    session.failed.push((block, error));
                }
            }
            session.next_block += 1;
        }
        if session.next_block > 0 {
            crate::log_info!(
                "resumed quantization at block {}/{} ({} journaled layers)",
                session.next_block,
                session.n_blocks(),
                session.layers.len()
            );
        }
        session.journal = Some(journal);
        Ok(session)
    }

    /// Install the event observer. Called synchronously on the driving
    /// thread for every [`PipelineEvent`]; return
    /// [`PipelineControl::Stop`] to cancel after the current stage.
    pub fn on_event<F>(mut self, observer: F) -> Self
    where
        F: FnMut(&PipelineEvent) -> PipelineControl + 'a,
    {
        self.observer = Some(Box::new(observer));
        self
    }

    /// Override the rounding algorithm (e.g. a custom [`Rounder`] not in
    /// the registry). Defaults to `cfg.quant.method`'s rounder.
    pub fn with_rounder(mut self, rounder: Arc<dyn Rounder>) -> Self {
        self.rounder = rounder;
        self
    }

    /// Attach an observability trace sink (DESIGN.md §9). Each layer's
    /// stage breakdown is bridged onto Chrome-trace spans — one
    /// `tid` lane per block, cat `"quantize"` — and non-PD damping
    /// escalations become instant markers, so a shared sink gives the
    /// pipeline and the serve path one timeline.
    pub fn with_trace(mut self, sink: Arc<TraceSink>) -> Self {
        self.trace = Some(sink);
        self
    }

    /// Attach a metric registry: the sharded Hessian store reports its
    /// peak resident bytes (`quip_hessian_peak_bytes`, a cross-block
    /// high-water mark) and spill counters through it (DESIGN.md §11).
    pub fn with_metrics(mut self, registry: Arc<MetricRegistry>) -> Self {
        self.metrics = Some(registry);
        self
    }

    pub fn n_blocks(&self) -> usize {
        self.ck.config.n_layers
    }

    /// All blocks processed, or the observer cancelled.
    pub fn is_done(&self) -> bool {
        self.cancelled || self.next_block >= self.n_blocks()
    }

    fn emit(&mut self, ev: PipelineEvent) -> PipelineControl {
        let control = match &mut self.observer {
            Some(f) => f(&ev),
            None => PipelineControl::Continue,
        };
        if control == PipelineControl::Stop {
            self.cancelled = true;
        }
        control
    }

    fn block_prefix(block: usize) -> String {
        format!("blk{block}.")
    }

    /// Stage 1: run the calibration set through the model (whose blocks
    /// < `block` are already quantized) and accumulate this block's
    /// proxy Hessians.
    pub fn collect_hessians(
        &mut self,
        block: usize,
        calib: &[Vec<u32>],
    ) -> crate::Result<HessianSet> {
        let prefix = Self::block_prefix(block);
        // Allocate accumulators for this block's hkeys only (not the
        // whole model's): the sink filters on the block prefix anyway,
        // and an n-block model does not need n× the accumulator memory.
        let mut accums = BTreeMap::new();
        for spec in self.specs.iter().filter(|s| s.name.starts_with(&prefix)) {
            accums
                .entry(spec.hkey.clone())
                .or_insert_with(|| HessianAccum::new(spec.in_dim));
        }
        let mut hset = HessianSet { accums };
        {
            let mut sink = |hkey: &str, rows: &[f32], n: usize| {
                if hkey.starts_with(&prefix) {
                    if let Some(acc) = hset.accums.get_mut(hkey) {
                        acc.add_rows(rows, n);
                    }
                }
            };
            for seq in calib {
                self.model.forward(seq, Some(&mut sink));
            }
        }
        Ok(hset)
    }

    /// Stage 2: quantize the block's linear layers in parallel on the
    /// thread pool. Pure compute — the running model is untouched until
    /// [`swap_weights`](Self::swap_weights).
    ///
    /// Failure isolation: each layer job runs under `catch_unwind`, so a
    /// panicking worker (a bug, or the `pipeline.layer_round` fault
    /// point) poisons only this block's result — the pool threads for
    /// sibling layers finish normally and the panic surfaces as this
    /// block's `Err`, which [`step`](Self::step) retries once with
    /// escalated damping before declaring [`PipelineEvent::BlockFailed`].
    pub fn quantize_block(
        &mut self,
        block: usize,
        hset: &HessianSet,
    ) -> crate::Result<BlockOutput> {
        self.quantize_block_with(block, hset, self.cfg.quant.clone())
    }

    fn quantize_block_with(
        &mut self,
        block: usize,
        hset: &HessianSet,
        qcfg: QuantConfig,
    ) -> crate::Result<BlockOutput> {
        let prefix = Self::block_prefix(block);
        let block_specs: Vec<LinearSpec> = self
            .specs
            .iter()
            .filter(|s| s.name.starts_with(&prefix))
            .cloned()
            .collect();
        let weights: Vec<Mat> = block_specs
            .iter()
            .map(|s| {
                let wdata = self.model.get_weight(&s.name)?;
                Ok(Mat {
                    rows: s.out_dim,
                    cols: s.in_dim,
                    data: wdata.iter().map(|&x| x as f64).collect(),
                })
            })
            .collect::<crate::Result<_>>()?;
        let hessians: Vec<Mat> = block_specs
            .iter()
            .map(|s| hset.finish(&s.hkey))
            .collect::<crate::Result<_>>()?;

        let seed = self.cfg.seed;
        let faults = self.cfg.faults.clone();
        let rounder = Arc::clone(&self.rounder);
        let results = parallel_map(block_specs.len(), default_threads(), |i| {
            let t = Instant::now();
            let layer_seed = seed
                .wrapping_mul(0x100000001B3)
                .wrapping_add((block * 16 + i) as u64);
            // catch_unwind here, inside the pool closure: parallel_map's
            // thread::scope would otherwise propagate a worker panic and
            // take the whole session down with it.
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                if let Some(f) = &faults {
                    f.hit("pipeline.layer_round")?;
                }
                quantize_layer_robust(
                    rounder.as_ref(),
                    &weights[i],
                    &hessians[i],
                    &qcfg,
                    layer_seed,
                )
            }))
            .unwrap_or_else(|p| Err(anyhow::anyhow!("worker panic: {}", panic_text(&p))));
            (out, t.elapsed().as_secs_f64())
        });
        let results = results
            .into_iter()
            .zip(&block_specs)
            .map(|((out, secs), spec)| {
                let (lq, damped) = out
                    .map_err(|e| anyhow::anyhow!("layer {}: {e}", spec.name))?;
                let (accumulate_seconds, accumulate_gbps) = hset
                    .accums
                    .get(&spec.hkey)
                    .map(|a| (a.seconds, a.effective_gbps()))
                    .unwrap_or((0.0, 0.0));
                Ok(LayerResult {
                    lq,
                    seconds: secs,
                    damped,
                    accumulate_seconds,
                    accumulate_gbps,
                    pool: None,
                })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(BlockOutput {
            block,
            specs: block_specs,
            results,
        })
    }

    /// Where this session's sharded store spills: under the checkpoint
    /// directory when journaling (so one run's scratch state lives with
    /// its durable state), else a per-process temp directory. Spill files
    /// are scratch, cleaned up by the store's `Drop`; stale files from a
    /// killed process are simply overwritten on re-collection.
    fn spill_dir(&self) -> std::path::PathBuf {
        match &self.journal {
            Some(j) => j.dir().join("spill"),
            None => std::env::temp_dir().join(format!(
                "quip_spill_{}_{:016x}",
                std::process::id(),
                self.cfg.seed
            )),
        }
    }

    /// Sharded stage 1 (DESIGN.md §11): stream the calibration set's
    /// activations into a budget-bounded [`ShardedHessianStore`] instead
    /// of an all-resident [`HessianSet`]. Flush boundaries and spill
    /// schedule are pure functions of the stream, so the finished
    /// Hessians are bit-identical to the in-memory path for any budget.
    fn collect_block_store(
        &mut self,
        block: usize,
        calib: &[Vec<u32>],
    ) -> crate::Result<ShardedHessianStore> {
        let prefix = Self::block_prefix(block);
        let mut keys: Vec<(String, usize)> = Vec::new();
        for spec in self.specs.iter().filter(|s| s.name.starts_with(&prefix)) {
            if !keys.iter().any(|(k, _)| k == &spec.hkey) {
                keys.push((spec.hkey.clone(), spec.in_dim));
            }
        }
        let mut store =
            ShardedHessianStore::new(&keys, self.cfg.hessian_mem_budget, &self.spill_dir())
                .with_faults(self.cfg.faults.clone())
                .with_metrics(self.metrics.as_ref().map(|r| ShardMetrics::register(r)));
        {
            let mut sink = |hkey: &str, rows: &[f32], n: usize| {
                if hkey.starts_with(&prefix) {
                    store.add_rows(hkey, rows, n);
                }
            };
            for seq in calib {
                self.model.forward(seq, Some(&mut sink));
            }
        }
        // The capture sink cannot return errors; spill failures (or an
        // armed soft `hessian.spill` fault) surface here, after the
        // in-flight forward pass completes.
        store.check()?;
        Ok(store)
    }

    /// Sharded stage 2: quantize the block's layers on a work-stealing
    /// across-layer pool, each worker loading its layer's finished
    /// Hessian from the store on demand — at most `layer_workers`
    /// finished n×n Hessians are resident at once, instead of one per
    /// layer. Results are collected in spec order and each layer's seed
    /// depends only on (session seed, block, spec index), so quantized
    /// bytes are identical for any worker count (pinned by
    /// `rust/tests/determinism.rs`).
    fn quantize_block_store(
        &mut self,
        block: usize,
        store: &ShardedHessianStore,
        qcfg: QuantConfig,
    ) -> crate::Result<BlockOutput> {
        let prefix = Self::block_prefix(block);
        let block_specs: Vec<LinearSpec> = self
            .specs
            .iter()
            .filter(|s| s.name.starts_with(&prefix))
            .cloned()
            .collect();
        let weights: Vec<Mat> = block_specs
            .iter()
            .map(|s| {
                let wdata = self.model.get_weight(&s.name)?;
                Ok(Mat {
                    rows: s.out_dim,
                    cols: s.in_dim,
                    data: wdata.iter().map(|&x| x as f64).collect(),
                })
            })
            .collect::<crate::Result<_>>()?;

        let seed = self.cfg.seed;
        let faults = self.cfg.faults.clone();
        let rounder = Arc::clone(&self.rounder);
        let workers = if self.cfg.layer_workers == 0 {
            default_threads()
        } else {
            self.cfg.layer_workers
        };
        let results = parallel_map_traced(block_specs.len(), workers, |i| {
            let t = Instant::now();
            // Identical to the legacy path's seed derivation: quantized
            // bytes must not depend on which path — or worker — ran.
            let layer_seed = seed
                .wrapping_mul(0x100000001B3)
                .wrapping_add((block * 16 + i) as u64);
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                if let Some(f) = &faults {
                    f.hit("pipeline.layer_round")?;
                }
                // On-demand Hessian: finish() reads the spill file when
                // the accumulator was evicted, so a worker only ever
                // materializes the layer it is currently rounding.
                let h = store.finish(&block_specs[i].hkey)?;
                quantize_layer_robust(rounder.as_ref(), &weights[i], &h, &qcfg, layer_seed)
            }))
            .unwrap_or_else(|p| Err(anyhow::anyhow!("worker panic: {}", panic_text(&p))));
            (out, t.elapsed().as_secs_f64())
        });
        let results = results
            .into_iter()
            .zip(&block_specs)
            .map(|(((out, secs), timing), spec)| {
                let (lq, damped) = out
                    .map_err(|e| anyhow::anyhow!("layer {}: {e}", spec.name))?;
                let (accumulate_seconds, accumulate_gbps) = store.stats(&spec.hkey);
                Ok(LayerResult {
                    lq,
                    seconds: secs,
                    damped,
                    accumulate_seconds,
                    accumulate_gbps,
                    pool: Some(timing),
                })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(BlockOutput {
            block,
            specs: block_specs,
            results,
        })
    }

    /// Stage 3: swap the block's dequantized weights into the running
    /// model, record reports/artifact layers, emit one
    /// [`PipelineEvent::LayerDone`] per layer, and advance the block
    /// cursor. Blocks must be swapped strictly in order (the §6
    /// quantized-prefix invariant) — swapping any other block is an
    /// error, so the staged API composes safely with
    /// [`step`](Self::step)/[`run`](Self::run).
    pub fn swap_weights(&mut self, out: BlockOutput) -> crate::Result<PipelineControl> {
        anyhow::ensure!(
            out.block == self.next_block,
            "swap_weights out of order: got block {}, expected {}",
            out.block,
            self.next_block
        );
        let BlockOutput {
            block,
            specs,
            results,
        } = out;
        let mut control = PipelineControl::Continue;
        let first_layer = self.layers.len();
        for (spec, res) in specs.iter().zip(results) {
            let LayerResult {
                lq,
                seconds: secs,
                damped,
                accumulate_seconds,
                accumulate_gbps,
                pool,
            } = res;
            if let (Some(trace), Some(pt)) = (&self.trace, pool) {
                // Pool scheduling on its own cat ("quantize_pool", one
                // tid lane per *worker*): queue wait + run of each layer
                // job, kept separate from the per-block "quantize" lanes
                // so existing span consumers see an unchanged stream.
                let end = trace.now_us();
                let run = (pt.run_seconds.max(0.0) * 1e6) as u64;
                trace.complete(
                    pt.worker as u64,
                    "layer_job",
                    "quantize_pool",
                    end.saturating_sub(run),
                    run,
                    vec![
                        ("layer".to_string(), Json::Str(spec.name.clone())),
                        ("block".to_string(), Json::Num(block as f64)),
                        (
                            "queued_ms".to_string(),
                            Json::Num(pt.start_seconds.max(0.0) * 1e3),
                        ),
                    ],
                );
            }
            if let Some(alpha) = damped {
                crate::log_warn!(
                    "layer {}: Hessian not PD at configured damping; escalated to α = {alpha}",
                    spec.name
                );
                if let Some(trace) = &self.trace {
                    trace.instant(
                        block as u64,
                        "hessian_damped",
                        "quantize",
                        vec![
                            ("layer".to_string(), Json::Str(spec.name.clone())),
                            ("alpha".to_string(), Json::Num(alpha)),
                        ],
                    );
                }
                let c = self.emit(PipelineEvent::HessianDamped {
                    block,
                    name: spec.name.clone(),
                    alpha,
                });
                if c == PipelineControl::Stop {
                    control = PipelineControl::Stop;
                }
            }
            if let Some(trace) = &self.trace {
                // Bridge the stage breakdown onto the shared timeline as
                // synthetic back-to-back spans ending "now" (the work
                // already happened on pool threads; only the durations
                // are meaningful, exactly as in LayerStageTimings). One
                // tid lane per block keeps concurrent layers readable.
                let end = trace.now_us();
                let us = |s: f64| (s.max(0.0) * 1e6) as u64;
                let (acc, fac, rnd) = (
                    us(accumulate_seconds),
                    us(lq.stages.factorize_seconds),
                    us(lq.stages.round_seconds),
                );
                let name_arg =
                    |n: &str| vec![("layer".to_string(), Json::Str(n.to_string()))];
                let tid = block as u64;
                let round_start = end.saturating_sub(rnd);
                let fac_start = round_start.saturating_sub(fac);
                let acc_start = fac_start.saturating_sub(acc);
                trace.complete(tid, "accumulate", "quantize", acc_start, acc, name_arg(&spec.name));
                trace.complete(tid, "factorize", "quantize", fac_start, fac, name_arg(&spec.name));
                trace.complete(tid, "round", "quantize", round_start, rnd, name_arg(&spec.name));
            }
            let c = self.emit(PipelineEvent::LayerStageTimings {
                block,
                name: spec.name.clone(),
                accumulate_seconds,
                accumulate_gbps,
                factorize_seconds: lq.stages.factorize_seconds,
                round_seconds: lq.stages.round_seconds,
            });
            if c == PipelineControl::Stop {
                control = PipelineControl::Stop;
            }
            let data: Vec<f32> = lq.w_hat.data.iter().map(|&x| x as f32).collect();
            self.model.set_weight(&spec.name, data)?;
            self.reports.push(LayerReport {
                name: spec.name.clone(),
                proxy_loss: lq.proxy_loss,
                seconds: secs,
                accumulate_seconds,
                factorize_seconds: lq.stages.factorize_seconds,
                round_seconds: lq.stages.round_seconds,
            });
            // Vector-rounded layers store per-group codebook indices
            // (`.qz` v3); scalar layers store bit-packed integer codes.
            let proxy_loss = lq.proxy_loss;
            self.layers.push(lq.into_layer(&spec.name));
            let c = self.emit(PipelineEvent::LayerDone {
                block,
                name: spec.name.clone(),
                proxy_loss,
                seconds: secs,
            });
            if c == PipelineControl::Stop {
                control = PipelineControl::Stop;
            }
        }
        // Make the block durable *before* advancing the cursor: a kill
        // at the `pipeline.block_done` fault point (immediately after the
        // append) leaves a journal whose replay reproduces exactly this
        // session state, so resume is byte-identical from every block
        // boundary.
        if self.journal.is_some() {
            let layers = self.layers[first_layer..]
                .iter()
                .zip(&self.reports[first_layer..])
                .map(|(layer, rep)| LayerRecord {
                    layer: layer.clone(),
                    proxy_loss: rep.proxy_loss,
                    seconds: rep.seconds,
                    accumulate_seconds: rep.accumulate_seconds,
                    factorize_seconds: rep.factorize_seconds,
                    round_seconds: rep.round_seconds,
                })
                .collect();
            if let Some(journal) = &mut self.journal {
                journal.append(&BlockRecord::Completed { block, layers })?;
            }
        }
        if let Some(f) = &self.cfg.faults {
            f.hit("pipeline.block_done")?;
        }
        self.next_block += 1;
        Ok(control)
    }

    /// Run all three stages for the next unprocessed block, emitting
    /// `BlockStarted`/`LayerDone`*/`BlockDone`. Returns the resulting
    /// control decision ([`PipelineControl::Stop`] once done/cancelled).
    pub fn step(&mut self, calib: &[Vec<u32>]) -> crate::Result<PipelineControl> {
        if self.is_done() {
            return Ok(PipelineControl::Stop);
        }
        let block = self.next_block;
        let prefix = Self::block_prefix(block);
        let n_layers = self
            .specs
            .iter()
            .filter(|s| s.name.starts_with(&prefix))
            .count();
        if self.emit(PipelineEvent::BlockStarted {
            block,
            layers: n_layers,
        }) == PipelineControl::Stop
        {
            return Ok(PipelineControl::Stop);
        }
        let t_block = Instant::now();
        // The driving path is the sharded one (DESIGN.md §11): budget 0
        // simply means nothing ever spills. The staged public API
        // (collect_hessians / quantize_block) keeps the all-resident
        // HessianSet, so `staged_api_matches_one_shot_wrapper` pins the
        // two paths byte-identical.
        let store = self.collect_block_store(block, calib)?;
        let out = match self.quantize_block_store(block, &store, self.cfg.quant.clone()) {
            Ok(out) => Ok(out),
            Err(first) => {
                // Failure isolation: retry the poisoned block once with
                // escalated damping (10× the configured α baseline, on
                // top of quantize_layer_robust's own per-layer α → 10α →
                // 100α ladder) before giving up on it.
                crate::log_warn!(
                    "block {block} failed ({first}); retrying once with escalated damping"
                );
                let mut qcfg = self.cfg.quant.clone();
                qcfg.processing.alpha = qcfg.processing.alpha.max(1e-3) * 10.0;
                self.quantize_block_store(block, &store, qcfg)
            }
        };
        drop(store);
        let mut control = match out {
            Ok(out) => {
                let control = self.swap_weights(out)?;
                crate::log_info!(
                    "block {block}: quantized {n_layers} layers ({:.1}s elapsed)",
                    self.t0.elapsed().as_secs_f64()
                );
                let c = self.emit(PipelineEvent::BlockDone {
                    block,
                    seconds: t_block.elapsed().as_secs_f64(),
                });
                if c == PipelineControl::Stop {
                    PipelineControl::Stop
                } else {
                    control
                }
            }
            Err(retry_err) => {
                // The retry failed too: skip the block (its weights stay
                // fp32 in the running model, so later blocks still see a
                // consistent prefix), journal the failure for resume, and
                // degrade gracefully instead of aborting the session.
                let error = retry_err.to_string();
                crate::log_warn!("block {block} failed after retry, skipping: {error}");
                if let Some(journal) = &mut self.journal {
                    journal.append(&BlockRecord::Failed {
                        block,
                        error: error.clone(),
                    })?;
                }
                if let Some(f) = &self.cfg.faults {
                    f.hit("pipeline.block_done")?;
                }
                self.failed.push((block, error.clone()));
                self.next_block += 1;
                self.emit(PipelineEvent::BlockFailed { block, error })
            }
        };
        if self.is_done() {
            control = PipelineControl::Stop;
        }
        Ok(control)
    }

    /// Drive every remaining block, then finish. Stops early (without
    /// error) if the observer cancels; the returned artifact then covers
    /// the completed blocks only.
    pub fn run(mut self, calib: &[Vec<u32>]) -> crate::Result<(QuantizedModel, PipelineReport)> {
        while !self.is_done() {
            self.step(calib)?;
        }
        Ok(self.finish())
    }

    /// Package whatever has been quantized so far into the artifact +
    /// report. Total on a completed run; partial after cancellation.
    pub fn finish(self) -> (QuantizedModel, PipelineReport) {
        let recipe = format!(
            "{}+{}",
            self.rounder.name(),
            if self.cfg.quant.processing.incoherent {
                // Name the incoherence backend so artifacts quantized
                // with different transforms are distinguishable.
                format!("incp-{}", self.cfg.quant.processing.transform)
            } else {
                "baseline".to_string()
            }
        );
        (
            QuantizedModel {
                config: self.ck.config.clone(),
                bits: self.cfg.quant.bits,
                recipe,
                layers: self.layers,
            },
            PipelineReport {
                layers: self.reports,
                total_seconds: self.t0.elapsed().as_secs_f64(),
                failed_blocks: self.failed,
            },
        )
    }
}

/// Quantize a whole model from its checkpoint with the given calibration
/// sequences. One-shot wrapper over [`QuantSession`]; returns the
/// quantized artifact + report.
pub fn quantize_model(
    ck: &Checkpoint,
    calib: &[Vec<u32>],
    cfg: &PipelineConfig,
) -> crate::Result<(QuantizedModel, PipelineReport)> {
    QuantSession::new(ck, cfg.clone())?.run(calib)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen::markov_stream;
    use crate::model::ModelConfig;
    use crate::quant::{Method, Processing};

    fn run_pipeline(
        bits: u32,
        method: Method,
        processing: Processing,
    ) -> (QuantizedModel, PipelineReport, Checkpoint) {
        let cfg = ModelConfig::sized("t", 32, 2, 4, 64);
        let ck = Checkpoint::random(&cfg, 1);
        let stream = markov_stream(cfg.vocab as u32, 4_000, 2);
        let calib = stream.calibration(24, 4, 3);
        let pcfg = PipelineConfig {
            quant: QuantConfig {
                bits,
                method,
                processing,
                greedy_passes: 2,
                ..Default::default()
            },
            calib_seqs: 4,
            calib_seq_len: 24,
            seed: 7,
            ..Default::default()
        };
        let (qm, report) = quantize_model(&ck, &calib, &pcfg).unwrap();
        (qm, report, ck)
    }

    fn tiny_setup() -> (Checkpoint, Vec<Vec<u32>>, PipelineConfig) {
        let cfg = ModelConfig::sized("t", 32, 2, 4, 64);
        let ck = Checkpoint::random(&cfg, 1);
        let stream = markov_stream(cfg.vocab as u32, 4_000, 2);
        let calib = stream.calibration(24, 4, 3);
        let pcfg = PipelineConfig {
            quant: QuantConfig {
                bits: 2,
                greedy_passes: 2,
                ..Default::default()
            },
            calib_seqs: 4,
            calib_seq_len: 24,
            seed: 7,
            ..Default::default()
        };
        (ck, calib, pcfg)
    }

    #[test]
    fn pipeline_produces_all_layers() {
        let (qm, report, ck) = run_pipeline(2, Method::Ldlq, Processing::incoherent());
        assert_eq!(qm.layers.len(), ck.config.linear_specs().len());
        assert_eq!(report.layers.len(), qm.layers.len());
        assert!(report.layers.iter().all(|l| l.proxy_loss.is_finite()));
        // Stage breakdown is populated and consistent with the total.
        for l in &report.layers {
            assert!(l.accumulate_seconds >= 0.0);
            assert!(l.factorize_seconds >= 0.0 && l.round_seconds >= 0.0);
            assert!(l.factorize_seconds + l.round_seconds <= l.seconds + 0.05);
        }
        // Applying the artifact reproduces a working model.
        let mut m = Transformer::from_checkpoint(&ck).unwrap();
        qm.apply_to(&mut m).unwrap();
        let logits = m.forward(&[1, 2, 3], None);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn vq_pipeline_stores_codebook_layers() {
        // End-to-end session with the vq rounder: every artifact layer
        // stores vector-codebook indices, and the artifact survives a
        // full v3 container roundtrip with identical dequantization.
        let (qm, report, ck) = run_pipeline(2, Method::Vq, Processing::incoherent());
        assert_eq!(qm.layers.len(), ck.config.linear_specs().len());
        assert!(report.layers.iter().all(|l| l.proxy_loss.is_finite()));
        assert_eq!(qm.recipe, "vq+incp-kron");
        for l in &qm.layers {
            assert!(
                matches!(l.layout, crate::quant::CodeLayout::Vq { .. }),
                "layer {} not vq",
                l.name
            );
        }
        let bytes = qm.to_bytes(crate::model::quantized::QZ_VERSION);
        let loaded = QuantizedModel::from_bytes(&bytes).unwrap();
        for (a, b) in loaded.layers.iter().zip(&qm.layers) {
            assert_eq!(a.dequantize().data, b.dequantize().data);
        }
        // And the artifact drives a working model.
        let mut m = Transformer::from_checkpoint(&ck).unwrap();
        loaded.apply_to(&mut m).unwrap();
        assert!(m.forward(&[1, 2, 3], None).iter().all(|x| x.is_finite()));
    }

    #[test]
    fn quip_proxy_below_baseline_near() {
        let (_, quip, _) = run_pipeline(2, Method::Ldlq, Processing::incoherent());
        let (_, near, _) = run_pipeline(2, Method::Nearest, Processing::baseline());
        assert!(
            quip.total_proxy() < near.total_proxy(),
            "quip {} vs near {}",
            quip.total_proxy(),
            near.total_proxy()
        );
    }

    #[test]
    fn report_serializes() {
        let (_, report, _) = run_pipeline(4, Method::Ldlq, Processing::baseline());
        let j = report.to_json();
        assert!(j.get("layers").is_some());
        assert!(Json::parse(&j.pretty()).is_ok());
    }

    #[test]
    fn event_stream_is_ordered_and_complete() {
        let (ck, calib, pcfg) = tiny_setup();
        let mut events: Vec<PipelineEvent> = Vec::new();
        let (qm, report) = QuantSession::new(&ck, pcfg.clone())
            .unwrap()
            .on_event(|ev| {
                events.push(ev.clone());
                PipelineControl::Continue
            })
            .run(&calib)
            .unwrap();

        // Events arrive in block order: Started, LayerDone*, Done per block.
        let n_blocks = ck.config.n_layers;
        let specs = ck.config.linear_specs();
        let mut idx = 0usize;
        for b in 0..n_blocks {
            let block_layers: Vec<&LinearSpec> = specs
                .iter()
                .filter(|s| s.name.starts_with(&format!("blk{b}.")))
                .collect();
            match &events[idx] {
                PipelineEvent::BlockStarted { block, layers } => {
                    assert_eq!(*block, b);
                    assert_eq!(*layers, block_layers.len());
                }
                other => panic!("expected BlockStarted({b}), got {other:?}"),
            }
            idx += 1;
            for spec in &block_layers {
                match &events[idx] {
                    PipelineEvent::LayerStageTimings {
                        block,
                        name,
                        accumulate_seconds,
                        accumulate_gbps,
                        factorize_seconds,
                        round_seconds,
                    } => {
                        assert_eq!(*block, b);
                        assert_eq!(name, &spec.name, "stage timings precede LayerDone");
                        assert!(*accumulate_seconds >= 0.0);
                        assert!(accumulate_gbps.is_finite() && *accumulate_gbps >= 0.0);
                        assert!(*factorize_seconds >= 0.0);
                        assert!(*round_seconds >= 0.0);
                    }
                    other => panic!("expected LayerStageTimings({}), got {other:?}", spec.name),
                }
                idx += 1;
                match &events[idx] {
                    PipelineEvent::LayerDone {
                        block,
                        name,
                        proxy_loss,
                        seconds,
                    } => {
                        assert_eq!(*block, b);
                        assert_eq!(name, &spec.name, "one LayerDone per spec, in order");
                        assert!(proxy_loss.is_finite());
                        assert!(*seconds >= 0.0);
                    }
                    other => panic!("expected LayerDone({}), got {other:?}", spec.name),
                }
                idx += 1;
            }
            match &events[idx] {
                PipelineEvent::BlockDone { block, .. } => assert_eq!(*block, b),
                other => panic!("expected BlockDone({b}), got {other:?}"),
            }
            idx += 1;
        }
        assert_eq!(idx, events.len(), "no extra events");

        // The observed run matches the one-shot wrapper bit for bit.
        let (qm2, report2) = quantize_model(&ck, &calib, &pcfg).unwrap();
        assert_eq!(qm.layers.len(), qm2.layers.len());
        for (a, b) in qm.layers.iter().zip(&qm2.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.packed, b.packed);
        }
        assert_eq!(report.total_proxy(), report2.total_proxy());
    }

    #[test]
    fn cancellation_after_first_block_yields_partial_report() {
        let (ck, calib, pcfg) = tiny_setup();
        let (qm, report) = QuantSession::new(&ck, pcfg)
            .unwrap()
            .on_event(|ev| match ev {
                PipelineEvent::BlockDone { .. } => PipelineControl::Stop,
                _ => PipelineControl::Continue,
            })
            .run(&calib)
            .unwrap();
        let blk0: Vec<LinearSpec> = ck
            .config
            .linear_specs()
            .into_iter()
            .filter(|s| s.name.starts_with("blk0."))
            .collect();
        assert!(ck.config.n_layers > 1, "test needs ≥2 blocks");
        assert_eq!(report.layers.len(), blk0.len(), "only block 0 quantized");
        assert_eq!(qm.layers.len(), blk0.len());
        assert!(report.layers.iter().all(|l| l.proxy_loss.is_finite()));
    }

    #[test]
    fn out_of_order_swap_rejected_and_staged_composes_with_run() {
        let (ck, calib, pcfg) = tiny_setup();
        let mut session = QuantSession::new(&ck, pcfg).unwrap();
        // Computing a later block's stages out of order is allowed (pure
        // compute), but swapping it must fail: it would break the §6
        // quantized-prefix invariant.
        let hset = session.collect_hessians(1, &calib).unwrap();
        let out = session.quantize_block(1, &hset).unwrap();
        assert!(session.swap_weights(out).is_err());
        // Drive block 0 manually, then let run() pick up the remainder —
        // block 0 must not be quantized twice.
        let hset = session.collect_hessians(0, &calib).unwrap();
        let out = session.quantize_block(0, &hset).unwrap();
        session.swap_weights(out).unwrap();
        let (qm, report) = session.run(&calib).unwrap();
        assert_eq!(qm.layers.len(), ck.config.linear_specs().len());
        assert_eq!(report.layers.len(), qm.layers.len());
        let mut names: Vec<&str> = qm.layers.iter().map(|l| l.name.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), qm.layers.len(), "no duplicate layers");
    }

    #[test]
    fn non_pd_hessian_escalates_damping_instead_of_aborting() {
        // An indefinite "Hessian" (one negative diagonal direction) fails
        // the Cholesky probe at the configured α and at 10α; 100α finally
        // dominates the negative eigenvalue. The layer must quantize with
        // escalated damping reported, not abort.
        let n = 8;
        let mut h = Mat::eye(n);
        h[(n - 1, n - 1)] = -0.1;
        let mut rng = crate::util::rng::Rng::new(3);
        let w = crate::util::testkit::random_mat(&mut rng, 4, n).scale(0.1);
        let cfg = QuantConfig {
            bits: 2,
            ..Default::default()
        };
        // Sanity: the damped Hessian really is non-PD at α and 10α.
        let base = cfg.processing.alpha.max(1e-3);
        assert!(crate::linalg::chol::cholesky(&crate::quant::incoherence::damp(
            &h,
            cfg.processing.alpha
        ))
        .is_err());
        assert!(crate::linalg::chol::cholesky(&crate::quant::incoherence::damp(&h, base * 10.0))
            .is_err());
        let rounder = cfg.method.rounder();
        let (out, damped) = quantize_layer_robust(rounder.as_ref(), &w, &h, &cfg, 7).unwrap();
        let alpha = damped.expect("escalation must be reported");
        assert!((alpha - base * 100.0).abs() < 1e-12, "alpha={alpha}");
        assert!(out.proxy_loss.is_finite());
        assert!(out.w_hat.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn healthy_hessian_does_not_escalate() {
        let mut rng = crate::util::rng::Rng::new(4);
        let w = crate::util::testkit::random_mat(&mut rng, 4, 12).scale(0.1);
        let h = crate::util::testkit::random_hessian(&mut rng, 12, 4, 1e-3);
        let cfg = QuantConfig::default();
        let rounder = cfg.method.rounder();
        let (out, damped) = quantize_layer_robust(rounder.as_ref(), &w, &h, &cfg, 7).unwrap();
        assert!(damped.is_none(), "healthy Hessian must not be re-damped");
        // Identical to the plain path (escalation 0 uses the config as-is).
        let direct = crate::quant::quantize_layer_with(rounder.as_ref(), &w, &h, &cfg, 7);
        assert_eq!(out.codes.data, direct.codes.data);
    }

    #[test]
    fn hopeless_hessian_is_clean_error_not_panic() {
        // NaN Hessians (overflowed calibration activations) cannot be
        // rescued by damping: the session must surface a clean error
        // naming the failure, never a panic/abort.
        let n = 6;
        let h = Mat::from_fn(n, n, |_, _| f64::NAN);
        let mut rng = crate::util::rng::Rng::new(5);
        let w = crate::util::testkit::random_mat(&mut rng, 3, n);
        let cfg = QuantConfig::default();
        let rounder = cfg.method.rounder();
        let err = quantize_layer_robust(rounder.as_ref(), &w, &h, &cfg, 1).unwrap_err();
        assert!(err.to_string().contains("damping"), "{err}");
    }

    #[test]
    fn damped_retry_emits_warning_event_through_session() {
        // Event plumbing: a BlockOutput carrying a damped layer must emit
        // HessianDamped before that layer's LayerDone.
        let (ck, calib, pcfg) = tiny_setup();
        let mut events: Vec<PipelineEvent> = Vec::new();
        {
            let mut session = QuantSession::new(&ck, pcfg).unwrap();
            let hset = session.collect_hessians(0, &calib).unwrap();
            let mut out = session.quantize_block(0, &hset).unwrap();
            // Simulate non-PD recovery on the first layer of the block.
            out.results[0].damped = Some(0.1);
            let mut session = session.on_event(|ev| {
                events.push(ev.clone());
                PipelineControl::Continue
            });
            session.swap_weights(out).unwrap();
        }
        let is_damped = |e: &PipelineEvent| {
            matches!(e, PipelineEvent::HessianDamped { block: 0, alpha, .. } if *alpha == 0.1)
        };
        let damped_at = events
            .iter()
            .position(|e| is_damped(e))
            .expect("HessianDamped emitted");
        let done_at = events
            .iter()
            .position(|e| matches!(e, PipelineEvent::LayerDone { .. }))
            .unwrap();
        assert!(damped_at < done_at, "warning precedes LayerDone");
    }

    #[test]
    fn quantize_spans_land_in_shared_trace_sink() {
        // The pipeline bridges its stage timings onto the same span API
        // the serving path uses: a shared TraceSink collects per-layer
        // accumulate/factorize/round spans in cat "quantize", one tid
        // lane per block, and exports well-formed Chrome trace JSON.
        let (ck, calib, pcfg) = tiny_setup();
        let sink = TraceSink::shared(4096);
        let (qm, _report) = QuantSession::new(&ck, pcfg)
            .unwrap()
            .with_trace(Arc::clone(&sink))
            .run(&calib)
            .unwrap();
        let json = Json::parse(&sink.to_chrome_json().to_string()).unwrap();
        let events = match json.get("traceEvents").unwrap() {
            Json::Arr(a) => a,
            other => panic!("traceEvents not an array: {other:?}"),
        };
        // Three spans per quantized layer, every one in cat "quantize"
        // with a layer arg, and block tids cover every block.
        let spans: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("cat").and_then(|c| c.as_str()) == Some("quantize"))
            .collect();
        assert_eq!(spans.len(), 3 * qm.layers.len());
        let mut tids: Vec<f64> = Vec::new();
        for s in &spans {
            let name = s.get("name").and_then(|n| n.as_str()).unwrap();
            assert!(
                matches!(name, "accumulate" | "factorize" | "round"),
                "unexpected span {name}"
            );
            assert!(s.get("args").unwrap().get("layer").is_some());
            let tid = s.get("tid").and_then(|t| t.as_f64()).unwrap();
            if !tids.contains(&tid) {
                tids.push(tid);
            }
        }
        assert_eq!(tids.len(), ck.config.n_layers, "one tid lane per block");
    }

    #[test]
    fn pool_spans_land_in_their_own_cat() {
        // The sharded path's queue spans ride a separate cat
        // ("quantize_pool", one tid per worker) so the per-block
        // "quantize" lanes asserted above stay untouched; every span
        // names its layer and carries the queue wait.
        let (ck, calib, pcfg) = tiny_setup();
        let sink = TraceSink::shared(4096);
        let (qm, _report) = QuantSession::new(&ck, pcfg)
            .unwrap()
            .with_trace(Arc::clone(&sink))
            .run(&calib)
            .unwrap();
        let json = Json::parse(&sink.to_chrome_json().to_string()).unwrap();
        let events = json.get("traceEvents").unwrap().as_arr().unwrap();
        let pool_spans: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("cat").and_then(|c| c.as_str()) == Some("quantize_pool"))
            .collect();
        assert_eq!(pool_spans.len(), qm.layers.len(), "one pool span per layer");
        for s in &pool_spans {
            assert_eq!(s.get("name").and_then(|n| n.as_str()), Some("layer_job"));
            assert!(s.get("args").unwrap().get("layer").is_some());
            assert!(s.get("args").unwrap().get("queued_ms").is_some());
        }
    }

    #[test]
    fn budget_and_workers_do_not_change_bytes() {
        // In-module smoke of the tentpole invariant (the full grid lives
        // in rust/tests/determinism.rs): a spill-forcing budget and a
        // fixed worker count produce the exact bytes of the defaults.
        let (ck, calib, pcfg) = tiny_setup();
        let (reference, _) = quantize_model(&ck, &calib, &pcfg).unwrap();
        let mut sharded = pcfg.clone();
        sharded.hessian_mem_budget = 64 * 64 * 8 + 4096; // < the block's accumulators
        sharded.layer_workers = 3;
        let (qm, report) = quantize_model(&ck, &calib, &sharded).unwrap();
        assert!(report.failed_blocks.is_empty());
        assert_eq!(
            qm.to_bytes(crate::model::quantized::QZ_VERSION),
            reference.to_bytes(crate::model::quantized::QZ_VERSION)
        );
    }

    use crate::model::quantized::QZ_VERSION;
    use crate::util::fault::{FaultInjector, FaultSpec};

    fn test_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("quip_pipe_ck_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn armed(specs: &[&str]) -> Option<Arc<FaultInjector>> {
        Some(Arc::new(FaultInjector::new(
            specs.iter().map(|s| FaultSpec::parse(s).unwrap()).collect(),
            true, // soft: faults surface as Err so one process can kill + resume
            0x5EED,
        )))
    }

    #[test]
    fn resume_matches_uninterrupted_at_2_and_4_bits() {
        // Acceptance pin: kill after block 0, resume, finish — the final
        // artifact must be byte-identical to an uninterrupted run with no
        // checkpointing at all, at both paper bit widths.
        for bits in [2u32, 4] {
            let (ck, calib, mut pcfg) = tiny_setup();
            pcfg.quant.bits = bits;
            let (cold, _) = quantize_model(&ck, &calib, &pcfg).unwrap();
            let cold_bytes = cold.to_bytes(QZ_VERSION);

            let dir = test_dir(&format!("resume{bits}"));
            let mut kill_cfg = pcfg.clone();
            kill_cfg.faults = armed(&["pipeline.block_done@1"]);
            let err = QuantSession::new(&ck, kill_cfg)
                .unwrap()
                .with_checkpoint_dir(&dir)
                .unwrap()
                .run(&calib)
                .err()
                .expect("injected fault must abort the run");
            assert!(err.to_string().contains("fault injected"), "{err}");

            let session = QuantSession::resume(&ck, pcfg.clone(), &dir).unwrap();
            let (qm, report) = session.run(&calib).unwrap();
            assert_eq!(
                qm.to_bytes(QZ_VERSION),
                cold_bytes,
                "resumed artifact differs at {bits} bits"
            );
            assert_eq!(report.layers.len(), ck.config.linear_specs().len());
            assert!(report.failed_blocks.is_empty());
        }
    }

    #[test]
    fn kill_at_every_block_boundary_resumes_bit_identical() {
        // Acceptance: the crash-resume loop — kill at block boundary n
        // for every n, resume each wreck to completion, and require the
        // exact uninterrupted bytes every time.
        let (ck, calib, pcfg) = tiny_setup();
        let (cold, _) = quantize_model(&ck, &calib, &pcfg).unwrap();
        let cold_bytes = cold.to_bytes(QZ_VERSION);
        let n_blocks = ck.config.n_layers;
        assert!(n_blocks >= 2, "loop needs ≥2 boundaries");
        for boundary in 1..=n_blocks {
            let dir = test_dir(&format!("bound{boundary}"));
            let mut kill_cfg = pcfg.clone();
            kill_cfg.faults = armed(&[format!("pipeline.block_done@{boundary}").as_str()]);
            let killed = QuantSession::new(&ck, kill_cfg)
                .unwrap()
                .with_checkpoint_dir(&dir)
                .unwrap()
                .run(&calib);
            assert!(killed.is_err(), "boundary {boundary} must kill the run");

            let session = QuantSession::resume(&ck, pcfg.clone(), &dir).unwrap();
            assert_eq!(session.next_block, boundary, "journal covers {boundary} blocks");
            let (qm, _) = session.run(&calib).unwrap();
            assert_eq!(
                qm.to_bytes(QZ_VERSION),
                cold_bytes,
                "kill at boundary {boundary}: resumed artifact differs"
            );
        }
    }

    #[test]
    fn resume_refuses_on_fingerprint_mismatch() {
        // Each of bits / rounder / transform / seed flipped must refuse
        // with an error naming the differing field.
        let (ck, calib, pcfg) = tiny_setup();
        let dir = test_dir("fp");
        let mut session = QuantSession::new(&ck, pcfg.clone())
            .unwrap()
            .with_checkpoint_dir(&dir)
            .unwrap();
        session.step(&calib).unwrap();
        drop(session);

        let flips: Vec<(&str, PipelineConfig)> = vec![
            ("bits", {
                let mut c = pcfg.clone();
                c.quant.bits = 4;
                c
            }),
            ("rounder", {
                let mut c = pcfg.clone();
                c.quant.method = Method::Nearest;
                c
            }),
            ("transform", {
                let mut c = pcfg.clone();
                c.quant.processing.transform = crate::linalg::TransformKind::Hadamard;
                c
            }),
            ("seed", {
                let mut c = pcfg.clone();
                c.seed = 8;
                c
            }),
            // Shard-layout knobs don't change quantized bytes, but resume
            // still refuses them: "resume" means "the same run".
            ("hessian_mem_budget", {
                let mut c = pcfg.clone();
                c.hessian_mem_budget = 1 << 20;
                c
            }),
            ("layer_workers", {
                let mut c = pcfg.clone();
                c.layer_workers = 3;
                c
            }),
        ];
        for (field, cfg) in flips {
            let err = QuantSession::resume(&ck, cfg, &dir)
                .map(|_| ())
                .unwrap_err()
                .to_string();
            assert!(err.contains(field), "flipping {field}: error was: {err}");
            assert!(err.contains("refusing to resume"), "{err}");
        }
        // And the unflipped config still resumes.
        assert!(QuantSession::resume(&ck, pcfg, &dir).is_ok());
    }

    #[test]
    fn torn_journal_tail_requantizes_block_to_identical_bytes() {
        // Kill mid-append (torn record) after block 1: resume must drop
        // the torn tail, re-quantize block 1, and still match the
        // uninterrupted bytes.
        let (ck, calib, pcfg) = tiny_setup();
        let (cold, _) = quantize_model(&ck, &calib, &pcfg).unwrap();
        let dir = test_dir("torn");
        let mut torn_cfg = pcfg.clone();
        torn_cfg.faults = armed(&["checkpoint.append@2:torn"]);
        let err = QuantSession::new(&ck, torn_cfg)
            .unwrap()
            .with_checkpoint_dir(&dir)
            .unwrap()
            .run(&calib)
            .err()
            .expect("torn-append fault must abort the run");
        assert!(err.to_string().contains("fault injected"), "{err}");

        let session = QuantSession::resume(&ck, pcfg.clone(), &dir).unwrap();
        assert_eq!(session.next_block, 1, "torn block 1 record must drop");
        let (qm, _) = session.run(&calib).unwrap();
        assert_eq!(qm.to_bytes(QZ_VERSION), cold.to_bytes(QZ_VERSION));
    }

    #[test]
    fn worker_panic_poisons_only_its_block() {
        // A worker panicking in block 0 (first attempt AND the escalated
        // retry: the block has 6 layers, so hits 1 and 7 are each
        // attempt's first rounding call) must yield BlockFailed(0) while
        // block 1 completes; finish() reports the failed set and the
        // artifact carries only block 1's layers.
        let (ck, calib, mut pcfg) = tiny_setup();
        pcfg.faults = armed(&["pipeline.layer_round@1:panic", "pipeline.layer_round@7:panic"]);
        let mut events: Vec<PipelineEvent> = Vec::new();
        let (qm, report) = QuantSession::new(&ck, pcfg)
            .unwrap()
            .on_event(|ev| {
                events.push(ev.clone());
                PipelineControl::Continue
            })
            .run(&calib)
            .unwrap();
        assert_eq!(report.failed_blocks.len(), 1);
        assert_eq!(report.failed_blocks[0].0, 0);
        assert!(
            report.failed_blocks[0].1.contains("worker panic"),
            "{}",
            report.failed_blocks[0].1
        );
        let failed_at = events
            .iter()
            .position(|e| matches!(e, PipelineEvent::BlockFailed { block: 0, .. }))
            .expect("BlockFailed(0) emitted");
        let block1_done = events
            .iter()
            .position(|e| matches!(e, PipelineEvent::BlockDone { block: 1, .. }))
            .expect("block 1 still completes");
        assert!(failed_at < block1_done);
        assert!(
            !events.iter().any(|e| matches!(e, PipelineEvent::BlockDone { block: 0, .. })),
            "failed block must not also report BlockDone"
        );
        // Artifact: block 1's layers only; report layers match.
        assert!(qm.layers.iter().all(|l| l.name.starts_with("blk1.")));
        assert_eq!(report.layers.len(), qm.layers.len());
        let j = report.to_json();
        let failed = j.get("failed_blocks").and_then(|f| f.as_arr()).unwrap();
        assert_eq!(failed.len(), 1);
    }

    #[test]
    fn single_worker_panic_recovers_via_block_retry() {
        // One panic on the first attempt only: the retry (escalated
        // damping) succeeds, no BlockFailed, all layers present.
        let (ck, calib, mut pcfg) = tiny_setup();
        pcfg.faults = armed(&["pipeline.layer_round@1:panic"]);
        let mut events: Vec<PipelineEvent> = Vec::new();
        let (qm, report) = QuantSession::new(&ck, pcfg)
            .unwrap()
            .on_event(|ev| {
                events.push(ev.clone());
                PipelineControl::Continue
            })
            .run(&calib)
            .unwrap();
        assert!(report.failed_blocks.is_empty());
        assert!(!events.iter().any(|e| matches!(e, PipelineEvent::BlockFailed { .. })));
        assert_eq!(qm.layers.len(), ck.config.linear_specs().len());
    }

    #[test]
    fn checkpointed_run_with_failed_block_resumes_failed_set() {
        // A journaled failed block replays as failed on resume: the
        // session does not retry it, and the final report carries it.
        let (ck, calib, mut pcfg) = tiny_setup();
        let dir = test_dir("failrec");
        pcfg.faults = armed(&[
            "pipeline.layer_round@1:panic",
            "pipeline.layer_round@7:panic",
            // block_done fires after every journaled record, including the
            // Failed one for block 0 — hit 2 is block 1's completion.
            "pipeline.block_done@2",
        ]);
        let err = QuantSession::new(&ck, pcfg.clone())
            .unwrap()
            .with_checkpoint_dir(&dir)
            .unwrap()
            .run(&calib)
            .err()
            .expect("block_done kill must abort the run");
        assert!(err.to_string().contains("fault injected"), "{err}");

        pcfg.faults = None;
        let session = QuantSession::resume(&ck, pcfg, &dir).unwrap();
        assert_eq!(session.next_block, 2, "failed block 0 + completed block 1");
        let (qm, report) = session.run(&calib).unwrap();
        assert_eq!(report.failed_blocks.len(), 1);
        assert_eq!(report.failed_blocks[0].0, 0);
        assert!(qm.layers.iter().all(|l| l.name.starts_with("blk1.")));
    }

    #[test]
    fn staged_api_matches_one_shot_wrapper() {
        let (ck, calib, pcfg) = tiny_setup();
        let mut session = QuantSession::new(&ck, pcfg.clone()).unwrap();
        for block in 0..session.n_blocks() {
            let hset = session.collect_hessians(block, &calib).unwrap();
            let out = session.quantize_block(block, &hset).unwrap();
            session.swap_weights(out).unwrap();
        }
        let (qm_staged, report_staged) = session.finish();
        let (qm, report) = quantize_model(&ck, &calib, &pcfg).unwrap();
        assert_eq!(qm_staged.recipe, qm.recipe);
        assert_eq!(qm_staged.layers.len(), qm.layers.len());
        for (a, b) in qm_staged.layers.iter().zip(&qm.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.packed, b.packed, "codes differ for {}", a.name);
        }
        assert_eq!(report_staged.total_proxy(), report.total_proxy());
    }
}
