//! Generation server: newline-delimited JSON over TCP.
//!
//! Request : {"id": 1, "prompt": [3, 17, 9], "max_tokens": 16,
//!            "temperature": 0.0, "stream": false}
//! Response: {"id": 1, "tokens": [...], "finish_reason": "stop"|"length",
//!            "latency_ms": 12.3}
//!   or      {"id": 1, "error": "..."}
//!
//! With `"stream": true` the server additionally pushes one frame per
//! generated token, {"id": 1, "index": 0, "token": 42}, before the final
//! frame (which carries `"done": true` plus the full token list).
//!
//! Architecture: an acceptor thread per listener, a shared [`Batcher`]
//! for intake (overflow → {"error":"overloaded"}), and a
//! continuous-batching scheduler: one decode loop advances every active
//! sequence a token at a time through the batched native engine
//! (`decode_step_batch`), new requests join at token boundaries and
//! finished ones respond and leave. KV memory comes from a paged
//! [`KvPool`] (O(active tokens), prompt-prefix sharing); admission
//! control only moves a request from the intake queue into the batch
//! when the pool can cover its prompt plus a decode reservation, so
//! under overload requests queue briefly and are then shed with a clean
//! "overloaded" error instead of the pool OOMing. The batched linears
//! parallelize internally across the `util::threadpool` substrate.
//!
//! Observability (DESIGN.md §9): besides request lines, a connection may
//! send four bare control commands — `metrics` (Prometheus text
//! exposition, terminated by a `# EOF` line), `stats` (the JSON metrics
//! summary as one line), `healthz` (one JSON line, `{"ok": true, …}`)
//! and `shutdown` (graceful drain, DESIGN.md §10: stop admission, let
//! admitted sequences finish within [`ServerConfig::drain_timeout`],
//! answer `{"ok": true, "draining": true}`).
//! Every request gets a trace id at admission and the scheduler records
//! spans (admission-wait, prefill, per-step decode, stream flush,
//! request) plus shed/eviction instants into the server's
//! [`TraceSink`]; `ServerConfig::trace_out` flushes them as Chrome
//! trace-event JSON on shutdown.

use super::batcher::{Batcher, Pending};
use super::generate::{step_batch, ActiveSeq, FinishReason, GenParams};
use super::metrics::Metrics;
use crate::engine::native::{FpLinears, LinearOps, QuantLinears};
use crate::model::quantized::QuantizedModel;
use crate::model::transformer::KvCache;
use crate::model::{KvPool, SharedKvPool, Transformer, DEFAULT_PAGE_TOKENS};
use crate::obs::trace::{take_stage, TraceSink, DEFAULT_TRACE_CAPACITY};
use crate::util::json::Json;
use crate::util::sync::lock_unpoisoned;
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub struct ServerConfig {
    pub addr: String,
    /// Upper bound on sequences decoded together per token step. Compute
    /// parallelism within a step is sized by the batched kernels
    /// themselves (`util::threadpool::default_threads`).
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_capacity: usize,
    /// Serve KV from the paged pool (default). `false` restores the
    /// contiguous per-sequence caches (no admission control: every
    /// sequence preallocates `max_seq` rows).
    pub paged: bool,
    /// Pool size in pages; 0 = auto-size to `max_batch` worst-case
    /// sequences (`max_batch · ⌈max_seq / page_tokens⌉`), which can never
    /// shed an admitted sequence mid-flight.
    pub kv_pages: usize,
    /// Token rows per page.
    pub page_tokens: usize,
    /// Decode-ahead reservation demanded at admission, capped by the
    /// request's own `max_tokens`. Larger values admit more
    /// conservatively; smaller values pack tighter but stall/shed more
    /// under pressure.
    pub reserve_tokens: usize,
    /// How long a request may sit in the admission queue waiting for
    /// pool pages before it is shed with "overloaded".
    pub admit_timeout: Duration,
    /// Span sink to trace into. `None` gives the server its own
    /// (default-capacity) sink; pass a shared one to merge serve spans
    /// with e.g. quantize-pipeline spans on a single timeline.
    pub trace: Option<Arc<TraceSink>>,
    /// Write the Chrome trace-event JSON here on shutdown (`quip serve
    /// --trace-out`). `None` disables the flush.
    pub trace_out: Option<String>,
    /// Graceful-drain budget (`quip serve --drain-timeout-ms`): after a
    /// `shutdown` control command the admitted sequences keep decoding
    /// for at most this long; any still unfinished at the deadline are
    /// answered "overloaded: drain timeout" so shutdown is bounded.
    pub drain_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7077".into(),
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            queue_capacity: 256,
            paged: true,
            kv_pages: 0,
            page_tokens: DEFAULT_PAGE_TOKENS,
            reserve_tokens: 32,
            admit_timeout: Duration::from_secs(2),
            trace: None,
            trace_out: None,
            drain_timeout: Duration::from_secs(5),
        }
    }
}

/// The engine the server decodes with.
pub enum EngineKind {
    Fp32,
    Quant(QuantizedModel),
}

impl EngineKind {
    /// Fold the "serve quantized iff an artifact is present" choice into
    /// one constructor — callers pass whatever `Option<QuantizedModel>`
    /// they loaded.
    pub fn auto(qm: Option<QuantizedModel>) -> EngineKind {
        match qm {
            Some(q) => EngineKind::Quant(q),
            None => EngineKind::Fp32,
        }
    }
}

/// Legacy name for [`EngineKind`], kept for transition-era call sites.
pub type ServeEngine = EngineKind;

struct Job {
    prompt: Vec<u32>,
    params: GenParams,
    stream: bool,
    resp: Mutex<Option<TcpStream>>,
    received: Instant,
}

/// A running server (owns its threads; `shutdown` + drop joins them).
pub struct Server {
    pub addr: std::net::SocketAddr,
    pub metrics: Arc<Metrics>,
    /// Span sink the scheduler traces into (shared with the config's
    /// sink when one was provided).
    pub trace: Arc<TraceSink>,
    trace_out: Option<String>,
    stop: Arc<AtomicBool>,
    batcher: Arc<Batcher<Job>>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving. Binds immediately; returns the handle.
    pub fn start(
        model: Arc<Transformer>,
        engine: EngineKind,
        cfg: ServerConfig,
    ) -> crate::Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let metrics = Arc::new(Metrics::new());
        let trace = cfg
            .trace
            .clone()
            .unwrap_or_else(|| TraceSink::shared(DEFAULT_TRACE_CAPACITY));
        let started = Instant::now();
        let stop = Arc::new(AtomicBool::new(false));
        let batcher = Arc::new(Batcher::<Job>::new(
            cfg.max_batch,
            cfg.max_wait,
            cfg.queue_capacity,
        ));
        let qlin: Arc<Option<QuantLinears>> = Arc::new(match engine {
            EngineKind::Fp32 => None,
            EngineKind::Quant(qm) => Some(QuantLinears::from_model(&qm)?),
        });

        let mut threads = Vec::new();

        // Acceptor: spawns one (detached) handler thread per connection so
        // a long-lived connection can never block accept or shutdown.
        {
            let stop = Arc::clone(&stop);
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let next_id = Arc::new(AtomicU64::new(1));
            threads.push(std::thread::spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let batcher = Arc::clone(&batcher);
                            let metrics = Arc::clone(&metrics);
                            let next_id = Arc::clone(&next_id);
                            let stop = Arc::clone(&stop);
                            std::thread::spawn(move || {
                                handle_connection(
                                    stream, &batcher, &metrics, &next_id, &stop, started,
                                );
                            });
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            }));
        }

        // Continuous-batching scheduler: intake → admit (pool permitting)
        // → step all → stream/retire, one token per iteration.
        {
            let stop = Arc::clone(&stop);
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let trace = Arc::clone(&trace);
            let max_batch = cfg.max_batch.max(1);
            let page_tokens = cfg.page_tokens.max(1);
            let pool: Option<SharedKvPool> = if cfg.paged {
                let pages = if cfg.kv_pages > 0 {
                    cfg.kv_pages
                } else {
                    max_batch * model.cfg.max_seq.div_ceil(page_tokens)
                };
                Some(KvPool::shared(
                    model.cfg.n_layers,
                    model.cfg.d_model,
                    pages,
                    page_tokens,
                ))
            } else {
                None
            };
            let reserve_tokens = cfg.reserve_tokens;
            let admit_timeout = cfg.admit_timeout;
            let drain_timeout = cfg.drain_timeout;
            threads.push(std::thread::spawn(move || {
                let mut active: Vec<ActiveSeq> = Vec::new();
                let mut slots: Vec<Slot> = Vec::new();
                let mut waiting: VecDeque<Pending<Job>> = VecDeque::new();
                let mut drain_deadline: Option<Instant> = None;
                loop {
                    // On stop: admit nothing more (waiting/queued jobs are
                    // shed with "overloaded"), but run the already admitted
                    // sequences to completion — bounded by `drain_timeout`
                    // — so every admitted request gets its response.
                    let stopping = stop.load(Ordering::SeqCst);
                    if stopping {
                        let deadline = *drain_deadline
                            .get_or_insert_with(|| Instant::now() + drain_timeout);
                        waiting.extend(batcher.poll(usize::MAX));
                        for p in waiting.drain(..) {
                            shed(p, &metrics, &trace, "overloaded: shutting down");
                        }
                        if active.is_empty() {
                            break;
                        }
                        if Instant::now() >= deadline {
                            for (seq, slot) in
                                active.drain(..).zip(slots.drain(..))
                            {
                                drop(seq); // releases its pool pages
                                metrics.shed.fetch_add(1, Ordering::Relaxed);
                                trace.instant(
                                    slot.trace_id,
                                    "drain_shed",
                                    "serve",
                                    vec![("id".into(), Json::Num(slot.id as f64))],
                                );
                                if let Some(s) = lock_unpoisoned(&slot.resp).take() {
                                    let _ = respond_err(
                                        &s,
                                        slot.id,
                                        "overloaded: drain timeout",
                                    );
                                }
                            }
                            break;
                        }
                    } else if active.is_empty() && waiting.is_empty() {
                        // Idle: park on the batcher until work (or close).
                        let Some(batch) = batcher.next_batch() else {
                            break;
                        };
                        waiting.extend(batch);
                    } else {
                        // Token boundary: top up without blocking the
                        // in-flight sequences. The batcher's bounded queue
                        // (overflow → immediate "overloaded") backstops
                        // the admission queue, which stays ≤ max_batch.
                        let room = max_batch.saturating_sub(active.len() + waiting.len());
                        if room > 0 {
                            waiting.extend(batcher.poll(room));
                        }
                    }

                    // Admission: FIFO from the waiting queue. A request
                    // the pool cannot cover blocks the queue head (no
                    // overtaking) until pages free up or its admission
                    // timeout sheds it.
                    while !stopping && active.len() < max_batch && !waiting.is_empty() {
                        let Some(p) = waiting.pop_front() else { break };
                        match admit(&model, pool.as_ref(), reserve_tokens, p, &trace) {
                            Admit::Taken(seq, slot) => {
                                active.push(seq);
                                slots.push(slot);
                            }
                            Admit::Answered => {}
                            Admit::Blocked(p) => {
                                if p.enqueued.elapsed() >= admit_timeout {
                                    shed(p, &metrics, &trace, "overloaded");
                                } else {
                                    waiting.push_front(p);
                                }
                                break;
                            }
                        }
                    }
                    if active.is_empty() {
                        if !waiting.is_empty() {
                            // Head blocked with nothing running: wait for
                            // its shed timeout without spinning hot.
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        continue;
                    }

                    let fp;
                    let lin: &dyn LinearOps = match &*qlin {
                        Some(q) => q,
                        None => {
                            fp = FpLinears { model: &*model };
                            &fp
                        }
                    };
                    let t0 = Instant::now();
                    let report = step_batch(&model, lin, &mut active);
                    metrics.record_batch(report.stepped);
                    if report.stepped > 0 {
                        // One step = one inter-token interval for every
                        // sequence it advanced.
                        let step_s = t0.elapsed().as_secs_f64();
                        metrics.record_token_latency(step_s);
                        // The batched kernels credited their GEMM time to
                        // the stage ledger on this (calling) thread; the
                        // step span carries the linear-vs-rest split.
                        let linear_s = take_stage("decode_linear");
                        trace.complete(
                            0,
                            "decode_step",
                            "serve",
                            trace.ts_of(t0),
                            (step_s * 1e6) as u64,
                            vec![
                                ("batch".into(), Json::Num(report.stepped as f64)),
                                ("linear_s".into(), Json::Num(linear_s)),
                            ],
                        );
                    }
                    if let Some(pool) = &pool {
                        metrics.record_pool(&lock_unpoisoned(pool).snapshot());
                    }
                    if report.stepped == 0 && report.stalled > 0 {
                        // Every live sequence is stalled on the exhausted
                        // pool: no step will ever free pages. Shed the
                        // youngest stalled sequence (least work lost) so
                        // the rest can make progress.
                        drop_youngest_stalled(&mut active, &mut slots, &metrics, &trace);
                    }
                    let mut i = 0;
                    while i < active.len() {
                        if !slots[i].prefill_traced && !active[i].prefilling() {
                            slots[i].prefill_traced = true;
                            let now = trace.now_us();
                            trace.complete(
                                slots[i].trace_id,
                                "prefill",
                                "serve",
                                slots[i].admitted_us,
                                now.saturating_sub(slots[i].admitted_us),
                                vec![(
                                    "prompt_tokens".into(),
                                    Json::Num(active[i].prompt_len() as f64),
                                )],
                            );
                        }
                        let sent_before = slots[i].sent;
                        let flush_t0 = trace.now_us();
                        flush_stream(&mut slots[i], &active[i], &metrics);
                        if slots[i].sent > sent_before {
                            trace.complete(
                                slots[i].trace_id,
                                "stream_flush",
                                "serve",
                                flush_t0,
                                trace.now_us().saturating_sub(flush_t0),
                                vec![(
                                    "frames".into(),
                                    Json::Num((slots[i].sent - sent_before) as f64),
                                )],
                            );
                        }
                        if active[i].done {
                            let seq = active.swap_remove(i);
                            let slot = slots.swap_remove(i);
                            finish_job(slot, seq, &metrics, &trace);
                        } else {
                            i += 1;
                        }
                    }
                }
            }));
        }

        Ok(Server {
            addr,
            metrics,
            trace,
            trace_out: cfg.trace_out,
            stop,
            batcher,
            threads,
        })
    }

    /// True once shutdown has been initiated — by [`shutdown`](Self::shutdown)
    /// or by a client's `shutdown` control command. The driving thread
    /// (e.g. `quip serve`) polls this and calls `shutdown()` to join the
    /// worker threads and flush the trace.
    pub fn draining(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.batcher.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // Flush the trace once, after the scheduler stopped recording.
        if let Some(path) = self.trace_out.take() {
            if let Err(e) = self.trace.write_chrome_trace(&path) {
                crate::log_warn!("trace flush to {path} failed: {e}");
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_connection(
    stream: TcpStream,
    batcher: &Batcher<Job>,
    metrics: &Metrics,
    next_id: &AtomicU64,
    stop: &AtomicBool,
    started: Instant,
) {
    let _ = stream.set_nonblocking(false);
    // Idle read timeout so handler threads drain on shutdown even if a
    // client holds its connection open.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut line = String::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        match reader.read_line(&mut line) {
            Ok(0) => return,
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue; // keep any partial line accumulated so far
            }
            Err(_) => return,
            Ok(_) => {}
        }
        if !line.ends_with('\n') {
            continue; // partial line (timeout mid-read); keep accumulating
        }
        let taken = std::mem::take(&mut line);
        let line = taken;
        if line.trim().is_empty() {
            continue;
        }
        // Graceful drain (DESIGN.md §10): a bare `shutdown` line stops
        // admission (new requests shed "overloaded: shutting down"),
        // lets admitted sequences finish within the drain budget, and
        // winds the server down. Acknowledged before stop flips so the
        // issuing client always gets its response.
        if line.trim() == "shutdown" {
            let mut o = Json::obj();
            o.set("ok", Json::Bool(true));
            o.set("draining", Json::Bool(true));
            let mut resp = o.to_string();
            resp.push('\n');
            let mut out: &TcpStream = &stream;
            let _ = out.write_all(resp.as_bytes());
            stop.store(true, Ordering::SeqCst);
            batcher.close();
            return;
        }
        // Bare control commands bypass request accounting entirely.
        if let Some(resp) = control_response(line.trim(), metrics, started) {
            let mut out: &TcpStream = &stream;
            if out.write_all(resp.as_bytes()).is_err() {
                return;
            }
            continue;
        }
        metrics.requests.fetch_add(1, Ordering::Relaxed);
        let parsed = parse_request(&line);
        let (prompt, params, req_id, stream_resp) = match parsed {
            Ok(v) => v,
            Err(e) => {
                let _ = respond_err(&stream, 0, &e.to_string());
                continue;
            }
        };
        let out = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => return,
        };
        let job = Job {
            prompt,
            params,
            stream: stream_resp,
            resp: Mutex::new(Some(out)),
            received: Instant::now(),
        };
        let id = next_id.fetch_add(1, Ordering::Relaxed);
        if let Err(job) = batcher.push(id, job) {
            metrics.rejected.fetch_add(1, Ordering::Relaxed);
            if let Some(s) = lock_unpoisoned(&job.resp).take() {
                let _ = respond_err(&s, req_id, "overloaded");
            }
        }
    }
}

/// Observability protocol commands: a bare `metrics`, `stats` or
/// `healthz` line gets an immediate response instead of being parsed as
/// a generation request. `metrics` answers with the full Prometheus
/// exposition (multi-line, terminated by `# EOF`); the other two answer
/// with one JSON line.
fn control_response(cmd: &str, metrics: &Metrics, started: Instant) -> Option<String> {
    match cmd {
        "metrics" => Some(metrics.render_prometheus()),
        "stats" => {
            let mut s = metrics.summary().to_string();
            s.push('\n');
            Some(s)
        }
        "healthz" => {
            let mut o = Json::obj();
            o.set("ok", Json::Bool(true));
            o.set("uptime_s", Json::Num(started.elapsed().as_secs_f64()));
            let mut s = o.to_string();
            s.push('\n');
            Some(s)
        }
        _ => None,
    }
}

fn parse_request(line: &str) -> crate::Result<(Vec<u32>, GenParams, u64, bool)> {
    let j = Json::parse(line)?;
    let prompt: Vec<u32> = j
        .req("prompt")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("prompt must be an array"))?
        .iter()
        .filter_map(|x| x.as_f64().map(|v| v as u32))
        .collect();
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let params = GenParams {
        max_tokens: j.get("max_tokens").and_then(|x| x.as_usize()).unwrap_or(16),
        temperature: j.get("temperature").and_then(|x| x.as_f64()).unwrap_or(0.0),
        seed: j.get("seed").and_then(|x| x.as_u64()).unwrap_or(0),
        stop_token: None,
    };
    let id = j.get("id").and_then(|x| x.as_u64()).unwrap_or(0);
    let stream = j.get("stream").and_then(|x| x.as_bool()).unwrap_or(false);
    Ok((prompt, params, id, stream))
}

/// Response bookkeeping for one in-flight sequence (same index as its
/// [`ActiveSeq`] in the scheduler's batch).
struct Slot {
    id: u64,
    resp: Mutex<Option<TcpStream>>,
    received: Instant,
    /// Client asked for per-token frames.
    stream: bool,
    /// Generated tokens already pushed as stream frames.
    sent: usize,
    /// Trace id minted at admission (the Chrome `tid` lane).
    trace_id: u64,
    /// Admission timestamp on the sink's timeline (prefill span start).
    admitted_us: u64,
    /// The prefill span has been recorded.
    prefill_traced: bool,
}

/// Outcome of trying to admit the waiting-queue head.
enum Admit {
    /// Joined the batch.
    Taken(ActiveSeq, Slot),
    /// Answered immediately (invalid request); gone from the queue.
    Answered,
    /// The pool cannot cover prompt + reservation yet; handed back.
    Blocked(Pending<Job>),
}

/// Admission control: move one queued request into the running batch if
/// the KV pool can cover its prompt plus `reserve_tokens` of decode
/// margin (contiguous mode admits unconditionally — every cache
/// preallocates `max_seq` rows).
fn admit(
    model: &Transformer,
    pool: Option<&SharedKvPool>,
    reserve_tokens: usize,
    p: Pending<Job>,
    trace: &TraceSink,
) -> Admit {
    if p.payload.prompt.len() > model.cfg.max_seq {
        if let Some(s) = lock_unpoisoned(&p.payload.resp).take() {
            let _ = respond_err(&s, p.id, "prompt exceeds context");
        }
        return Admit::Answered;
    }
    let cache = match pool {
        None => model.new_cache(),
        Some(pool) => {
            let reserve = p.payload.params.max_tokens.min(reserve_tokens);
            match lock_unpoisoned(pool).try_admit(&p.payload.prompt, reserve) {
                Some(table) => KvCache::paged(pool, table),
                None => return Admit::Blocked(p),
            }
        }
    };
    let job = p.payload;
    // Trace id minted exactly at admission; the admission-wait span
    // covers receipt → here (queueing + blocked-head time).
    let trace_id = trace.mint_trace();
    let admitted_us = trace.now_us();
    let received_us = trace.ts_of(job.received);
    trace.complete(
        trace_id,
        "admission_wait",
        "serve",
        received_us,
        admitted_us.saturating_sub(received_us),
        vec![
            ("id".into(), Json::Num(p.id as f64)),
            ("prompt_tokens".into(), Json::Num(job.prompt.len() as f64)),
        ],
    );
    let seq = ActiveSeq::with_cache(model, &job.prompt, job.params, cache);
    Admit::Taken(
        seq,
        Slot {
            id: p.id,
            resp: job.resp,
            received: job.received,
            stream: job.stream,
            sent: 0,
            trace_id,
            admitted_us,
            prefill_traced: false,
        },
    )
}

/// Refuse a queued request with a protocol-level error.
fn shed(p: Pending<Job>, metrics: &Metrics, trace: &TraceSink, msg: &str) {
    metrics.shed.fetch_add(1, Ordering::Relaxed);
    // Never admitted, so no trace id: shed instants land on lane 0.
    trace.instant(
        0,
        "shed",
        "serve",
        vec![("id".into(), Json::Num(p.id as f64))],
    );
    if let Some(s) = lock_unpoisoned(&p.payload.resp).take() {
        let _ = respond_err(&s, p.id, msg);
    }
}

/// Deadlock breaker: every live sequence is stalled on an exhausted
/// pool. Drop the youngest stalled sequence (least decode work lost,
/// FIFO fairness for the old ones) and answer it "overloaded"; its
/// released pages unblock the rest next step.
fn drop_youngest_stalled(
    active: &mut Vec<ActiveSeq>,
    slots: &mut Vec<Slot>,
    metrics: &Metrics,
    trace: &TraceSink,
) {
    let mut victim: Option<usize> = None;
    for (i, s) in active.iter().enumerate() {
        if s.done || !s.stalled {
            continue;
        }
        let younger = match victim {
            None => true,
            Some(v) => slots[i].received > slots[v].received,
        };
        if younger {
            victim = Some(i);
        }
    }
    let Some(i) = victim else { return };
    let _seq = active.swap_remove(i); // dropped: releases its pool pages
    let slot = slots.swap_remove(i);
    metrics.shed.fetch_add(1, Ordering::Relaxed);
    metrics.evicted.fetch_add(1, Ordering::Relaxed);
    trace.instant(
        slot.trace_id,
        "evicted",
        "serve",
        vec![("id".into(), Json::Num(slot.id as f64))],
    );
    if let Some(s) = lock_unpoisoned(&slot.resp).take() {
        let _ = respond_err(&s, slot.id, "overloaded: kv pool exhausted");
    }
}

/// Push per-token frames for a streaming sequence (no-op otherwise).
fn flush_stream(slot: &mut Slot, seq: &ActiveSeq, metrics: &Metrics) {
    if !slot.stream || slot.sent >= seq.tokens.len() {
        return;
    }
    let dead = {
        let guard = lock_unpoisoned(&slot.resp);
        let Some(s) = guard.as_ref() else { return };
        let mut dead = false;
        while slot.sent < seq.tokens.len() {
            let mut o = Json::obj();
            o.set("id", Json::Num(slot.id as f64));
            o.set("index", Json::Num(slot.sent as f64));
            o.set("token", Json::Num(seq.tokens[slot.sent] as f64));
            if writeln_json(s, &o).is_err() {
                dead = true; // client gone; stop pushing frames
                break;
            }
            slot.sent += 1;
            metrics.streamed_tokens.fetch_add(1, Ordering::Relaxed);
        }
        dead
    };
    if dead {
        *lock_unpoisoned(&slot.resp) = None;
    }
}

/// Respond to a finished sequence and record its serving metrics.
fn finish_job(slot: Slot, seq: ActiveSeq, metrics: &Metrics, trace: &TraceSink) {
    let latency = slot.received.elapsed().as_secs_f64();
    metrics.completed.fetch_add(1, Ordering::Relaxed);
    metrics
        .tokens_out
        .fetch_add(seq.tokens.len() as u64, Ordering::Relaxed);
    metrics.record_latency(latency);
    let reason = seq.finish.unwrap_or(FinishReason::Length);
    trace.complete(
        slot.trace_id,
        "request",
        "serve",
        trace.ts_of(slot.received),
        (latency * 1e6) as u64,
        vec![
            ("id".into(), Json::Num(slot.id as f64)),
            ("tokens".into(), Json::Num(seq.tokens.len() as f64)),
            (
                "finish_reason".into(),
                Json::Str(reason.as_str().to_string()),
            ),
        ],
    );
    if let Some(s) = lock_unpoisoned(&slot.resp).take() {
        let mut o = Json::obj();
        o.set("id", Json::Num(slot.id as f64));
        if slot.stream {
            o.set("done", Json::Bool(true));
        }
        o.set(
            "tokens",
            Json::Arr(seq.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
        );
        o.set("finish_reason", Json::Str(reason.as_str().to_string()));
        o.set("latency_ms", Json::Num(latency * 1e3));
        let _ = writeln_json(&s, &o);
    }
}

fn respond_err(stream: &TcpStream, id: u64, msg: &str) -> std::io::Result<()> {
    let mut o = Json::obj();
    o.set("id", Json::Num(id as f64));
    o.set("error", Json::Str(msg.to_string()));
    writeln_json(stream, &o)
}

fn writeln_json(mut stream: &TcpStream, j: &Json) -> std::io::Result<()> {
    let mut line = j.to_string();
    line.push('\n');
    stream.write_all(line.as_bytes())
}

/// Simple blocking client used by examples, benches and tests.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> crate::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    pub fn request(
        &mut self,
        prompt: &[u32],
        max_tokens: usize,
    ) -> crate::Result<(Vec<u32>, f64)> {
        let mut o = Json::obj();
        o.set(
            "prompt",
            Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()),
        );
        o.set("max_tokens", Json::Num(max_tokens as f64));
        let mut line = o.to_string();
        line.push('\n');
        self.stream.write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        let j = Json::parse(&resp)?;
        if let Some(err) = j.get("error") {
            anyhow::bail!("server error: {}", err.as_str().unwrap_or("?"));
        }
        let tokens: Vec<u32> = j
            .req("tokens")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|x| x.as_f64().map(|v| v as u32))
            .collect();
        let latency = j.req_f64("latency_ms")? / 1e3;
        Ok((tokens, latency))
    }

    /// Streaming request: collects per-token frames until the final
    /// `"done"` frame. Returns (streamed tokens in arrival order, final
    /// token list, finish reason).
    pub fn request_stream(
        &mut self,
        prompt: &[u32],
        max_tokens: usize,
    ) -> crate::Result<(Vec<u32>, Vec<u32>, String)> {
        let mut o = Json::obj();
        o.set(
            "prompt",
            Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()),
        );
        o.set("max_tokens", Json::Num(max_tokens as f64));
        o.set("stream", Json::Bool(true));
        let mut line = o.to_string();
        line.push('\n');
        self.stream.write_all(line.as_bytes())?;
        let mut streamed = Vec::new();
        loop {
            let mut resp = String::new();
            self.reader.read_line(&mut resp)?;
            anyhow::ensure!(!resp.is_empty(), "connection closed mid-stream");
            let j = Json::parse(&resp)?;
            if let Some(err) = j.get("error") {
                anyhow::bail!("server error: {}", err.as_str().unwrap_or("?"));
            }
            if j.get("done").and_then(|x| x.as_bool()).unwrap_or(false) {
                let tokens: Vec<u32> = j
                    .req("tokens")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|x| x.as_f64().map(|v| v as u32))
                    .collect();
                let reason = j
                    .get("finish_reason")
                    .and_then(|x| x.as_str())
                    .unwrap_or("?")
                    .to_string();
                return Ok((streamed, tokens, reason));
            }
            let tok = j.req_f64("token")? as u32;
            let idx = j.req_f64("index")? as usize;
            anyhow::ensure!(idx == streamed.len(), "stream frame out of order");
            streamed.push(tok);
        }
    }

    /// Scrape the server's Prometheus exposition (`metrics` command);
    /// reads until the terminating `# EOF` line (included).
    pub fn scrape_metrics(&mut self) -> crate::Result<String> {
        self.stream.write_all(b"metrics\n")?;
        let mut text = String::new();
        loop {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line)?;
            anyhow::ensure!(n > 0, "connection closed mid-scrape");
            let done = line.trim_end() == "# EOF";
            text.push_str(&line);
            if done {
                return Ok(text);
            }
        }
    }

    /// Fetch the JSON metrics summary (`stats` command).
    pub fn stats(&mut self) -> crate::Result<Json> {
        self.stream.write_all(b"stats\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line)
    }

    /// Graceful drain (`shutdown` command): Ok once the server has
    /// acknowledged `{"ok": true, "draining": true}`. In-flight requests
    /// still finish (within the server's drain budget); new ones are
    /// shed.
    pub fn shutdown(&mut self) -> crate::Result<()> {
        self.stream.write_all(b"shutdown\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let j = Json::parse(&line)?;
        anyhow::ensure!(
            j.get("draining").and_then(|x| x.as_bool()).unwrap_or(false),
            "unexpected shutdown response: {line}"
        );
        Ok(())
    }

    /// Liveness probe (`healthz` command): Ok(uptime seconds) when the
    /// server answers `{"ok": true, …}`.
    pub fn healthz(&mut self) -> crate::Result<f64> {
        self.stream.write_all(b"healthz\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let j = Json::parse(&line)?;
        anyhow::ensure!(
            j.get("ok").and_then(|x| x.as_bool()).unwrap_or(false),
            "healthz not ok: {line}"
        );
        j.req_f64("uptime_s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::Checkpoint;
    use crate::model::ModelConfig;

    fn tiny_model() -> Arc<Transformer> {
        let cfg = ModelConfig::sized("t", 32, 2, 4, 64);
        Arc::new(Transformer::from_checkpoint(&Checkpoint::random(&cfg, 5)).unwrap())
    }

    #[test]
    fn serves_requests_end_to_end() {
        let model = tiny_model();
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        };
        let mut server = Server::start(model, EngineKind::auto(None), cfg).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let (tokens, latency) = client.request(&[1, 2, 3], 5).unwrap();
        assert_eq!(tokens.len(), 5);
        assert!(latency >= 0.0);
        // Pipelined requests on the same connection.
        let (t2, _) = client.request(&[4, 5], 3).unwrap();
        assert_eq!(t2.len(), 3);
        assert_eq!(server.metrics.completed.load(Ordering::Relaxed), 2);
        // The paged pool is the default serving path and its gauges moved.
        let j = server.metrics.summary();
        assert!(j.req_f64("kv_pages_total").unwrap() > 0.0);
        assert!(j.req_f64("kv_pages_peak").unwrap() > 0.0);
        assert!(j.req_f64("p50_tok_s").unwrap() > 0.0);
        server.shutdown();
    }

    #[test]
    fn contiguous_mode_still_serves() {
        let model = tiny_model();
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            paged: false,
            ..Default::default()
        };
        let mut server = Server::start(model, EngineKind::auto(None), cfg).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let (tokens, _) = client.request(&[1, 2, 3], 5).unwrap();
        assert_eq!(tokens.len(), 5);
        assert_eq!(server.metrics.kv_pages_total.load(Ordering::Relaxed), 0);
        server.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let model = tiny_model();
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        };
        let mut server = Server::start(model, EngineKind::auto(None), cfg).unwrap();
        let addr = server.addr;
        let handles: Vec<_> = (0..6)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    let (tokens, _) = c.request(&[1, 2, (i % 30) as u32], 4).unwrap();
                    tokens.len()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 4);
        }
        assert_eq!(server.metrics.completed.load(Ordering::Relaxed), 6);
        // The continuous-batching loop ran and its occupancy counters moved.
        assert!(server.metrics.batched_steps.load(Ordering::Relaxed) > 0);
        assert!(server.metrics.mean_batch_size() >= 1.0);
        let j = server.metrics.summary();
        assert!(j.req_f64("mean_batch").unwrap() >= 1.0);
        server.shutdown();
    }

    #[test]
    fn quantized_engine_serves_batched() {
        // End-to-end through the quantized fused batch kernel.
        use crate::coordinator::pipeline::{quantize_model, PipelineConfig};
        use crate::data::gen::markov_stream;
        use crate::model::weights::Checkpoint;
        let cfg_m = ModelConfig::sized("t", 32, 2, 4, 64);
        let ck = Checkpoint::random(&cfg_m, 5);
        let stream = markov_stream(cfg_m.vocab as u32, 4_000, 2);
        let calib = stream.calibration(24, 4, 3);
        let (qm, _) = quantize_model(&ck, &calib, &PipelineConfig::default()).unwrap();
        let model = Arc::new(Transformer::from_checkpoint(&ck).unwrap());
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        };
        let mut server = Server::start(model, EngineKind::auto(Some(qm)), cfg).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let (tokens, _) = client.request(&[1, 2, 3], 6).unwrap();
        assert_eq!(tokens.len(), 6);
        assert!(server.metrics.batched_steps.load(Ordering::Relaxed) > 0);
        server.shutdown();
    }

    #[test]
    fn oversized_prompt_is_rejected_not_fatal() {
        let model = tiny_model();
        let max_seq = model.cfg.max_seq;
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        };
        let mut server = Server::start(model, EngineKind::auto(None), cfg).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let long: Vec<u32> = (0..max_seq + 5).map(|i| (i % 30) as u32).collect();
        assert!(client.request(&long, 4).is_err());
        // Server is still alive and serving after the rejection.
        let (tokens, _) = client.request(&[1, 2], 3).unwrap();
        assert_eq!(tokens.len(), 3);
        server.shutdown();
    }

    #[test]
    fn malformed_request_gets_error() {
        let model = tiny_model();
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        };
        // Uses the legacy `ServeEngine` alias on purpose — it must keep
        // compiling until downstream callers finish migrating.
        let mut server = Server::start(model, ServeEngine::Fp32, cfg).unwrap();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut s2 = stream.try_clone().unwrap();
        use std::io::Write as _;
        s2.write_all(b"{\"nonsense\": true}\n").unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error"), "{line}");
        server.shutdown();
    }

    #[test]
    fn streaming_roundtrip_matches_final_tokens() {
        let model = tiny_model();
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        };
        let mut server = Server::start(model, EngineKind::auto(None), cfg).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let (streamed, fin, reason) = client.request_stream(&[1, 2, 3], 5).unwrap();
        assert_eq!(streamed, fin, "per-token frames must replay the answer");
        assert_eq!(fin.len(), 5);
        assert_eq!(reason, "length");
        assert!(server.metrics.streamed_tokens.load(Ordering::Relaxed) >= 5);
        // Non-streaming requests still work on the same connection.
        let (tokens, _) = client.request(&[4, 5], 3).unwrap();
        assert_eq!(tokens.len(), 3);
        server.shutdown();
    }

    #[test]
    fn admission_control_sheds_cleanly_when_pool_cannot_fit() {
        // A pool of 2×4-token pages can never cover prompt 8 + reserve 8,
        // so the request waits out its admission timeout and is shed with
        // "overloaded" — no panic, no OOM — while small requests still fit.
        let model = tiny_model();
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_batch: 2,
            kv_pages: 2,
            page_tokens: 4,
            reserve_tokens: 8,
            admit_timeout: Duration::from_millis(50),
            ..Default::default()
        };
        let mut server = Server::start(model, EngineKind::auto(None), cfg).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let big: Vec<u32> = (0..8).map(|i| i as u32).collect();
        let err = client.request(&big, 8).unwrap_err();
        assert!(err.to_string().contains("overloaded"), "{err}");
        assert!(server.metrics.shed.load(Ordering::Relaxed) >= 1);
        // The server is alive and a pool-sized request is served.
        let (tokens, _) = client.request(&[1, 2], 2).unwrap();
        assert_eq!(tokens.len(), 2);
        server.shutdown();
    }

    #[test]
    fn metrics_stats_healthz_commands() {
        use crate::obs::registry::validate_prometheus_text;
        let model = tiny_model();
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        };
        let mut server = Server::start(model, EngineKind::auto(None), cfg).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let (tokens, _) = client.request(&[1, 2, 3], 4).unwrap();
        assert_eq!(tokens.len(), 4);
        // healthz: one JSON line, ok + uptime.
        assert!(client.healthz().unwrap() >= 0.0);
        // stats: the JSON summary, same content as server.metrics.summary().
        let stats = client.stats().unwrap();
        assert_eq!(stats.req_f64("completed").unwrap(), 1.0);
        assert!(stats.req_f64("tokens_out").unwrap() >= 4.0);
        // metrics: valid Prometheus exposition covering the summary state.
        let text = client.scrape_metrics().unwrap();
        validate_prometheus_text(&text).unwrap();
        assert!(text.contains("quip_completed_total 1"));
        assert!(text.contains("# TYPE quip_request_latency_seconds histogram"));
        assert!(text.contains("quip_request_latency_seconds_count 1"));
        // Control commands are not generation requests.
        assert_eq!(server.metrics.requests.load(Ordering::Relaxed), 1);
        // The connection still serves generation afterwards.
        let (t2, _) = client.request(&[4, 5], 2).unwrap();
        assert_eq!(t2.len(), 2);
        server.shutdown();
    }

    #[test]
    fn trace_out_writes_chrome_trace_on_shutdown() {
        let model = tiny_model();
        let path = std::env::temp_dir().join(format!(
            "quip_serve_trace_{}.json",
            std::process::id()
        ));
        let path_s = path.to_string_lossy().to_string();
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            trace_out: Some(path_s.clone()),
            ..Default::default()
        };
        let mut server = Server::start(model, EngineKind::auto(None), cfg).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let (tokens, _) = client.request(&[1, 2, 3], 5).unwrap();
        assert_eq!(tokens.len(), 5);
        server.shutdown();
        let text = std::fs::read_to_string(&path_s).unwrap();
        let j = Json::parse(&text).unwrap();
        let events = j.req("traceEvents").unwrap().as_arr().unwrap();
        let names: Vec<&str> = events
            .iter()
            .map(|e| e.req_str("name").unwrap())
            .collect();
        for expected in ["admission_wait", "prefill", "decode_step", "request"] {
            assert!(names.contains(&expected), "missing {expected} in {names:?}");
        }
        // The per-request spans share one tid lane ≥ 1; decode steps
        // ride the scheduler lane 0.
        let req = events
            .iter()
            .find(|e| e.req_str("name").unwrap() == "request")
            .unwrap();
        assert!(req.req_f64("tid").unwrap() >= 1.0);
        assert!(req.req_f64("dur").unwrap() > 0.0);
        let _ = std::fs::remove_file(&path_s);
        server.shutdown(); // idempotent: trace_out flushed once
    }

    /// A model big enough that decoding tens of tokens takes many
    /// scheduler iterations — gives the shutdown command a wide window
    /// to land while a request is mid-decode.
    fn slow_model() -> Arc<Transformer> {
        let cfg = ModelConfig::sized("t", 128, 4, 4, 512);
        Arc::new(Transformer::from_checkpoint(&Checkpoint::random(&cfg, 5)).unwrap())
    }

    /// Open a streaming request and return (writer, reader) after the
    /// first token frame arrived — i.e. once the request is provably
    /// admitted and decoding.
    fn admitted_stream(
        addr: &std::net::SocketAddr,
        max_tokens: usize,
    ) -> (TcpStream, BufReader<TcpStream>) {
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        let req = format!(
            "{{\"prompt\": [1, 2, 3], \"max_tokens\": {max_tokens}, \"stream\": true}}\n"
        );
        w.write_all(req.as_bytes()).unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert!(j.get("error").is_none(), "request not admitted: {line}");
        assert_eq!(j.req_f64("index").unwrap(), 0.0);
        (w, reader)
    }

    #[test]
    fn shutdown_command_drains_in_flight_request() {
        let model = slow_model();
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        };
        let mut server = Server::start(model, EngineKind::auto(None), cfg).unwrap();
        let max_tokens = 8;
        let (_w, mut reader) = admitted_stream(&server.addr, max_tokens);

        // Drain from a second connection while the first is mid-decode.
        let mut ctl = Client::connect(&server.addr).unwrap();
        ctl.shutdown().unwrap();
        assert!(server.draining());
        // The issuing connection is closed; new work on it is refused.
        assert!(ctl.request(&[1, 2], 2).is_err());

        // The in-flight request still runs to completion.
        let mut frames = 1usize;
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(!line.is_empty(), "stream cut before done frame");
            let j = Json::parse(&line).unwrap();
            assert!(j.get("error").is_none(), "drained request errored: {line}");
            if j.get("done").and_then(|x| x.as_bool()).unwrap_or(false) {
                let tokens = j.req("tokens").unwrap().as_arr().unwrap().len();
                assert_eq!(tokens, max_tokens);
                break;
            }
            frames += 1;
        }
        assert_eq!(frames, max_tokens);
        server.shutdown();
        assert_eq!(server.metrics.completed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn drain_timeout_zero_sheds_active_sequences() {
        let model = slow_model();
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            drain_timeout: Duration::from_millis(0),
            ..Default::default()
        };
        let mut server = Server::start(model, EngineKind::auto(None), cfg).unwrap();
        // Enough decode budget that the request cannot finish before the
        // shutdown lands (each step on the slow model is ~ms).
        let (_w, mut reader) = admitted_stream(&server.addr, 60);

        let mut ctl = Client::connect(&server.addr).unwrap();
        ctl.shutdown().unwrap();

        // With a zero drain budget the scheduler sheds the in-flight
        // sequence at the next token boundary instead of finishing it.
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(!line.is_empty(), "stream cut without a shed response");
            let j = Json::parse(&line).unwrap();
            if j.get("done").and_then(|x| x.as_bool()).unwrap_or(false) {
                panic!("sequence finished despite zero drain budget");
            }
            if let Some(err) = j.get("error") {
                let msg = err.as_str().unwrap_or("?");
                assert!(msg.contains("drain timeout"), "{msg}");
                break;
            }
        }
        server.shutdown();
        assert!(server.metrics.shed.load(Ordering::Relaxed) >= 1);
        assert_eq!(server.metrics.completed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn mid_flight_stall_is_shed_not_wedged() {
        // Zero decode reservation lets a long request through admission,
        // but it outgrows the 3-page pool mid-flight (prompt 4 + 40-token
        // budget vs 12 rows). Once every live sequence is stalled the
        // scheduler drops the youngest stalled one with "overloaded"
        // instead of wedging the decode loop forever.
        let model = tiny_model();
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_batch: 2,
            kv_pages: 3,
            page_tokens: 4,
            reserve_tokens: 0,
            admit_timeout: Duration::from_secs(5),
            ..Default::default()
        };
        let mut server = Server::start(model, EngineKind::auto(None), cfg).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let err = client.request(&[5, 6, 7, 8], 40).unwrap_err();
        assert!(err.to_string().contains("overloaded"), "{err}");
        assert!(server.metrics.evicted.load(Ordering::Relaxed) >= 1);
        assert!(server.metrics.shed.load(Ordering::Relaxed) >= 1);
        // The shed sequence's pages were released: the pool serves a
        // fitting request afterwards.
        let (tokens, _) = client.request(&[1, 2], 2).unwrap();
        assert_eq!(tokens.len(), 2);
        assert_eq!(server.metrics.kv_pages_total.load(Ordering::Relaxed), 3);
        server.shutdown();
    }
}
