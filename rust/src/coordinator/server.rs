//! Generation server: newline-delimited JSON over TCP.
//!
//! Request : {"id": 1, "prompt": [3, 17, 9], "max_tokens": 16,
//!            "temperature": 0.0}
//! Response: {"id": 1, "tokens": [...], "latency_ms": 12.3}
//!   or      {"id": 1, "error": "..."}
//!
//! Architecture: an acceptor thread per listener, a shared [`Batcher`]
//! for admission (backpressure → {"error":"overloaded"}), and a
//! continuous-batching scheduler: one decode loop advances every active
//! sequence a token at a time through the batched native engine
//! (`decode_step_batch`), new requests join at token boundaries and
//! finished ones respond and leave. The batched linears parallelize
//! internally across the `util::threadpool` substrate.

use super::batcher::Batcher;
use super::generate::{step_batch, ActiveSeq, GenParams};
use super::metrics::Metrics;
use crate::engine::native::{FpLinears, LinearOps, QuantLinears};
use crate::model::quantized::QuantizedModel;
use crate::model::Transformer;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub struct ServerConfig {
    pub addr: String,
    /// Upper bound on sequences decoded together per token step. Compute
    /// parallelism within a step is sized by the batched kernels
    /// themselves (`util::threadpool::default_threads`).
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_capacity: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7077".into(),
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            queue_capacity: 256,
        }
    }
}

/// The engine the server decodes with.
pub enum EngineKind {
    Fp32,
    Quant(QuantizedModel),
}

impl EngineKind {
    /// Fold the "serve quantized iff an artifact is present" choice into
    /// one constructor — callers pass whatever `Option<QuantizedModel>`
    /// they loaded.
    pub fn auto(qm: Option<QuantizedModel>) -> EngineKind {
        match qm {
            Some(q) => EngineKind::Quant(q),
            None => EngineKind::Fp32,
        }
    }
}

/// Legacy name for [`EngineKind`], kept for transition-era call sites.
pub type ServeEngine = EngineKind;

struct Job {
    prompt: Vec<u32>,
    params: GenParams,
    resp: Mutex<Option<TcpStream>>,
    received: Instant,
}

/// A running server (owns its threads; `shutdown` + drop joins them).
pub struct Server {
    pub addr: std::net::SocketAddr,
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    batcher: Arc<Batcher<Job>>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving. Binds immediately; returns the handle.
    pub fn start(
        model: Arc<Transformer>,
        engine: EngineKind,
        cfg: ServerConfig,
    ) -> crate::Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let batcher = Arc::new(Batcher::<Job>::new(
            cfg.max_batch,
            cfg.max_wait,
            cfg.queue_capacity,
        ));
        let qlin: Arc<Option<QuantLinears>> = Arc::new(match engine {
            EngineKind::Fp32 => None,
            EngineKind::Quant(qm) => Some(QuantLinears::from_model(&qm)?),
        });

        let mut threads = Vec::new();

        // Acceptor: spawns one (detached) handler thread per connection so
        // a long-lived connection can never block accept or shutdown.
        {
            let stop = Arc::clone(&stop);
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let next_id = Arc::new(AtomicU64::new(1));
            threads.push(std::thread::spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let batcher = Arc::clone(&batcher);
                            let metrics = Arc::clone(&metrics);
                            let next_id = Arc::clone(&next_id);
                            let stop = Arc::clone(&stop);
                            std::thread::spawn(move || {
                                handle_connection(stream, &batcher, &metrics, &next_id, &stop);
                            });
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            }));
        }

        // Continuous-batching scheduler: admit → step all → retire, one
        // token per iteration.
        {
            let stop = Arc::clone(&stop);
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let max_batch = cfg.max_batch.max(1);
            threads.push(std::thread::spawn(move || {
                let mut active: Vec<ActiveSeq> = Vec::new();
                let mut slots: Vec<Slot> = Vec::new();
                loop {
                    // On stop: admit nothing more, but run the already
                    // admitted sequences to completion so every accepted
                    // request gets its response (the old worker-pool path
                    // guaranteed this via pool.wait_idle()).
                    let stopping = stop.load(Ordering::SeqCst);
                    if active.is_empty() {
                        if stopping {
                            break;
                        }
                        // Idle: park on the batcher until work (or close).
                        let Some(batch) = batcher.next_batch() else {
                            break;
                        };
                        for p in batch {
                            admit(&model, p, &mut active, &mut slots);
                        }
                    } else if !stopping && active.len() < max_batch {
                        // Token boundary: top up the running batch without
                        // blocking the in-flight sequences.
                        for p in batcher.poll(max_batch - active.len()) {
                            admit(&model, p, &mut active, &mut slots);
                        }
                    }
                    let fp;
                    let lin: &dyn LinearOps = match &*qlin {
                        Some(q) => q,
                        None => {
                            fp = FpLinears { model: &*model };
                            &fp
                        }
                    };
                    let stepped = step_batch(&model, lin, &mut active);
                    metrics.record_batch(stepped);
                    let mut i = 0;
                    while i < active.len() {
                        if active[i].done {
                            let seq = active.swap_remove(i);
                            let slot = slots.swap_remove(i);
                            finish_job(slot, seq, &metrics);
                        } else {
                            i += 1;
                        }
                    }
                }
            }));
        }

        Ok(Server {
            addr,
            metrics,
            stop,
            batcher,
            threads,
        })
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.batcher.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_connection(
    stream: TcpStream,
    batcher: &Batcher<Job>,
    metrics: &Metrics,
    next_id: &AtomicU64,
    stop: &AtomicBool,
) {
    let _ = stream.set_nonblocking(false);
    // Idle read timeout so handler threads drain on shutdown even if a
    // client holds its connection open.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut line = String::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        match reader.read_line(&mut line) {
            Ok(0) => return,
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue; // keep any partial line accumulated so far
            }
            Err(_) => return,
            Ok(_) => {}
        }
        if !line.ends_with('\n') {
            continue; // partial line (timeout mid-read); keep accumulating
        }
        let taken = std::mem::take(&mut line);
        let line = taken;
        if line.trim().is_empty() {
            continue;
        }
        metrics.requests.fetch_add(1, Ordering::Relaxed);
        let parsed = parse_request(&line);
        let (prompt, params, req_id) = match parsed {
            Ok(v) => v,
            Err(e) => {
                let _ = respond_err(&stream, 0, &e.to_string());
                continue;
            }
        };
        let out = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => return,
        };
        let job = Job {
            prompt,
            params,
            resp: Mutex::new(Some(out)),
            received: Instant::now(),
        };
        let id = next_id.fetch_add(1, Ordering::Relaxed);
        if let Err(job) = batcher.push(id, job) {
            metrics.rejected.fetch_add(1, Ordering::Relaxed);
            if let Some(s) = job.resp.lock().unwrap().take() {
                let _ = respond_err(&s, req_id, "overloaded");
            }
        }
    }
}

fn parse_request(line: &str) -> crate::Result<(Vec<u32>, GenParams, u64)> {
    let j = Json::parse(line)?;
    let prompt: Vec<u32> = j
        .req("prompt")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("prompt must be an array"))?
        .iter()
        .filter_map(|x| x.as_f64().map(|v| v as u32))
        .collect();
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let params = GenParams {
        max_tokens: j.get("max_tokens").and_then(|x| x.as_usize()).unwrap_or(16),
        temperature: j.get("temperature").and_then(|x| x.as_f64()).unwrap_or(0.0),
        seed: j.get("seed").and_then(|x| x.as_u64()).unwrap_or(0),
        stop_token: None,
    };
    let id = j.get("id").and_then(|x| x.as_u64()).unwrap_or(0);
    Ok((prompt, params, id))
}

/// Response bookkeeping for one in-flight sequence (same index as its
/// [`ActiveSeq`] in the scheduler's batch).
struct Slot {
    id: u64,
    resp: Mutex<Option<TcpStream>>,
    received: Instant,
}

/// Admit one queued request into the running batch (invalid requests are
/// answered immediately instead of joining).
fn admit(
    model: &Transformer,
    p: super::batcher::Pending<Job>,
    active: &mut Vec<ActiveSeq>,
    slots: &mut Vec<Slot>,
) {
    let job = p.payload;
    if job.prompt.len() > model.cfg.max_seq {
        if let Some(s) = job.resp.lock().unwrap().take() {
            let _ = respond_err(&s, p.id, "prompt exceeds context");
        }
        return;
    }
    active.push(ActiveSeq::new(model, &job.prompt, job.params));
    slots.push(Slot {
        id: p.id,
        resp: job.resp,
        received: job.received,
    });
}

/// Respond to a finished sequence and record its serving metrics.
fn finish_job(slot: Slot, seq: ActiveSeq, metrics: &Metrics) {
    let latency = slot.received.elapsed().as_secs_f64();
    metrics.completed.fetch_add(1, Ordering::Relaxed);
    metrics
        .tokens_out
        .fetch_add(seq.tokens.len() as u64, Ordering::Relaxed);
    metrics.record_latency(latency);
    if let Some(s) = slot.resp.lock().unwrap().take() {
        let mut o = Json::obj();
        o.set("id", Json::Num(slot.id as f64));
        o.set(
            "tokens",
            Json::Arr(seq.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
        );
        o.set("latency_ms", Json::Num(latency * 1e3));
        let _ = writeln_json(&s, &o);
    }
}

fn respond_err(stream: &TcpStream, id: u64, msg: &str) -> std::io::Result<()> {
    let mut o = Json::obj();
    o.set("id", Json::Num(id as f64));
    o.set("error", Json::Str(msg.to_string()));
    writeln_json(stream, &o)
}

fn writeln_json(mut stream: &TcpStream, j: &Json) -> std::io::Result<()> {
    let mut line = j.to_string();
    line.push('\n');
    stream.write_all(line.as_bytes())
}

/// Simple blocking client used by examples, benches and tests.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> crate::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    pub fn request(
        &mut self,
        prompt: &[u32],
        max_tokens: usize,
    ) -> crate::Result<(Vec<u32>, f64)> {
        let mut o = Json::obj();
        o.set(
            "prompt",
            Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()),
        );
        o.set("max_tokens", Json::Num(max_tokens as f64));
        let mut line = o.to_string();
        line.push('\n');
        self.stream.write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        let j = Json::parse(&resp)?;
        if let Some(err) = j.get("error") {
            anyhow::bail!("server error: {}", err.as_str().unwrap_or("?"));
        }
        let tokens: Vec<u32> = j
            .req("tokens")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|x| x.as_f64().map(|v| v as u32))
            .collect();
        let latency = j.req_f64("latency_ms")? / 1e3;
        Ok((tokens, latency))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::Checkpoint;
    use crate::model::ModelConfig;

    fn tiny_model() -> Arc<Transformer> {
        let cfg = ModelConfig::sized("t", 32, 2, 4, 64);
        Arc::new(Transformer::from_checkpoint(&Checkpoint::random(&cfg, 5)).unwrap())
    }

    #[test]
    fn serves_requests_end_to_end() {
        let model = tiny_model();
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        };
        let mut server = Server::start(model, EngineKind::auto(None), cfg).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let (tokens, latency) = client.request(&[1, 2, 3], 5).unwrap();
        assert_eq!(tokens.len(), 5);
        assert!(latency >= 0.0);
        // Pipelined requests on the same connection.
        let (t2, _) = client.request(&[4, 5], 3).unwrap();
        assert_eq!(t2.len(), 3);
        assert_eq!(server.metrics.completed.load(Ordering::Relaxed), 2);
        server.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let model = tiny_model();
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        };
        let mut server = Server::start(model, EngineKind::auto(None), cfg).unwrap();
        let addr = server.addr;
        let handles: Vec<_> = (0..6)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    let (tokens, _) = c.request(&[1, 2, (i % 30) as u32], 4).unwrap();
                    tokens.len()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 4);
        }
        assert_eq!(server.metrics.completed.load(Ordering::Relaxed), 6);
        // The continuous-batching loop ran and its occupancy counters moved.
        assert!(server.metrics.batched_steps.load(Ordering::Relaxed) > 0);
        assert!(server.metrics.mean_batch_size() >= 1.0);
        let j = server.metrics.summary();
        assert!(j.req_f64("mean_batch").unwrap() >= 1.0);
        server.shutdown();
    }

    #[test]
    fn quantized_engine_serves_batched() {
        // End-to-end through the quantized fused batch kernel.
        use crate::coordinator::pipeline::{quantize_model, PipelineConfig};
        use crate::data::gen::markov_stream;
        use crate::model::weights::Checkpoint;
        let cfg_m = ModelConfig::sized("t", 32, 2, 4, 64);
        let ck = Checkpoint::random(&cfg_m, 5);
        let stream = markov_stream(cfg_m.vocab as u32, 4_000, 2);
        let calib = stream.calibration(24, 4, 3);
        let (qm, _) = quantize_model(&ck, &calib, &PipelineConfig::default()).unwrap();
        let model = Arc::new(Transformer::from_checkpoint(&ck).unwrap());
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        };
        let mut server = Server::start(model, EngineKind::auto(Some(qm)), cfg).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let (tokens, _) = client.request(&[1, 2, 3], 6).unwrap();
        assert_eq!(tokens.len(), 6);
        assert!(server.metrics.batched_steps.load(Ordering::Relaxed) > 0);
        server.shutdown();
    }

    #[test]
    fn oversized_prompt_is_rejected_not_fatal() {
        let model = tiny_model();
        let max_seq = model.cfg.max_seq;
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        };
        let mut server = Server::start(model, EngineKind::auto(None), cfg).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let long: Vec<u32> = (0..max_seq + 5).map(|i| (i % 30) as u32).collect();
        assert!(client.request(&long, 4).is_err());
        // Server is still alive and serving after the rejection.
        let (tokens, _) = client.request(&[1, 2], 3).unwrap();
        assert_eq!(tokens.len(), 3);
        server.shutdown();
    }

    #[test]
    fn malformed_request_gets_error() {
        let model = tiny_model();
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        };
        // Uses the legacy `ServeEngine` alias on purpose — it must keep
        // compiling until downstream callers finish migrating.
        let mut server = Server::start(model, ServeEngine::Fp32, cfg).unwrap();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut s2 = stream.try_clone().unwrap();
        use std::io::Write as _;
        s2.write_all(b"{\"nonsense\": true}\n").unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error"), "{line}");
        server.shutdown();
    }
}
