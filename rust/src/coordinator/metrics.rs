//! Serving metrics: counters + streaming latency histograms (log-spaced
//! buckets), all lock-free on the record path. Request latency and
//! per-token (inter-step) latency get separate histograms; KV-pool
//! gauges are copied in from [`crate::model::kvpool::PoolSnapshot`]
//! after each scheduler step.

use crate::model::kvpool::PoolSnapshot;
use std::sync::atomic::{AtomicU64, Ordering};

const BUCKETS: usize = 40;
/// Bucket i covers [BASE·GROWTH^i, BASE·GROWTH^{i+1}) seconds.
const BASE: f64 = 1e-5;
const GROWTH: f64 = 1.45;

fn bucket_index(seconds: f64) -> usize {
    let mut idx = 0usize;
    let mut bound = BASE;
    while idx < BUCKETS - 1 && seconds >= bound {
        bound *= GROWTH;
        idx += 1;
    }
    idx
}

fn quantile_from(counts: &[u64], q: f64) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let target = (q * total as f64).ceil() as u64;
    let mut acc = 0u64;
    let mut bound = BASE;
    for &c in counts.iter() {
        acc += c;
        if acc >= target {
            return bound;
        }
        bound *= GROWTH;
    }
    bound
}

pub struct Metrics {
    pub requests: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub tokens_out: AtomicU64,
    /// Requests refused or dropped by admission control ("overloaded"):
    /// pool could not cover the prompt + reservation, or the wait in the
    /// admission queue timed out, or a stalled sequence was dropped.
    pub shed: AtomicU64,
    /// Admitted-then-dropped sequences (stalled on an exhausted pool with
    /// no step progressing); a subset of `shed`.
    pub evicted: AtomicU64,
    /// Tokens pushed to clients as incremental stream frames.
    pub streamed_tokens: AtomicU64,
    /// Batched decode steps executed by the continuous-batching loop.
    pub batched_steps: AtomicU64,
    /// Sum of batch sizes over those steps (occupancy numerator).
    pub batch_occupancy_sum: AtomicU64,
    /// Largest batch seen in a single step.
    pub max_batch_seen: AtomicU64,
    // KV-pool gauges/counters, refreshed from the pool snapshot.
    pub kv_pages_used: AtomicU64,
    pub kv_pages_total: AtomicU64,
    pub kv_pages_peak: AtomicU64,
    pub cow_copies: AtomicU64,
    pub prefix_lookups: AtomicU64,
    pub prefix_hits: AtomicU64,
    pub prefix_tokens_shared: AtomicU64,
    pub pool_evictions: AtomicU64,
    latency: [AtomicU64; BUCKETS],
    latency_sum_us: AtomicU64,
    tok_latency: [AtomicU64; BUCKETS],
    tok_latency_sum_us: AtomicU64,
    tok_latency_count: AtomicU64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            tokens_out: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            streamed_tokens: AtomicU64::new(0),
            batched_steps: AtomicU64::new(0),
            batch_occupancy_sum: AtomicU64::new(0),
            max_batch_seen: AtomicU64::new(0),
            kv_pages_used: AtomicU64::new(0),
            kv_pages_total: AtomicU64::new(0),
            kv_pages_peak: AtomicU64::new(0),
            cow_copies: AtomicU64::new(0),
            prefix_lookups: AtomicU64::new(0),
            prefix_hits: AtomicU64::new(0),
            prefix_tokens_shared: AtomicU64::new(0),
            pool_evictions: AtomicU64::new(0),
            latency: std::array::from_fn(|_| AtomicU64::new(0)),
            latency_sum_us: AtomicU64::new(0),
            tok_latency: std::array::from_fn(|_| AtomicU64::new(0)),
            tok_latency_sum_us: AtomicU64::new(0),
            tok_latency_count: AtomicU64::new(0),
        }
    }

    /// Record one continuous-batching step that advanced `size` sequences.
    pub fn record_batch(&self, size: usize) {
        if size == 0 {
            return;
        }
        self.batched_steps.fetch_add(1, Ordering::Relaxed);
        self.batch_occupancy_sum
            .fetch_add(size as u64, Ordering::Relaxed);
        self.max_batch_seen.fetch_max(size as u64, Ordering::Relaxed);
    }

    /// Mean sequences per batched step (1.0 = no batching benefit).
    pub fn mean_batch_size(&self) -> f64 {
        let steps = self.batched_steps.load(Ordering::Relaxed);
        if steps == 0 {
            return 0.0;
        }
        self.batch_occupancy_sum.load(Ordering::Relaxed) as f64 / steps as f64
    }

    pub fn record_latency(&self, seconds: f64) {
        self.latency[bucket_index(seconds)].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us
            .fetch_add((seconds * 1e6) as u64, Ordering::Relaxed);
    }

    /// Record one inter-token interval (one scheduler step's duration,
    /// from the perspective of every sequence it advanced).
    pub fn record_token_latency(&self, seconds: f64) {
        self.tok_latency[bucket_index(seconds)].fetch_add(1, Ordering::Relaxed);
        self.tok_latency_sum_us
            .fetch_add((seconds * 1e6) as u64, Ordering::Relaxed);
        self.tok_latency_count.fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate request-latency quantile from the histogram.
    pub fn latency_quantile(&self, q: f64) -> f64 {
        let counts: Vec<u64> = self
            .latency
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        quantile_from(&counts, q)
    }

    /// Approximate per-token latency quantile from the histogram.
    pub fn token_latency_quantile(&self, q: f64) -> f64 {
        let counts: Vec<u64> = self
            .tok_latency
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        quantile_from(&counts, q)
    }

    pub fn mean_latency(&self) -> f64 {
        let n = self.completed.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / 1e6 / n as f64
    }

    pub fn mean_token_latency(&self) -> f64 {
        let n = self.tok_latency_count.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.tok_latency_sum_us.load(Ordering::Relaxed) as f64 / 1e6 / n as f64
    }

    /// Fraction of admission lookups that found a shared prompt prefix.
    pub fn prefix_hit_rate(&self) -> f64 {
        let lookups = self.prefix_lookups.load(Ordering::Relaxed);
        if lookups == 0 {
            return 0.0;
        }
        self.prefix_hits.load(Ordering::Relaxed) as f64 / lookups as f64
    }

    /// Refresh the pool gauges from a snapshot (taken under the pool
    /// lock once per scheduler step).
    pub fn record_pool(&self, s: &PoolSnapshot) {
        self.kv_pages_used.store(s.pages_used as u64, Ordering::Relaxed);
        self.kv_pages_total.store(s.pages_total as u64, Ordering::Relaxed);
        self.kv_pages_peak.store(s.peak_pages as u64, Ordering::Relaxed);
        self.cow_copies.store(s.cow_copies, Ordering::Relaxed);
        self.prefix_lookups.store(s.prefix_lookups, Ordering::Relaxed);
        self.prefix_hits.store(s.prefix_hits, Ordering::Relaxed);
        self.prefix_tokens_shared
            .store(s.prefix_tokens_shared, Ordering::Relaxed);
        self.pool_evictions.store(s.evictions, Ordering::Relaxed);
    }

    pub fn summary(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let g = |a: &AtomicU64| Json::Num(a.load(Ordering::Relaxed) as f64);
        let mut j = Json::obj();
        j.set("requests", g(&self.requests));
        j.set("rejected", g(&self.rejected));
        j.set("completed", g(&self.completed));
        j.set("tokens_out", g(&self.tokens_out));
        j.set("shed", g(&self.shed));
        j.set("evicted", g(&self.evicted));
        j.set("streamed_tokens", g(&self.streamed_tokens));
        j.set("mean_latency_s", Json::Num(self.mean_latency()));
        j.set("p50_s", Json::Num(self.latency_quantile(0.5)));
        j.set("p95_s", Json::Num(self.latency_quantile(0.95)));
        j.set("mean_tok_latency_s", Json::Num(self.mean_token_latency()));
        j.set("p50_tok_s", Json::Num(self.token_latency_quantile(0.5)));
        j.set("p95_tok_s", Json::Num(self.token_latency_quantile(0.95)));
        j.set("batched_steps", g(&self.batched_steps));
        j.set("mean_batch", Json::Num(self.mean_batch_size()));
        j.set("max_batch", g(&self.max_batch_seen));
        j.set("kv_pages_used", g(&self.kv_pages_used));
        j.set("kv_pages_total", g(&self.kv_pages_total));
        j.set("kv_pages_peak", g(&self.kv_pages_peak));
        j.set("cow_copies", g(&self.cow_copies));
        j.set("prefix_hit_rate", Json::Num(self.prefix_hit_rate()));
        j.set("prefix_tokens_shared", g(&self.prefix_tokens_shared));
        j.set("pool_evictions", g(&self.pool_evictions));
        j
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_are_ordered() {
        let m = Metrics::new();
        for i in 1..=1000 {
            m.completed.fetch_add(1, Ordering::Relaxed);
            m.record_latency(i as f64 * 1e-4);
        }
        let p50 = m.latency_quantile(0.5);
        let p95 = m.latency_quantile(0.95);
        assert!(p50 <= p95);
        // p50 ≈ 0.05s within a histogram bucket factor
        assert!((0.02..0.12).contains(&p50), "p50={p50}");
    }

    #[test]
    fn mean_latency_sane() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.completed.fetch_add(1, Ordering::Relaxed);
            m.record_latency(0.01);
        }
        assert!((m.mean_latency() - 0.01).abs() < 1e-3);
    }

    #[test]
    fn summary_is_json() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        let j = m.summary();
        assert_eq!(j.req_f64("requests").unwrap(), 3.0);
        assert_eq!(j.req_f64("shed").unwrap(), 0.0);
    }

    #[test]
    fn batch_occupancy_counters() {
        let m = Metrics::new();
        assert_eq!(m.mean_batch_size(), 0.0);
        m.record_batch(0); // empty steps are not counted
        m.record_batch(4);
        m.record_batch(16);
        m.record_batch(4);
        assert_eq!(m.batched_steps.load(Ordering::Relaxed), 3);
        assert_eq!(m.max_batch_seen.load(Ordering::Relaxed), 16);
        assert!((m.mean_batch_size() - 8.0).abs() < 1e-12);
        let j = m.summary();
        assert_eq!(j.req_f64("batched_steps").unwrap(), 3.0);
        assert_eq!(j.req_f64("max_batch").unwrap(), 16.0);
    }

    #[test]
    fn token_latency_histogram_is_separate() {
        let m = Metrics::new();
        for _ in 0..100 {
            m.record_token_latency(2e-3);
        }
        assert!((m.mean_token_latency() - 2e-3).abs() < 2e-4);
        let p50 = m.token_latency_quantile(0.5);
        // Within one log-bucket (×1.45) of the true value.
        assert!((1e-3..5e-3).contains(&p50), "p50_tok={p50}");
        // The request-latency histogram is untouched.
        assert_eq!(m.latency_quantile(0.5), 0.0);
    }

    #[test]
    fn pool_gauges_come_from_snapshot() {
        let m = Metrics::new();
        let s = PoolSnapshot {
            pages_used: 7,
            pages_total: 64,
            peak_pages: 12,
            cow_copies: 3,
            prefix_lookups: 10,
            prefix_hits: 4,
            prefix_tokens_shared: 36,
            evictions: 1,
        };
        m.record_pool(&s);
        assert_eq!(m.kv_pages_used.load(Ordering::Relaxed), 7);
        assert!((m.prefix_hit_rate() - 0.4).abs() < 1e-12);
        let j = m.summary();
        assert_eq!(j.req_f64("kv_pages_total").unwrap(), 64.0);
        assert_eq!(j.req_f64("cow_copies").unwrap(), 3.0);
        assert_eq!(j.req_f64("pool_evictions").unwrap(), 1.0);
    }
}
