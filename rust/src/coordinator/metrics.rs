//! Serving metrics: counters + streaming latency histogram (log-spaced
//! buckets), all lock-free on the record path.

use std::sync::atomic::{AtomicU64, Ordering};

const BUCKETS: usize = 40;
/// Bucket i covers [BASE·GROWTH^i, BASE·GROWTH^{i+1}) seconds.
const BASE: f64 = 1e-5;
const GROWTH: f64 = 1.45;

pub struct Metrics {
    pub requests: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub tokens_out: AtomicU64,
    /// Batched decode steps executed by the continuous-batching loop.
    pub batched_steps: AtomicU64,
    /// Sum of batch sizes over those steps (occupancy numerator).
    pub batch_occupancy_sum: AtomicU64,
    /// Largest batch seen in a single step.
    pub max_batch_seen: AtomicU64,
    latency: [AtomicU64; BUCKETS],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            tokens_out: AtomicU64::new(0),
            batched_steps: AtomicU64::new(0),
            batch_occupancy_sum: AtomicU64::new(0),
            max_batch_seen: AtomicU64::new(0),
            latency: std::array::from_fn(|_| AtomicU64::new(0)),
            latency_sum_us: AtomicU64::new(0),
        }
    }

    /// Record one continuous-batching step that advanced `size` sequences.
    pub fn record_batch(&self, size: usize) {
        if size == 0 {
            return;
        }
        self.batched_steps.fetch_add(1, Ordering::Relaxed);
        self.batch_occupancy_sum
            .fetch_add(size as u64, Ordering::Relaxed);
        self.max_batch_seen.fetch_max(size as u64, Ordering::Relaxed);
    }

    /// Mean sequences per batched step (1.0 = no batching benefit).
    pub fn mean_batch_size(&self) -> f64 {
        let steps = self.batched_steps.load(Ordering::Relaxed);
        if steps == 0 {
            return 0.0;
        }
        self.batch_occupancy_sum.load(Ordering::Relaxed) as f64 / steps as f64
    }

    pub fn record_latency(&self, seconds: f64) {
        let mut idx = 0usize;
        let mut bound = BASE;
        while idx < BUCKETS - 1 && seconds >= bound {
            bound *= GROWTH;
            idx += 1;
        }
        self.latency[idx].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us
            .fetch_add((seconds * 1e6) as u64, Ordering::Relaxed);
    }

    /// Approximate latency quantile from the histogram.
    pub fn latency_quantile(&self, q: f64) -> f64 {
        let counts: Vec<u64> = self
            .latency
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut acc = 0u64;
        let mut bound = BASE;
        for &c in counts.iter() {
            acc += c;
            if acc >= target {
                return bound;
            }
            bound *= GROWTH;
        }
        bound
    }

    pub fn mean_latency(&self) -> f64 {
        let n = self.completed.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / 1e6 / n as f64
    }

    pub fn summary(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut j = Json::obj();
        j.set("requests", Json::Num(self.requests.load(Ordering::Relaxed) as f64));
        j.set("rejected", Json::Num(self.rejected.load(Ordering::Relaxed) as f64));
        j.set("completed", Json::Num(self.completed.load(Ordering::Relaxed) as f64));
        j.set("tokens_out", Json::Num(self.tokens_out.load(Ordering::Relaxed) as f64));
        j.set("mean_latency_s", Json::Num(self.mean_latency()));
        j.set("p50_s", Json::Num(self.latency_quantile(0.5)));
        j.set("p95_s", Json::Num(self.latency_quantile(0.95)));
        j.set(
            "batched_steps",
            Json::Num(self.batched_steps.load(Ordering::Relaxed) as f64),
        );
        j.set("mean_batch", Json::Num(self.mean_batch_size()));
        j.set(
            "max_batch",
            Json::Num(self.max_batch_seen.load(Ordering::Relaxed) as f64),
        );
        j
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_are_ordered() {
        let m = Metrics::new();
        for i in 1..=1000 {
            m.completed.fetch_add(1, Ordering::Relaxed);
            m.record_latency(i as f64 * 1e-4);
        }
        let p50 = m.latency_quantile(0.5);
        let p95 = m.latency_quantile(0.95);
        assert!(p50 <= p95);
        // p50 ≈ 0.05s within a histogram bucket factor
        assert!((0.02..0.12).contains(&p50), "p50={p50}");
    }

    #[test]
    fn mean_latency_sane() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.completed.fetch_add(1, Ordering::Relaxed);
            m.record_latency(0.01);
        }
        assert!((m.mean_latency() - 0.01).abs() < 1e-3);
    }

    #[test]
    fn summary_is_json() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        let j = m.summary();
        assert_eq!(j.req_f64("requests").unwrap(), 3.0);
    }

    #[test]
    fn batch_occupancy_counters() {
        let m = Metrics::new();
        assert_eq!(m.mean_batch_size(), 0.0);
        m.record_batch(0); // empty steps are not counted
        m.record_batch(4);
        m.record_batch(16);
        m.record_batch(4);
        assert_eq!(m.batched_steps.load(Ordering::Relaxed), 3);
        assert_eq!(m.max_batch_seen.load(Ordering::Relaxed), 16);
        assert!((m.mean_batch_size() - 8.0).abs() < 1e-12);
        let j = m.summary();
        assert_eq!(j.req_f64("batched_steps").unwrap(), 3.0);
        assert_eq!(j.req_f64("max_batch").unwrap(), 16.0);
    }
}
