//! Serving metrics: counters + streaming latency histograms, all
//! lock-free on the record path, now registered in a central
//! [`MetricRegistry`] (DESIGN.md §9) so the same state renders both as
//! the legacy JSON `summary()` (key order preserved) and as Prometheus
//! text exposition (the server's `metrics` protocol command). Request
//! latency and per-token (inter-step) latency get separate histograms;
//! KV-pool gauges are copied in from
//! [`crate::model::kvpool::PoolSnapshot`] after each scheduler step.
//!
//! Histogram buckets are log-spaced (see [`crate::obs::registry`]):
//! bucket 0 covers `[0, BASE)` seconds, bucket i (1 ≤ i < BUCKETS−1)
//! covers `[BASE·GROWTH^(i−1), BASE·GROWTH^i)`, and the last bucket is
//! the `+Inf` overflow; quantiles report the matched bucket's *upper*
//! edge.

use crate::model::kvpool::PoolSnapshot;
use crate::obs::registry::{Counter, Gauge, Histogram, MetricRegistry};
use std::sync::atomic::Ordering;
use std::sync::Arc;

pub struct Metrics {
    registry: Arc<MetricRegistry>,
    pub requests: Counter,
    pub rejected: Counter,
    pub completed: Counter,
    pub tokens_out: Counter,
    /// Requests refused or dropped by admission control ("overloaded"):
    /// pool could not cover the prompt + reservation, or the wait in the
    /// admission queue timed out, or a stalled sequence was dropped.
    pub shed: Counter,
    /// Admitted-then-dropped sequences (stalled on an exhausted pool with
    /// no step progressing); a subset of `shed`.
    pub evicted: Counter,
    /// Tokens pushed to clients as incremental stream frames.
    pub streamed_tokens: Counter,
    /// Batched decode steps executed by the continuous-batching loop.
    pub batched_steps: Counter,
    /// Sum of batch sizes over those steps (occupancy numerator).
    pub batch_occupancy_sum: Counter,
    /// Largest batch seen in a single step.
    pub max_batch_seen: Gauge,
    // KV-pool gauges/counters, refreshed from the pool snapshot.
    pub kv_pages_used: Gauge,
    pub kv_pages_total: Gauge,
    pub kv_pages_peak: Gauge,
    pub cow_copies: Gauge,
    pub prefix_lookups: Gauge,
    pub prefix_hits: Gauge,
    pub prefix_tokens_shared: Gauge,
    pub pool_evictions: Gauge,
    latency: Histogram,
    tok_latency: Histogram,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::with_registry(MetricRegistry::shared())
    }

    /// Register every serving metric in `registry`. All handles share
    /// the registry's catalog, so `registry.render_prometheus()` covers
    /// exactly the state `summary()` reports.
    pub fn with_registry(registry: Arc<MetricRegistry>) -> Metrics {
        let r = &registry;
        Metrics {
            requests: r.counter(
                "quip_requests_total",
                "Generation request lines received (control commands excluded).",
            ),
            rejected: r.counter(
                "quip_rejected_total",
                "Requests refused at intake (bounded queue overflow).",
            ),
            completed: r.counter(
                "quip_completed_total",
                "Requests answered with a full token list.",
            ),
            tokens_out: r.counter("quip_tokens_out_total", "Tokens generated across requests."),
            shed: r.counter(
                "quip_shed_total",
                "Requests shed by admission control or mid-flight eviction.",
            ),
            evicted: r.counter(
                "quip_evicted_total",
                "Admitted sequences dropped while stalled on an exhausted pool.",
            ),
            streamed_tokens: r.counter(
                "quip_streamed_tokens_total",
                "Tokens pushed to clients as incremental stream frames.",
            ),
            batched_steps: r.counter(
                "quip_batched_steps_total",
                "Decode steps executed by the continuous-batching loop.",
            ),
            batch_occupancy_sum: r.counter(
                "quip_batch_occupancy_sum",
                "Sum of batch sizes over all decode steps.",
            ),
            max_batch_seen: r.gauge(
                "quip_max_batch_seen",
                "Largest batch advanced in a single decode step.",
            ),
            kv_pages_used: r.gauge("quip_kv_pages_used", "KV-pool pages currently allocated."),
            kv_pages_total: r.gauge("quip_kv_pages_total", "KV-pool size in pages."),
            kv_pages_peak: r.gauge("quip_kv_pages_peak", "High-water mark of allocated pages."),
            cow_copies: r.gauge(
                "quip_cow_copies",
                "Copy-on-write page splits from shared prefixes.",
            ),
            prefix_lookups: r.gauge(
                "quip_prefix_lookups",
                "Admission-time prompt-prefix registry lookups.",
            ),
            prefix_hits: r.gauge(
                "quip_prefix_hits",
                "Prefix lookups that found shareable pages.",
            ),
            prefix_tokens_shared: r.gauge(
                "quip_prefix_tokens_shared",
                "Prompt tokens served from shared prefix pages.",
            ),
            pool_evictions: r.gauge(
                "quip_pool_evictions",
                "Page evictions performed by the pool itself.",
            ),
            latency: r.histogram(
                "quip_request_latency_seconds",
                "End-to-end request latency (admission to final frame).",
            ),
            tok_latency: r.histogram(
                "quip_token_latency_seconds",
                "Inter-token interval per batched decode step.",
            ),
            registry: Arc::clone(&registry),
        }
    }

    /// The registry these metrics are registered in (for exposition).
    pub fn registry(&self) -> &Arc<MetricRegistry> {
        &self.registry
    }

    /// Prometheus text exposition of every registered metric.
    pub fn render_prometheus(&self) -> String {
        self.registry.render_prometheus()
    }

    /// Record one continuous-batching step that advanced `size` sequences.
    pub fn record_batch(&self, size: usize) {
        if size == 0 {
            return;
        }
        self.batched_steps.fetch_add(1, Ordering::Relaxed);
        self.batch_occupancy_sum
            .fetch_add(size as u64, Ordering::Relaxed);
        self.max_batch_seen.fetch_max(size as u64, Ordering::Relaxed);
    }

    /// Mean sequences per batched step (1.0 = no batching benefit).
    pub fn mean_batch_size(&self) -> f64 {
        let steps = self.batched_steps.load(Ordering::Relaxed);
        if steps == 0 {
            return 0.0;
        }
        self.batch_occupancy_sum.load(Ordering::Relaxed) as f64 / steps as f64
    }

    pub fn record_latency(&self, seconds: f64) {
        self.latency.record(seconds);
    }

    /// Record one inter-token interval (one scheduler step's duration,
    /// from the perspective of every sequence it advanced).
    pub fn record_token_latency(&self, seconds: f64) {
        self.tok_latency.record(seconds);
    }

    /// Approximate request-latency quantile from the histogram.
    pub fn latency_quantile(&self, q: f64) -> f64 {
        self.latency.quantile(q)
    }

    /// Approximate per-token latency quantile from the histogram.
    pub fn token_latency_quantile(&self, q: f64) -> f64 {
        self.tok_latency.quantile(q)
    }

    /// Mean request latency over *recorded latency samples* (the
    /// histogram's own count — not the `completed` counter, so a latency
    /// recorded for a shed/errored request can never skew the mean).
    pub fn mean_latency(&self) -> f64 {
        self.latency.mean_seconds()
    }

    pub fn mean_token_latency(&self) -> f64 {
        self.tok_latency.mean_seconds()
    }

    /// Fraction of admission lookups that found a shared prompt prefix.
    pub fn prefix_hit_rate(&self) -> f64 {
        let lookups = self.prefix_lookups.load(Ordering::Relaxed);
        if lookups == 0 {
            return 0.0;
        }
        self.prefix_hits.load(Ordering::Relaxed) as f64 / lookups as f64
    }

    /// Refresh the pool gauges from a snapshot (taken under the pool
    /// lock once per scheduler step).
    pub fn record_pool(&self, s: &PoolSnapshot) {
        self.kv_pages_used.store(s.pages_used as u64, Ordering::Relaxed);
        self.kv_pages_total.store(s.pages_total as u64, Ordering::Relaxed);
        self.kv_pages_peak.store(s.peak_pages as u64, Ordering::Relaxed);
        self.cow_copies.store(s.cow_copies, Ordering::Relaxed);
        self.prefix_lookups.store(s.prefix_lookups, Ordering::Relaxed);
        self.prefix_hits.store(s.prefix_hits, Ordering::Relaxed);
        self.prefix_tokens_shared
            .store(s.prefix_tokens_shared, Ordering::Relaxed);
        self.pool_evictions.store(s.evictions, Ordering::Relaxed);
    }

    pub fn summary(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let c = |a: &Counter| Json::Num(a.load(Ordering::Relaxed) as f64);
        let g = |a: &Gauge| Json::Num(a.load(Ordering::Relaxed) as f64);
        let mut j = Json::obj();
        j.set("requests", c(&self.requests));
        j.set("rejected", c(&self.rejected));
        j.set("completed", c(&self.completed));
        j.set("tokens_out", c(&self.tokens_out));
        j.set("shed", c(&self.shed));
        j.set("evicted", c(&self.evicted));
        j.set("streamed_tokens", c(&self.streamed_tokens));
        j.set("mean_latency_s", Json::Num(self.mean_latency()));
        j.set("p50_s", Json::Num(self.latency_quantile(0.5)));
        j.set("p95_s", Json::Num(self.latency_quantile(0.95)));
        j.set("mean_tok_latency_s", Json::Num(self.mean_token_latency()));
        j.set("p50_tok_s", Json::Num(self.token_latency_quantile(0.5)));
        j.set("p95_tok_s", Json::Num(self.token_latency_quantile(0.95)));
        j.set("batched_steps", c(&self.batched_steps));
        j.set("mean_batch", Json::Num(self.mean_batch_size()));
        j.set("max_batch", g(&self.max_batch_seen));
        j.set("kv_pages_used", g(&self.kv_pages_used));
        j.set("kv_pages_total", g(&self.kv_pages_total));
        j.set("kv_pages_peak", g(&self.kv_pages_peak));
        j.set("cow_copies", g(&self.cow_copies));
        j.set("prefix_hit_rate", Json::Num(self.prefix_hit_rate()));
        j.set("prefix_tokens_shared", g(&self.prefix_tokens_shared));
        j.set("pool_evictions", g(&self.pool_evictions));
        j
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_are_ordered() {
        let m = Metrics::new();
        for i in 1..=1000 {
            m.completed.fetch_add(1, Ordering::Relaxed);
            m.record_latency(i as f64 * 1e-4);
        }
        let p50 = m.latency_quantile(0.5);
        let p95 = m.latency_quantile(0.95);
        assert!(p50 <= p95);
        // p50 ≈ 0.05s within a histogram bucket factor
        assert!((0.02..0.12).contains(&p50), "p50={p50}");
    }

    #[test]
    fn mean_latency_sane() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.completed.fetch_add(1, Ordering::Relaxed);
            m.record_latency(0.01);
        }
        assert!((m.mean_latency() - 0.01).abs() < 1e-3);
    }

    #[test]
    fn mean_latency_independent_of_completed_counter() {
        // A latency recorded for a shed/errored request (no `completed`
        // increment) must not skew the mean: the denominator is the
        // histogram's own sample count.
        let m = Metrics::new();
        m.record_latency(0.02);
        m.record_latency(0.04);
        assert_eq!(m.completed.load(Ordering::Relaxed), 0);
        assert!((m.mean_latency() - 0.03).abs() < 1e-6);
        // And extra completions without latency samples don't dilute it.
        m.completed.fetch_add(100, Ordering::Relaxed);
        assert!((m.mean_latency() - 0.03).abs() < 1e-6);
    }

    #[test]
    fn summary_is_json() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        let j = m.summary();
        assert_eq!(j.req_f64("requests").unwrap(), 3.0);
        assert_eq!(j.req_f64("shed").unwrap(), 0.0);
    }

    #[test]
    fn batch_occupancy_counters() {
        let m = Metrics::new();
        assert_eq!(m.mean_batch_size(), 0.0);
        m.record_batch(0); // empty steps are not counted
        m.record_batch(4);
        m.record_batch(16);
        m.record_batch(4);
        assert_eq!(m.batched_steps.load(Ordering::Relaxed), 3);
        assert_eq!(m.max_batch_seen.load(Ordering::Relaxed), 16);
        assert!((m.mean_batch_size() - 8.0).abs() < 1e-12);
        let j = m.summary();
        assert_eq!(j.req_f64("batched_steps").unwrap(), 3.0);
        assert_eq!(j.req_f64("max_batch").unwrap(), 16.0);
    }

    #[test]
    fn token_latency_histogram_is_separate() {
        let m = Metrics::new();
        for _ in 0..100 {
            m.record_token_latency(2e-3);
        }
        assert!((m.mean_token_latency() - 2e-3).abs() < 2e-4);
        let p50 = m.token_latency_quantile(0.5);
        // Within one log-bucket (×1.45) of the true value.
        assert!((1e-3..5e-3).contains(&p50), "p50_tok={p50}");
        // The request-latency histogram is untouched.
        assert_eq!(m.latency_quantile(0.5), 0.0);
    }

    #[test]
    fn pool_gauges_come_from_snapshot() {
        let m = Metrics::new();
        let s = PoolSnapshot {
            pages_used: 7,
            pages_total: 64,
            peak_pages: 12,
            cow_copies: 3,
            prefix_lookups: 10,
            prefix_hits: 4,
            prefix_tokens_shared: 36,
            evictions: 1,
        };
        m.record_pool(&s);
        assert_eq!(m.kv_pages_used.load(Ordering::Relaxed), 7);
        assert!((m.prefix_hit_rate() - 0.4).abs() < 1e-12);
        let j = m.summary();
        assert_eq!(j.req_f64("kv_pages_total").unwrap(), 64.0);
        assert_eq!(j.req_f64("cow_copies").unwrap(), 3.0);
        assert_eq!(j.req_f64("pool_evictions").unwrap(), 1.0);
    }

    #[test]
    fn prometheus_exposition_covers_every_summary_metric() {
        use crate::obs::registry::validate_prometheus_text;
        let m = Metrics::new();
        m.requests.fetch_add(2, Ordering::Relaxed);
        m.record_latency(0.01);
        m.record_token_latency(1e-3);
        m.record_batch(4);
        let text = m.render_prometheus();
        validate_prometheus_text(&text).unwrap();
        for name in [
            "quip_requests_total",
            "quip_rejected_total",
            "quip_completed_total",
            "quip_tokens_out_total",
            "quip_shed_total",
            "quip_evicted_total",
            "quip_streamed_tokens_total",
            "quip_batched_steps_total",
            "quip_batch_occupancy_sum",
            "quip_max_batch_seen",
            "quip_kv_pages_used",
            "quip_kv_pages_total",
            "quip_kv_pages_peak",
            "quip_cow_copies",
            "quip_prefix_lookups",
            "quip_prefix_hits",
            "quip_prefix_tokens_shared",
            "quip_pool_evictions",
            "quip_request_latency_seconds",
            "quip_token_latency_seconds",
        ] {
            assert!(text.contains(&format!("# TYPE {name} ")), "missing {name}");
        }
        assert!(text.contains("quip_requests_total 2"));
        assert!(text.contains("quip_request_latency_seconds_count 1"));
    }
}
