//! Crash-safe quantization sessions: the `.qzp` block journal + config
//! fingerprint manifest (DESIGN.md §10).
//!
//! A checkpointed [`QuantSession`](super::pipeline::QuantSession) appends
//! one journal record per finished block, so a run killed at block 37 of
//! 48 resumes from block 37 instead of zero. Two files live in the
//! checkpoint directory:
//!
//! * `manifest.json` — the config *fingerprint* (bits, rounder, transform,
//!   seeds, calibration shape, model shape hash). Resume refuses when any
//!   field differs: replaying blocks quantized under a different config
//!   would silently splice incompatible layers into one artifact.
//! * `journal.qzp` — append-only, length-prefixed records:
//!
//! ```text
//! record  := len u32 | crc u32 | payload (len bytes)     (crc = crc32(payload))
//! payload := block u32 | status u8 |
//!            ok(0):     n_layers u32 | { layer (.qz v3) | 5×f64 report } …
//!            failed(1): error string
//! ```
//!
//! The length prefix makes torn tails *detectable* and the CRC makes
//! corruption *distinguishable* from tearing: a record whose header or
//! payload runs past EOF can only be an interrupted append (truncation
//! cannot alter the already-written length), so it is dropped and the
//! file truncated back to the last whole record; a full-length record
//! with a bad CRC means bit rot, and resume refuses rather than rebuild
//! on damaged layers. Records are strictly sequential from block 0 — the
//! §6 quantized-prefix invariant means a gap is unrecoverable.

use crate::quant::packed::{QuantizedLayer, FORMAT_V3};
use crate::util::bytes::{Reader, Writer};
use crate::util::crc32::crc32;
use crate::util::fault::{FaultInjector, FaultMode};
use crate::util::json::Json;
use std::io::{Read, Seek, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

const MANIFEST: &str = "manifest.json";
const JOURNAL: &str = "journal.qzp";

/// The config fingerprint stored in `manifest.json`. Every field that
/// changes what bytes a block quantizes to is included; two sessions with
/// equal fingerprints produce bit-identical journals.
#[derive(Clone, Debug, PartialEq)]
pub struct Fingerprint {
    pub bits: u32,
    pub rounder: String,
    pub transform: String,
    pub incoherent: bool,
    pub stochastic: bool,
    pub greedy_passes: usize,
    pub alg5_c: f64,
    /// Pipeline seed, serialized as a hex string (JSON numbers are f64
    /// and cannot represent every u64 exactly).
    pub seed: u64,
    pub calib_seqs: usize,
    pub calib_seq_len: usize,
    pub model: String,
    /// CRC-32 of the model config JSON — catches shape mismatches even
    /// when two configs share a name.
    pub shape_hash: u32,
    /// Sharded-collection layout (DESIGN.md §11): the Hessian residency
    /// budget in bytes (0 = unlimited) and the across-layer worker count
    /// (0 = auto). Neither changes quantized bytes — the differential
    /// determinism suite pins that — but resume refuses a mismatch
    /// anyway: a session resumed under a different shard layout has
    /// different spill files and memory behavior than the journal's
    /// provenance claims, and the cheap, safe contract is "resume means
    /// the same run".
    pub hessian_mem_budget: u64,
    pub layer_workers: usize,
}

impl Fingerprint {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("bits", Json::Num(self.bits as f64));
        j.set("rounder", Json::Str(self.rounder.clone()));
        j.set("transform", Json::Str(self.transform.clone()));
        j.set("incoherent", Json::Bool(self.incoherent));
        j.set("stochastic", Json::Bool(self.stochastic));
        j.set("greedy_passes", Json::Num(self.greedy_passes as f64));
        j.set("alg5_c", Json::Num(self.alg5_c));
        j.set("seed", Json::Str(format!("{:016x}", self.seed)));
        j.set("calib_seqs", Json::Num(self.calib_seqs as f64));
        j.set("calib_seq_len", Json::Num(self.calib_seq_len as f64));
        j.set("model", Json::Str(self.model.clone()));
        j.set("shape_hash", Json::Str(format!("{:08x}", self.shape_hash)));
        j.set(
            "hessian_mem_budget",
            Json::Str(format!("{:016x}", self.hessian_mem_budget)),
        );
        j.set("layer_workers", Json::Num(self.layer_workers as f64));
        j
    }

    pub fn from_json(j: &Json) -> crate::Result<Fingerprint> {
        let hex_u64 = |key: &str| -> crate::Result<u64> {
            u64::from_str_radix(j.req_str(key)?, 16)
                .map_err(|e| anyhow::anyhow!("manifest field '{key}': {e}"))
        };
        let bool_of = |key: &str| -> crate::Result<bool> {
            j.req(key)?
                .as_bool()
                .ok_or_else(|| anyhow::anyhow!("manifest field '{key}' is not a bool"))
        };
        Ok(Fingerprint {
            bits: j.req_f64("bits")? as u32,
            rounder: j.req_str("rounder")?.to_string(),
            transform: j.req_str("transform")?.to_string(),
            incoherent: bool_of("incoherent")?,
            stochastic: bool_of("stochastic")?,
            greedy_passes: j.req_usize("greedy_passes")?,
            alg5_c: j.req_f64("alg5_c")?,
            seed: hex_u64("seed")?,
            calib_seqs: j.req_usize("calib_seqs")?,
            calib_seq_len: j.req_usize("calib_seq_len")?,
            model: j.req_str("model")?.to_string(),
            shape_hash: hex_u64("shape_hash")? as u32,
            // Absent in pre-§11 manifests; those were collected with the
            // unlimited in-memory layout, which the defaults name.
            hessian_mem_budget: match j.get("hessian_mem_budget") {
                Some(_) => hex_u64("hessian_mem_budget")?,
                None => 0,
            },
            layer_workers: match j.get("layer_workers") {
                Some(_) => j.req_usize("layer_workers")?,
                None => 0,
            },
        })
    }

    /// Names of the fields where `self` (the session) differs from
    /// `stored` (the manifest). Empty means resumable.
    pub fn diff(&self, stored: &Fingerprint) -> Vec<&'static str> {
        let mut d = Vec::new();
        if self.bits != stored.bits {
            d.push("bits");
        }
        if self.rounder != stored.rounder {
            d.push("rounder");
        }
        if self.transform != stored.transform {
            d.push("transform");
        }
        if self.incoherent != stored.incoherent {
            d.push("incoherent");
        }
        if self.stochastic != stored.stochastic {
            d.push("stochastic");
        }
        if self.greedy_passes != stored.greedy_passes {
            d.push("greedy_passes");
        }
        if self.alg5_c != stored.alg5_c {
            d.push("alg5_c");
        }
        if self.seed != stored.seed {
            d.push("seed");
        }
        if self.calib_seqs != stored.calib_seqs {
            d.push("calib_seqs");
        }
        if self.calib_seq_len != stored.calib_seq_len {
            d.push("calib_seq_len");
        }
        if self.model != stored.model {
            d.push("model");
        }
        if self.shape_hash != stored.shape_hash {
            d.push("shape_hash");
        }
        if self.hessian_mem_budget != stored.hessian_mem_budget {
            d.push("hessian_mem_budget");
        }
        if self.layer_workers != stored.layer_workers {
            d.push("layer_workers");
        }
        d
    }
}

/// One layer inside a completed-block record: the artifact layer plus the
/// numbers its [`LayerReport`](super::pipeline::LayerReport) carries, so a
/// resumed session's final report covers replayed blocks too.
#[derive(Clone)]
pub struct LayerRecord {
    pub layer: QuantizedLayer,
    pub proxy_loss: f64,
    pub seconds: f64,
    pub accumulate_seconds: f64,
    pub factorize_seconds: f64,
    pub round_seconds: f64,
}

/// One journal record: block `b` either completed with its quantized
/// layers, or failed (worker panic / unusable Hessians after the retry)
/// and was skipped by the degrading session.
#[derive(Clone)]
pub enum BlockRecord {
    Completed {
        block: usize,
        layers: Vec<LayerRecord>,
    },
    Failed {
        block: usize,
        error: String,
    },
}

impl BlockRecord {
    pub fn block(&self) -> usize {
        match self {
            BlockRecord::Completed { block, .. } | BlockRecord::Failed { block, .. } => *block,
        }
    }

    fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            BlockRecord::Completed { block, layers } => {
                w.u32(*block as u32);
                w.u8(0);
                w.u32(layers.len() as u32);
                for l in layers {
                    l.layer.serialize(&mut w);
                    w.f64(l.proxy_loss);
                    w.f64(l.seconds);
                    w.f64(l.accumulate_seconds);
                    w.f64(l.factorize_seconds);
                    w.f64(l.round_seconds);
                }
            }
            BlockRecord::Failed { block, error } => {
                w.u32(*block as u32);
                w.u8(1);
                w.string(error);
            }
        }
        w.buf
    }

    fn decode(payload: &[u8]) -> crate::Result<BlockRecord> {
        let mut r = Reader::new(payload);
        let block = r.u32()? as usize;
        let rec = match r.u8()? {
            0 => {
                let n = r.u32()? as usize;
                let mut layers = Vec::with_capacity(n);
                for i in 0..n {
                    let layer = QuantizedLayer::deserialize(&mut r, FORMAT_V3)
                        .map_err(|e| anyhow::anyhow!("journal block {block} layer {i}: {e}"))?;
                    layers.push(LayerRecord {
                        layer,
                        proxy_loss: r.f64()?,
                        seconds: r.f64()?,
                        accumulate_seconds: r.f64()?,
                        factorize_seconds: r.f64()?,
                        round_seconds: r.f64()?,
                    });
                }
                BlockRecord::Completed { block, layers }
            }
            1 => BlockRecord::Failed {
                block,
                error: r.string()?,
            },
            other => anyhow::bail!("journal block {block}: unknown status byte {other}"),
        };
        anyhow::ensure!(
            r.remaining() == 0,
            "journal block {block}: {} trailing bytes",
            r.remaining()
        );
        Ok(rec)
    }
}

/// Append handle on a checkpoint directory's `journal.qzp` + the manifest
/// beside it. Created fresh by
/// [`QuantSession::with_checkpoint_dir`](super::pipeline::QuantSession::with_checkpoint_dir),
/// reopened (with replay) by
/// [`QuantSession::resume`](super::pipeline::QuantSession::resume).
pub struct CheckpointJournal {
    dir: PathBuf,
    file: std::fs::File,
    faults: Option<Arc<FaultInjector>>,
}

impl CheckpointJournal {
    /// Start a fresh journal: write the manifest (atomically) and
    /// truncate any prior journal — a new session owns the directory.
    pub fn create(
        dir: &Path,
        fp: &Fingerprint,
        faults: Option<Arc<FaultInjector>>,
    ) -> crate::Result<CheckpointJournal> {
        std::fs::create_dir_all(dir)?;
        crate::util::fsx::atomic_write(&dir.join(MANIFEST), fp.to_json().pretty().as_bytes())?;
        let file = std::fs::OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(dir.join(JOURNAL))?;
        Ok(CheckpointJournal {
            dir: dir.to_path_buf(),
            file,
            faults,
        })
    }

    /// Reopen an existing checkpoint directory: verify the fingerprint,
    /// replay every whole record, drop a torn tail (truncating the file
    /// back to the last whole record so the next append starts clean),
    /// and refuse on CRC failure or a non-sequential block order.
    pub fn open(
        dir: &Path,
        expected: &Fingerprint,
        faults: Option<Arc<FaultInjector>>,
    ) -> crate::Result<(CheckpointJournal, Vec<BlockRecord>)> {
        let manifest_path = dir.join(MANIFEST);
        let raw = std::fs::read_to_string(&manifest_path)
            .map_err(|e| anyhow::anyhow!("no resumable session at {dir:?}: {e}"))?;
        let stored = Fingerprint::from_json(&Json::parse(&raw)?)
            .map_err(|e| anyhow::anyhow!("manifest {manifest_path:?}: {e}"))?;
        let diff = expected.diff(&stored);
        anyhow::ensure!(
            diff.is_empty(),
            "refusing to resume {dir:?}: config fingerprint differs on {} \
             (session vs manifest); blocks quantized under the stored config \
             cannot be spliced into this session's artifact",
            diff.join(", ")
        );

        let journal_path = dir.join(JOURNAL);
        let mut file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .open(&journal_path)
            .map_err(|e| anyhow::anyhow!("opening journal {journal_path:?}: {e}"))?;
        let mut buf = Vec::new();
        file.read_to_end(&mut buf)?;

        let mut records = Vec::new();
        let mut pos = 0usize;
        while pos < buf.len() {
            // An incomplete header or payload can only be a torn append
            // (the length prefix was written before the bytes it counts);
            // drop the tail and stop. A whole record with a CRC mismatch
            // is corruption, not tearing — refuse.
            if buf.len() - pos < 8 {
                break;
            }
            let len =
                u32::from_le_bytes([buf[pos], buf[pos + 1], buf[pos + 2], buf[pos + 3]]) as usize;
            let stored_crc =
                u32::from_le_bytes([buf[pos + 4], buf[pos + 5], buf[pos + 6], buf[pos + 7]]);
            if buf.len() - pos - 8 < len {
                break;
            }
            let payload = &buf[pos + 8..pos + 8 + len];
            let actual = crc32(payload);
            anyhow::ensure!(
                stored_crc == actual,
                "corrupt journal {journal_path:?}: record {} CRC mismatch \
                 (stored {stored_crc:08x}, computed {actual:08x}) — refusing to resume \
                 on damaged layers",
                records.len()
            );
            let rec = BlockRecord::decode(payload)?;
            anyhow::ensure!(
                rec.block() == records.len(),
                "journal {journal_path:?}: record {} covers block {} — blocks must be \
                 sequential from 0",
                records.len(),
                rec.block()
            );
            records.push(rec);
            pos += 8 + len;
        }
        if pos < buf.len() {
            crate::log_warn!(
                "journal {journal_path:?}: dropping {} torn trailing bytes \
                 (interrupted append)",
                buf.len() - pos
            );
            file.set_len(pos as u64)?;
            file.sync_all()?;
        }
        file.seek(std::io::SeekFrom::Start(pos as u64))?;
        Ok((
            CheckpointJournal {
                dir: dir.to_path_buf(),
                file,
                faults,
            },
            records,
        ))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Append one block record and fsync it durable. The
    /// `checkpoint.append` fault point fires here: `torn` persists only a
    /// seeded prefix of the record before dying, reproducing a power cut
    /// mid-append.
    pub fn append(&mut self, rec: &BlockRecord) -> crate::Result<()> {
        let payload = rec.encode();
        let mut bytes = Vec::with_capacity(8 + payload.len());
        bytes.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        bytes.extend_from_slice(&crc32(&payload).to_le_bytes());
        bytes.extend_from_slice(&payload);
        if let Some(f) = &self.faults {
            match f.check("checkpoint.append") {
                Some(FaultMode::Torn) => {
                    let keep = f.torn_len("checkpoint.append", bytes.len());
                    self.file.write_all(&bytes[..keep])?;
                    self.file.sync_data()?;
                    return f.die("checkpoint.append", FaultMode::Torn);
                }
                // preflight: allow(panic, "the panic fault mode exists to panic on purpose")
                Some(FaultMode::Panic) => panic!("fault injected: checkpoint.append (panic)"),
                Some(mode) => return f.die("checkpoint.append", mode),
                None => {}
            }
        }
        self.file.write_all(&bytes)?;
        self.file.sync_data()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::quant::{incoherence, Processing};

    fn test_fp() -> Fingerprint {
        Fingerprint {
            bits: 2,
            rounder: "ldlq".into(),
            transform: "kron".into(),
            incoherent: true,
            stochastic: false,
            greedy_passes: 2,
            alg5_c: 0.3,
            seed: 0xDEAD_BEEF_0BAD_F00D,
            calib_seqs: 4,
            calib_seq_len: 24,
            model: "t".into(),
            shape_hash: 0x1234_ABCD,
            hessian_mem_budget: 1 << 20,
            layer_workers: 3,
        }
    }

    fn test_layer(seed: u64) -> QuantizedLayer {
        // A real preprocess → round → postprocess cycle so PostState
        // carries honest transform seeds/scales.
        let mut rng = crate::util::rng::Rng::new(seed);
        let w = crate::util::testkit::random_mat(&mut rng, 6, 8).scale(0.2);
        let h = crate::util::testkit::random_hessian(&mut rng, 8, 4, 1e-2);
        let pre = incoherence::preprocess(&w, &h, 2, &Processing::incoherent(), seed);
        let codes = Mat::from_fn(6, 8, |i, j| ((i * 8 + j + seed as usize) % 4) as f64);
        QuantizedLayer::from_codes(&format!("blk0.l{seed}"), &codes, 2, pre.post)
    }

    fn completed(block: usize, n: usize) -> BlockRecord {
        BlockRecord::Completed {
            block,
            layers: (0..n)
                .map(|i| LayerRecord {
                    layer: test_layer((block * 10 + i) as u64),
                    proxy_loss: 0.25 + i as f64,
                    seconds: 0.5,
                    accumulate_seconds: 0.1,
                    factorize_seconds: 0.2,
                    round_seconds: 0.3,
                })
                .collect(),
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("quip_qzp_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn fingerprint_roundtrips_and_diffs() {
        let fp = test_fp();
        let back = Fingerprint::from_json(&Json::parse(&fp.to_json().pretty()).unwrap()).unwrap();
        assert_eq!(fp, back);
        assert!(fp.diff(&back).is_empty());
        let mut other = fp.clone();
        other.bits = 4;
        other.seed ^= 1;
        assert_eq!(fp.diff(&other), vec!["bits", "seed"]);
        // Shard-layout fields participate in diff and name themselves.
        let mut other = fp.clone();
        other.hessian_mem_budget = 0;
        other.layer_workers = 8;
        assert_eq!(fp.diff(&other), vec!["hessian_mem_budget", "layer_workers"]);
    }

    #[test]
    fn manifest_without_shard_fields_defaults_to_unlimited() {
        // Pre-§11 manifests (no shard-layout fields) parse as the
        // unlimited in-memory layout, so old checkpoints resume under a
        // default-config session and refuse under a budgeted one.
        let j = test_fp().to_json();
        let mut legacy = Json::obj();
        for key in [
            "bits",
            "rounder",
            "transform",
            "incoherent",
            "stochastic",
            "greedy_passes",
            "alg5_c",
            "seed",
            "calib_seqs",
            "calib_seq_len",
            "model",
            "shape_hash",
        ] {
            legacy.set(key, j.get(key).unwrap().clone());
        }
        let fp = Fingerprint::from_json(&legacy).unwrap();
        assert_eq!(fp.hessian_mem_budget, 0);
        assert_eq!(fp.layer_workers, 0);
    }

    #[test]
    fn append_and_replay_roundtrip() {
        let dir = tmpdir("roundtrip");
        let fp = test_fp();
        let mut j = CheckpointJournal::create(&dir, &fp, None).unwrap();
        j.append(&completed(0, 2)).unwrap();
        j.append(&BlockRecord::Failed {
            block: 1,
            error: "worker panic: boom".into(),
        })
        .unwrap();
        j.append(&completed(2, 1)).unwrap();
        drop(j);
        let (_, records) = CheckpointJournal::open(&dir, &fp, None).unwrap();
        assert_eq!(records.len(), 3);
        match &records[0] {
            BlockRecord::Completed { block: 0, layers } => {
                assert_eq!(layers.len(), 2);
                assert_eq!(layers[0].layer.name, "blk0.l0");
                assert_eq!(layers[0].proxy_loss, 0.25);
                assert_eq!(layers[1].round_seconds, 0.3);
                // Dequantization is bit-identical through the journal.
                let orig = match completed(0, 2) {
                    BlockRecord::Completed { layers, .. } => layers,
                    _ => unreachable!(),
                };
                assert_eq!(
                    layers[0].layer.dequantize().data,
                    orig[0].layer.dequantize().data
                );
            }
            _ => panic!("record 0 is not Completed(block 0)"),
        }
        match &records[1] {
            BlockRecord::Failed { block: 1, error } => {
                assert!(error.contains("boom"));
            }
            _ => panic!("record 1 is not Failed(block 1)"),
        }
    }

    #[test]
    fn torn_tail_dropped_at_every_byte() {
        // Truncating the journal anywhere inside the *last* record — any
        // header byte, any payload byte — must replay the first record
        // and drop the tail, never error. This is the on-disk state a
        // power cut leaves at every possible instant of an append.
        let dir = tmpdir("torn");
        let fp = test_fp();
        let mut j = CheckpointJournal::create(&dir, &fp, None).unwrap();
        j.append(&completed(0, 1)).unwrap();
        let whole_first = std::fs::metadata(dir.join(JOURNAL)).unwrap().len() as usize;
        j.append(&completed(1, 1)).unwrap();
        drop(j);
        let full = std::fs::read(dir.join(JOURNAL)).unwrap();
        for cut in whole_first..full.len() {
            let d2 = tmpdir("torn_cut");
            crate::util::fsx::atomic_write(&d2.join(MANIFEST), fp.to_json().pretty().as_bytes())
                .unwrap();
            std::fs::write(d2.join(JOURNAL), &full[..cut]).unwrap();
            let (_, records) = CheckpointJournal::open(&d2, &fp, None)
                .unwrap_or_else(|e| panic!("cut at {cut}/{}: {e}", full.len()));
            assert_eq!(records.len(), 1, "cut at {cut}: tail must drop");
            // The torn tail is physically gone: the next append resumes
            // from a whole-record boundary.
            assert_eq!(
                std::fs::metadata(d2.join(JOURNAL)).unwrap().len() as usize,
                whole_first
            );
        }
    }

    #[test]
    fn crc_corruption_refuses_resume() {
        let dir = tmpdir("crc");
        let fp = test_fp();
        let mut j = CheckpointJournal::create(&dir, &fp, None).unwrap();
        j.append(&completed(0, 1)).unwrap();
        drop(j);
        let mut bytes = std::fs::read(dir.join(JOURNAL)).unwrap();
        let mid = 8 + (bytes.len() - 8) / 2;
        bytes[mid] ^= 0x20;
        std::fs::write(dir.join(JOURNAL), &bytes).unwrap();
        let err = CheckpointJournal::open(&dir, &fp, None).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
    }

    #[test]
    fn fingerprint_mismatch_names_fields() {
        let dir = tmpdir("fpmismatch");
        let fp = test_fp();
        drop(CheckpointJournal::create(&dir, &fp, None).unwrap());
        let mut other = fp.clone();
        other.rounder = "vq".into();
        let err = CheckpointJournal::open(&dir, &other, None).unwrap_err().to_string();
        assert!(err.contains("rounder"), "{err}");
        assert!(err.contains("refusing to resume"), "{err}");
    }

    #[test]
    fn non_sequential_journal_refused() {
        let dir = tmpdir("gap");
        let fp = test_fp();
        let mut j = CheckpointJournal::create(&dir, &fp, None).unwrap();
        j.append(&completed(1, 1)).unwrap(); // starts at 1, not 0
        drop(j);
        let err = CheckpointJournal::open(&dir, &fp, None).unwrap_err().to_string();
        assert!(err.contains("sequential"), "{err}");
    }

    #[test]
    fn create_truncates_stale_journal() {
        let dir = tmpdir("truncate");
        let fp = test_fp();
        let mut j = CheckpointJournal::create(&dir, &fp, None).unwrap();
        j.append(&completed(0, 1)).unwrap();
        drop(j);
        drop(CheckpointJournal::create(&dir, &fp, None).unwrap());
        let (_, records) = CheckpointJournal::open(&dir, &fp, None).unwrap();
        assert!(records.is_empty(), "fresh create must own the directory");
    }

    #[test]
    fn torn_fault_point_tears_the_append() {
        use crate::util::fault::FaultSpec;
        let dir = tmpdir("fault_torn");
        let fp = test_fp();
        let faults = Arc::new(FaultInjector::new(
            vec![FaultSpec::parse("checkpoint.append@2:torn").unwrap()],
            true,
            99,
        ));
        let mut j = CheckpointJournal::create(&dir, &fp, Some(Arc::clone(&faults))).unwrap();
        j.append(&completed(0, 1)).unwrap();
        let whole_first = std::fs::metadata(dir.join(JOURNAL)).unwrap().len();
        let err = j.append(&completed(1, 1)).unwrap_err().to_string();
        assert!(err.contains("fault injected"), "{err}");
        drop(j);
        let torn_len = std::fs::metadata(dir.join(JOURNAL)).unwrap().len();
        assert!(torn_len >= whole_first, "first record untouched");
        // The torn directory resumes cleanly with exactly block 0.
        let (_, records) = CheckpointJournal::open(&dir, &fp, None).unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(
            std::fs::metadata(dir.join(JOURNAL)).unwrap().len(),
            whole_first
        );
    }
}
