//! Dynamic request batcher: requests queue up; a batch is released when
//! either `max_batch` requests are waiting or the oldest has waited
//! `max_wait`. Bounded queue provides backpressure (enqueue fails when
//! full). The serving loop drains batches onto the worker pool.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// A queued request (generic payload).
pub struct Pending<T> {
    pub id: u64,
    pub payload: T,
    pub enqueued: Instant,
}

struct Inner<T> {
    queue: VecDeque<Pending<T>>,
    closed: bool,
}

pub struct Batcher<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub capacity: usize,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize, max_wait: Duration, capacity: usize) -> Batcher<T> {
        Batcher {
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
            max_batch: max_batch.max(1),
            max_wait,
            capacity: capacity.max(1),
        }
    }

    /// Enqueue a request; `Err` = queue full (backpressure) or closed.
    pub fn push(&self, id: u64, payload: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.queue.len() >= self.capacity {
            return Err(payload);
        }
        g.queue.push_back(Pending {
            id,
            payload,
            enqueued: Instant::now(),
        });
        self.cv.notify_all();
        Ok(())
    }

    /// Block until a batch is ready (≥1 requests, released by size or
    /// timeout policy). Returns None when closed and drained.
    pub fn next_batch(&self) -> Option<Vec<Pending<T>>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.queue.len() >= self.max_batch {
                return Some(drain(&mut g.queue, self.max_batch));
            }
            if let Some(front) = g.queue.front() {
                let waited = front.enqueued.elapsed();
                if waited >= self.max_wait {
                    let n = g.queue.len().min(self.max_batch);
                    return Some(drain(&mut g.queue, n));
                }
                let remaining = self.max_wait - waited;
                let (g2, _timeout) = self.cv.wait_timeout(g, remaining).unwrap();
                g = g2;
            } else {
                if g.closed {
                    return None;
                }
                let (g2, _t) = self
                    .cv
                    .wait_timeout(g, Duration::from_millis(50))
                    .unwrap();
                g = g2;
            }
        }
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }
}

fn drain<T>(q: &mut VecDeque<Pending<T>>, n: usize) -> Vec<Pending<T>> {
    q.drain(..n).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn batches_by_size() {
        let b = Batcher::new(4, Duration::from_secs(10), 100);
        for i in 0..4 {
            b.push(i, i).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.iter().map(|p| p.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn batches_by_timeout() {
        let b = Batcher::new(100, Duration::from_millis(30), 100);
        b.push(1, "x").unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn backpressure_when_full() {
        let b = Batcher::new(4, Duration::from_secs(1), 2);
        b.push(1, 1).unwrap();
        b.push(2, 2).unwrap();
        assert!(b.push(3, 3).is_err());
        assert_eq!(b.depth(), 2);
    }

    #[test]
    fn close_unblocks_consumer() {
        let b = Arc::new(Batcher::<u32>::new(4, Duration::from_secs(10), 10));
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap().is_none());
        assert!(b.push(1, 1).is_err());
    }

    #[test]
    fn no_loss_no_duplication_under_concurrency() {
        // Property: every pushed id appears in exactly one batch.
        let b = Arc::new(Batcher::new(8, Duration::from_millis(5), 10_000));
        let n = 500u64;
        let producer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                for i in 0..n {
                    while b.push(i, i).is_err() {
                        std::thread::yield_now();
                    }
                }
                b.close();
            })
        };
        let mut seen = std::collections::HashSet::new();
        while let Some(batch) = b.next_batch() {
            for p in batch {
                assert!(seen.insert(p.id), "duplicate id {}", p.id);
            }
        }
        producer.join().unwrap();
        assert_eq!(seen.len(), n as usize);
    }
}
