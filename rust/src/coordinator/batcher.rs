//! Dynamic request batcher: requests queue up; a batch is released when
//! either `max_batch` requests are waiting or the oldest has waited
//! `max_wait` (a hard latency bound — see `next_batch`). Bounded queue
//! provides backpressure (enqueue fails when full). The serving
//! scheduler parks on `next_batch` while idle and tops up its running
//! batch with the non-blocking `poll` at token boundaries.

use crate::util::sync::{lock_unpoisoned, wait_timeout_unpoisoned};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// A queued request (generic payload).
pub struct Pending<T> {
    pub id: u64,
    pub payload: T,
    pub enqueued: Instant,
}

struct Inner<T> {
    queue: VecDeque<Pending<T>>,
    closed: bool,
}

pub struct Batcher<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub capacity: usize,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize, max_wait: Duration, capacity: usize) -> Batcher<T> {
        Batcher {
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
            max_batch: max_batch.max(1),
            max_wait,
            capacity: capacity.max(1),
        }
    }

    /// Enqueue a request; `Err` = queue full (backpressure) or closed.
    pub fn push(&self, id: u64, payload: T) -> Result<(), T> {
        let mut g = lock_unpoisoned(&self.inner);
        if g.closed || g.queue.len() >= self.capacity {
            return Err(payload);
        }
        g.queue.push_back(Pending {
            id,
            payload,
            enqueued: Instant::now(),
        });
        self.cv.notify_all();
        Ok(())
    }

    /// Block until a batch is ready (≥1 requests, released by size or
    /// timeout policy). Returns None when closed and drained.
    ///
    /// Latency bound: a non-empty queue is *always* flushed once its
    /// oldest request has waited `max_wait`, even when far below
    /// `max_batch` — no request waits unboundedly for a full batch — and
    /// `close` flushes whatever is queued immediately.
    pub fn next_batch(&self) -> Option<Vec<Pending<T>>> {
        let mut g = lock_unpoisoned(&self.inner);
        loop {
            // Closing flushes the partial batch at once: shutdown must not
            // sit out the remainder of `max_wait`.
            if g.closed {
                if g.queue.is_empty() {
                    return None;
                }
                let n = g.queue.len().min(self.max_batch);
                return Some(drain(&mut g.queue, n));
            }
            if g.queue.len() >= self.max_batch {
                return Some(drain(&mut g.queue, self.max_batch));
            }
            if let Some(front) = g.queue.front() {
                let waited = front.enqueued.elapsed();
                if waited >= self.max_wait {
                    let n = g.queue.len().min(self.max_batch);
                    return Some(drain(&mut g.queue, n));
                }
                let remaining = self.max_wait - waited;
                g = wait_timeout_unpoisoned(&self.cv, g, remaining);
            } else {
                g = wait_timeout_unpoisoned(&self.cv, g, Duration::from_millis(50));
            }
        }
    }

    /// Non-blocking drain of up to `max_n` queued requests, bypassing the
    /// size/timeout release policy. Continuous-batching admission: a
    /// running decode loop tops up its batch at every token boundary
    /// without ever parking on the queue.
    pub fn poll(&self, max_n: usize) -> Vec<Pending<T>> {
        let mut g = lock_unpoisoned(&self.inner);
        let n = g.queue.len().min(max_n);
        drain(&mut g.queue, n)
    }

    pub fn close(&self) {
        lock_unpoisoned(&self.inner).closed = true;
        self.cv.notify_all();
    }

    pub fn depth(&self) -> usize {
        lock_unpoisoned(&self.inner).queue.len()
    }
}

fn drain<T>(q: &mut VecDeque<Pending<T>>, n: usize) -> Vec<Pending<T>> {
    q.drain(..n).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn batches_by_size() {
        let b = Batcher::new(4, Duration::from_secs(10), 100);
        for i in 0..4 {
            b.push(i, i).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.iter().map(|p| p.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn batches_by_timeout() {
        let b = Batcher::new(100, Duration::from_millis(30), 100);
        b.push(1, "x").unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn backpressure_when_full() {
        let b = Batcher::new(4, Duration::from_secs(1), 2);
        b.push(1, 1).unwrap();
        b.push(2, 2).unwrap();
        assert!(b.push(3, 3).is_err());
        assert_eq!(b.depth(), 2);
    }

    #[test]
    fn partial_batch_flushes_at_max_wait_latency_bound() {
        // Regression for the latency audit: a queue stuck far below
        // max_batch must flush once max_wait elapses. Pin both sides of
        // the bound: released no earlier than max_wait, and well before
        // any multiple of it (generous upper slack for CI jitter).
        let max_wait = Duration::from_millis(40);
        let b = Batcher::new(64, max_wait, 100);
        b.push(1, "only").unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        let waited = t0.elapsed();
        assert_eq!(batch.len(), 1);
        assert!(waited >= Duration::from_millis(35), "released early: {waited:?}");
        assert!(
            waited < Duration::from_millis(2000),
            "latency bound violated: {waited:?}"
        );
        // A second request arriving mid-wait rides the same flush.
        b.push(2, "a").unwrap();
        std::thread::sleep(Duration::from_millis(10));
        b.push(3, "b").unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2, "sub-max_batch queue flushed together");
    }

    #[test]
    fn close_flushes_waiting_partial_batch_immediately() {
        // Shutdown must not sit out max_wait: closing releases the
        // partial batch at once.
        let b = Arc::new(Batcher::new(64, Duration::from_secs(30), 100));
        b.push(1, 1).unwrap();
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || {
            let t0 = Instant::now();
            let batch = b2.next_batch();
            (batch, t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(30));
        b.close();
        let (batch, waited) = h.join().unwrap();
        assert_eq!(batch.unwrap().len(), 1);
        assert!(waited < Duration::from_secs(5), "close did not flush: {waited:?}");
        // Drained and closed → None.
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn poll_is_nonblocking_and_caps() {
        let b = Batcher::new(4, Duration::from_secs(30), 100);
        assert!(b.poll(8).is_empty());
        for i in 0..5 {
            b.push(i, i).unwrap();
        }
        let got = b.poll(3);
        assert_eq!(got.iter().map(|p| p.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.depth(), 2);
        assert_eq!(b.poll(8).len(), 2);
    }

    #[test]
    fn close_unblocks_consumer() {
        let b = Arc::new(Batcher::<u32>::new(4, Duration::from_secs(10), 10));
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap().is_none());
        assert!(b.push(1, 1).is_err());
    }

    #[test]
    fn no_loss_no_duplication_under_concurrency() {
        // Property: every pushed id appears in exactly one batch.
        let b = Arc::new(Batcher::new(8, Duration::from_millis(5), 10_000));
        let n = 500u64;
        let producer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                for i in 0..n {
                    while b.push(i, i).is_err() {
                        std::thread::yield_now();
                    }
                }
                b.close();
            })
        };
        let mut seen = std::collections::HashSet::new();
        while let Some(batch) = b.next_batch() {
            for p in batch {
                assert!(seen.insert(p.id), "duplicate id {}", p.id);
            }
        }
        producer.join().unwrap();
        assert_eq!(seen.len(), n as usize);
    }
}
