//! Thread-local stage-timing façade for the quantization hot path.
//!
//! The factorization entry points (`linalg::ldl::ldl_lower`,
//! `linalg::chol::cholesky`) credit their wall-clock here, and
//! `quant::quantize_layer_with` drains the ledger around the rounder call
//! to split "factorize" time from "round" time without widening the
//! object-safe `Rounder` trait. A thread-local works because layers
//! quantize one-per-worker-thread (`coordinator::pipeline`) and the
//! factorization itself always runs on the thread that called `round` —
//! only the per-row rounding fans out. See EXPERIMENTS.md §Perf 4 for the
//! stage breakdown this feeds.
//!
//! Since the observability layer landed (DESIGN.md §9) the storage lives
//! in [`crate::obs::trace`]'s named stage ledger — the same mechanism
//! the batched decode kernels use to credit GEMM time to serve spans —
//! and this module keeps its original public API as a thin façade over
//! the `"factorize"` stage.

/// Ledger key for factorization wall-clock in the obs stage ledger.
pub const FACTORIZE_STAGE: &str = "factorize";

/// Credit `seconds` of factorization work to the current thread's ledger.
pub fn credit_factorize(seconds: f64) {
    crate::obs::trace::credit_stage(FACTORIZE_STAGE, seconds);
}

/// Drain the current thread's factorization ledger, returning the total
/// credited since the last drain (0.0 when nothing was credited).
pub fn take_factorize() -> f64 {
    crate::obs::trace::take_stage(FACTORIZE_STAGE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn credits_accumulate_and_drain() {
        let _ = take_factorize(); // clear residue from other tests on this thread
        credit_factorize(0.25);
        credit_factorize(0.5);
        assert!((take_factorize() - 0.75).abs() < 1e-12);
        assert_eq!(take_factorize(), 0.0);
    }

    #[test]
    fn ledger_is_per_thread() {
        let _ = take_factorize();
        credit_factorize(1.0);
        let other = std::thread::spawn(take_factorize).join().unwrap();
        assert_eq!(other, 0.0, "fresh thread starts at zero");
        assert!((take_factorize() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn facade_shares_the_obs_stage_ledger() {
        let _ = take_factorize();
        crate::obs::trace::credit_stage(FACTORIZE_STAGE, 0.125);
        assert!((take_factorize() - 0.125).abs() < 1e-12);
    }
}
