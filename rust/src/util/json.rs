//! Minimal JSON parser/serializer (the offline toolchain has no `serde`).
//!
//! Supports the full JSON grammar minus exotic number forms; preserves
//! object insertion order (needed for stable manifests and result files).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/overwrite a key on an object (panics on non-objects).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(map) => {
                if let Some(slot) = map.iter_mut().find(|(k, _)| k == key) {
                    slot.1 = value;
                } else {
                    map.push((key.to_string(), value));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: required field lookups with readable errors.
    pub fn req(&self, key: &str) -> crate::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON key '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> crate::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("JSON key '{key}' is not a string"))
    }

    pub fn req_f64(&self, key: &str) -> crate::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("JSON key '{key}' is not a number"))
    }

    pub fn req_usize(&self, key: &str) -> crate::Result<usize> {
        Ok(self.req_f64(key)? as usize)
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> crate::Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            anyhow::bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    // JSON has no Inf/NaN; emit null (documented lossy case).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> crate::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected '{}' at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> crate::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> crate::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn string(&mut self) -> crate::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let Some(c) = self.peek() else {
                anyhow::bail!("unterminated string");
            };
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        anyhow::bail!("unterminated escape");
                    };
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            // Surrogate pairs: join if a low surrogate follows.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 2..self.pos + 6)
                                        .ok_or_else(|| anyhow::anyhow!("bad surrogate"))?;
                                    let low =
                                        u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                    self.pos += 6;
                                    0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                                } else {
                                    0xFFFD
                                }
                            } else {
                                code
                            };
                            s.push(char::from_u32(ch).unwrap_or('\u{FFFD}'));
                        }
                        _ => anyhow::bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences faithfully.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = utf8_width(c);
                        let end = start + width;
                        let chunk = self
                            .bytes
                            .get(start..end)
                            .ok_or_else(|| anyhow::anyhow!("truncated utf-8"))?;
                        s.push_str(std::str::from_utf8(chunk)?);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> crate::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }

    fn array(&mut self) -> crate::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => anyhow::bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> crate::Result<Json> {
        self.expect(b'{')?;
        let mut map = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => anyhow::bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }
}

fn utf8_width(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

/// Build a `Json::Arr` from f64 values.
pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

/// Build a `Json::Arr` from strings.
pub fn arr_str(xs: &[String]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
}

/// Read a BTreeMap<String, f64> out of a JSON object (sorted by key).
pub fn obj_to_map(j: &Json) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    if let Json::Obj(map) = j {
        for (k, v) in map {
            if let Some(x) = v.as_f64() {
                out.insert(k.clone(), x);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req_f64("a").unwrap(), 1.0);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().req_f64("d").unwrap(), -2500.0);
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn preserves_insertion_order() {
        let mut o = Json::obj();
        o.set("zeta", Json::Num(1.0));
        o.set("alpha", Json::Num(2.0));
        let s = o.to_string();
        assert!(s.find("zeta").unwrap() < s.find("alpha").unwrap());
    }

    #[test]
    fn parses_unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn pretty_is_reparseable() {
        let mut o = Json::obj();
        o.set("xs", arr_f64(&[1.0, 2.5, -3.0]));
        o.set("name", Json::Str("quip".into()));
        let v = Json::parse(&o.pretty()).unwrap();
        assert_eq!(v, o);
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
