//! Benchmark timing substrate (replacing `criterion` offline): warmup +
//! repeated measurement with robust summary statistics.

use std::time::Instant;

/// Summary statistics over a set of per-iteration timings (seconds).
#[derive(Debug, Clone)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Stats {
    pub fn from_samples(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| xs[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: xs[0],
            p50: pct(0.50),
            p95: pct(0.95),
            max: xs[n - 1],
        }
    }

    /// Human-readable one-liner, scaled to ns/µs/ms/s.
    pub fn human(&self) -> String {
        format!(
            "mean {} ± {}  (p50 {}, p95 {}, min {}, n={})",
            fmt_time(self.mean),
            fmt_time(self.std),
            fmt_time(self.p50),
            fmt_time(self.p95),
            fmt_time(self.min),
            self.n
        )
    }
}

/// Format seconds with an appropriate SI unit.
pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{:.3}s", secs)
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3}µs", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

/// Time one invocation of `f`, returning (seconds, result).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (f64, T) {
    let t0 = Instant::now();
    let out = f();
    (t0.elapsed().as_secs_f64(), out)
}

/// Benchmark `f`: `warmup` unmeasured runs, then `iters` measured runs.
/// A `std::hint::black_box` on the result prevents dead-code elimination.
pub fn bench<T, F: FnMut() -> T>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    Stats::from_samples(samples)
}

/// Benchmark with a time budget: run until `budget_secs` elapsed (at least
/// 3 iterations), after `warmup` runs. Used by `cargo bench` targets.
pub fn bench_budget<T, F: FnMut() -> T>(warmup: usize, budget_secs: f64, mut f: F) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < 3 || start.elapsed().as_secs_f64() < budget_secs {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() > 100_000 {
            break;
        }
    }
    Stats::from_samples(samples)
}

/// Print a bench line in a stable, grep-friendly format.
pub fn report(name: &str, stats: &Stats) {
    println!("bench  {:<44} {}", name, stats.human());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_constant() {
        let s = Stats::from_samples(vec![2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn stats_percentiles_ordered() {
        let s = Stats::from_samples((1..=100).map(|i| i as f64).collect());
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.max);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn bench_returns_requested_iters() {
        let s = bench(1, 5, || 1 + 1);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.5).ends_with('s'));
        assert!(fmt_time(2.5e-3).ends_with("ms"));
        assert!(fmt_time(2.5e-6).ends_with("µs"));
        assert!(fmt_time(2.5e-9).ends_with("ns"));
    }
}
