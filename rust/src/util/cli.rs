//! Tiny CLI argument parser (`clap` is unavailable offline).
//!
//! Grammar: `prog <subcommand> [positional...] [--key value] [--flag]`.

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: Vec<(String, String)>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.push((k.to_string(), v.to_string()));
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.push((name.to_string(), v));
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options
            .iter()
            .rev()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// A byte-size option accepting `k`/`m`/`g` suffixes (binary units,
    /// case-insensitive): `--hessian-mem-budget 512m`. A bare number is
    /// bytes; unparsable values fall back to the default, like the other
    /// typed accessors.
    pub fn opt_bytes(&self, name: &str, default: usize) -> usize {
        self.opt(name)
            .and_then(parse_bytes)
            .unwrap_or(default)
    }

    /// A boolean `--flag` (also accepts `--key true/false`).
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self
                .opt(name)
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false)
    }

    pub fn pos(&self, idx: usize) -> Option<&str> {
        self.positional.get(idx).map(|s| s.as_str())
    }
}

/// Parse `123`, `64k`, `512M`, `2g` (binary multipliers) into bytes.
fn parse_bytes(s: &str) -> Option<usize> {
    let s = s.trim();
    let (digits, mult) = match s.char_indices().last()? {
        (i, 'k') | (i, 'K') => (&s[..i], 1usize << 10),
        (i, 'm') | (i, 'M') => (&s[..i], 1usize << 20),
        (i, 'g') | (i, 'G') => (&s[..i], 1usize << 30),
        _ => (s, 1),
    };
    let n: usize = digits.parse().ok()?;
    n.checked_mul(mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn parses_mixed() {
        let a = parse("quantize model.bin --bits 2 --method ldlq --verbose --out=q.qz");
        assert_eq!(a.pos(0), Some("quantize"));
        assert_eq!(a.pos(1), Some("model.bin"));
        assert_eq!(a.opt_usize("bits", 4), 2);
        assert_eq!(a.opt("method"), Some("ldlq"));
        assert!(a.flag("verbose"));
        assert_eq!(a.opt("out"), Some("q.qz"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn last_option_wins() {
        let a = parse("x --bits 2 --bits 3");
        assert_eq!(a.opt_usize("bits", 0), 3);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("x --dry-run");
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse("x --alpha -0.5");
        // "-0.5" does not start with --, so it binds as the value.
        assert_eq!(a.opt_f64("alpha", 0.0), -0.5);
    }

    #[test]
    fn byte_sizes_parse_with_suffixes() {
        assert_eq!(parse_bytes("0"), Some(0));
        assert_eq!(parse_bytes("4096"), Some(4096));
        assert_eq!(parse_bytes("64k"), Some(64 << 10));
        assert_eq!(parse_bytes("512M"), Some(512 << 20));
        assert_eq!(parse_bytes("2g"), Some(2 << 30));
        assert_eq!(parse_bytes(""), None);
        assert_eq!(parse_bytes("k"), None);
        assert_eq!(parse_bytes("12q"), None);
        assert_eq!(parse_bytes("-5"), None);
        let a = parse("x --hessian-mem-budget 64k --layer-workers 3");
        assert_eq!(a.opt_bytes("hessian-mem-budget", 0), 64 << 10);
        assert_eq!(a.opt_bytes("missing", 7), 7);
        assert_eq!(a.opt_usize("layer-workers", 0), 3);
    }
}
