//! Threading substrates: a data-parallel `parallel_for` built on scoped
//! threads (replacing `rayon`), and a persistent `ThreadPool` used by the
//! serving coordinator.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Number of worker threads to use by default (bounded: quantization jobs
/// are memory-bandwidth heavy, more threads than cores only adds noise).
pub fn default_threads() -> usize {
    thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Run `f(i)` for every `i in 0..n`, work-stealing over `threads` scoped
/// workers via an atomic cursor. `f` must be `Sync` (called concurrently).
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Like `parallel_for` but chunked: `f(lo, hi)` over disjoint ranges.
/// Lower dispatch overhead when per-item work is tiny.
pub fn parallel_chunks<F>(n: usize, threads: usize, chunk: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let chunk = chunk.max(1);
    let n_chunks = n.div_ceil(chunk);
    parallel_for(n_chunks, threads, |c| {
        let lo = c * chunk;
        let hi = (lo + chunk).min(n);
        f(lo, hi);
    });
}

/// Map `f` over `0..n` in parallel, collecting results in order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<Mutex<&mut Option<T>>> = out.iter_mut().map(Mutex::new).collect();
        parallel_for(n, threads, |i| {
            let v = f(i);
            **slots[i].lock().unwrap() = Some(v);
        });
    }
    out.into_iter().map(|v| v.unwrap()).collect()
}

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// A persistent worker pool with a shared job queue. Used by the serving
/// coordinator for per-connection handlers and background jobs.
pub struct ThreadPool {
    tx: mpsc::Sender<Message>,
    workers: Vec<thread::JoinHandle<()>>,
    inflight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let inflight = Arc::clone(&inflight);
                thread::spawn(move || loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Message::Run(job)) => {
                            job();
                            inflight.fetch_sub(1, Ordering::SeqCst);
                        }
                        Ok(Message::Shutdown) | Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool {
            tx,
            workers,
            inflight,
        }
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inflight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .send(Message::Run(Box::new(f)))
            .expect("thread pool has shut down");
    }

    /// Number of queued-or-running jobs.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yielding) until all submitted jobs finished.
    pub fn wait_idle(&self) {
        while self.inflight() > 0 {
            thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Message::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(1000, 8, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_chunks_partitions() {
        let sum = AtomicU64::new(0);
        parallel_chunks(1003, 4, 17, |lo, hi| {
            let s: u64 = (lo as u64..hi as u64).sum();
            sum.fetch_add(s, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), (0..1003u64).sum::<u64>());
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..64 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn zero_items_is_noop() {
        parallel_for(0, 4, |_| panic!("should not run"));
    }
}
