//! Threading substrates: a data-parallel `parallel_for` built on scoped
//! threads (replacing `rayon`), and a persistent `ThreadPool` used by the
//! serving coordinator.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Instant;

/// Number of worker threads to use by default (bounded: quantization jobs
/// are memory-bandwidth heavy, more threads than cores only adds noise).
pub fn default_threads() -> usize {
    thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Run `f(i)` for every `i in 0..n`, work-stealing over `threads` scoped
/// workers via an atomic cursor. `f` must be `Sync` (called concurrently).
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Like `parallel_for` but chunked: `f(lo, hi)` over disjoint ranges.
/// Lower dispatch overhead when per-item work is tiny.
pub fn parallel_chunks<F>(n: usize, threads: usize, chunk: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let chunk = chunk.max(1);
    let n_chunks = n.div_ceil(chunk);
    parallel_for(n_chunks, threads, |c| {
        let lo = c * chunk;
        let hi = (lo + chunk).min(n);
        f(lo, hi);
    });
}

/// Map `f` over `0..n` in parallel, collecting results in order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<Mutex<&mut Option<T>>> = out.iter_mut().map(Mutex::new).collect();
        parallel_for(n, threads, |i| {
            let v = f(i);
            **slots[i].lock().unwrap() = Some(v);
        });
    }
    out.into_iter().map(|v| v.unwrap()).collect()
}

/// Where and when one work item of [`parallel_map_traced`] ran, for
/// bridging pool scheduling onto observability spans (queue time vs run
/// time, which worker lane). Timestamps are seconds since the dispatch
/// call's start, so they are directly comparable across items.
#[derive(Clone, Copy, Debug)]
pub struct ItemTiming {
    /// Index of the worker thread that claimed the item (0-based).
    pub worker: usize,
    /// Seconds from dispatch start until the item was claimed.
    pub start_seconds: f64,
    /// Seconds the item's closure ran.
    pub run_seconds: f64,
}

/// [`parallel_map`] plus per-item [`ItemTiming`]. Work-stealing over an
/// atomic cursor exactly like `parallel_for`, so which *worker* runs an
/// item is racy — but item order, and therefore any result the caller
/// derives from `f` alone, is not. Callers must treat the timings as
/// observability, never as inputs to deterministic outputs.
pub fn parallel_map_traced<T, F>(n: usize, threads: usize, f: F) -> Vec<(T, ItemTiming)>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<(T, ItemTiming)>> = (0..n).map(|_| None).collect();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    let t0 = Instant::now();
    let cursor = AtomicUsize::new(0);
    {
        let slots: Vec<Mutex<&mut Option<(T, ItemTiming)>>> =
            out.iter_mut().map(Mutex::new).collect();
        let worker = |w: usize| loop {
            let i = cursor.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            let start_seconds = t0.elapsed().as_secs_f64();
            let run = Instant::now();
            let v = f(i);
            let timing = ItemTiming {
                worker: w,
                start_seconds,
                run_seconds: run.elapsed().as_secs_f64(),
            };
            **slots[i].lock().unwrap() = Some((v, timing));
        };
        if threads == 1 {
            worker(0);
        } else {
            thread::scope(|s| {
                for w in 0..threads {
                    let worker = &worker;
                    s.spawn(move || worker(w));
                }
            });
        }
    }
    out.into_iter().map(|v| v.unwrap()).collect()
}

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// A persistent worker pool with a shared job queue. Used by the serving
/// coordinator for per-connection handlers and background jobs.
pub struct ThreadPool {
    tx: mpsc::Sender<Message>,
    workers: Vec<thread::JoinHandle<()>>,
    inflight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let inflight = Arc::clone(&inflight);
                thread::spawn(move || loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Message::Run(job)) => {
                            job();
                            inflight.fetch_sub(1, Ordering::SeqCst);
                        }
                        Ok(Message::Shutdown) | Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool {
            tx,
            workers,
            inflight,
        }
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inflight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .send(Message::Run(Box::new(f)))
            .expect("thread pool has shut down");
    }

    /// Number of queued-or-running jobs.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yielding) until all submitted jobs finished.
    pub fn wait_idle(&self) {
        while self.inflight() > 0 {
            thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Message::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(1000, 8, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_chunks_partitions() {
        let sum = AtomicU64::new(0);
        parallel_chunks(1003, 4, 17, |lo, hi| {
            let s: u64 = (lo as u64..hi as u64).sum();
            sum.fetch_add(s, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), (0..1003u64).sum::<u64>());
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_traced_matches_untraced_results() {
        // Same ordered results as parallel_map, any worker count; the
        // timing side-channel never perturbs the values.
        let want: Vec<usize> = (0..57).map(|i| i * 3 + 1).collect();
        for threads in [1usize, 3, 8] {
            let out = parallel_map_traced(57, threads, |i| i * 3 + 1);
            let vals: Vec<usize> = out.iter().map(|(v, _)| *v).collect();
            assert_eq!(vals, want, "threads={threads}");
            for (_, t) in &out {
                assert!(t.worker < threads.max(1));
                assert!(t.start_seconds >= 0.0);
                assert!(t.run_seconds >= 0.0);
            }
        }
    }

    #[test]
    fn parallel_map_traced_uses_multiple_workers() {
        // With more items than workers and non-trivial work, at least two
        // worker lanes claim items (work-stealing is real, not serial).
        let out = parallel_map_traced(64, 4, |i| {
            let mut x = i as u64;
            for _ in 0..50_000 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            }
            x
        });
        let mut workers: Vec<usize> = out.iter().map(|(_, t)| t.worker).collect();
        workers.sort_unstable();
        workers.dedup();
        assert!(workers.len() >= 2, "only workers {workers:?} ran");
    }

    #[test]
    fn parallel_map_traced_empty_and_single() {
        assert!(parallel_map_traced(0, 4, |i| i).is_empty());
        let out = parallel_map_traced(1, 4, |i| i + 10);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, 10);
        assert_eq!(out[0].1.worker, 0);
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..64 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn zero_items_is_noop() {
        parallel_for(0, 4, |_| panic!("should not run"));
    }
}
