//! General-purpose substrates built in-repo (the offline environment ships
//! no `rand`, `serde`, `clap`, `rayon` or `criterion`; these modules replace
//! exactly the slices of those crates the system needs).

pub mod bytes;
pub mod crc32;
pub mod fault;
pub mod fsx;
pub mod rng;
pub mod sync;
pub mod json;
pub mod cli;
pub mod threadpool;
pub mod timer;
pub mod stagetimer;
pub mod logging;
pub mod testkit;
