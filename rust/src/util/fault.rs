//! Deterministic fault injection for crash-safety testing.
//!
//! Production code declares named *fault points* — `pipeline.block_done`,
//! `checkpoint.append`, `pipeline.layer_round` — by calling
//! [`FaultInjector::hit`] at the moment the corresponding failure could
//! strike in the wild. A [`FaultInjector`] armed with specs like
//! `"pipeline.block_done@2"` counts hits per point and fires the
//! configured [`FaultMode`] on the n-th one, so a crash-resume test can
//! kill a quantization session at *every* block boundary, tear a journal
//! write at a seeded byte, or panic a worker mid-round — reproducibly,
//! from the same spec string the CLI accepts (`--inject-fault
//! point@n[:mode]`).
//!
//! Two delivery flavors (`soft` flag):
//!
//! * **hard** (CLI default): `Kill` calls `std::process::exit(137)` — a
//!   real SIGKILL stand-in; `Torn` truncates the in-flight write and then
//!   exits. What lands on disk is exactly what a power cut would leave.
//! * **soft** (in-process tests and sweeps): the same on-disk state is
//!   produced, but the fault surfaces as an `Err` so the calling test can
//!   drop the session and resume within one process.
//!
//! `Panic` mode always panics — the worker-pool isolation path catches it
//! regardless of flavor.

use std::collections::HashMap;
use std::sync::Mutex;

/// What happens when an armed fault point fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultMode {
    /// Process death at the point (soft: error return; hard: exit(137)).
    Kill,
    /// Torn write: the caller persists only a prefix of the record it was
    /// about to write, then dies as in `Kill`.
    Torn,
    /// Worker panic, for exercising pool failure isolation.
    Panic,
}

impl FaultMode {
    pub fn name(self) -> &'static str {
        match self {
            FaultMode::Kill => "kill",
            FaultMode::Torn => "torn",
            FaultMode::Panic => "panic",
        }
    }
}

/// One armed fault: fire `mode` on the `at`-th hit (1-indexed) of `point`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultSpec {
    pub point: String,
    pub at: u64,
    pub mode: FaultMode,
}

impl FaultSpec {
    /// Parse `point@n[:kill|torn|panic]` (mode defaults to `kill`).
    pub fn parse(s: &str) -> crate::Result<FaultSpec> {
        let (point, rest) = s
            .split_once('@')
            .ok_or_else(|| anyhow::anyhow!("fault spec '{s}': expected point@n[:mode]"))?;
        anyhow::ensure!(!point.is_empty(), "fault spec '{s}': empty point name");
        let (n, mode) = match rest.split_once(':') {
            Some((n, m)) => (n, m),
            None => (rest, "kill"),
        };
        let at: u64 = n
            .parse()
            .map_err(|_| anyhow::anyhow!("fault spec '{s}': bad hit count '{n}'"))?;
        anyhow::ensure!(at >= 1, "fault spec '{s}': hit count is 1-indexed");
        let mode = match mode {
            "kill" => FaultMode::Kill,
            "torn" => FaultMode::Torn,
            "panic" => FaultMode::Panic,
            other => anyhow::bail!("fault spec '{s}': unknown mode '{other}' (kill|torn|panic)"),
        };
        Ok(FaultSpec {
            point: point.to_string(),
            at,
            mode,
        })
    }
}

/// Seeded registry of armed fault points with per-point hit counters.
#[derive(Debug)]
pub struct FaultInjector {
    specs: Vec<FaultSpec>,
    hits: Mutex<HashMap<String, u64>>,
    /// Soft faults return `Err` instead of exiting the process.
    soft: bool,
    /// Seeds the torn-write truncation length.
    seed: u64,
}

impl FaultInjector {
    pub fn new(specs: Vec<FaultSpec>, soft: bool, seed: u64) -> FaultInjector {
        FaultInjector {
            specs,
            hits: Mutex::new(HashMap::new()),
            soft,
            seed,
        }
    }

    /// Parse a comma/whitespace-free CLI list: one `--inject-fault` value
    /// per spec, already split by the caller.
    pub fn from_args(raw: &[String], soft: bool, seed: u64) -> crate::Result<FaultInjector> {
        let specs = raw
            .iter()
            .map(|s| FaultSpec::parse(s))
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(FaultInjector::new(specs, soft, seed))
    }

    pub fn is_soft(&self) -> bool {
        self.soft
    }

    /// Record one hit of `point`; return the armed mode if a spec fires
    /// on exactly this hit. Counters survive retries, so `point@n` means
    /// the n-th dynamic hit over the whole process/session lifetime.
    pub fn check(&self, point: &str) -> Option<FaultMode> {
        let mut hits = crate::util::sync::lock_unpoisoned(&self.hits);
        let count = hits.entry(point.to_string()).or_insert(0);
        *count += 1;
        let now = *count;
        self.specs
            .iter()
            .find(|s| s.point == point && s.at == now)
            .map(|s| s.mode)
    }

    /// Hit `point` and deliver any armed fault. `Kill` and `Torn` both
    /// die here (torn-write callers truncate *before* calling `hit`, via
    /// [`FaultInjector::torn_len`] + [`FaultInjector::check`]); `Panic`
    /// panics with a recognizable message.
    pub fn hit(&self, point: &str) -> crate::Result<()> {
        match self.check(point) {
            None => Ok(()),
            Some(FaultMode::Panic) => panic!("fault injected: {point} (panic)"),
            Some(mode) => self.die(point, mode),
        }
    }

    /// Deliver a kill-class fault that was already detected via `check`.
    pub fn die(&self, point: &str, mode: FaultMode) -> crate::Result<()> {
        if self.soft {
            anyhow::bail!("fault injected: {point} ({})", mode.name());
        }
        eprintln!("fault injected: {point} ({}) — exiting", mode.name());
        std::process::exit(137);
    }

    /// Seeded truncation length for a torn write of `len` bytes: some
    /// strict prefix in `[0, len)`, varying with the point's hit count so
    /// repeated torn faults tear at different offsets.
    pub fn torn_len(&self, point: &str, len: usize) -> usize {
        if len == 0 {
            return 0;
        }
        let hits = crate::util::sync::lock_unpoisoned(&self.hits);
        let count = hits.get(point).copied().unwrap_or(0);
        let mut x = self
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(count)
            .wrapping_mul(0x2545_F491_4F6C_DD1D);
        x ^= x >> 33;
        (x % len as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_specs() {
        let s = FaultSpec::parse("pipeline.block_done@2").unwrap();
        assert_eq!(s.point, "pipeline.block_done");
        assert_eq!(s.at, 2);
        assert_eq!(s.mode, FaultMode::Kill);
        let s = FaultSpec::parse("checkpoint.append@1:torn").unwrap();
        assert_eq!(s.mode, FaultMode::Torn);
        let s = FaultSpec::parse("pipeline.layer_round@7:panic").unwrap();
        assert_eq!(s.mode, FaultMode::Panic);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in ["", "p", "p@", "p@0", "p@x", "@1", "p@1:frob"] {
            assert!(FaultSpec::parse(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn fires_on_exact_hit_only() {
        let f = FaultInjector::new(
            vec![FaultSpec::parse("p@3").unwrap()],
            true,
            7,
        );
        assert!(f.hit("p").is_ok());
        assert!(f.hit("q").is_ok()); // other points independent
        assert!(f.hit("p").is_ok());
        let err = f.hit("p").unwrap_err().to_string();
        assert!(err.contains("fault injected: p (kill)"), "{err}");
        // Past the armed hit: quiet again.
        assert!(f.hit("p").is_ok());
    }

    #[test]
    fn panic_mode_panics_even_when_soft() {
        let f = FaultInjector::new(
            vec![FaultSpec::parse("w@1:panic").unwrap()],
            true,
            7,
        );
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = f.hit("w");
        }));
        assert!(caught.is_err());
    }

    #[test]
    fn torn_len_is_deterministic_and_strict_prefix() {
        let f = FaultInjector::new(Vec::new(), true, 42);
        let g = FaultInjector::new(Vec::new(), true, 42);
        for len in [1usize, 2, 17, 1024] {
            let a = f.torn_len("checkpoint.append", len);
            assert_eq!(a, g.torn_len("checkpoint.append", len));
            assert!(a < len, "torn length must drop at least one byte");
        }
        assert_eq!(f.torn_len("x", 0), 0);
    }

    #[test]
    fn torn_len_varies_with_hit_count() {
        let f = FaultInjector::new(Vec::new(), true, 42);
        let before = f.torn_len("p", 1 << 20);
        let _ = f.check("p");
        let _ = f.check("p");
        let after = f.torn_len("p", 1 << 20);
        assert_ne!(before, after);
    }
}
