//! Poison-tolerant synchronization helpers for the serving path.
//!
//! Every mutex in the coordinator/engine layer protects state that stays
//! structurally valid across a panic (bounded queues, scratch buffers,
//! response handles, pool tables): a panicking holder never leaves a
//! half-written invariant behind, it only abandons work. Recovering the
//! guard and continuing is therefore strictly better for availability
//! than cascading the poison into every worker thread as a second panic.

use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// [`Condvar::wait_timeout`] with the same poison recovery as
/// [`lock_unpoisoned`]. The timeout result is dropped: callers here
/// re-check their predicate under the lock regardless of why they woke.
pub fn wait_timeout_unpoisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, dur) {
        Ok((g, _timeout)) => g,
        Err(e) => e.into_inner().0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn recovers_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock_unpoisoned(&m), 7);
        *lock_unpoisoned(&m) = 9;
        assert_eq!(*lock_unpoisoned(&m), 9);
    }

    #[test]
    fn wait_timeout_passes_guard_through() {
        let m = Mutex::new(1u32);
        let cv = Condvar::new();
        let g = lock_unpoisoned(&m);
        let g = wait_timeout_unpoisoned(&cv, g, Duration::from_millis(1));
        assert_eq!(*g, 1);
    }
}
