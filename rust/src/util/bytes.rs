//! Little-endian binary reader/writer for artifact formats (checkpoints,
//! packed quantized layers). No `serde` offline; formats are versioned by
//! magic+u32 headers at the call sites.

/// Append-only little-endian writer.
#[derive(Default)]
pub struct Writer {
    pub buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    /// Length-prefixed UTF-8 string.
    pub fn string(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.bytes(s.as_bytes());
    }

    pub fn f32s(&mut self, xs: &[f32]) {
        self.u64(xs.len() as u64);
        for &x in xs {
            self.f32(x);
        }
    }

    pub fn f64s(&mut self, xs: &[f64]) {
        self.u64(xs.len() as u64);
        for &x in xs {
            self.f64(x);
        }
    }
}

/// Cursor-based little-endian reader.
pub struct Reader<'a> {
    pub buf: &'a [u8],
    pub pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| anyhow::anyhow!("corrupt length {n} at offset {}", self.pos))?;
        if end > self.buf.len() {
            anyhow::bail!("truncated input: need {n} bytes at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub fn u8(&mut self) -> crate::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> crate::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> crate::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> crate::Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> crate::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn bytes(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        self.take(n)
    }

    pub fn string(&mut self) -> crate::Result<String> {
        let n = self.u32()? as usize;
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }

    pub fn f32s(&mut self) -> crate::Result<Vec<f32>> {
        let n = self.u64()? as usize;
        // Checked: a corrupt length prefix must produce a clean error,
        // not an overflow-wrapped short read.
        let nbytes = n
            .checked_mul(4)
            .ok_or_else(|| anyhow::anyhow!("corrupt f32 array length {n}"))?;
        let raw = self.take(nbytes)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn f64s(&mut self) -> crate::Result<Vec<f64>> {
        let n = self.u64()? as usize;
        let nbytes = n
            .checked_mul(8)
            .ok_or_else(|| anyhow::anyhow!("corrupt f64 array length {n}"))?;
        let raw = self.take(nbytes)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEADBEEF);
        w.u64(u64::MAX - 3);
        w.f32(1.5);
        w.f64(-2.25);
        w.string("héllo");
        w.f32s(&[1.0, 2.0]);
        w.f64s(&[3.0]);
        let mut r = Reader::new(&w.buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.f64().unwrap(), -2.25);
        assert_eq!(r.string().unwrap(), "héllo");
        assert_eq!(r.f32s().unwrap(), vec![1.0, 2.0]);
        assert_eq!(r.f64s().unwrap(), vec![3.0]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncation_errors() {
        let w = {
            let mut w = Writer::new();
            w.u32(5);
            w
        };
        let mut r = Reader::new(&w.buf);
        assert!(r.u64().is_err());
    }
}
