//! CRC-32 (IEEE 802.3, the zlib/gzip polynomial) — the integrity footer of
//! `.qz` v2 containers. Table-driven, one lookup per byte; the table is
//! built at compile time so there is no init path or dependency.

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 {
                (crc >> 1) ^ 0xEDB8_8320
            } else {
                crc >> 1
            };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

const TABLE: [u32; 256] = build_table();

/// CRC-32 of `data` (initial value 0xFFFFFFFF, final XOR 0xFFFFFFFF —
/// matches zlib's `crc32(0, data)`).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard check value for "123456789" and a couple of anchors.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn detects_any_single_byte_flip() {
        let data: Vec<u8> = (0..255u8).collect();
        let base = crc32(&data);
        for i in 0..data.len() {
            let mut bad = data.clone();
            bad[i] ^= 0x40;
            assert_ne!(crc32(&bad), base, "flip at {i} undetected");
        }
    }

    #[test]
    fn detects_truncation() {
        let data = vec![7u8; 1024];
        let base = crc32(&data);
        assert_ne!(crc32(&data[..1023]), base);
    }
}
