//! Minimal leveled logger. Level from `QUIP_LOG` (error|warn|info|debug),
//! default `info`. Thread-safe via stderr's line buffering.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

fn level() -> Level {
    let v = LEVEL.load(Ordering::Relaxed);
    if v == 255 {
        let lv = match std::env::var("QUIP_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            _ => Level::Info,
        };
        LEVEL.store(lv as u8, Ordering::Relaxed);
        lv
    } else {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            _ => Level::Debug,
        }
    }
}

/// Override the log level programmatically (tests, CLI `--quiet`).
pub fn set_level(lv: Level) {
    LEVEL.store(lv as u8, Ordering::Relaxed);
}

pub fn log(lv: Level, module: &str, msg: &str) {
    if lv <= level() {
        let t = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0);
        let tag = match lv {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{t:.3}] {tag} {module}: {msg}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*)) };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), &format!($($arg)*)) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*)) };
}
