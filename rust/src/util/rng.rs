//! Deterministic pseudo-randomness: xoshiro256** + SplitMix64 seeding,
//! uniform / Gaussian sampling, shuffles and permutations.
//!
//! QuIP's incoherence processing is *seeded*: the orthogonal factors are
//! regenerated from a stored 64-bit seed at load time (storing them would
//! defeat compression), so the generator must be stable across the
//! quantizer (Rust), the artifact loader (Rust) and tests.

/// SplitMix64 — used to expand a 64-bit seed into xoshiro state and to
/// derive independent substreams.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from Box–Muller.
    spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent substream keyed by `stream`. Deterministic:
    /// `fork` of equal (seed, stream) pairs always agree.
    pub fn fork(&self, stream: u64) -> Rng {
        // Mix the current state with the stream id through SplitMix64.
        let mut sm = self.s[0] ^ self.s[2] ^ stream.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) with 24 bits of precision.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Bernoulli with probability p.
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (polar form), with spare caching.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A uniformly random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_equal_seeds() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fork_is_deterministic_and_independent() {
        let root = Rng::new(7);
        let mut a = root.fork(1);
        let mut b = root.fork(1);
        let mut c = root.fork(2);
        assert_eq!(a.next_u64(), b.next_u64());
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_is_centered() {
        let mut rng = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(4);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut rng = Rng::new(6);
        let p = rng.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut rng = Rng::new(8);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[rng.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.4, "ratio={ratio}");
    }
}
