//! Crash-safe filesystem helpers.
//!
//! Every durable artifact in the tree (`.qz` models, `QCKP` checkpoints,
//! token streams, result JSON, Chrome traces, the `.qzp` quantization
//! journal manifest) goes through [`atomic_write`]: the bytes land in a
//! sibling temp file, are fsynced, and are renamed over the destination
//! in one step. A process killed mid-save therefore leaves either the old
//! file or the new file — never a truncated hybrid that later loads as
//! "corrupt artifact". The preflight `atomic-writes` check enforces that
//! non-test code never calls bare `std::fs::write` outside this module.

use std::io::Write;
use std::path::Path;

/// Write `data` to `path` atomically: create parent directories, write
/// `path.tmp.<pid>`, fsync, then rename over `path`. On any error the
/// temp file is removed and `path` is left untouched.
pub fn atomic_write(path: &Path, data: &[u8]) -> crate::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let file_name = path
        .file_name()
        .ok_or_else(|| anyhow::anyhow!("atomic_write: path {path:?} has no file name"))?;
    // Pid-suffixed so concurrent writers of the same artifact never
    // clobber each other's temp file mid-flight.
    let tmp = path.with_file_name(format!(
        "{}.tmp.{}",
        file_name.to_string_lossy(),
        std::process::id()
    ));
    let write = || -> crate::Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(data)?;
        // Durability barrier: the rename below must never expose a file
        // whose bytes are still in the page cache only.
        f.sync_all()?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    };
    write().map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        anyhow::anyhow!("atomic write of {path:?} failed: {e}")
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("quip_fsx_{tag}"));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn writes_and_replaces() {
        let dir = tmpdir("basic");
        let path = dir.join("a.bin");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second — longer payload").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second — longer payload");
    }

    #[test]
    fn creates_missing_parents() {
        let dir = tmpdir("parents").join("x").join("y");
        let path = dir.join("deep.bin");
        atomic_write(&path, b"ok").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"ok");
    }

    #[test]
    fn no_temp_file_left_behind() {
        let dir = tmpdir("clean");
        let path = dir.join("b.bin");
        atomic_write(&path, &vec![7u8; 4096]).unwrap();
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp files left: {leftovers:?}");
    }

    #[test]
    fn directory_target_is_clean_error() {
        let dir = tmpdir("direrr");
        let err = atomic_write(&dir, b"x").unwrap_err().to_string();
        assert!(err.contains("atomic write"), "{err}");
        // The original directory is intact.
        assert!(dir.is_dir());
    }
}
