//! Mini property-testing harness (no `proptest` offline) plus shared
//! random-structure generators used across the test suite.

use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Run `body` for `cases` seeded cases. On panic the failing case index and
/// seed are reported so the case can be replayed deterministically.
pub fn propcheck<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, cases: usize, body: F) {
    for case in 0..cases {
        let seed = 0x9E37_79B9u64
            .wrapping_mul(case as u64 + 1)
            .wrapping_add(name.len() as u64);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            body(&mut rng);
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("propcheck '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Random dense matrix with entries Unif[-1, 1).
pub fn random_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.uniform(-1.0, 1.0))
}

/// Random symmetric positive definite matrix: AᵀA/n + ridge·I.
pub fn random_spd(rng: &mut Rng, n: usize, ridge: f64) -> Mat {
    let a = random_mat(rng, n + 4, n);
    let mut h = crate::linalg::gemm::gram(&a).scale(1.0 / (n + 4) as f64);
    for i in 0..n {
        h[(i, i)] += ridge;
    }
    h
}

/// Random PSD matrix of rank ≤ k (models the paper's low-rank Hessians).
pub fn random_low_rank_psd(rng: &mut Rng, n: usize, k: usize) -> Mat {
    let a = random_mat(rng, k, n);
    crate::linalg::gemm::gram(&a).scale(1.0 / k as f64)
}

/// Random calibration-style Hessian: low-rank + small ridge, like observed
/// LLM proxy Hessians (Fig 1 / Table 6).
pub fn random_hessian(rng: &mut Rng, n: usize, k: usize, ridge: f64) -> Mat {
    let mut h = random_low_rank_psd(rng, n, k);
    for i in 0..n {
        h[(i, i)] += ridge;
    }
    h
}

/// Assert scalar closeness with a readable message.
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64) {
    assert!(
        (a - b).abs() <= tol,
        "expected {a} ≈ {b} (tol {tol}, diff {})",
        (a - b).abs()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn propcheck_runs_all_cases() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        propcheck("count", 17, |_rng| {
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(COUNT.load(Ordering::SeqCst), 17);
    }

    #[test]
    #[should_panic(expected = "propcheck 'boom' failed")]
    fn propcheck_reports_failure() {
        propcheck("boom", 5, |rng| {
            let x = rng.next_f64();
            assert!(x < 2.0); // always true
            if x >= 0.0 {
                panic!("intentional");
            }
        });
    }

    #[test]
    fn random_spd_is_spd() {
        propcheck("spd", 5, |rng| {
            let h = random_spd(rng, 10, 1e-3);
            // symmetric
            for i in 0..10 {
                for j in 0..10 {
                    assert!((h[(i, j)] - h[(j, i)]).abs() < 1e-12);
                }
            }
            // positive definite: Cholesky succeeds
            assert!(crate::linalg::chol::cholesky(&h).is_ok());
        });
    }

    #[test]
    fn low_rank_has_low_rank() {
        let mut rng = Rng::new(5);
        let h = random_low_rank_psd(&mut rng, 16, 3);
        let e = crate::linalg::eigen::eigen_sym(&h, 1e-13, 60);
        let nonzero = e.values.iter().filter(|&&l| l > 1e-9).count();
        assert!(nonzero <= 3);
    }
}
