//! Quantized model artifacts: the `.qz` container (config + per-layer
//! packed codes) and application of dequantized weights onto a
//! [`Transformer`] for evaluation.
//!
//! ## Container layout
//!
//! ```text
//! v3 (current):  magic u32 | version=3 u32 | config json | bits u32 |
//!                recipe str | layer count u32 | layers… | crc32 u32
//! v2 (legacy):   magic u32 | version=2 u32 | …same layout, layer
//!                records lack the code-layout tag
//! v1 (legacy):   magic u32 | version=1 u32 | …same, no crc footer
//! ```
//!
//! v2 layer records carry the incoherence-transform kind
//! ([`crate::linalg::TransformKind`]) after the `incoherent` flag; v1
//! layers predate the transform subsystem and load as `Kron`. v3 layer
//! records additionally carry a [`crate::quant::CodeLayout`] tag —
//! scalar bit-packed codes, or vector-codebook indices plus the seed
//! that regenerates the E8-style codebook; v1/v2 layers load as scalar.
//! The v2+ trailing CRC-32 covers every preceding byte, so truncated or
//! corrupted artifacts fail with a clean error before any layer parsing
//! happens.

use super::config::ModelConfig;
use super::transformer::Transformer;
use crate::quant::packed::{FORMAT_V1, FORMAT_V2, FORMAT_V3, QuantizedLayer};
use crate::util::bytes::{Reader, Writer};
use crate::util::crc32::crc32;
use crate::util::json::Json;

pub const QZ_MAGIC: u32 = 0x5A51_5051; // "QPQZ" LE-ish
/// Current container version written by [`QuantizedModel::save`].
pub const QZ_VERSION: u32 = FORMAT_V3;

/// A fully quantized model: every linear layer's packed codes + metadata.
pub struct QuantizedModel {
    pub config: ModelConfig,
    pub bits: u32,
    /// Method/processing description (informational, goes in reports).
    pub recipe: String,
    pub layers: Vec<QuantizedLayer>,
}

impl QuantizedModel {
    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        let buf = self.to_bytes(QZ_VERSION);
        crate::util::fsx::atomic_write(path, &buf)
    }

    /// Serialize into an in-memory container of the given version (v1/v2
    /// are exposed so back-compat tests can author pre-subsystem
    /// artifacts).
    ///
    /// Panics if `version` is v1 and any layer uses a non-Kron transform,
    /// or `version` < v3 and any layer stores vector-codebook indices
    /// (see [`QuantizedLayer::serialize_version`]): the older layouts
    /// have no field for either, so writing such a model would silently
    /// reload wrong and dequantize to garbage.
    pub fn to_bytes(&self, version: u32) -> Vec<u8> {
        assert!((FORMAT_V1..=FORMAT_V3).contains(&version));
        let mut w = Writer::new();
        w.u32(QZ_MAGIC);
        w.u32(version);
        w.string(&self.config.to_json().to_string());
        w.u32(self.bits);
        w.string(&self.recipe);
        w.u32(self.layers.len() as u32);
        for l in &self.layers {
            l.serialize_version(&mut w, version);
        }
        if version >= FORMAT_V2 {
            let crc = crc32(&w.buf);
            w.u32(crc);
        }
        w.buf
    }

    pub fn load(path: &std::path::Path) -> crate::Result<QuantizedModel> {
        let raw = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading quantized model {path:?}: {e}"))?;
        Self::from_bytes(&raw)
            .map_err(|e| anyhow::anyhow!("loading quantized model {path:?}: {e}"))
    }

    pub fn from_bytes(raw: &[u8]) -> crate::Result<QuantizedModel> {
        anyhow::ensure!(raw.len() >= 8, "truncated .qz: {} bytes", raw.len());
        let mut r = Reader::new(raw);
        anyhow::ensure!(r.u32()? == QZ_MAGIC, "bad .qz magic");
        let version = r.u32()?;
        anyhow::ensure!(
            (FORMAT_V1..=FORMAT_V3).contains(&version),
            "unsupported .qz version {version} (this build reads v1-v{QZ_VERSION})"
        );
        let body = if version >= FORMAT_V2 {
            // Verify the CRC footer before parsing anything: a truncated
            // or bit-flipped file fails here with a clean error.
            anyhow::ensure!(raw.len() >= 12, "truncated .qz: no CRC footer");
            let (payload, tail) = raw.split_at(raw.len() - 4);
            let stored = u32::from_le_bytes(tail.try_into().unwrap());
            let actual = crc32(payload);
            anyhow::ensure!(
                stored == actual,
                "corrupt .qz artifact: CRC mismatch (stored {stored:08x}, \
                 computed {actual:08x}) — file truncated or damaged"
            );
            payload
        } else {
            raw
        };
        let mut r = Reader::new(body);
        r.pos = 8; // past magic + version, already validated
        let config = ModelConfig::from_json(&Json::parse(&r.string()?)?)?;
        let bits = r.u32()?;
        let recipe = r.string()?;
        let n = r.u32()? as usize;
        let mut layers = Vec::new();
        for i in 0..n {
            layers.push(
                QuantizedLayer::deserialize(&mut r, version)
                    .map_err(|e| anyhow::anyhow!("layer {i}/{n}: {e}"))?,
            );
        }
        anyhow::ensure!(
            r.remaining() == 0,
            "corrupt .qz artifact: {} trailing bytes after {n} layers",
            r.remaining()
        );
        Ok(QuantizedModel {
            config,
            bits,
            recipe,
            layers,
        })
    }

    pub fn layer(&self, name: &str) -> crate::Result<&QuantizedLayer> {
        self.layers
            .iter()
            .find(|l| l.name == name)
            .ok_or_else(|| anyhow::anyhow!("quantized model missing layer '{name}'"))
    }

    /// Dequantize every layer into an existing fp32 model (whose
    /// non-linear weights — embeddings, LNs, biases — stay fp16/fp32, as
    /// in the paper's setup).
    pub fn apply_to(&self, model: &mut Transformer) -> crate::Result<()> {
        anyhow::ensure!(
            model.cfg == self.config,
            "model/quantized config mismatch ({} vs {})",
            model.cfg.name,
            self.config.name
        );
        for l in &self.layers {
            let wd = l.dequantize();
            let data: Vec<f32> = wd.data.iter().map(|&x| x as f32).collect();
            model.set_weight(&l.name, data)?;
        }
        Ok(())
    }

    /// Average storage bits per quantized weight (incl. metadata).
    pub fn bits_per_weight(&self) -> f64 {
        let total_params: usize = self.layers.iter().map(|l| l.m * l.n).sum();
        let mut w = Writer::new();
        for l in &self.layers {
            l.serialize(&mut w);
        }
        (w.buf.len() as f64 * 8.0) / total_params.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::model::weights::Checkpoint;
    use crate::quant::{quantize_layer, Method, Processing, QuantConfig};
    use crate::util::testkit::random_hessian;

    fn quantize_tiny(bits: u32) -> (QuantizedModel, Transformer) {
        let cfg = ModelConfig::sized("t", 32, 2, 4, 64);
        let ck = Checkpoint::random(&cfg, 11);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let mut rng = crate::util::rng::Rng::new(5);
        let mut layers = Vec::new();
        for spec in cfg.linear_specs() {
            let wdata = model.get_weight(&spec.name).unwrap();
            let w = Mat {
                rows: spec.out_dim,
                cols: spec.in_dim,
                data: wdata.iter().map(|&x| x as f64).collect(),
            };
            let h = random_hessian(&mut rng, spec.in_dim, spec.in_dim / 4, 1e-3);
            let qcfg = QuantConfig {
                bits,
                method: Method::Ldlq,
                processing: Processing::incoherent(),
                ..Default::default()
            };
            let out = quantize_layer(&w, &h, &qcfg, 99);
            layers.push(crate::quant::packed::QuantizedLayer::from_codes(
                &spec.name, &out.codes, bits, out.post,
            ));
        }
        (
            QuantizedModel {
                config: cfg,
                bits,
                recipe: "ldlq+incp".into(),
                layers,
            },
            model,
        )
    }

    #[test]
    fn save_load_apply_roundtrip() {
        let (qm, mut model) = quantize_tiny(4);
        let dir = std::env::temp_dir().join("quip_qz_test");
        let path = dir.join("t.qz");
        qm.save(&path).unwrap();
        let loaded = QuantizedModel::load(&path).unwrap();
        assert_eq!(loaded.layers.len(), qm.layers.len());
        let before = model.forward(&[1, 2, 3], None);
        loaded.apply_to(&mut model).unwrap();
        let after = model.forward(&[1, 2, 3], None);
        assert_ne!(before, after);
        assert!(after.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn v1_container_still_loads() {
        // Acceptance: a `.qz` written before the transform subsystem (v1
        // layout, no transform byte, no CRC footer) must keep loading,
        // with Kron implied on every layer.
        let (qm, _) = quantize_tiny(2);
        let v1 = qm.to_bytes(crate::quant::packed::FORMAT_V1);
        let v2 = qm.to_bytes(crate::quant::packed::FORMAT_V2);
        // v2 = v1 + one transform byte per layer + 4-byte CRC footer.
        assert_eq!(v2.len(), v1.len() + qm.layers.len() + 4);
        let loaded = QuantizedModel::from_bytes(&v1).unwrap();
        assert_eq!(loaded.layers.len(), qm.layers.len());
        for (a, b) in loaded.layers.iter().zip(&qm.layers) {
            assert_eq!(a.post.transform, crate::linalg::TransformKind::Kron);
            assert_eq!(a.dequantize().data, b.dequantize().data);
        }
    }

    #[test]
    fn v2_container_still_loads() {
        // A `.qz` written before the codebook subsystem (v2 layout, no
        // code-layout tag) must keep loading, as scalar on every layer.
        let (qm, _) = quantize_tiny(2);
        let v2 = qm.to_bytes(crate::quant::packed::FORMAT_V2);
        let v3 = qm.to_bytes(crate::quant::packed::FORMAT_V3);
        // v3 = v2 + one (scalar) layout byte per layer.
        assert_eq!(v3.len(), v2.len() + qm.layers.len());
        let loaded = QuantizedModel::from_bytes(&v2).unwrap();
        assert_eq!(loaded.layers.len(), qm.layers.len());
        for (a, b) in loaded.layers.iter().zip(&qm.layers) {
            assert_eq!(a.layout, crate::quant::CodeLayout::Scalar);
            assert_eq!(a.dequantize().data, b.dequantize().data);
        }
        // Unknown future versions fail loudly.
        let mut v9 = v3.clone();
        v9[4] = 9;
        let err = QuantizedModel::from_bytes(&v9).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn vq_model_roundtrips_through_v3_container() {
        // Acceptance: quantize with the vq rounder → save → load →
        // dequantize identically, with the codebook seed preserved.
        let cfg = ModelConfig::sized("t", 32, 2, 4, 64);
        let ck = Checkpoint::random(&cfg, 11);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let mut rng = crate::util::rng::Rng::new(5);
        let mut layers = Vec::new();
        for spec in cfg.linear_specs() {
            let wdata = model.get_weight(&spec.name).unwrap();
            let w = Mat {
                rows: spec.out_dim,
                cols: spec.in_dim,
                data: wdata.iter().map(|&x| x as f64).collect(),
            };
            let h = random_hessian(&mut rng, spec.in_dim, spec.in_dim / 4, 1e-3);
            let qcfg = QuantConfig {
                bits: 2,
                method: Method::Vq,
                processing: Processing::incoherent(),
                ..Default::default()
            };
            let out = quantize_layer(&w, &h, &qcfg, 99);
            let vq = out.vq.expect("vq rounder emits indices");
            layers.push(crate::quant::packed::QuantizedLayer::from_vq_indices(
                &spec.name, w.rows, w.cols, 2, &vq, out.post,
            ));
        }
        let qm = QuantizedModel {
            config: cfg,
            bits: 2,
            recipe: "vq+incp-kron".into(),
            layers,
        };
        let bytes = qm.to_bytes(QZ_VERSION);
        let loaded = QuantizedModel::from_bytes(&bytes).unwrap();
        for (a, b) in loaded.layers.iter().zip(&qm.layers) {
            assert_eq!(a.layout, b.layout);
            assert!(matches!(a.layout, crate::quant::CodeLayout::Vq { .. }));
            assert_eq!(a.dequantize().data, b.dequantize().data);
        }
        // v1/v2 cannot represent vq layers.
        let qm2 = loaded;
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            qm2.to_bytes(crate::quant::packed::FORMAT_V2)
        }));
        assert!(caught.is_err(), "v2 write of vq layers must refuse");
    }

    #[test]
    fn corrupt_v2_container_is_clean_crc_error() {
        let (qm, _) = quantize_tiny(2);
        let good = qm.to_bytes(QZ_VERSION);
        assert!(QuantizedModel::from_bytes(&good).is_ok());
        // Flip one byte anywhere in the payload: CRC must catch it.
        for at in [9usize, good.len() / 2, good.len() - 5] {
            let mut bad = good.clone();
            bad[at] ^= 0x10;
            let err = QuantizedModel::from_bytes(&bad).unwrap_err().to_string();
            assert!(err.contains("CRC"), "byte {at}: unexpected error: {err}");
        }
        // Truncations at every region: clean errors, never a panic.
        for cut in [0usize, 4, 7, 11, good.len() / 3, good.len() - 1] {
            assert!(
                QuantizedModel::from_bytes(&good[..cut]).is_err(),
                "cut={cut} should fail"
            );
        }
        // Trailing garbage after a valid container: rejected (the CRC
        // covers len-4 bytes, so appended bytes shift the footer).
        let mut padded = good.clone();
        padded.extend_from_slice(&[0u8; 16]);
        assert!(QuantizedModel::from_bytes(&padded).is_err());
    }

    #[test]
    fn hadamard_model_roundtrips_through_v2_container() {
        let cfg = ModelConfig::sized("t", 32, 2, 4, 64);
        let ck = Checkpoint::random(&cfg, 11);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let mut rng = crate::util::rng::Rng::new(5);
        let mut layers = Vec::new();
        for spec in cfg.linear_specs() {
            let wdata = model.get_weight(&spec.name).unwrap();
            let w = Mat {
                rows: spec.out_dim,
                cols: spec.in_dim,
                data: wdata.iter().map(|&x| x as f64).collect(),
            };
            let h = random_hessian(&mut rng, spec.in_dim, spec.in_dim / 4, 1e-3);
            let qcfg = QuantConfig {
                bits: 2,
                method: Method::Ldlq,
                processing: Processing::incoherent_with(crate::linalg::TransformKind::Hadamard),
                ..Default::default()
            };
            let out = quantize_layer(&w, &h, &qcfg, 99);
            layers.push(crate::quant::packed::QuantizedLayer::from_codes(
                &spec.name, &out.codes, 2, out.post,
            ));
        }
        let qm = QuantizedModel {
            config: cfg,
            bits: 2,
            recipe: "ldlq+incp-rht".into(),
            layers,
        };
        let bytes = qm.to_bytes(QZ_VERSION);
        let loaded = QuantizedModel::from_bytes(&bytes).unwrap();
        for (a, b) in loaded.layers.iter().zip(&qm.layers) {
            assert_eq!(a.post.transform, crate::linalg::TransformKind::Hadamard);
            assert_eq!(a.dequantize().data, b.dequantize().data);
        }
    }

    #[test]
    fn four_bit_quantization_preserves_function_roughly() {
        // 4-bit + IncP should keep outputs close to fp on a random model.
        let (qm, mut model) = quantize_tiny(4);
        let before = model.forward(&[5, 6, 7, 8], None);
        qm.apply_to(&mut model).unwrap();
        let after = model.forward(&[5, 6, 7, 8], None);
        let num: f64 = before
            .iter()
            .zip(&after)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        let den: f64 = before.iter().map(|a| (*a as f64).powi(2)).sum();
        let rel = (num / den.max(1e-12)).sqrt();
        assert!(rel < 0.5, "relative logit error {rel}");
    }

    #[test]
    fn bits_per_weight_tracks_bits() {
        let (q2, _) = quantize_tiny(2);
        let (q4, _) = quantize_tiny(4);
        assert!(q2.bits_per_weight() < q4.bits_per_weight());
        assert!(q2.bits_per_weight() < 4.5, "bpw2={}", q2.bits_per_weight());
    }

    #[test]
    fn config_mismatch_rejected() {
        let (qm, _) = quantize_tiny(2);
        let other = ModelConfig::sized("other", 64, 2, 4, 128);
        let mut m2 =
            Transformer::from_checkpoint(&Checkpoint::random(&other, 1)).unwrap();
        assert!(qm.apply_to(&mut m2).is_err());
    }
}
