//! Quantized model artifacts: the `.qz` container (config + per-layer
//! packed codes) and application of dequantized weights onto a
//! [`Transformer`] for evaluation.

use super::config::ModelConfig;
use super::transformer::Transformer;
use crate::quant::packed::QuantizedLayer;
use crate::util::bytes::{Reader, Writer};
use crate::util::json::Json;

pub const QZ_MAGIC: u32 = 0x5A51_5051; // "QPQZ" LE-ish

/// A fully quantized model: every linear layer's packed codes + metadata.
pub struct QuantizedModel {
    pub config: ModelConfig,
    pub bits: u32,
    /// Method/processing description (informational, goes in reports).
    pub recipe: String,
    pub layers: Vec<QuantizedLayer>,
}

impl QuantizedModel {
    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        let mut w = Writer::new();
        w.u32(QZ_MAGIC);
        w.u32(1);
        w.string(&self.config.to_json().to_string());
        w.u32(self.bits);
        w.string(&self.recipe);
        w.u32(self.layers.len() as u32);
        for l in &self.layers {
            l.serialize(&mut w);
        }
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, &w.buf)?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> crate::Result<QuantizedModel> {
        let raw = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading quantized model {path:?}: {e}"))?;
        let mut r = Reader::new(&raw);
        anyhow::ensure!(r.u32()? == QZ_MAGIC, "bad .qz magic");
        anyhow::ensure!(r.u32()? == 1, "unsupported .qz version");
        let config = ModelConfig::from_json(&Json::parse(&r.string()?)?)?;
        let bits = r.u32()?;
        let recipe = r.string()?;
        let n = r.u32()? as usize;
        let mut layers = Vec::with_capacity(n);
        for _ in 0..n {
            layers.push(QuantizedLayer::deserialize(&mut r)?);
        }
        Ok(QuantizedModel {
            config,
            bits,
            recipe,
            layers,
        })
    }

    pub fn layer(&self, name: &str) -> crate::Result<&QuantizedLayer> {
        self.layers
            .iter()
            .find(|l| l.name == name)
            .ok_or_else(|| anyhow::anyhow!("quantized model missing layer '{name}'"))
    }

    /// Dequantize every layer into an existing fp32 model (whose
    /// non-linear weights — embeddings, LNs, biases — stay fp16/fp32, as
    /// in the paper's setup).
    pub fn apply_to(&self, model: &mut Transformer) -> crate::Result<()> {
        anyhow::ensure!(
            model.cfg == self.config,
            "model/quantized config mismatch ({} vs {})",
            model.cfg.name,
            self.config.name
        );
        for l in &self.layers {
            let wd = l.dequantize();
            let data: Vec<f32> = wd.data.iter().map(|&x| x as f32).collect();
            model.set_weight(&l.name, data)?;
        }
        Ok(())
    }

    /// Average storage bits per quantized weight (incl. metadata).
    pub fn bits_per_weight(&self) -> f64 {
        let total_params: usize = self.layers.iter().map(|l| l.m * l.n).sum();
        let mut w = Writer::new();
        for l in &self.layers {
            l.serialize(&mut w);
        }
        (w.buf.len() as f64 * 8.0) / total_params.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::model::weights::Checkpoint;
    use crate::quant::{quantize_layer, Method, Processing, QuantConfig};
    use crate::util::testkit::random_hessian;

    fn quantize_tiny(bits: u32) -> (QuantizedModel, Transformer) {
        let cfg = ModelConfig::sized("t", 32, 2, 4, 64);
        let ck = Checkpoint::random(&cfg, 11);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let mut rng = crate::util::rng::Rng::new(5);
        let mut layers = Vec::new();
        for spec in cfg.linear_specs() {
            let wdata = model.get_weight(&spec.name).unwrap();
            let w = Mat {
                rows: spec.out_dim,
                cols: spec.in_dim,
                data: wdata.iter().map(|&x| x as f64).collect(),
            };
            let h = random_hessian(&mut rng, spec.in_dim, spec.in_dim / 4, 1e-3);
            let qcfg = QuantConfig {
                bits,
                method: Method::Ldlq,
                processing: Processing::incoherent(),
                ..Default::default()
            };
            let out = quantize_layer(&w, &h, &qcfg, 99);
            layers.push(crate::quant::packed::QuantizedLayer::from_codes(
                &spec.name, &out.codes, bits, out.post,
            ));
        }
        (
            QuantizedModel {
                config: cfg,
                bits,
                recipe: "ldlq+incp".into(),
                layers,
            },
            model,
        )
    }

    #[test]
    fn save_load_apply_roundtrip() {
        let (qm, mut model) = quantize_tiny(4);
        let dir = std::env::temp_dir().join("quip_qz_test");
        let path = dir.join("t.qz");
        qm.save(&path).unwrap();
        let loaded = QuantizedModel::load(&path).unwrap();
        assert_eq!(loaded.layers.len(), qm.layers.len());
        let before = model.forward(&[1, 2, 3], None);
        loaded.apply_to(&mut model).unwrap();
        let after = model.forward(&[1, 2, 3], None);
        assert_ne!(before, after);
        assert!(after.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn four_bit_quantization_preserves_function_roughly() {
        // 4-bit + IncP should keep outputs close to fp on a random model.
        let (qm, mut model) = quantize_tiny(4);
        let before = model.forward(&[5, 6, 7, 8], None);
        qm.apply_to(&mut model).unwrap();
        let after = model.forward(&[5, 6, 7, 8], None);
        let num: f64 = before
            .iter()
            .zip(&after)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        let den: f64 = before.iter().map(|a| (*a as f64).powi(2)).sum();
        let rel = (num / den.max(1e-12)).sqrt();
        assert!(rel < 0.5, "relative logit error {rel}");
    }

    #[test]
    fn bits_per_weight_tracks_bits() {
        let (q2, _) = quantize_tiny(2);
        let (q4, _) = quantize_tiny(4);
        assert!(q2.bits_per_weight() < q4.bits_per_weight());
        assert!(q2.bits_per_weight() < 4.5, "bpw2={}", q2.bits_per_weight());
    }

    #[test]
    fn config_mismatch_rejected() {
        let (qm, _) = quantize_tiny(2);
        let other = ModelConfig::sized("other", 64, 2, 4, 128);
        let mut m2 =
            Transformer::from_checkpoint(&Checkpoint::random(&other, 1)).unwrap();
        assert!(qm.apply_to(&mut m2).is_err());
    }
}
